"""Serving benchmark: continuous-streaming throughput vs sequential run().

Drives the :class:`~repro.runtime.cnn_serving.CnnServingEngine` over the
executable mini ResNet-18 (the 21-engine pipeline_throughput config) with
two workloads:

  * **closed loop** (saturation): a burst of mixed-size requests (1..4
    images each) submitted at once, ``credits`` microbatches in flight —
    the §V-A always-full pipeline.  Reported against the *sequential
    baseline*: the same requests run one at a time through warm
    ``CompiledPipeline.run()`` calls (one fused dispatch per request,
    blocking each).  The two sides are timed INTERLEAVED — each repeat
    runs sequential then serving back to back, and
    ``serving_speedup_x`` is the median of the per-pair ratios (the
    pipeline benchmark's scheme: host load spikes land on both sides of
    the ratio).  The acceptance bar is >= 1.5x with 4 in-flight
    credits; packing + double-buffering typically lands ~2x on the
    2-core CI shape (batching amortizes dispatch overhead AND the
    in-flight microbatches overlap on separate cores).
  * **open loop** (Poisson arrivals at ~60% of the measured closed-loop
    throughput): latency percentiles and queue depth under a live
    arrival process instead of a pre-filled queue.

Wall-clock numbers are interpret-mode Pallas on CPU — relative
comparison only, not an FPGA throughput claim; the deterministic
``hbm_words_per_image`` row joins the existing bench_diff Eq. 2 gate.

  PYTHONPATH=src python benchmarks/serving_throughput.py \
      [--requests N] [--repeats R] [--smoke] [--json BENCH_serving.json]

``--json`` writes the artifact CI uploads and diffs (bench_diff.py gates
``serving_images_per_s`` / ``serving_speedup_x`` at >5% regression, and
the measured ``admission_wait_fraction`` / ``dispatch_gap_fraction``
stall attribution under the wide wall-clock floor).  ``--trace`` writes
a Chrome Trace Event JSON of the final closed-loop serving repeat — load
it at https://ui.perfetto.dev or ``chrome://tracing`` to see admission
waits, packing, dispatches, in-flight microbatches and deliveries on
their own tracks.
"""
from __future__ import annotations

import argparse
import json
import statistics
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import compiler
from repro.configs.cnn import mini_resnet18
from repro.models.cnn import cnn_input_shape, init_cnn_params
from repro.obs import Tracer

MICROBATCH = 16
CREDITS = 4
REQ_SIZES = (1, 2, 1, 4)              # mixed request sizes, cycled


def make_requests(cfg, n_requests: int) -> List[np.ndarray]:
    rng = np.random.default_rng(0)
    shape = cnn_input_shape(cfg, 1)[1:]
    return [rng.integers(-127, 128, size=(REQ_SIZES[i % len(REQ_SIZES)],)
                         + shape, dtype=np.int16).astype(np.int8)
            for i in range(n_requests)]


def closed_loop_vs_sequential(cp, params, requests, repeats: int,
                              tracer: Optional[Tracer] = None) -> Dict:
    """Interleaved pairs: each repeat times the sequential baseline (one
    blocking warm ``run()`` per request, at the request's own batch
    size) then the saturated serving engine over the SAME requests; the
    speedup is the median of the per-pair ratios.  ``tracer`` (optional)
    records the LAST serving repeat only, so the traced repeat's spans
    line up with the reported throughput numbers."""
    ex = cp.executor()
    for n in sorted({len(r) for r in requests}):    # warm every shape
        jax.block_until_ready(ex.run(params, jnp.asarray(
            requests[0][:1].repeat(n, axis=0)))[0])
    with cp.serve(params, microbatch=MICROBATCH, credits=CREDITS) as eng:
        eng.serve(requests[:2])                     # warm the packed shape
    images = sum(len(r) for r in requests)
    seq, srv, ratios, report = [], [], [], None
    for rep_i in range(repeats):
        t0 = time.perf_counter()
        for r in requests:
            jax.block_until_ready(ex.run(params, jnp.asarray(r))[0])
        seq.append(images / (time.perf_counter() - t0))
        kw = {"tracer": tracer} if (
            tracer is not None and rep_i == repeats - 1) else {}
        with cp.serve(params, microbatch=MICROBATCH,
                      credits=CREDITS, **kw) as eng:
            t0 = time.perf_counter()
            _, report = eng.serve(requests)
            srv.append(images / (time.perf_counter() - t0))
        ratios.append(srv[-1] / seq[-1])
    return {"images_per_s": statistics.median(srv),
            "sequential_images_per_s": statistics.median(seq),
            "speedup": statistics.median(ratios), "report": report}


def open_loop(cp, params, requests, rate_images_per_s: float) -> Dict:
    """Poisson arrivals at ``rate_images_per_s`` offered load."""
    rng = np.random.default_rng(1)
    with cp.serve(params, microbatch=MICROBATCH, credits=CREDITS) as eng:
        for r in requests:
            time.sleep(float(rng.exponential(len(r) / rate_images_per_s)))
            eng.submit(r)
        eng.drain()
        report = eng.report()
    return {"report": report}


def bench(n_requests: int = 32, repeats: int = 3,
          tracer: Optional[Tracer] = None) -> List[Dict]:
    cfg = mini_resnet18(hw=8, width=16, stages=4)
    cp = compiler.compile(cfg, compiler.TPU_INTERPRET)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    requests = make_requests(cfg, n_requests)
    images = sum(len(r) for r in requests)

    closed = closed_loop_vs_sequential(cp, params, requests, repeats,
                                       tracer)
    rep = closed["report"]
    measured = rep.bandwidth_efficiency.get("measured", {})
    # the flat keys are the bench_diff gate surface; everything else
    # rides in the serialized report (no hand-rolled duplicate dicts)
    rows = [{
        "name": "serving/closed_loop",
        "net": cfg.name,
        "requests": n_requests,
        "images": images,
        "microbatch": MICROBATCH,
        "credits": CREDITS,
        "timing_repeats": repeats,
        "serving_images_per_s": round(closed["images_per_s"], 2),
        "sequential_images_per_s": round(
            closed["sequential_images_per_s"], 2),
        "serving_speedup_x": round(closed["speedup"], 2),
        "admission_wait_fraction": round(
            measured.get("admission_wait_fraction", 0.0), 4),
        "dispatch_gap_fraction": round(
            measured.get("dispatch_gap_fraction", 0.0), 4),
        "hbm_words_per_image": rep.hbm_words_per_image,
        "report": rep.to_dict(),
    }]

    target_rate = 0.6 * closed["images_per_s"]
    orep = open_loop(cp, params, requests, target_rate)["report"]
    rows.append({
        "name": "serving/open_loop",
        "net": cfg.name,
        "requests": n_requests,
        "images": images,
        "offered_images_per_s": round(target_rate, 2),
        "achieved_images_per_s": round(orep.images_per_s, 2),
        "hbm_words_per_image": orep.hbm_words_per_image,
        "report": orep.to_dict(),
    })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer requests/repeats)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the BENCH_serving.json artifact here")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write a Chrome Trace Event JSON of the final "
                         "closed-loop serving repeat (Perfetto loadable)")
    args = ap.parse_args()
    n_requests, repeats = args.requests, args.repeats
    if args.smoke:
        n_requests = min(n_requests, 16)

    tracer = Tracer(process_name="serving_throughput") \
        if args.trace else None
    rows = bench(n_requests, repeats, tracer)
    for row in rows:
        print("  ".join(f"{k}={v}" for k, v in row.items()
                        if k != "report"))
    if args.trace:
        tracer.dump(args.trace)
        print(f"wrote {args.trace} "
              f"({len(tracer.events())} events, {tracer.dropped} dropped)")
    if args.json:
        artifact = {"benchmark": "serving_throughput", "rows": rows}
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
