"""Pipeline benchmark: images/s + stall cycles vs fifo_sim, as JSON.

Runs the executable mini ResNet-18 through the compiled pipeline twice —
all weights pinned vs the Algorithm 1 hybrid plan — and reports, per plan:

  * warm-cache wall-clock images/s of the actual JAX execution, as the
    MEDIAN of ``--repeats`` runs after compilation (interpret-mode
    Pallas on CPU: a functional emulation, so wall-clock is for
    *relative* comparison only, not an FPGA throughput claim), for BOTH
    executor backends — the fused single-dispatch jit program and the
    eager per-layer walk — plus their speedup ratio.  The mini net is
    sized so host dispatch overhead is visible against compute: that
    overhead is exactly what the fused path removes;
  * the §VI analytic throughput model over the same plan;
  * streamed weight traffic (Eq. 2 words) from the traced dispatch
    counters — cross-checked (hard fail, ``ExecutionReport.verify``)
    against the plan analytics over 100% of the topology: every node
    (pool/GAP included) dispatched, per-node and per-fused-block words
    exact, plus the whole-graph ``topology_words_per_image`` total the
    regression gate tracks;
  * tail-engine stall cycles predicted by the §V-A credit-mode fifo_sim
    over the plan's per-row word demands, against the sim's delivered
    word counts.

It also records the *modelled* throughput + Eq. 2 HBM words/image for the
paper's full-size nets (compile-only — nothing executes at 224x224 on
CPU), so the perf trajectory of the planner is tracked per commit; CI
diffs these modelled numbers against the previous run's artifact and
fails on >5% regression (benchmarks/bench_diff.py).

  PYTHONPATH=src python benchmarks/pipeline_throughput.py [batch] \
      [--repeats N] [--json BENCH_pipeline.json]

``--json`` writes the machine-readable artifact CI uploads per run.
"""
from __future__ import annotations

import argparse
import json
import statistics
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro import compiler
from repro.configs.cnn import CNN_CONFIGS, mini_resnet18
from repro.core import fifo_sim
from repro.models.cnn import cnn_input_shape, init_cnn_params

PAPER_NETS = ("resnet18", "resnet50", "vgg16")
BACKENDS = ("eager", "fused")


def _paired_times_s(cp, params, x, repeats: int):
    """Warm-cache timing of both backends, INTERLEAVED: each repeat runs
    eager then fused back to back, and the reported speedup is the
    median of the per-pair ratios — so host load spikes land on both
    sides of the ratio instead of whichever backend was being timed.
    The first (untimed) run per backend absorbs trace/compile cost.
    Returns (times dict, last fused ExecutionReport) — the report is
    deterministic per shape, so reusing it saves an extra execution."""
    exs = {be: cp.executor(backend=be) for be in BACKENDS}
    for ex in exs.values():
        jax.block_until_ready(ex.run(params, x)[0])    # warm-up / compile
    times: Dict[str, List[float]] = {be: [] for be in BACKENDS}
    ratios = []
    report = None
    for _ in range(repeats):
        for be in BACKENDS:
            t0 = time.perf_counter()
            logits, rep = exs[be].run(params, x)
            jax.block_until_ready(logits)          # time execution, not
            times[be].append(time.perf_counter() - t0)   # async dispatch
            if be == "fused":
                report = rep
        ratios.append(times["eager"][-1] / times["fused"][-1])
    out = {be: statistics.median(ts) for be, ts in times.items()}
    out["speedup"] = statistics.median(ratios)
    return out, report


def bench(batch: int = 2, repeats: int = 7) -> List[Dict]:
    """Execute the mini net under pinned vs hybrid compiled pipelines,
    on both executor backends.

    The net is ResNet-18's full four-stage topology at executable scale
    (21 engines, tiny maps): per-engine compute is small against the
    ~20 host dispatches + jit-cache lookups a ``backend="eager"`` run
    pays per image — which is exactly the overhead the fused
    single-dispatch program removes, and what the speedup column
    measures."""
    cfg = mini_resnet18(hw=8, width=16, stages=4)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.randint(jax.random.PRNGKey(1),
                           cnn_input_shape(cfg, batch), -127, 128, jnp.int8)

    hybrid = compiler.compile(cfg, compiler.TPU_INTERPRET)
    plans = {"pinned": hybrid.with_offload([]), "hybrid": hybrid}

    rows = []
    for label, cp in plans.items():
        t, report = _paired_times_s(cp, params, x, repeats)
        row = {
            "name": f"pipeline/{label}",
            "net": cfg.name,
            "topology_nodes": len(cp.schedules),
            "pool_nodes": sum(1 for s in cp.schedules if s.spec.is_pool),
            "streamed_layers": len(cp.streamed_names),
            "engines": sorted(set(cp.engine_table().values())),
            "fused_blocks": len(cp.block_assignments),
            "timing_repeats": repeats,
            "wallclock_images_per_s": round(batch / t["fused"], 2),
            "eager_images_per_s": round(batch / t["eager"], 2),
            "fused_speedup_x": round(t["speedup"], 2),
            "model_images_per_s": round(cp.throughput()["images_per_s"], 1),
            "hbm_words_streamed": report.total_hbm_words,
            "hbm_words_per_image": report.total_hbm_words // batch,
            # Eq. 2 words over the WHOLE topology (pool nodes included —
            # 0 words each by construction, so this equals the streamed
            # total; the gate catches any node ever starting to charge)
            "topology_words_per_image": sum(
                cp.hbm_words_per_image().values()),
        }
        # whole-net Eq. 2 cross-check, hard fail: every topology node
        # dispatched, executed words == plan analytics per node AND per
        # fused res_block_int8 unit (Eq2MismatchError on drift)
        report.verify()
        row["block_hbm_words_per_image"] = sum(
            r["hbm_words_per_image"] for r in report.block_rows())
        if cp.streamed_names:
            sim_cfg, scale = cp.plan.sim_config(outputs_needed=8)
            sim = fifo_sim.simulate(sim_cfg, "credit")
            row.update({
                "sim_stall_cycles": sim.stall_cycles,
                "sim_cycles": sim.cycles,
                "sim_words_delivered": sum(sim.per_layer_weight_words)
                * scale,
                "sim_completed": sim.completed,
            })
        rows.append(row)
    return rows


def modelled_rows() -> List[Dict]:
    """Compile-only §VI model numbers for the paper's full-size nets."""
    rows = []
    for name in PAPER_NETS:
        cp = compiler.compile(CNN_CONFIGS[name], compiler.NX2100)
        # execution-free whole-net Eq. 2 cross-check (hard fail): the
        # shape-static stats the bound engines will report must equal
        # the plan analytics for 100% of the topology
        cp.eq2_report().verify()
        t = cp.throughput()
        words = sum(cp.hbm_words_per_image().values())
        rows.append({
            "name": f"model/{name}",
            "net": name,
            "topology_nodes": len(cp.schedules),
            "pool_nodes": sum(1 for s in cp.schedules if s.spec.is_pool),
            "streamed_layers": len(cp.streamed_names),
            "fused_blocks": len(cp.block_assignments),
            "model_images_per_s": round(t["images_per_s"], 1),
            "bottleneck": t["bottleneck"],
            "hbm_words_per_image": words,
            "topology_words_per_image": words,
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("batch", nargs="?", type=int, default=2)
    ap.add_argument("--repeats", type=int, default=5,
                    help="warm runs per timing (median reported)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the BENCH_pipeline.json artifact here")
    args = ap.parse_args()

    rows = bench(args.batch, args.repeats) + modelled_rows()
    for row in rows:
        print("  ".join(f"{k}={v}" for k, v in row.items()))
    if args.json:
        artifact = {"benchmark": "pipeline_throughput",
                    "batch": args.batch, "rows": rows}
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
