"""Pipeline-executor benchmark: images/s + stall cycles vs fifo_sim.

Runs the executable mini ResNet-18 through the pipeline executor twice —
all weights pinned vs the Algorithm 1 hybrid plan — and reports, per plan:

  * wall-clock images/s of the actual JAX execution (interpret-mode Pallas
    on CPU: a functional emulation, so wall-clock is for *relative*
    pinned-vs-streamed comparison only, not an FPGA throughput claim);
  * the §VI analytic throughput model over the same plan;
  * streamed weight traffic (Eq. 2 words) counted at kernel dispatch;
  * tail-engine stall cycles predicted by the §V-A credit-mode fifo_sim
    over the plan's per-row word demands, against the sim's delivered
    word counts.

  PYTHONPATH=src python benchmarks/pipeline_throughput.py [batch]
"""
from __future__ import annotations

import sys
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs.cnn import mini_resnet18
from repro.core import build_pipeline_plan, fifo_sim
from repro.models.cnn import cnn_input_shape, init_cnn_params
from repro.runtime.pipeline import PipelineExecutor


def bench(batch: int = 2) -> List[Dict]:
    cfg = mini_resnet18(hw=32, width=32)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.randint(jax.random.PRNGKey(1),
                           cnn_input_shape(cfg, batch), -127, 128, jnp.int8)

    hybrid = build_pipeline_plan(cfg, tb_budget=500, bram_m20ks=40)
    plans = {"pinned": hybrid.with_offload([]), "hybrid": hybrid}

    rows = []
    for label, plan in plans.items():
        ex = PipelineExecutor(plan)
        ex.run(params, x)                          # warm-up / compile
        t0 = time.perf_counter()
        _, report = ex.run(params, x)
        dt = time.perf_counter() - t0
        row = {
            "name": f"pipeline/{label}",
            "streamed_layers": len(plan.streamed),
            "wallclock_images_per_s": round(batch / dt, 2),
            "model_images_per_s": round(plan.throughput()["images_per_s"], 1),
            "hbm_words_streamed": report.total_hbm_words,
        }
        if plan.streamed:
            sim_cfg, scale = plan.sim_config(outputs_needed=8)
            sim = fifo_sim.simulate(sim_cfg, "credit")
            row.update({
                "sim_stall_cycles": sim.stall_cycles,
                "sim_cycles": sim.cycles,
                "sim_words_delivered": sum(sim.per_layer_weight_words)
                * scale,
                "sim_completed": sim.completed,
            })
        rows.append(row)
    return rows


def main() -> None:
    batch = int(sys.argv[1]) if len(sys.argv) > 1 else 2
    for row in bench(batch):
        print("  ".join(f"{k}={v}" for k, v in row.items()))


if __name__ == "__main__":
    main()
