"""One function per paper table/figure.  Each returns a list of row-dicts;
``benchmarks.run`` times them and emits the CSV.

Validation targets (from the paper, checked by the asserts here and in
tests/test_core_paper.py):
  Fig. 3   read eff ~50% below burst 4, 83% @ 8, 93% @ 32; latency ~400 ns
  Table I  activations < 35% of memory; ResNet-50/VGG-16 exceed 140 Mb
  Fig. 5   ready/valid deadlocks; credits complete
  Table II burst 8 == 16 on ResNet-18 (bottleneck on chip); ResNet-50
           gains ~2% from 8 -> 32 (bottleneck on HBM)
  Fig. 6   all-HBM hw within 68-78% of the Eq. 2 bound; hybrid > all-HBM
           with ResNet-18 gaining most; ResNet-50/VGG-16 would scale
           2.27x / 2.08x with unlimited HBM
  Table III H2PIPE throughput model vs published prior-work numbers
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs import CNN_CONFIGS
from repro.core import bounds, fifo_sim, hbm_model, placement

# paper-measured DSP utilization (Table III) drives the TB budget per net
DSP_FRAC = {"resnet18": 0.51, "resnet50": 0.33, "vgg16": 0.40}


def fig3_hbm() -> List[Dict]:
    rows = []
    for burst in (1, 2, 4, 8, 16, 32):
        sim = hbm_model.simulate_pc(
            hbm_model.interleaved_stream(3, 120, burst), burst)
        rows.append({
            "name": f"fig3/burst{burst}",
            "read_eff_model": round(hbm_model.read_efficiency(burst), 3),
            "read_eff_sim": round(sim.efficiency, 3),
            "write_eff_model": round(hbm_model.write_efficiency(burst), 3),
            "lat_avg_ns": hbm_model.read_latency_ns(burst, "avg"),
            "lat_max_ns": hbm_model.read_latency_ns(burst, "max"),
        })
    return rows


def table1_memory() -> List[Dict]:
    """Table I over the FULL topology: pool/GAP nodes are first-class
    graph nodes now, contributing activation line buffers (never
    weights) to the memory breakdown; the Eq. 2 columns make the
    zero-weight-traffic property of the topology nodes auditable."""
    rows = []
    for name, cfg in CNN_CONFIGS.items():
        w = cfg.total_weight_bits() / 1e6
        a = cfg.total_activation_bits() / 1e6
        pools = [l for l in cfg.layers if l.is_pool]
        rows.append({
            "name": f"table1/{name}",
            "topology_nodes": len(cfg.layers),
            "pool_nodes": len(pools),
            "weight_Mb": round(w), "act_Mb": round(a),
            "act_frac_pct": round(100 * a / (a + w), 1),
            "fits_140Mb": (w + a) <= 140,
            # Eq. 2 re-read traffic, whole graph vs its pool subset (the
            # latter is 0 by construction: pooling engines are weightless)
            "eq2_traffic_MB": round(cfg.total_weight_traffic() / 1e6, 1),
            "pool_eq2_bytes": sum(l.weight_traffic_bytes() for l in pools),
        })
    return rows


def _plans_for(name: str, all_hbm: bool, burst: int = 8):
    cfg = CNN_CONFIGS[name]
    frac = DSP_FRAC.get(name, 0.5)
    plans = placement.allocate_parallelism(
        cfg, int(bounds.NX2100_TENSOR_BLOCKS * frac))
    if all_hbm:
        for p in plans:
            p.offload = True
    else:
        plans = placement.hybrid_selection(plans, bounds.NX2100_M20KS,
                                           burst=burst)
    placement.assign_pseudo_channels(plans)
    return cfg, plans


def table2_burst() -> List[Dict]:
    """Hybrid rows reproduce the paper's conclusion shape (burst
    insensitivity when the bottleneck layer is on chip); the all-HBM rows
    expose the raw efficiency-vs-burst trend (bottleneck on HBM), which is
    where the paper's ResNet-50 +2% lives — our analytic pipeline model
    keeps the hybrid bottleneck on chip, a documented deviation
    (EXPERIMENTS.md §Benchmarks)."""
    rows = []
    for name in ("resnet18", "resnet50"):
        for burst in (8, 16, 32):
            cfg, plans = _plans_for(name, all_hbm=False, burst=burst)
            t = placement.pipeline_throughput(plans, burst=burst)
            cfg, plans_a = _plans_for(name, all_hbm=True, burst=burst)
            t_a = placement.pipeline_throughput(plans_a, burst=burst)
            rows.append({
                "name": f"table2/{name}/burst{burst}",
                "im_s": round(t["images_per_s"], 1),
                "bottleneck_on_hbm": t["bottleneck_on_hbm"],
                "all_hbm_im_s": round(t_a["images_per_s"], 1),
                "onchip_fifo_m20ks": hbm_model.fifo_m20k_cost(burst),
            })
    return rows


def fig5_deadlock() -> List[Dict]:
    out = fifo_sim.demo()
    return [{
        "name": f"fig5/{mode}",
        "deadlocked": o.deadlocked,
        "completed": o.completed,
        "cycles": o.cycles,
        "outputs": o.outputs,
    } for mode, o in out.items()]


def fig6_bounds() -> List[Dict]:
    rows = []
    for name in ("resnet18", "resnet50", "vgg16"):
        cfg, plans_a = _plans_for(name, all_hbm=True)
        all_hbm = placement.pipeline_throughput(plans_a)["images_per_s"]
        cfg, plans_h = _plans_for(name, all_hbm=False)
        hybrid = placement.pipeline_throughput(plans_h)["images_per_s"]
        used_tbs = sum(p.tensor_blocks for p in plans_h)
        s = bounds.fig6_summary(cfg, all_hbm, hybrid, used_tbs)
        rows.append({
            "name": f"fig6/{name}",
            "all_hbm_sim": round(all_hbm, 1),
            "hybrid_sim": round(hybrid, 1),
            "eq2_bound": round(s["all_hbm_bound"], 1),
            "frac_of_bound": round(s["fraction_of_bound"], 2),
            "unlimited_bound": round(s["unlimited_bound"], 1),
            "paper_all_hbm": {"resnet18": 1811, "resnet50": 748,
                              "vgg16": 430}[name],
            "paper_hybrid": {"resnet18": 4174, "resnet50": 1004,
                             "vgg16": 545}[name],
        })
    return rows


# Table III prior-work rows (from the paper, batch=1); the bool marks
# comparable (>= 8-bit) precision — the paper's headline speedups (19.4x /
# 5.1x / 10.5x) are vs the best comparable-precision prior work.
PRIOR = [
    ("resnet18", "Venieris-23", 59.7, True),
    ("resnet18", "FILM-QNN", 214.8, True),
    ("resnet50", "Venieris-23", 71.7, True),
    ("resnet50", "Liu-22", 197.2, True),
    ("resnet50", "DNNVM", 88.3, True), ("resnet50", "FTDL", 151.2, True),
    ("resnet50", "BNN-PYNQ", 527.0, False),      # 1-bit
    ("vgg16", "fpgaconvnet", 4.0, True), ("vgg16", "Ma-20", 51.8, True),
    ("vgg16", "Nguyen-23-HBM", 29.5, True),
]
PAPER_H2PIPE = {"resnet18": 4174, "resnet50": 1004, "vgg16": 545}


def table3_throughput() -> List[Dict]:
    rows = []
    for name in ("resnet18", "resnet50", "vgg16"):
        burst = 32 if name != "resnet18" else 8
        cfg, plans = _plans_for(name, all_hbm=False, burst=burst)
        sim = placement.pipeline_throughput(plans,
                                            burst=burst)["images_per_s"]
        best_cmp = max(t for n, _, t, cmp_ in PRIOR if n == name and cmp_)
        best_any = max(t for n, _, t, _ in PRIOR if n == name)
        rows.append({
            "name": f"table3/{name}",
            "h2pipe_sim_im_s": round(sim, 1),
            "h2pipe_paper_im_s": PAPER_H2PIPE[name],
            "best_comparable_prior_im_s": best_cmp,
            "speedup_sim_vs_comparable": round(sim / best_cmp, 1),
            "speedup_paper_vs_comparable": round(
                PAPER_H2PIPE[name] / best_cmp, 1),
            "speedup_sim_vs_any": round(sim / best_any, 1),
            "gops_sim": round(bounds.gops(cfg, sim)),
        })
    return rows


def sec4c_write_path() -> List[Dict]:
    """§IV-C: narrow write bus registers saved + boot-time per network."""
    from repro.core import write_path
    rows = [{
        "name": "sec4c/registers",
        "regs_30bit": write_path.write_path_registers(30),
        "regs_256bit": write_path.write_path_registers(256),
        "saved": write_path.registers_saved(30),
        "paper_claim": ">3000 saved",
    }]
    for net in ("resnet18", "resnet50", "vgg16"):
        b = CNN_CONFIGS[net].total_weight_bits() // 8
        rows.append({
            "name": f"sec4c/boot/{net}",
            "weight_MB": round(b / 1e6, 1),
            "boot_s_30bit": round(write_path.boot_time_s(b, 30), 3),
        })
    return rows


def kernel_vmem() -> List[Dict]:
    """The stream_matmul VMEM footprint vs burst depth — the kernel-level
    Table II: bigger bursts (bk) and deeper FIFOs (n_buffers) cost VMEM
    exactly as bigger bursts cost M20Ks on the FPGA."""
    from repro.kernels.stream_matmul.ops import vmem_bytes
    rows = []
    M, K, N = 256, 8192, 4096            # a d_model x d_ff-scale matmul
    for mode in ("pinned", "stream", "fifo"):
        for bk in (128, 512, 2048):
            for nb in ((2, 4) if mode == "fifo" else (2,)):
                rows.append({
                    "name": f"kernelvmem/{mode}/bk{bk}/nb{nb}",
                    "vmem_KiB": vmem_bytes(mode, M, K, N, 2, bk=bk,
                                           n_buffers=nb) // 1024,
                })
    return rows


ALL = {
    "fig3": fig3_hbm,
    "table1": table1_memory,
    "table2": table2_burst,
    "fig5": fig5_deadlock,
    "fig6": fig6_bounds,
    "table3": table3_throughput,
    "sec4c": sec4c_write_path,
    "kernelvmem": kernel_vmem,
}
