"""Benchmark harness: one entry per paper table/figure plus system
microbenches.  Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig6       # one artifact
"""
from __future__ import annotations

import json
import sys
import time

from benchmarks.paper_tables import ALL


def _microbench():
    """CPU-timeable system microbenches (reduced configs)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.models import transformer as tmod

    rows = []
    key = jax.random.PRNGKey(0)
    for arch_id in ("phi4-mini-3.8b", "qwen2-moe-a2.7b", "xlstm-125m"):
        cfg = get_arch(arch_id).reduced()
        params = tmod.init_params(key, cfg)
        tk = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
        batch = {"tokens": tk, "labels": jnp.roll(tk, -1, 1)}
        f = jax.jit(lambda p, b: tmod.loss_fn(p, cfg, b, remat=False))
        f(params, batch).block_until_ready()
        t0 = time.perf_counter()
        n = 5
        for _ in range(n):
            f(params, batch).block_until_ready()
        us = (time.perf_counter() - t0) / n * 1e6
        rows.append({"name": f"micro/loss/{arch_id}", "us_per_call": round(us)})

        _, cache = tmod.prefill(params, cfg, batch, max_seq=40)
        tok = jnp.ones((2, 1), jnp.int32)
        g = jax.jit(lambda p, c, t: tmod.decode_step(p, cfg, c, t,
                                                     jnp.int32(32)))
        g(params, cache, tok)[0].block_until_ready()
        t0 = time.perf_counter()
        for _ in range(n):
            g(params, cache, tok)[0].block_until_ready()
        us = (time.perf_counter() - t0) / n * 1e6
        rows.append({"name": f"micro/decode/{arch_id}",
                     "us_per_call": round(us)})
    return rows


def main() -> None:
    which = sys.argv[1:] or list(ALL) + ["micro"]
    print("name,us_per_call,derived")
    for key in which:
        if key == "micro":
            for row in _microbench():
                name = row.pop("name")
                us = row.pop("us_per_call", "")
                print(f"{name},{us},{json.dumps(row)}")
            continue
        fn = ALL[key]
        t0 = time.perf_counter()
        rows = fn()
        us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
        for row in rows:
            name = row.pop("name")
            print(f"{name},{us:.0f},{json.dumps(row, default=str)}")


if __name__ == "__main__":
    main()
