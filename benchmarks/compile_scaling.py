"""Compile-scaling benchmark: the scan-over-blocks trace win, as JSON.

Deep nets are repetitive: ResNet-50's stage-2 is five IDENTICAL
bottleneck blocks, and the unrolled stage-6 trace pays the full jaxpr
cost of every repeat.  The scan-over-blocks compile path
(``compile(cfg, target)`` default) detects shape- AND
schedule-homogeneous block runs and emits ONE ``lax.scan`` body per run,
so the traced program's size grows with the number of DISTINCT block
shapes, not the depth.  This benchmark measures exactly that, on the IR
itself — no weights materialized, nothing executed
(:func:`repro.compiler.trace_fused_abstract` traces against abstract
params, which is what lets the full-size 224x224 ResNet-50 appear here):

  * ``jaxpr_eqn_count``        equations in the scanned fused trace
                               (sub-jaxprs counted once — gated: may
                               not GROW);
  * ``jaxpr_eqn_count_unrolled``  the same net compiled ``scan=False``;
  * ``eqn_reduction_x``        unrolled / scanned — the win.  The deep
                               mini-ResNet-50 row HARD-ASSERTS >= 3x
                               (the ISSUE's acceptance bar);
  * ``trace_seconds``          wall seconds for the scanned trace
                               (gated with a wide threshold — wall
                               clocks on shared CI are noisy);
  * ``scan_groups`` / ``scanned_blocks``  how much of the net the
                               binding covered;
  * ``topology_nodes``         the graph size (resets the bench_diff
                               baseline on deliberate topology changes).

Rows: the executable mini-ResNet-18 (a control: 2-deep stages still
scan), a DEEP mini-ResNet-50 (16 blocks/stage — the depth regime the
scan path exists for), and — unless ``--smoke`` — the paper's full-size
ResNet-50 (partial runs only: its stages repeat 3/4/6/3, so the
reduction is real but bounded by the distinct-shape floor).

  PYTHONPATH=src python benchmarks/compile_scaling.py \
      [--smoke] [--json BENCH_compile.json]
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

from repro import compiler
from repro.configs.cnn import get_cnn, mini_resnet18, mini_resnet50

MIN_REDUCTION_X = 3.0          # acceptance bar on the deep mini-ResNet-50


def _configs(smoke: bool):
    out = [
        ("compile/mini_resnet18", mini_resnet18(hw=8, width=16, stages=4)),
        # the headline row: deep homogeneous stages, executable geometry
        ("compile/mini_resnet50_deep",
         mini_resnet50(hw=16, width=16, stages=2, blocks_per_stage=16)),
    ]
    if not smoke:
        out.append(("compile/resnet50", get_cnn("resnet50")))
    return out


def bench(smoke: bool = False) -> List[Dict]:
    # throwaway warm-up trace so first-import costs (kernel modules,
    # jit machinery) never land inside a timed row
    compiler.trace_fused_abstract(
        compiler.compile(mini_resnet18(hw=8, width=16, stages=1),
                         compiler.TPU_INTERPRET))

    rows: List[Dict] = []
    for name, cfg in _configs(smoke):
        scanned = compiler.compile(cfg, compiler.TPU_INTERPRET, scan=True)
        unrolled = compiler.compile(cfg, compiler.TPU_INTERPRET, scan=False)
        # unrolled first: any residual warm-up lands on the baseline side
        j_u, t_u = compiler.trace_fused_abstract(unrolled)
        j_s, t_s = compiler.trace_fused_abstract(scanned)
        n_s = compiler.count_jaxpr_eqns(j_s)
        n_u = compiler.count_jaxpr_eqns(j_u)
        # the scanned trace must also keep the Eq. 2 guarantee whole
        scanned.eq2_report().verify()
        rows.append({
            "name": name,
            "net": cfg.name,
            "topology_nodes": len(scanned.schedules),
            "scan_groups": len(scanned.scan_assignments),
            "scanned_blocks": sum(g.n_blocks
                                  for g in scanned.scan_assignments),
            "fused_blocks": len(scanned.block_assignments),
            "jaxpr_eqn_count": n_s,
            "jaxpr_eqn_count_unrolled": n_u,
            "eqn_reduction_x": round(n_u / n_s, 2),
            "trace_seconds": round(t_s, 3),
            "trace_seconds_unrolled": round(t_u, 3),
        })
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="skip the full-size ResNet-50 row (CI fast path)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the machine-readable artifact")
    args = ap.parse_args(argv)

    rows = bench(smoke=args.smoke)
    hdr = (f"{'row':30s} {'nodes':>5s} {'groups':>6s} {'blocks':>6s} "
           f"{'eqns':>6s} {'unrolled':>8s} {'red.x':>6s} {'trace_s':>8s} "
           f"{'unr_s':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['name']:30s} {r['topology_nodes']:>5d} "
              f"{r['scan_groups']:>6d} {r['scanned_blocks']:>6d} "
              f"{r['jaxpr_eqn_count']:>6d} "
              f"{r['jaxpr_eqn_count_unrolled']:>8d} "
              f"{r['eqn_reduction_x']:>6.2f} {r['trace_seconds']:>8.3f} "
              f"{r['trace_seconds_unrolled']:>7.3f}")

    deep = next(r for r in rows if r["name"] == "compile/mini_resnet50_deep")
    if deep["eqn_reduction_x"] < MIN_REDUCTION_X:
        print(f"FAIL: deep mini-ResNet-50 eqn reduction "
              f"{deep['eqn_reduction_x']}x < required {MIN_REDUCTION_X}x")
        return 1
    print(f"scan-over-blocks reduction {deep['eqn_reduction_x']}x "
          f">= {MIN_REDUCTION_X}x on {deep['net']}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"benchmark": "compile_scaling",
                       "smoke": args.smoke, "rows": rows}, f, indent=2)
        print(f"wrote {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
