"""Multi-tenant multi-network serving benchmark: one front door, three
compiled pipelines, weighted-fair delivery.

Drives :class:`~repro.runtime.frontend.MultiTenantFrontEnd` over the
executable mini ResNet-18 + ResNet-50 + MobileNet pipelines with three
workloads:

  * **bit identity** (hard gate, not a timing): mixed three-network
    closed- AND open-loop traffic through the front door must be
    BIT-IDENTICAL per request to each network's sequential
    ``CompiledPipeline.run()`` — the scheduler reorders service, never
    an output bit.  The MobileNet engine runs with the adaptive
    microbatch ladder so shape growth/shrink is exercised under load.
    Any mismatch exits non-zero;
  * **weighted fairness** (1:4): two tenants on one network under
    sustained backlog (front-end-wide ``max_outstanding=1`` serializes
    service, so the backlog pools at the DRR tier).  A mid-run snapshot
    measures the delivered split — the drained end state always
    converges to the submitted ratio and proves nothing.  The run
    hard-fails unless the ratio lands within 20% of the weights;
  * **deadline attribution**: one tenant with an unmeetable 0 ms
    deadline (miss rate pinned at 1.0) and one with an effectively
    infinite deadline (pinned at 0.0) — deliberately extreme so the
    per-tenant ``deadline_miss_rate`` rows are STABLE for the diff
    gate, plus the promotion counter showing the overdue tenant really
    jumped the line.

Wall-clock numbers are interpret-mode Pallas on CPU — relative
comparison only.  ``bench_diff.py`` gates ``tenant_images_per_s``
(down) and ``deadline_miss_rate`` (up) under METRIC_THRESHOLD_FLOOR
(both wall-clock-derived; the extreme deadlines keep the miss rates
exactly 0.0 / 1.0 so that gate only fires on a real behavior change).

  PYTHONPATH=src python benchmarks/multitenant_serving.py \
      [--requests N] [--smoke] [--json BENCH_multitenant.json]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro import compiler
from repro.configs.cnn import mini_mobilenet, mini_resnet18, mini_resnet50
from repro.models.cnn import cnn_input_shape, init_cnn_params
from repro.runtime.frontend import MultiTenantFrontEnd

NET_FACTORIES = {
    "mini_resnet18": lambda: mini_resnet18(hw=8, width=16, stages=4),
    "mini_resnet50": lambda: mini_resnet50(hw=8, width=16, stages=4),
    "mini_mobilenet": lambda: mini_mobilenet(hw=8, width=16, blocks=4),
}
REQ_SIZES = (1, 2, 1, 4)


def build_nets() -> Dict[str, Tuple]:
    out = {}
    for i, (name, factory) in enumerate(NET_FACTORIES.items()):
        cfg = factory()
        cp = compiler.compile(cfg, compiler.TPU_INTERPRET)
        out[name] = (cfg, cp, init_cnn_params(jax.random.PRNGKey(i), cfg))
    return out


def make_requests(cfg, n_requests: int, seed: int) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    shape = cnn_input_shape(cfg, 1)[1:]
    return [rng.integers(-127, 128, size=(REQ_SIZES[i % len(REQ_SIZES)],)
                         + shape, dtype=np.int16).astype(np.int8)
            for i in range(n_requests)]


def reference_rows(cp, params, batches) -> List[np.ndarray]:
    big = np.concatenate(batches, axis=0)
    ref = np.asarray(cp.run(params, big)[0])
    out, off = [], 0
    for b in batches:
        out.append(ref[off:off + len(b)])
        off += len(b)
    return out


def bit_identity(nets, n_requests: int) -> Dict:
    """Mixed closed+open traffic through the front door vs per-network
    sequential run() — hard-fails on the first differing bit."""
    engines = {}
    for name, (cfg, cp, params) in nets.items():
        kw = {"adaptive": True} if name == "mini_mobilenet" else {}
        engines[name] = cp.serve(params, microbatch=4, credits=2,
                                 queue_depth=4, **kw)
    fe = MultiTenantFrontEnd(engines, max_outstanding=6)
    per_net = {name: make_requests(cfg, n_requests, seed=10 + i)
               for i, (name, (cfg, _, _)) in enumerate(nets.items())}
    for i, name in enumerate(nets):
        fe.register_tenant(f"t_{name}", network=name, weight=float(i + 1))
    half = n_requests // 2
    t0 = time.perf_counter()
    with fe:
        closed, _ = fe.serve([(f"t_{n}", b) for n in per_net
                              for b in per_net[n][:half]])
        open_reqs = [(n, i, fe.submit(f"t_{n}", per_net[n][i]))
                     for i in range(half, n_requests) for n in per_net]
        fe.drain()
        rep = fe.report()
    wall = time.perf_counter() - t0
    want = {n: reference_rows(nets[n][1], nets[n][2], per_net[n])
            for n in per_net}
    mismatches = 0
    idx = 0
    for n in per_net:
        for i in range(half):
            if not np.array_equal(closed[idx], want[n][i]):
                mismatches += 1
            idx += 1
    for n, i, req in open_reqs:
        if not np.array_equal(req.result(), want[n][i]):
            mismatches += 1
    if mismatches:
        raise SystemExit(
            f"BIT-IDENTITY FAILED: {mismatches} request(s) differ from "
            f"the sequential run() reference")
    shapes = {}
    for name, eng in engines.items():
        shapes[name] = eng.report().microbatch_shapes
    return {
        "name": "multitenant/bit_identity",
        "networks": len(nets),
        "requests": rep.requests,
        "images": rep.images,
        "bit_identical": 1,
        "frontend_images_per_s": round(rep.images / wall, 2),
        "adaptive_shapes_mobilenet": shapes["mini_mobilenet"],
        "report": rep.to_dict(),
    }


def weighted_fairness(nets, n_each: int) -> List[Dict]:
    """1:4 weights under sustained backlog; mid-run delivered split must
    track the weights within 20% (hard gate)."""
    cfg, cp, params = nets["mini_resnet18"]
    fe = MultiTenantFrontEnd(
        {"mini_resnet18": cp.serve(params, microbatch=1, credits=1,
                                   queue_depth=1)},
        max_outstanding=1)
    fe.register_tenant("light", network="mini_resnet18", weight=1.0)
    fe.register_tenant("heavy", network="mini_resnet18", weight=4.0)
    batches = make_requests(cfg, n_each, seed=20)
    batches = [b[:1] for b in batches]            # unit cost per request
    with fe:
        for b in batches:
            fe.submit("light", b)
            fe.submit("heavy", b)
        # mid-run snapshot, no earlier than 22 deliveries: right after a
        # light pick the DRR split reads 4k/(k+1), which only clears the
        # 20% band once k >= 4 — snapshotting sooner would flake on
        # quantization, not on fairness
        snapshot_at = min(2 * n_each - 4, max(22, n_each))
        while True:
            snap = fe.report()
            done = {r["tenant"]: r["images"] for r in snap.tenant_rows}
            if sum(done.values()) >= snapshot_at:
                break
            time.sleep(0.005)
        fe.drain()
        final = fe.report()
    ratio = done["heavy"] / max(1, done["light"])
    if not (4.0 * 0.8 <= ratio <= 4.0 * 1.2):
        raise SystemExit(
            f"WEIGHTED FAIRNESS FAILED: delivered ratio {ratio:.2f} "
            f"outside 20% of the 4.0 weight ratio ({done})")
    rows = []
    wall = final.wall_s
    for r in final.tenant_rows:
        rows.append({
            "name": f"multitenant/fairness/{r['tenant']}",
            "weight": r["weight"],
            "requests": r["requests"],
            "tenant_images_per_s": round(r["images_per_s"], 2),
            "deadline_miss_rate": r["deadline_miss_rate"],
            "p50_ms": round(r["p50_ms"], 3),
            "p99_ms": round(r["p99_ms"], 3),
        })
    rows.append({
        "name": "multitenant/fairness_summary",
        "weight_ratio": 4.0,
        "delivered_ratio_mid_run": round(ratio, 3),
        "jain_fairness_mid_run": round(snap.fairness, 4),
        "wall_s": round(wall, 4),
        "report": final.to_dict(),
    })
    return rows


def deadline_attribution(nets, n_each: int) -> List[Dict]:
    """Extreme deadlines → stable miss rates (1.0 / 0.0) for the diff
    gate, plus promotion evidence."""
    cfg, cp, params = nets["mini_mobilenet"]
    fe = MultiTenantFrontEnd(
        {"mini_mobilenet": cp.serve(params, microbatch=1, credits=1,
                                    queue_depth=1)},
        max_outstanding=1)
    fe.register_tenant("bulk", network="mini_mobilenet", weight=8.0,
                       deadline_ms=1e9)           # never missable
    fe.register_tenant("rt", network="mini_mobilenet", weight=1.0,
                       deadline_ms=0.0)           # never meetable
    batches = make_requests(cfg, n_each, seed=30)
    batches = [b[:1] for b in batches]
    with fe:
        for b in batches:
            fe.submit("bulk", b)
            fe.submit("rt", b)
        fe.drain()
        rep = fe.report()
    rows = []
    for r in rep.tenant_rows:
        rows.append({
            "name": f"multitenant/deadline/{r['tenant']}",
            "weight": r["weight"],
            "deadline_ms": r["deadline_ms"],
            "requests": r["requests"],
            "tenant_images_per_s": round(r["images_per_s"], 2),
            "deadline_miss_rate": r["deadline_miss_rate"],
            "deadline_misses": r["deadline_misses"],
        })
    want = {"rt": 1.0, "bulk": 0.0}
    for row in rows:
        tenant = row["name"].rsplit("/", 1)[1]
        if row["deadline_miss_rate"] != want[tenant]:
            raise SystemExit(
                f"DEADLINE ATTRIBUTION FAILED: {tenant} miss rate "
                f"{row['deadline_miss_rate']} != {want[tenant]}")
    if rep.promotions <= 0:
        raise SystemExit("DEADLINE ATTRIBUTION FAILED: the overdue "
                         "tenant was never promoted")
    rows.append({
        "name": "multitenant/deadline_summary",
        "promotions": rep.promotions,
        "report": rep.to_dict(),
    })
    return rows


def bench(n_requests: int = 12, n_fair: int = 24) -> List[Dict]:
    nets = build_nets()
    rows = [bit_identity(nets, n_requests)]
    rows.extend(weighted_fairness(nets, n_fair))
    rows.extend(deadline_attribution(nets, max(6, n_fair // 3)))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=12,
                    help="bit-identity requests per network")
    ap.add_argument("--fair-requests", type=int, default=24,
                    help="per-tenant requests in the fairness run")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer requests)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the BENCH_multitenant.json artifact here")
    args = ap.parse_args()
    n_requests, n_fair = args.requests, args.fair_requests
    if args.smoke:
        n_requests = min(n_requests, 8)
        n_fair = min(n_fair, 20)

    rows = bench(n_requests, n_fair)
    for row in rows:
        print("  ".join(f"{k}={v}" for k, v in row.items()
                        if k != "report"))
    if args.json:
        artifact = {"benchmark": "multitenant_serving", "rows": rows}
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
