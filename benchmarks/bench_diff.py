"""Benchmark regression gate: diff two benchmark JSON artifacts.

Works over all six artifact families (``BENCH_pipeline.json`` from
pipeline_throughput.py, ``BENCH_serving.json`` from
serving_throughput.py, ``BENCH_autotune.json`` from
autotune_placement.py, ``BENCH_sharded.json`` from sharded_serving.py,
``BENCH_compile.json`` from compile_scaling.py,
``BENCH_multitenant.json`` from multitenant_serving.py): rows are
matched on
``name`` and only the gated metrics *present in a row* are compared, so
one gate serves all.

  * ``model_images_per_s``     may not DROP by more than the threshold
                               (deterministic §VI model output);
  * ``hbm_words_per_image``    may not GROW by more than the threshold
                               (deterministic Eq. 2 accounting — on both
                               pipeline and serving rows);
  * ``serving_images_per_s``   may not DROP by more than the threshold
                               (closed-loop serving throughput);
  * ``serving_speedup_x``      may not DROP by more than the threshold
                               (serving vs sequential ratio — both sides
                               measured back to back on the same
                               machine, so host noise largely cancels;
                               the noise-robust half of the serving
                               gate);
  * ``sharded_images_per_s`` /
    ``scaling_efficiency``     may not DROP (sharded-serving rows: the
                               cycle model under the M/(M+S-1) fill law
                               over the partitioned graph —
                               deterministic compiler outputs, same
                               family as ``model_images_per_s``);
  * ``tuned_stall_cycles`` /
    ``tuned_m20ks``            may not GROW, and
  * ``tuned_images_per_s``     may not DROP (autotune rows: fixed-seed
                               search over deterministic sim/analytic
                               cost — any drift is a code change in the
                               optimizer or its cost model, not noise).

  * ``jaxpr_eqn_count``        may not GROW (compile_scaling rows: the
                               scanned fused trace's IR size is
                               deterministic — growth means scan-group
                               binding regressed), and
  * ``trace_seconds``          may not grow past a WIDE floor (>=50%:
                               wall clock on shared runners — only a
                               gross trace slowdown is a signal).

  * ``admission_wait_fraction`` /
    ``dispatch_gap_fraction``  may not GROW past the same wide floor
                               (serving rows: the measured half of the
                               §VI stall attribution — host wall-clock
                               shares of the serving wall, so only a
                               gross structural stall regression is a
                               signal).

  * ``tenant_images_per_s``    may not DROP past the wide floor, and
  * ``deadline_miss_rate``     may not GROW past it (multitenant rows:
                               delivered throughput is wall-clock; the
                               miss rates are pinned to 0.0/1.0 by the
                               benchmark's extreme deadlines, so any
                               movement at all is a behavior change).

The pipeline wall-clock fields stay ungated (CI noise), and the serving
throughput gate accepts some flake risk by design: a real >5% serving
regression is exactly what this file exists to catch.

Deliberate graph changes reset the baseline per row: pipeline rows carry
``topology_nodes`` (the compiled node count), and a row whose node count
differs from the previous artifact's is reported as a note and NOT
gated — modelled images/s and Eq. 2 words of a *different* graph are not
comparable (e.g. the topology-engine migration added pool/GAP nodes and
legitimately moved one more ResNet-50 layer to HBM).  Rows without the
field on both sides (serving artifacts) gate as before.

  python benchmarks/bench_diff.py PREV.json NEW.json [--threshold 0.05]

Exit status 1 when any gated metric regresses past the threshold (or a
previously-present row disappeared); 0 otherwise.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

# metric -> direction: "down" fails when the value shrinks, "up" when it
# grows.  Rows lacking a metric are skipped, so pipeline and serving
# artifacts share this table.
GATED_METRICS = {
    "model_images_per_s": "down",
    "hbm_words_per_image": "up",
    "topology_words_per_image": "up",     # whole-graph Eq. 2 total (pool
                                          # nodes included, 0 words each)
    "serving_images_per_s": "down",
    "serving_speedup_x": "down",
    # sharded_serving.py rows (deterministic cycle model + fill law over
    # the partitioned graph; topology_nodes resets the baseline on
    # deliberate graph changes, same as pipeline rows)
    "sharded_images_per_s": "down",
    "scaling_efficiency": "down",
    # autotune_placement.py rows (deterministic search + sim outputs):
    # the co-optimizer may never get worse at finding plans
    "tuned_stall_cycles": "up",
    "tuned_m20ks": "up",
    "tuned_images_per_s": "down",
    # compile_scaling.py rows: the scanned fused trace may never get
    # BIGGER (deterministic IR size — any growth is a scan-group binding
    # regression), and tracing it may not get slower (wall clock, so the
    # per-metric floor below widens its allowance against CI noise)
    "jaxpr_eqn_count": "up",
    "trace_seconds": "up",
    # serving stall attribution (ServingReport.bandwidth_efficiency
    # measured fractions): host wall-clock shares of the serving wall
    # spent blocked on §V-A credits / starved for work.  Wall-clock on
    # shared runners, so they gate only past the wide floor below — the
    # signal is a gross structural stall regression, not noise.
    "admission_wait_fraction": "up",
    "dispatch_gap_fraction": "up",
    # multitenant_serving.py per-tenant rows: delivered throughput is
    # wall-clock (floor below); the deadline-miss rate is pinned to the
    # extremes 0.0 / 1.0 by construction (unmeetable vs unmissable
    # deadlines), so any drift at all is a behavior change — it still
    # rides the floor only because old==0 -> inf would otherwise trip
    # on an artifact produced before the row existed.
    "tenant_images_per_s": "down",
    "deadline_miss_rate": "up",
}

# wall-clock metrics gate with AT LEAST this threshold regardless of
# --threshold: trace_seconds is host wall time on shared CI runners, so
# a tight 5% gate would flake; only a gross (>50%) slowdown is a signal.
METRIC_THRESHOLD_FLOOR = {
    "trace_seconds": 0.5,
    "admission_wait_fraction": 0.5,
    "dispatch_gap_fraction": 0.5,
    "tenant_images_per_s": 0.5,
    "deadline_miss_rate": 0.5,
}


def _rows_by_name(artifact: Dict) -> Dict[str, Dict]:
    return {row["name"]: row for row in artifact.get("rows", [])}


def compare(prev: Dict, new: Dict, threshold: float
            ) -> Tuple[List[str], List[str]]:
    """Returns (regressions, notes) over the gated modelled metrics."""
    regressions: List[str] = []
    notes: List[str] = []
    prev_rows, new_rows = _rows_by_name(prev), _rows_by_name(new)
    for name, prow in sorted(prev_rows.items()):
        nrow = new_rows.get(name)
        if nrow is None:
            regressions.append(f"{name}: row disappeared from the artifact")
            continue
        if prow.get("topology_nodes") != nrow.get("topology_nodes"):
            notes.append(
                f"{name}: graph changed "
                f"({prow.get('topology_nodes')} -> "
                f"{nrow.get('topology_nodes')} nodes); baseline reset, "
                f"row not gated")
            continue
        for metric, direction in GATED_METRICS.items():
            if metric not in prow:
                continue
            if metric not in nrow:
                regressions.append(f"{name}: {metric} disappeared")
                continue
            old, cur = float(prow[metric]), float(nrow[metric])
            if old == 0:
                delta = 0.0 if cur == 0 else float("inf")
            else:
                delta = (cur - old) / old
            allowed = max(threshold,
                          METRIC_THRESHOLD_FLOOR.get(metric, 0.0))
            worse = delta < -allowed if direction == "down" \
                else delta > allowed
            line = (f"{name}: {metric} {old:g} -> {cur:g} "
                    f"({delta:+.1%}, allowed {allowed:.0%})")
            if worse:
                regressions.append(line)
            elif delta != 0:
                notes.append(line)
    for name in sorted(set(new_rows) - set(prev_rows)):
        notes.append(f"{name}: new row (not gated)")
    return regressions, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("prev", help="previous run's BENCH_pipeline.json")
    ap.add_argument("new", help="this run's BENCH_pipeline.json")
    ap.add_argument("--threshold", type=float, default=0.05,
                    help="allowed relative regression (default 5%%)")
    args = ap.parse_args(argv)

    with open(args.prev) as f:
        prev = json.load(f)
    with open(args.new) as f:
        new = json.load(f)
    regressions, notes = compare(prev, new, args.threshold)

    for line in notes:
        print(f"note: {line}")
    if regressions:
        for line in regressions:
            print(f"REGRESSION: {line}")
        print(f"{len(regressions)} modelled-metric regression(s) past "
              f"{args.threshold:.0%}")
        return 1
    print("modelled benchmark numbers within threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
