"""Greedy vs co-optimized placement: the autotuner's acceptance artifact.

Runs the search-based placement + FIFO co-optimizer
(:mod:`repro.compiler.autotune`) against the one-shot greedy Alg. 1 seed
on the executable mini networks @ TPU_INTERPRET, and records both sides
of every metric the search trades:

  * ``greedy_stall_cycles`` / ``tuned_stall_cycles`` — credit-mode
    tail-engine stalls from the exact §V-A ``fifo_sim`` (same fixed
    ``word_scale`` on both sides, so the counts are comparable);
  * ``greedy_m20ks`` / ``tuned_m20ks`` — on-chip M20K footprint at the
    plans' actual FIFO depths (``hbm_model.fifo_m20k_cost``);
  * ``greedy_images_per_s`` / ``tuned_images_per_s`` — the §VI
    throughput model (the search never trades this down: throughput
    parity with the seed is a hard feasibility constraint);
  * the tuned knob values (burst / bm_words / laststage / offload count)
    and the co-optimized ``serving_credits`` bound.

Every number is deterministic (fixed search seed, analytic + simulated
cost model — no wall clocks), so the artifact diffs exactly:
bench_diff.py gates ``tuned_stall_cycles`` and ``tuned_m20ks`` against
growth and ``tuned_images_per_s`` against drops.  The compiled tuned
pipeline re-passes the whole-topology Eq. 2 cross-check
(``eq2_report().verify()``) before its row is emitted — a tuned plan
that drifted from the dispatch accounting fails the benchmark, not just
a test.

  PYTHONPATH=src python benchmarks/autotune_placement.py \
      [--iterations N] [--seed S] [--smoke] [--json BENCH_autotune.json]

``--smoke`` is the CI size (fewer annealing iterations; the bm-FIFO
deepening win is found within the first ~50 moves, so smoke results
match the full run on these nets).
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List

from repro import compiler
from repro.compiler.autotune import AutotuneConfig
from repro.configs.cnn import mini_resnet18, mini_resnet50

NETS = (
    ("mini_resnet18", lambda: mini_resnet18(hw=8, width=16, stages=4)),
    ("mini_resnet50", lambda: mini_resnet50(hw=8, width=16, stages=4)),
)


def bench(iterations: int, seed: int) -> List[Dict]:
    rows: List[Dict] = []
    for label, build in NETS:
        cfg = build()
        at = AutotuneConfig(seed=seed, iterations=iterations)
        cp = compiler.compile(cfg, compiler.TPU_INTERPRET, autotune=at)
        cp.eq2_report().verify()      # tuned plan must still cross-check
        row: Dict = {"name": f"autotune/{label}",
                     "topology_nodes": len(cp.plan.schedules)}
        row.update(cp.tuning.summary())
        rows.append(row)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--iterations", type=int, default=400)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer annealing iterations)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the BENCH_autotune.json artifact here")
    args = ap.parse_args()
    iterations = min(args.iterations, 150) if args.smoke else args.iterations

    rows = bench(iterations, args.seed)
    for row in rows:
        print("  ".join(f"{k}={v}" for k, v in sorted(row.items())))
        if not row["improved"]:
            raise SystemExit(
                f"{row['name']}: tuned plan failed to beat the greedy seed "
                f"on stalls or M20Ks — the acceptance bar of this artifact")
    if args.json:
        artifact = {"benchmark": "autotune_placement", "rows": rows}
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
