"""Sharded-serving benchmark: the mesh-pipelined engine vs one stage.

Partitions each executable mini net 4 ways
(``CompiledPipeline.partition``), runs the
:class:`~repro.runtime.sharded_serving.ShardedCnnServingEngine` on a
FORCED 4-device host-platform CPU mesh, and reports both sides of the
acceptance bar:

  * **modelled throughput** (gated): the per-layer cycle model under the
    M/(M + S - 1) pipeline fill law — ``sharded_images_per_s``,
    ``scaling_efficiency`` and ``sharded_speedup_x`` from
    ``StagePartition.modelled_throughput(M=32)``.  These are
    deterministic compiler outputs (like ``model_images_per_s`` in the
    pipeline benchmark): the speedup bar (>= 2.5x over the 1-stage
    model on the mini resnets) is asserted here and the first two join
    the bench_diff gate.  Forced host devices TIME-SLICE one CPU, so
    wall clock cannot show parallel speedup in CI — the cycle model is
    the paper-facing claim, the wall numbers below are reported
    ungated;
  * **measured serving** (ungated): wall-clock images/s of the sharded
    engine on the forced mesh, plus the §V-A credit high-water mark;
  * **bit identity** (asserted): every request's sharded logits equal
    sequential ``run()`` exactly.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python benchmarks/sharded_serving.py \
      [--requests N] [--smoke] [--json BENCH_sharded.json]

(The script forces the device count itself when XLA_FLAGS doesn't
already; ``--json`` writes the artifact CI uploads and diffs —
bench_diff.py gates ``sharded_images_per_s`` / ``scaling_efficiency``
at >5% regression, with ``topology_nodes`` resetting the baseline on
deliberate graph changes.)
"""
from __future__ import annotations

import os

_FORCE = "--xla_force_host_platform_device_count=8"
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " " + _FORCE).strip()

import argparse                                        # noqa: E402
import json                                            # noqa: E402
import sys                                             # noqa: E402
import time                                            # noqa: E402
from typing import Dict, List                          # noqa: E402

import jax                                             # noqa: E402
import numpy as np                                     # noqa: E402

from repro import compiler                             # noqa: E402
from repro.configs.cnn import (mini_mobilenet,         # noqa: E402
                               mini_resnet18, mini_resnet50)
from repro.launch.mesh import compat_make_mesh         # noqa: E402
from repro.models.cnn import (cnn_input_shape,         # noqa: E402
                              init_cnn_params)

N_STAGES = 4
MICROBATCH = 2
ROUND_MB = 32                # modelled round: amortizes the S-1 bubble
SPEEDUP_BAR = 2.5            # acceptance: modelled sharded vs 1-stage
REQ_SIZES = (1, 3, 2, 5)     # mixed request sizes, cycled

#: name -> (config, speedup bar asserted?).  The resnets carry the
#: acceptance bar; the depthwise net rides for dwconv coverage (its
#: dw/pw alternation balances less evenly at this depth).
NETS = {
    "mini_resnet18": (lambda: mini_resnet18(hw=8, width=16, stages=4),
                      True),
    "mini_resnet50": (lambda: mini_resnet50(hw=8, width=16, stages=4),
                      True),
    "mini_mobilenet": (lambda: mini_mobilenet(hw=8, width=32, blocks=6),
                       False),
}


def make_requests(cfg, n_requests: int) -> List[np.ndarray]:
    rng = np.random.default_rng(0)
    shape = cnn_input_shape(cfg, 1)[1:]
    return [rng.integers(-127, 128,
                         size=(REQ_SIZES[i % len(REQ_SIZES)],) + shape,
                         dtype=np.int16).astype(np.int8)
            for i in range(n_requests)]


def bench_net(name: str, cfg_fn, assert_bar: bool,
              n_requests: int) -> Dict:
    cfg = cfg_fn()
    cp = compiler.compile(cfg, compiler.TPU_INTERPRET)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    part = cp.partition(N_STAGES)
    model = part.modelled_throughput(ROUND_MB)

    mesh = compat_make_mesh((N_STAGES,), ("model",))
    requests = make_requests(cfg, n_requests)
    images = sum(len(r) for r in requests)
    with cp.serve_sharded(params, mesh=mesh, microbatch=MICROBATCH,
                          round_microbatches=8) as eng:
        eng.serve(requests[:2])                        # warm
        t0 = time.perf_counter()
        outs, rep = eng.serve(requests)
        wall = time.perf_counter() - t0

    bit_identical = all(
        np.array_equal(o, np.asarray(cp.run(params, r)[0]))
        for o, r in zip(outs, requests))
    if not bit_identical:
        raise SystemExit(f"{name}: sharded outputs != sequential run()")
    if assert_bar and model["sharded_speedup_x"] < SPEEDUP_BAR:
        raise SystemExit(
            f"{name}: modelled sharded speedup "
            f"{model['sharded_speedup_x']:.2f}x under the "
            f"{SPEEDUP_BAR}x acceptance bar")

    return {
        "name": f"sharded/{name}",
        "net": cfg.name,
        "topology_nodes": len(cp.schedules),
        "n_stages": N_STAGES,
        "round_microbatches": ROUND_MB,
        "microbatch": MICROBATCH,
        "balance": round(part.balance, 3),
        "max_stage_cycles": part.max_stage_cycles,
        "total_cycles": part.total_cycles,
        # gated modelled metrics (deterministic cycle model + fill law)
        "sharded_images_per_s": round(model["sharded_images_per_s"], 2),
        "scaling_efficiency": round(model["scaling_efficiency"], 4),
        "one_stage_images_per_s": round(
            model["one_stage_images_per_s"], 2),
        "sharded_speedup_x": round(model["sharded_speedup_x"], 3),
        "hbm_words_per_image": rep.hbm_words_per_image,
        # measured on the forced (time-sliced) mesh — ungated CI noise
        "wall_images_per_s": round(images / wall, 2) if wall > 0 else 0.0,
        "requests": len(requests),
        "images": images,
        "bit_identical": bit_identical,
        # everything else (rounds, credit high-water mark, latency
        # percentiles, metrics, stall attribution) rides in the
        # serialized report — no hand-rolled duplicate dict
        "report": rep.to_dict(),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer requests)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the BENCH_sharded.json artifact here")
    args = ap.parse_args()
    n_requests = min(args.requests, 6) if args.smoke else args.requests

    if jax.device_count() < N_STAGES:
        print(f"need {N_STAGES} devices, have {jax.device_count()} "
              f"(XLA_FLAGS was already set without the forced host "
              f"device count?)", file=sys.stderr)
        raise SystemExit(2)

    rows = [bench_net(name, fn, bar, n_requests)
            for name, (fn, bar) in NETS.items()]
    for row in rows:
        print("  ".join(f"{k}={v}" for k, v in row.items()
                        if k != "report"))
    if args.json:
        artifact = {"benchmark": "sharded_serving", "rows": rows}
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
