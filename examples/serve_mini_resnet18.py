"""Continuous-streaming CNN serving demo — a burst of mixed-size requests.

H2PIPE's accelerator admits a new image every initiation interval with
FIFO credits bounding the number in flight (§V-A); this drives the
software analogue end to end: compile the executable mini ResNet-18,
start a :class:`CnnServingEngine` (packed fixed-shape microbatches,
credit-bounded double-buffered dispatch), submit a burst of requests of
1..5 images each from several producer threads at once, and print the
:class:`ServingReport` table — throughput, latency percentiles, queue
depth, and per-request Eq. 2 HBM words.

  PYTHONPATH=src python examples/serve_mini_resnet18.py \
      [--requests 24] [--microbatch 8] [--credits 4] [--producers 4]
"""
import argparse
import threading

import jax
import numpy as np

from repro import compiler
from repro.configs.cnn import mini_resnet18
from repro.models.cnn import cnn_input_shape, init_cnn_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--microbatch", type=int, default=8)
    ap.add_argument("--credits", type=int, default=4)
    ap.add_argument("--producers", type=int, default=4)
    args = ap.parse_args()

    cfg = mini_resnet18(hw=8, width=16, stages=4)
    print(f"compiling {cfg.name} ({len(cfg.layers)} layers) ...")
    cp = compiler.compile(cfg, compiler.TPU_INTERPRET)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    print(f"  {len(cp.streamed_names)} HBM-streamed layers, "
          f"{len(cp.block_assignments)} fused residual blocks")

    rng = np.random.default_rng(0)
    shape = cnn_input_shape(cfg, 1)[1:]
    bursts = [rng.integers(-127, 128, size=(int(rng.integers(1, 6)),)
                           + shape, dtype=np.int16).astype(np.int8)
              for _ in range(args.requests)]

    with cp.serve(params, microbatch=args.microbatch,
                  credits=args.credits) as eng:
        # N producers submitting concurrently — the credit bound holds
        # (the admission controller's high-water mark is in the report)
        chunks = [bursts[i::args.producers] for i in range(args.producers)]
        threads = [threading.Thread(
            target=lambda c=c: [eng.submit(b) for b in c]) for c in chunks]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        eng.drain()
        report = eng.report()

    print()
    print(report.table())
    eng.admission.check_invariants()
    assert report.requests == args.requests
    assert report.max_in_flight <= args.credits


if __name__ == "__main__":
    main()
