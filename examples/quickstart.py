"""Quickstart: the whole system in one minute on CPU.

  PYTHONPATH=src python examples/quickstart.py

1. picks an architecture (reduced config),
2. shows the H2PIPE placement plan (which weights would pin vs stream),
3. trains a few steps (loss decreases),
4. serves a batch of requests through prefill + credit-bounded decode.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import streaming
from repro.data.pipeline import DataConfig, TokenDataset
from repro.models import transformer as tmod
from repro.models.layers import set_mesh_axis_sizes
from repro.optim.adamw import AdamWConfig
from repro.runtime.serving import Request, ServingEngine
from repro.runtime.trainer import TrainConfig, Trainer


def main():
    arch_full = get_arch("qwen2-moe-a2.7b")
    arch = arch_full.reduced()
    print(f"arch: {arch.name} (reduced: {arch.n_layers}L d={arch.d_model})")

    # --- placement plan on the production mesh (abstract, no allocation) --
    set_mesh_axis_sizes({"data": 16, "model": 16})
    abstract = jax.eval_shape(
        lambda: tmod.init_params(jax.random.PRNGKey(0), arch_full))
    plan = streaming.plan_placement(abstract, tmod.param_specs(arch_full),
                                    arch_full)
    print(f"H2PIPE placement plan (full {arch_full.name}): {plan.notes}")
    streamed = plan.streamed()
    if streamed:
        print(f"  example streamed tensor: {streamed[0].path} "
              f"({streamed[0].bytes/2**20:.0f} MiB, "
              f"score={streamed[0].score:.1f})")
    set_mesh_axis_sizes({})

    # --- train a few steps ------------------------------------------------
    data = TokenDataset(DataConfig(vocab_size=arch.vocab_size, seq_len=32,
                                   global_batch=4))
    tcfg = TrainConfig(steps=20, ckpt_every=10, log_every=5,
                       ckpt_path="/tmp/quickstart_ckpt",
                       adamw=AdamWConfig(lr_peak=1e-3, warmup_steps=2,
                                         total_steps=20))
    tr = Trainer(arch, tcfg, data)
    hist = tr.run()
    print("train:", " -> ".join(f"{h['loss']:.3f}" for h in hist))

    # --- serve ------------------------------------------------------------
    eng = ServingEngine(tr.params, arch, batch_slots=2, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, arch.vocab_size, size=6).astype(
        np.int32), max_new=5) for i in range(3)]
    for r in eng.run(reqs):
        print(f"serve req{r.rid}: {r.out}")
    print("quickstart OK")


if __name__ == "__main__":
    main()
