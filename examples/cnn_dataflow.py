"""The paper's own scenario, end to end: a CNN compiled into a
layer-pipelined dataflow accelerator with a hybrid weight memory.

  PYTHONPATH=src python examples/cnn_dataflow.py [resnet18|resnet50|vgg16]

1. allocates per-layer parallelism (the HPIPE balancing pass),
2. runs Eq. 1 + Algorithm 1 to decide which layers stream from HBM,
3. assigns pseudo-channels clockwise and reports the throughput model
   against the paper's measured numbers and Eq. 2 bound,
4. EXECUTES an executable-scale variant of the network end-to-end through
   the pipeline executor (runtime/pipeline.py): conv layers dispatch to
   the conv2d_int8 Pallas engine with weights pinned or HBM-streamed per
   its own Algorithm 1 plan, fc heads ride stream_matmul — and the result
   is verified bit-identical to the functional reference.
"""
import sys

import jax
import jax.numpy as jnp

from repro.configs import CNN_CONFIGS
from repro.configs.cnn import mini_resnet18
from repro.core import bounds, build_pipeline_plan, placement
from repro.models.cnn import cnn_forward, cnn_input_shape, init_cnn_params
from repro.runtime.pipeline import PipelineExecutor


def main(name: str = "resnet18"):
    cfg = CNN_CONFIGS[name]
    frac = {"resnet18": .51, "resnet50": .33, "vgg16": .40}.get(name, .5)
    plans = placement.allocate_parallelism(
        cfg, int(bounds.NX2100_TENSOR_BLOCKS * frac))
    plans = placement.hybrid_selection(plans, bounds.NX2100_M20KS)
    placement.assign_pseudo_channels(plans)

    print(f"== {name}: H2PIPE compile ==")
    offloaded = [p for p in plans if p.offload]
    print(f"layers: {len(plans)}, offloaded to HBM: {len(offloaded)}")
    for p in offloaded[:6]:
        print(f"  {p.spec.name:10s} -> PC{p.pc:<2d} "
              f"score={placement.eq1_score(p):8.1f} "
              f"chains={p.chains}")
    t = placement.pipeline_throughput(plans)
    print(f"modelled throughput: {t['images_per_s']:.0f} im/s "
          f"(bottleneck {t['bottleneck']}, "
          f"{'HBM' if t['bottleneck_on_hbm'] else 'on-chip'})")
    print(f"Eq.2 all-HBM bound: {bounds.all_hbm_bound_ims(cfg):.0f} im/s")

    # --- execute through the pipeline executor ---------------------------
    # Executable scale: the mini ResNet-18 topology is big enough that
    # Eq. 1 scores go positive and Algorithm 1 streams layers at a
    # 40-M20K budget (a smaller device), yet runs in interpret mode on CPU.
    r = mini_resnet18(hw=32, width=32)
    plan = build_pipeline_plan(r, tb_budget=500, bram_m20ks=40)
    assert plan.streamed, "Algorithm 1 chose no HBM layers?"
    print(f"\n== {r.name}: pipeline execution under the Algorithm 1 plan ==")
    print(f"streamed from HBM: {', '.join(plan.streamed_names)}")
    print(f"pinned on chip:    "
          f"{', '.join(s.spec.name for s in plan.pinned)}")

    params = init_cnn_params(jax.random.PRNGKey(0), r)
    x = jax.random.randint(jax.random.PRNGKey(1), cnn_input_shape(r, 4),
                           -127, 128, jnp.int8)
    executor = PipelineExecutor(plan)
    logits, report = executor.run(params, x)
    ref = cnn_forward(params, r, x)
    print(f"images {x.shape} -> logits {logits.shape}, "
          f"bit-identical to reference: {bool(jnp.all(logits == ref))}")
    print(f"Eq.2 weight words streamed: {report.total_hbm_words} "
          f"over {report.streamed_layer_count} layers")
    sim = report.fifo_prediction(outputs_needed=8)
    print(f"fifo_sim (credit mode): completed={sim.completed}, "
          f"tail stalls={sim.stall_cycles} cycles over {sim.cycles}")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "resnet18")
