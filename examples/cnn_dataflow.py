"""The paper's own scenario, end to end: a CNN compiled into a
layer-pipelined dataflow accelerator with a hybrid weight memory.

  PYTHONPATH=src python examples/cnn_dataflow.py [resnet18|resnet50|vgg16]

1. allocates per-layer parallelism (the HPIPE balancing pass),
2. runs Eq. 1 + Algorithm 1 to decide which layers stream from HBM,
3. assigns pseudo-channels clockwise and reports the throughput model
   against the paper's measured numbers and Eq. 2 bound,
4. executes the reduced network as an actual pipelined dataflow over the
   devices of this host (stages = layer groups, microbatched images).
"""
import sys

import jax
import jax.numpy as jnp

from repro.configs import CNN_CONFIGS
from repro.core import bounds, placement
from repro.models.cnn import cnn_forward, cnn_input_shape, init_cnn_params


def main(name: str = "resnet18"):
    cfg = CNN_CONFIGS[name]
    frac = {"resnet18": .51, "resnet50": .33, "vgg16": .40}.get(name, .5)
    plans = placement.allocate_parallelism(
        cfg, int(bounds.NX2100_TENSOR_BLOCKS * frac))
    plans = placement.hybrid_selection(plans, bounds.NX2100_M20KS)
    placement.assign_pseudo_channels(plans)

    print(f"== {name}: H2PIPE compile ==")
    offloaded = [p for p in plans if p.offload]
    print(f"layers: {len(plans)}, offloaded to HBM: {len(offloaded)}")
    for p in offloaded[:6]:
        print(f"  {p.spec.name:10s} -> PC{p.pc:<2d} "
              f"score={placement.eq1_score(p):8.1f} "
              f"chains={p.chains}")
    t = placement.pipeline_throughput(plans)
    print(f"modelled throughput: {t['images_per_s']:.0f} im/s "
          f"(bottleneck {t['bottleneck']}, "
          f"{'HBM' if t['bottleneck_on_hbm'] else 'on-chip'})")
    print(f"Eq.2 all-HBM bound: {bounds.all_hbm_bound_ims(cfg):.0f} im/s")

    # --- run the reduced network as a real dataflow -----------------------
    r = cfg.reduced()
    params = init_cnn_params(jax.random.PRNGKey(0), r)
    x = jax.random.randint(jax.random.PRNGKey(1), cnn_input_shape(r, 4),
                           -127, 128, jnp.int8)
    logits = cnn_forward(params, r, x)
    print(f"reduced {r.name}: images {x.shape} -> logits {logits.shape}, "
          f"finite={bool(jnp.all(jnp.isfinite(logits)))}")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "resnet18")
