"""The paper's own scenario, end to end: a CNN compiled into a
layer-pipelined dataflow accelerator with a hybrid weight memory.

  PYTHONPATH=src python examples/cnn_dataflow.py [resnet18|resnet50|vgg16]

1. ``compile(cfg, NX2100)`` runs the staged compiler against the paper's
   device descriptor: parallelism allocation (HPIPE balancing), Eq. 1 +
   Algorithm 1 placement, clockwise pseudo-channels, FIFO sizing, engine
   binding, VMEM validation — and prints the engine table (which
   registered LayerEngine runs each layer, in which weight tier) BEFORE
   anything executes;
2. reports the throughput model against the paper's measured numbers and
   the Eq. 2 bound;
3. EXECUTES an executable-scale variant of the network end-to-end through
   the compiled pipeline (runtime/pipeline.py): conv layers dispatch to
   the conv2d_int8 Pallas engine with weights pinned or HBM-streamed per
   its own Algorithm 1 plan, fc heads ride stream_matmul — and the result
   is verified bit-identical to the functional reference.
"""
import sys

import jax
import jax.numpy as jnp

from repro import compiler
from repro.configs import CNN_CONFIGS
from repro.configs.cnn import mini_resnet18
from repro.core import bounds, placement
from repro.models.cnn import cnn_forward, cnn_input_shape, init_cnn_params


def main(name: str = "resnet18"):
    cfg = CNN_CONFIGS[name]
    frac = {"resnet18": .51, "resnet50": .33, "vgg16": .40}.get(name, .5)
    target = compiler.NX2100.replace(
        name=f"nx2100-{name}",
        tb_budget=int(bounds.NX2100_TENSOR_BLOCKS * frac))
    compiled = compiler.compile(cfg, target)

    print(f"== {name}: H2PIPE compile for target {target.name!r} ==")
    offloaded = compiled.plan.streamed
    print(f"layers: {len(compiled.schedules)}, "
          f"offloaded to HBM: {len(offloaded)}")
    placements = {p.spec.name: p for p in compiled.plan.placements}
    for s in offloaded[:6]:
        p = placements[s.spec.name]
        print(f"  {s.spec.name:10s} -> PC{s.pc:<2d} "
              f"score={placement.eq1_score(p):8.1f} "
              f"chains={p.chains}")
    t = compiled.throughput()
    print(f"modelled throughput: {t['images_per_s']:.0f} im/s "
          f"(bottleneck {t['bottleneck']}, "
          f"{'HBM' if t['bottleneck_on_hbm'] else 'on-chip'})")
    print(f"Eq.2 all-HBM bound: {bounds.all_hbm_bound_ims(cfg):.0f} im/s")

    # --- execute through the compiled pipeline ----------------------------
    # Executable scale: the mini ResNet-18 topology is big enough that
    # Eq. 1 scores go positive and Algorithm 1 streams layers on the
    # TPU_INTERPRET target (a smaller device), yet runs in interpret mode
    # on CPU.
    r = mini_resnet18(hw=32, width=32)
    cp = compiler.compile(r, compiler.TPU_INTERPRET)
    assert cp.streamed_names, "Algorithm 1 chose no HBM layers?"
    print(f"\n== {r.name}: compiled for {cp.target.name!r} ==")
    print(cp.describe())

    params = init_cnn_params(jax.random.PRNGKey(0), r)
    x = jax.random.randint(jax.random.PRNGKey(1), cnn_input_shape(r, 4),
                           -127, 128, jnp.int8)
    logits, report = cp.run(params, x)
    ref = cnn_forward(params, r, x)
    print(f"images {x.shape} -> logits {logits.shape}, "
          f"bit-identical to reference: {bool(jnp.all(logits == ref))}")
    print(f"Eq.2 weight words streamed: {report.total_hbm_words} "
          f"over {report.streamed_layer_count} layers")
    sim = report.fifo_prediction(outputs_needed=8)
    print(f"fifo_sim (credit mode): completed={sim.completed}, "
          f"tail stalls={sim.stall_cycles} cycles over {sim.cycles}")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "resnet18")
