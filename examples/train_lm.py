"""End-to-end training driver: train an LM for a few hundred steps with
the full substrate (deterministic data, ZeRO AdamW, async checkpoints,
crash recovery).

  # fast demo (reduced config, ~1 min on CPU)
  PYTHONPATH=src python examples/train_lm.py

  # the ~100M-parameter run (xlstm-125m, a few hundred steps)
  PYTHONPATH=src python examples/train_lm.py --full --steps 300
"""
import argparse

from repro.configs import get_arch
from repro.data.pipeline import DataConfig, TokenDataset
from repro.optim.adamw import AdamWConfig
from repro.runtime.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--full", action="store_true",
                    help="use the full (125M) config instead of reduced")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if not args.full:
        arch = arch.reduced()
    data = TokenDataset(DataConfig(vocab_size=arch.vocab_size,
                                   seq_len=args.seq_len,
                                   global_batch=args.batch))
    tcfg = TrainConfig(
        steps=args.steps, microbatches=2, ckpt_every=50, log_every=10,
        ckpt_path="/tmp/train_lm_ckpt",
        adamw=AdamWConfig(lr_peak=3e-3, warmup_steps=args.steps // 10,
                          total_steps=args.steps))
    tr = Trainer(arch, tcfg, data)
    hist = tr.run(fail_at=args.fail_at)
    print("step,loss,grad_norm")
    for h in hist:
        print(f"{h['step']},{h['loss']:.4f},{h['grad_norm']:.3f}")
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'OK: decreased' if last < first else 'WARNING'})")
    tr.save(sync=True)


if __name__ == "__main__":
    main()
