"""End-to-end serving driver (the paper is an inference system, so the
end-to-end example is serving: batched requests through prefill +
credit-bounded continuous decode).

  PYTHONPATH=src python examples/serve_batched.py [--arch gemma2-9b]

Serves a stream of requests against a reduced model, reporting tokens/s,
admission behaviour (credits) and per-request outputs.  The same engine
code drives the decode_32k dry-run cells at production scale.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import transformer as tmod
from repro.runtime.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    arch = get_arch(args.arch).reduced()
    params = tmod.init_params(jax.random.PRNGKey(0), arch)
    engine = ServingEngine(params, arch, batch_slots=args.slots,
                           max_seq=128)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, arch.vocab_size,
                                    size=int(rng.integers(4, 12))).astype(
        np.int32), max_new=args.max_new) for i in range(args.requests)]

    print(f"serving {len(reqs)} requests on {arch.name} "
          f"({args.slots} slots = credits)")
    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    for r in done[:4]:
        print(f"  req{r.rid} prompt_len={len(r.prompt)} -> {r.out}")
    print(f"{toks} tokens in {dt:.2f}s = {toks/dt:.1f} tok/s")
    assert all(r.done for r in done)


if __name__ == "__main__":
    main()
