"""Multi-tenant serving: three networks, one front door, weighted-fair.

  PYTHONPATH=src python examples/serve_multitenant.py

1. compiles executable-scale mini ResNet-18, ResNet-50, and MobileNet
   pipelines (each its own H2PIPE accelerator with its own §V-A credit
   bound — the MobileNet one with the adaptive microbatch ladder);
2. registers four tenants against them through one
   :class:`~repro.runtime.frontend.MultiTenantFrontEnd`: weighted
   shares (``video`` gets 4x ``batch``), one latency-sensitive tenant
   with a per-request deadline;
3. pushes mixed open-loop traffic through the front door, then prints
   the :class:`FrontEndReport`: per-tenant latency percentiles,
   deadline-miss rates, the deficit-round-robin pick counts, and
   Jain's fairness index over weight-normalized delivered throughput;
4. spot-checks one request per network against the sequential
   ``run()`` reference — scheduling policy never changes an output bit.
"""
import jax
import numpy as np

from repro import compiler
from repro.configs.cnn import mini_mobilenet, mini_resnet18, mini_resnet50
from repro.models.cnn import cnn_input_shape, init_cnn_params
from repro.runtime.frontend import MultiTenantFrontEnd


def main() -> None:
    nets = {}
    for i, (name, cfg) in enumerate({
            "resnet18": mini_resnet18(hw=8, width=16, stages=4),
            "resnet50": mini_resnet50(hw=8, width=16, stages=4),
            "mobilenet": mini_mobilenet(hw=8, width=16, blocks=4),
    }.items()):
        cp = compiler.compile(cfg, compiler.TPU_INTERPRET)
        nets[name] = (cfg, cp, init_cnn_params(jax.random.PRNGKey(i), cfg))
        print(f"compiled {name}: {len(cp.plan.schedules)} layers, "
              f"{len(cp.plan.streamed)} streamed")

    fe = MultiTenantFrontEnd(
        {
            "resnet18": nets["resnet18"][1].serve(
                nets["resnet18"][2], microbatch=4, credits=2,
                queue_depth=4),
            "resnet50": nets["resnet50"][1].serve(
                nets["resnet50"][2], microbatch=4, credits=2,
                queue_depth=4),
            "mobilenet": nets["mobilenet"][1].serve(
                nets["mobilenet"][2], microbatch=4, credits=2,
                queue_depth=4, adaptive=True),
        },
        max_outstanding=6)
    fe.register_tenant("video", network="resnet18", weight=4.0)
    fe.register_tenant("batch", network="resnet18", weight=1.0)
    fe.register_tenant("search", network="resnet50", weight=2.0)
    fe.register_tenant("edge", network="mobilenet", weight=1.0,
                       deadline_ms=5000.0)

    rng = np.random.default_rng(0)

    def images(cfg, n):
        shape = cnn_input_shape(cfg, 1)[1:]
        return rng.integers(-127, 128, size=(n,) + shape,
                            dtype=np.int16).astype(np.int8)

    traffic = []
    for k in range(6):
        traffic.append(("video", images(nets["resnet18"][0], 2)))
        traffic.append(("search", images(nets["resnet50"][0], 1)))
        if k % 2 == 0:
            traffic.append(("batch", images(nets["resnet18"][0], 3)))
        traffic.append(("edge", images(nets["mobilenet"][0], 1)))

    with fe:
        reqs = [(t, fe.submit(t, imgs)) for t, imgs in traffic]
        fe.drain()
        report = fe.report()

    print()
    print(report.table())

    # scheduling never changes an output bit: spot-check one request
    # per network against the sequential reference
    spot = {"video": "resnet18", "search": "resnet50", "edge": "mobilenet"}
    for tenant, net in spot.items():
        t, req = next(r for r in reqs if r[0] == tenant)
        _, cp, params = nets[net]
        want = np.asarray(cp.run(params, req.images)[0])
        assert np.array_equal(req.result(), want), f"{tenant} diverged!"
    print("\nspot-checked bit-identical to sequential run() "
          "on all three networks")


if __name__ == "__main__":
    main()
