"""CNN model tests: reduced networks run, kernel-vs-model agreement, and
the dataflow engine's layer accounting."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import CNN_CONFIGS
from repro.models.cnn import (cnn_forward, cnn_input_shape, init_cnn_params,
                              conv_layer_forward)


@pytest.mark.parametrize("name", sorted(CNN_CONFIGS))
def test_reduced_cnn_forward(name, rng_key):
    cfg = CNN_CONFIGS[name].reduced()
    params = init_cnn_params(rng_key, cfg)
    x = jax.random.randint(rng_key, cnn_input_shape(cfg, 2), -127, 128,
                           jnp.int8)
    logits = cnn_forward(params, cfg, x)
    assert logits.shape[0] == 2 and logits.shape[1] > 0
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_conv_layer_matches_pallas_kernel(rng_key):
    """The model's conv layer and the Pallas engine produce identical int8
    activations (same requantization contract)."""
    from repro.configs.cnn import ConvLayerSpec
    from repro.kernels.conv2d_int8.ops import conv2d_int8_requant
    spec = ConvLayerSpec("t", "conv", 3, 3, 8, 16, 1, 12, 12)
    from repro.models.cnn import init_conv_layer
    params = init_conv_layer(rng_key, spec)
    x = jax.random.randint(rng_key, (2, 12, 12, 8), -127, 128, jnp.int8)
    y_model, _ = conv_layer_forward(params, spec, x)
    y_kernel = conv2d_int8_requant(x, params["w"], params["w_scale"],
                                   params["bias"], stride=1, interpret=True)
    assert bool(jnp.all(y_model == y_kernel))


def test_macs_and_traffic_positive():
    for name, cfg in CNN_CONFIGS.items():
        assert cfg.total_macs() > 0
        assert cfg.total_weight_traffic() > cfg.total_weight_bits() // 8, \
            name                      # traffic >= one full read (out_h >= 1)
