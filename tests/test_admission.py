"""The §V-A credit-admission law: runtime controller == cycle model.

``core/admission.py`` is the slot/credit bookkeeping both serving
runtimes share.  Its contract is proved three ways:

  * **controller semantics** — acquire/release accounting, the blocking
    path, and the invariant hooks stress tests rely on;
  * **law == fifo_sim** — :func:`replay_schedule` (an actual
    ``AdmissionController`` driven on a discrete clock) is
    makespan-, stall- and bound-exact against
    ``fifo_sim.simulate(..., "credit")`` on the single-engine law
    topology (one layer, burst 1, one word per activation: credits =
    burst-matching FIFO depth, admission = prefetcher issue, completion
    = engine consume).  This is the property the ISSUE calls the
    runtime/cycle-model agreement;
  * **law == dataflow schedule** — with ``latency = n_stages - 1`` the
    replay reproduces ``core.dataflow.pipeline_stats`` exactly: makespan
    ``M + S - 1`` ticks, at most ``S`` (= ``in_flight_credits``) in
    flight.
"""
import threading
import time

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import fifo_sim
from repro.core.admission import (AdmissionController, AdmissionError,
                                  replay_schedule,
                                  replay_staged_schedule)
from repro.core.dataflow import pipeline_stats


# ---------------------------------------------------------------------------
# controller semantics
# ---------------------------------------------------------------------------


def test_controller_basic_accounting():
    c = AdmissionController(2)
    assert c.free_credits == 2 and c.in_flight == 0
    assert c.try_acquire() and c.try_acquire()
    assert not c.try_acquire()                   # bound enforced
    assert c.in_flight == 2 == c.max_in_flight_seen
    c.release()
    assert c.free_credits == 1
    c.release()
    c.assert_quiescent()
    assert c.admitted_total == 2 == c.completed_total


def test_over_release_raises():
    c = AdmissionController(1)
    with pytest.raises(AdmissionError, match="release"):
        c.release()
    assert c.try_acquire()
    c.release(1)
    with pytest.raises(AdmissionError):
        c.release(1)


def test_slot_context_manager():
    c = AdmissionController(1)
    with c.slot():
        assert c.in_flight == 1
        with pytest.raises(AdmissionError):      # second slot: no credit
            with c.slot(timeout=0.01):
                pass
    c.assert_quiescent()


def test_blocking_acquire_wakes_on_release():
    c = AdmissionController(1)
    assert c.acquire()
    got = []
    t = threading.Thread(target=lambda: got.append(c.acquire(timeout=5)))
    t.start()
    time.sleep(0.05)
    assert not got                               # genuinely blocked
    c.release()
    t.join(timeout=5)
    assert got == [True]
    c.release()
    c.assert_quiescent()


def test_close_wakes_blocked_acquirers():
    c = AdmissionController(1)
    assert c.acquire()
    got = []
    t = threading.Thread(target=lambda: got.append(c.acquire()))
    t.start()
    time.sleep(0.05)
    c.close()
    t.join(timeout=5)
    assert got == [False]
    assert not c.try_acquire()                   # closed stays closed


def test_invalid_capacity_rejected():
    with pytest.raises(ValueError):
        AdmissionController(0)


# ---------------------------------------------------------------------------
# the law vs the fifo_sim cycle model
# ---------------------------------------------------------------------------


def _law_sim(capacity: int, latency: int, n: int) -> fifo_sim.SimOutcome:
    """fifo_sim's credit mode on the single-engine law topology: the
    prefetcher's per-layer credits ARE the admission credits."""
    return fifo_sim.simulate(fifo_sim.SimConfig(
        n_layers=1, burst=1, bm_fifo_depth=capacity, act_fifo_depth=1,
        dcfifo_depth=max(64, capacity), hbm_latency=latency,
        weights_per_act=(1,), outputs_needed=n), "credit")


@settings(max_examples=60, deadline=None)
@given(capacity=st.integers(1, 8), latency=st.integers(1, 40),
       n=st.integers(1, 50))
def test_replay_matches_fifo_sim_credit_mode(capacity, latency, n):
    """Runtime admission law == cycle model, exactly: same makespan,
    same tail-stall count, and the in-flight high-water mark equals the
    Little's-law bound min(credits, latency+1, n)."""
    sim = _law_sim(capacity, latency, n)
    trace = replay_schedule(n, capacity=capacity, latency_ticks=latency)
    assert sim.completed and not sim.deadlocked
    assert trace.makespan == sim.cycles
    assert trace.idle_ticks == sim.stall_cycles
    assert len(trace.admit_ticks) == n == len(trace.complete_ticks)
    assert trace.max_in_flight == min(capacity, latency + 1, n)
    # one admission per tick, never two
    assert all(b > a for a, b in zip(trace.admit_ticks,
                                     trace.admit_ticks[1:]))


def test_replay_verifies_caller_controller():
    """Passing a controller replays the law through THAT instance —
    its counters afterwards show the whole schedule went through it."""
    ctl = AdmissionController(3)
    trace = replay_schedule(10, capacity=3, latency_ticks=5,
                            controller=ctl)
    assert ctl.admitted_total == ctl.completed_total == 10
    assert ctl.max_in_flight_seen == trace.max_in_flight == 3
    ctl.assert_quiescent()
    with pytest.raises(ValueError, match="capacity"):
        replay_schedule(1, capacity=2, latency_ticks=1, controller=ctl)
    # a controller that can never admit must be rejected, not spun on
    busy = AdmissionController(2)
    assert busy.try_acquire()
    with pytest.raises(ValueError, match="open and idle"):
        replay_schedule(1, capacity=2, latency_ticks=1, controller=busy)
    busy.release()
    busy.close()
    with pytest.raises(ValueError, match="open and idle"):
        replay_schedule(1, capacity=2, latency_ticks=1, controller=busy)


@settings(max_examples=30, deadline=None)
@given(stages=st.integers(1, 6), microbatches=st.integers(1, 24))
def test_replay_matches_dataflow_static_schedule(stages, microbatches):
    """latency = S-1 ticks (a microbatch leaves the pipe S-1 ticks after
    admission) reproduces core/dataflow.py's static schedule: makespan
    M + S - 1 with at most S = in_flight_credits in flight."""
    stats = pipeline_stats(stages, microbatches)
    trace = replay_schedule(microbatches, capacity=stages,
                            latency_ticks=stages - 1)
    assert trace.makespan == stats["ticks"]
    assert trace.max_in_flight <= stats["in_flight_credits"]
    assert trace.max_in_flight == min(stages, microbatches)
    # admissions are back to back: the static schedule never stalls the
    # admission port when credits cover the pipeline depth
    assert trace.admit_ticks == list(range(1, microbatches + 1))


@settings(max_examples=30, deadline=None)
@given(stages=st.integers(1, 6), microbatches=st.integers(1, 24),
       extra=st.integers(0, 4))
def test_staged_replay_matches_flat_replay(stages, microbatches, extra):
    """The staged replay (per-stage occupancy checked, not assumed) is
    the flat replay at latency S-1: same makespan, same admissions —
    and no stage ever held two microbatches, for any capacity >= S."""
    capacity = stages + extra
    staged = replay_staged_schedule(microbatches, n_stages=stages,
                                    capacity=capacity)
    flat = replay_schedule(microbatches, capacity=capacity,
                           latency_ticks=stages - 1)
    assert staged.makespan == flat.makespan
    assert staged.admit_ticks == flat.admit_ticks
    assert staged.complete_ticks == flat.complete_ticks
    assert staged.max_stage_occupancy <= 1
    assert staged.max_in_flight <= capacity


def test_staged_replay_tight_credits_stall_not_overrun():
    """capacity < S stalls admission (longer makespan) but still never
    puts two microbatches on one stage."""
    S, M = 5, 12
    tight = replay_staged_schedule(M, n_stages=S, capacity=2)
    full = replay_staged_schedule(M, n_stages=S)
    assert tight.max_stage_occupancy <= 1
    assert tight.max_in_flight <= 2
    assert tight.makespan > full.makespan == M + S - 1


def test_staged_replay_through_caller_controller():
    ctl = AdmissionController(4)
    trace = replay_staged_schedule(9, n_stages=4, capacity=4,
                                   controller=ctl)
    assert ctl.admitted_total == ctl.completed_total == 9
    assert trace.makespan == 9 + 4 - 1
    ctl.assert_quiescent()
    with pytest.raises(ValueError, match="n_stages"):
        replay_staged_schedule(1, n_stages=0)


# ---------------------------------------------------------------------------
# weighted-fair tenant scheduling (the front-end tier over the law)
# ---------------------------------------------------------------------------

from repro.core.admission import (HeadOfQueue, WeightedFairScheduler,
                                  jain_fairness)


def test_wfs_registration_and_validation():
    s = WeightedFairScheduler()
    with pytest.raises(ValueError, match="quantum"):
        WeightedFairScheduler(quantum=0.0)
    s.register("a", 2.0)
    with pytest.raises(ValueError, match="already"):
        s.register("a")
    with pytest.raises(ValueError, match="weight"):
        s.register("b", 0.0)
    with pytest.raises(ValueError, match="at least one"):
        s.pick({})
    with pytest.raises(ValueError, match="not registered"):
        s.pick({"ghost": HeadOfQueue(1.0)})
    with pytest.raises(ValueError, match="not registered"):
        s.unregister("ghost")
    assert s.tenants == ["a"] and s.weight("a") == 2.0
    s.unregister("a")
    assert s.tenants == []


@settings(max_examples=40, deadline=None)
@given(weights=st.lists(st.integers(1, 8), min_size=2, max_size=5),
       rounds=st.integers(50, 300))
def test_wfs_long_run_shares_track_weights(weights, rounds):
    """DRR law: for continuously backlogged tenants with unit-cost
    heads, delivered counts are weight-proportional to within one
    quantum per tenant per ring cycle."""
    s = WeightedFairScheduler()
    for i, w in enumerate(weights):
        s.register(i, float(w))
    backlog = {i: HeadOfQueue(1.0) for i in range(len(weights))}
    n = rounds * sum(weights)
    for _ in range(n):
        s.pick(backlog)
    assert sum(s.picks.values()) == n
    for i, w in enumerate(weights):
        want = n * w / sum(weights)
        # the deficit mechanism bounds the deviation by one cycle's
        # grant — generous slack here, exactness is not the law
        assert abs(s.picks[i] - want) <= sum(weights) + 1


def test_wfs_deadline_promotion_charges_deficit():
    s = WeightedFairScheduler()
    s.register("heavy", 8.0)
    s.register("urgent", 0.5)
    backlog = {"heavy": HeadOfQueue(1.0),
               "urgent": HeadOfQueue(1.0, deadline=5.0)}
    # slack still positive: normal DRR order (heavy first, weight 8)
    assert s.pick(backlog, now=0.0) == "heavy"
    assert s.promotions == 0
    # slack negative: urgent jumps the line regardless of weight...
    assert s.pick(backlog, now=6.0) == "urgent"
    assert s.promotions == 1
    # ...and the cost was charged — its deficit went negative, so the
    # promotion is NOT a way to escape the long-run weighted share
    assert s._deficit["urgent"] < 0.0
    # most-overdue-first among several negative slacks
    b2 = {"heavy": HeadOfQueue(1.0, deadline=4.0),
          "urgent": HeadOfQueue(1.0, deadline=1.0)}
    assert s.pick(b2, now=10.0) == "urgent"
    assert s.promotions == 2


def test_wfs_idle_tenant_deficit_resets():
    """Standard DRR: a tenant observed idle must not hoard deficit and
    burst past its share when it returns."""
    s = WeightedFairScheduler()
    s.register("a", 1.0)
    s.register("b", 1.0)
    # b idle: a is served repeatedly while b's deficit is reset each call
    for _ in range(10):
        assert s.pick({"a": HeadOfQueue(1.0)}) == "a"
    assert s._deficit["b"] == 0.0
    # b returns: it gets its fair alternation, not a 10-pick burst
    backlog = {"a": HeadOfQueue(1.0), "b": HeadOfQueue(1.0)}
    picks = [s.pick(backlog) for _ in range(10)]
    assert 4 <= picks.count("b") <= 6


def test_wfs_unregister_mid_rotation_keeps_cursor_sane():
    s = WeightedFairScheduler()
    for k in ("a", "b", "c"):
        s.register(k)
    s.pick({"c": HeadOfQueue(1.0)})         # cursor parked at c
    s.unregister("a")                        # removal BEFORE the cursor
    # remaining tenants still alternate fairly
    backlog = {"b": HeadOfQueue(1.0), "c": HeadOfQueue(1.0)}
    picks = [s.pick(backlog) for _ in range(8)]
    assert 3 <= picks.count("b") <= 5


def test_wfs_nonconvergence_guard():
    s = WeightedFairScheduler(quantum=1e-12)
    s.register("a", 1.0)
    with pytest.raises(RuntimeError, match="converge"):
        s.pick({"a": HeadOfQueue(1e12)})


def test_jain_fairness_index():
    assert jain_fairness({}) == 1.0
    assert jain_fairness({"a": 5.0, "b": 5.0}) == pytest.approx(1.0)
    assert jain_fairness({"a": 1.0, "b": 0.0}) == pytest.approx(0.5)
    got = jain_fairness({"a": 1.0, "b": 1.0, "c": 1.0, "d": 0.0})
    assert got == pytest.approx(0.75)
    assert jain_fairness({"a": 0.0}) == 1.0
