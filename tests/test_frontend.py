"""Multi-tenant multi-network serving front-end: one admission door
over several running engines.

Contract under test (runtime/frontend.py + core/admission.py's
WeightedFairScheduler over the UNCHANGED AdmissionController):

  * mixed three-network traffic (mini ResNet-18 + ResNet-50 +
    MobileNet), closed- AND open-loop, is BIT-IDENTICAL per request to
    each network's sequential ``run()`` — weighted-fair scheduling and
    deadline promotion reorder service, never an output bit;
  * the front-end-wide credit bound (``max_outstanding``) holds under
    concurrent multi-tenant producers, asserted through the admission
    controller's invariant hooks (high-water mark, conservation,
    quiescence) — and each engine's own §V-A controller stays
    quiescent too;
  * under sustained backlog, delivered throughput tracks tenant
    weights (1:4 within 20%) and the report's Jain index over
    weight-normalized shares is high;
  * a tenant with an expiring deadline is promoted past heavier
    tenants (``promotions`` observable in the report);
  * observability rides the shared obs subsystem: tenant-labelled
    counters, one ``tenant:<name>`` trace track per tenant, and a
    :class:`FrontEndReport` that JSON round-trips to equality.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro import compiler
from repro.compiler import TPU_INTERPRET
from repro.configs.cnn import (mini_mobilenet, mini_resnet18,
                               mini_resnet50)
from repro.models.cnn import cnn_input_shape, init_cnn_params
from repro.obs import Tracer, validate_chrome_trace
from repro.runtime.frontend import (FrontEndReport, MultiTenantFrontEnd,
                                    TenantSpec)

CFGS = {
    "mini_resnet18": mini_resnet18(hw=8, width=16, stages=4),
    "mini_resnet50": mini_resnet50(hw=8, width=16, stages=4),
    "mini_mobilenet": mini_mobilenet(hw=8, width=16, blocks=4),
}


@pytest.fixture(scope="module")
def nets():
    """network name -> (compiled pipeline, params)."""
    out = {}
    for i, (name, cfg) in enumerate(CFGS.items()):
        cp = compiler.compile(cfg, TPU_INTERPRET)
        out[name] = (cp, init_cnn_params(jax.random.PRNGKey(i), cfg))
    return out


def _requests(cfg, sizes, seed=0):
    rng = np.random.default_rng(seed)
    shape = cnn_input_shape(cfg, 1)[1:]
    return [rng.integers(-127, 128, size=(n,) + shape,
                         dtype=np.int16).astype(np.int8) for n in sizes]


def _reference_rows(cp, params, batches):
    big = np.concatenate(batches, axis=0)
    ref = np.asarray(cp.run(params, big)[0])
    out, off = [], 0
    for b in batches:
        out.append(ref[off:off + len(b)])
        off += len(b)
    return out


def _front_end(nets, **kw):
    engines = {name: cp.serve(params, microbatch=4, credits=2,
                              queue_depth=4)
               for name, (cp, params) in nets.items()}
    return MultiTenantFrontEnd(engines, **kw)


def test_three_network_traffic_bit_identical(nets):
    """Closed-loop serve() AND open-loop submit/collect across all
    three networks at once: every request's logits equal the sequential
    run() reference for its own network."""
    fe = _front_end(nets, max_outstanding=6)
    per_net = {}
    for i, name in enumerate(nets):
        per_net[name] = _requests(CFGS[name], [1, 3, 2, 5], seed=100 + i)
    fe.register_tenant("a18", network="mini_resnet18", weight=1.0)
    fe.register_tenant("a50", network="mini_resnet50", weight=2.0)
    fe.register_tenant("amb", network="mini_mobilenet", weight=1.0)
    tenant_of = {"mini_resnet18": "a18", "mini_resnet50": "a50",
                 "mini_mobilenet": "amb"}
    with fe:
        # closed loop: first two batches of each net through serve()
        closed = [(tenant_of[n], b) for n in per_net
                  for b in per_net[n][:2]]
        closed_out, _ = fe.serve(closed)
        # open loop: remaining batches submitted interleaved, results
        # collected after the fact
        open_reqs = [(n, fe.submit(tenant_of[n], b))
                     for i in (2, 3) for n in per_net
                     for b in [per_net[n][i]]]
        fe.drain()
        rep = fe.report()
    # closed-loop identity
    want = {n: _reference_rows(*nets[n], per_net[n]) for n in per_net}
    idx = 0
    for n in per_net:
        for i in range(2):
            assert np.array_equal(closed_out[idx], want[n][i])
            idx += 1
    # open-loop identity
    seen = {n: 2 for n in per_net}
    for n, req in open_reqs:
        assert np.array_equal(req.result(), want[n][seen[n]])
        seen[n] += 1
    assert rep.requests == 12
    assert rep.images == sum(sum(len(b) for b in bs)
                             for bs in per_net.values())
    assert rep.networks == tuple(sorted(nets))
    assert {r["tenant"] for r in rep.tenant_rows} == {"a18", "a50", "amb"}


def test_concurrent_producers_hold_admission_invariants(nets):
    """N producer threads x 3 tenants on one shared front door: the
    global max_outstanding bound holds through the controller's
    invariant hooks, every engine's own credit bound stays quiescent,
    and nothing is lost or corrupted."""
    name = "mini_resnet18"
    cp, params = nets[name]
    fe = MultiTenantFrontEnd(
        {name: cp.serve(params, microbatch=4, credits=2, queue_depth=2)},
        max_outstanding=3)
    tenants = ["t0", "t1", "t2"]
    for t in tenants:
        fe.register_tenant(t, network=name, weight=1.0)
    batches = {t: _requests(CFGS[name], [1, 2, 1, 3], seed=i)
               for i, t in enumerate(tenants)}
    got = {}
    errors = []

    def producer(t):
        try:
            got[t] = [fe.submit(t, b) for b in batches[t]]
        except BaseException as exc:          # pragma: no cover
            errors.append(exc)

    with fe:
        threads = [threading.Thread(target=producer, args=(t,))
                   for t in tenants]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert not errors
        fe.drain()
    ctl = fe.admission
    assert ctl.max_in_flight_seen <= 3        # the global bound HELD
    assert ctl.admitted_total == ctl.completed_total == 12
    ctl.assert_quiescent()
    eng = fe._lanes[name].engine
    assert eng.admission.max_in_flight_seen <= 2
    eng.admission.assert_quiescent()
    for t in tenants:
        for req, want in zip(got[t],
                             _reference_rows(cp, params, batches[t])):
            assert np.array_equal(req.result(), want)


def test_weighted_shares_track_weights_under_backlog(nets):
    """1:4 weights on one network under sustained backlog: the DRR
    tier's delivered split tracks the weights within 20%, visible in a
    mid-run report snapshot (the drained end-state always converges to
    the submitted ratio and proves nothing)."""
    name = "mini_resnet18"
    cp, params = nets[name]
    fe = MultiTenantFrontEnd(
        {name: cp.serve(params, microbatch=1, credits=1, queue_depth=1)},
        max_outstanding=1)                    # serialize: backlog pools here
    fe.register_tenant("light", network=name, weight=1.0)
    fe.register_tenant("heavy", network=name, weight=4.0)
    n_each = 30
    batches = _requests(CFGS[name], [1] * n_each, seed=0)
    with fe:
        for b in batches:                     # enqueue far faster than service
            fe.submit("light", b)
            fe.submit("heavy", b)
        # mid-run: wait for a window past scheduler warm-up, snapshot
        while True:
            rep = fe.report()
            done = {r["tenant"]: r["images"] for r in rep.tenant_rows}
            if sum(done.values()) >= 25:
                break
            time.sleep(0.01)
        fe.drain()
        final = fe.report()
    ratio = done["heavy"] / max(1, done["light"])
    assert 4.0 * 0.8 <= ratio <= 4.0 * 1.2, (done, ratio)
    assert rep.fairness >= 0.95               # weight-normalized Jain
    # the drained end state delivered everything for both tenants
    rows = {r["tenant"]: r for r in final.tenant_rows}
    assert rows["light"]["images"] == rows["heavy"]["images"] == n_each
    # scheduler evidence rode into the report rows
    assert rows["heavy"]["picks"] + rows["light"]["picks"] == 2 * n_each
    assert rows["heavy"]["served_cost"] == pytest.approx(n_each)


def test_deadline_promotion_jumps_the_line(nets):
    """An overdue tenant is served out of DRR order: with an (already
    expiring) deadline against a weight-8 competitor, its requests are
    promoted — observable as report.promotions — and every deadline
    miss is counted per tenant."""
    name = "mini_mobilenet"
    cp, params = nets[name]
    fe = MultiTenantFrontEnd(
        {name: cp.serve(params, microbatch=1, credits=1, queue_depth=1)},
        max_outstanding=1)
    fe.register_tenant("bulk", network=name, weight=8.0)
    # 0 ms of slack: overdue the moment the scheduler looks at it
    fe.register_tenant("rt", network=name, weight=1.0, deadline_ms=0.0)
    batches = _requests(CFGS[name], [1] * 10, seed=1)
    with fe:
        for b in batches:
            fe.submit("bulk", b)
            fe.submit("rt", b)
        fe.drain()
        rep = fe.report()
    rows = {r["tenant"]: r for r in rep.tenant_rows}
    assert rep.promotions > 0
    assert rows["rt"]["deadline_misses"] > 0       # 0ms is unmeetable
    assert rows["rt"]["deadline_miss_rate"] == \
        rows["rt"]["deadline_misses"] / rows["rt"]["requests"]
    assert rows["bulk"]["deadline_misses"] == 0    # no deadline, no miss
    assert rows["bulk"]["deadline_miss_rate"] == 0.0


def test_tenant_labelled_obs_and_trace_tracks(nets):
    """Per-tenant observability: labelled counters on the front-end
    registry and one ``tenant:<name>`` async track per tenant in the
    exported Chrome trace."""
    name = "mini_resnet18"
    cp, params = nets[name]
    tr = Tracer()
    fe = MultiTenantFrontEnd(
        {name: cp.serve(params, microbatch=4, credits=2)}, tracer=tr)
    fe.register_tenant("alice", network=name, weight=1.0)
    fe.register_tenant("bob", network=name, weight=1.0)
    with fe:
        _, rep = fe.serve([("alice", b) for b in
                           _requests(CFGS[name], [1, 2], seed=3)]
                          + [("bob", b) for b in
                             _requests(CFGS[name], [3], seed=4)])
    c = rep.metrics["counters"]
    assert c["frontend_requests_submitted{tenant=alice}"] == 2
    assert c["frontend_requests_submitted{tenant=bob}"] == 1
    assert c["frontend_images_delivered{tenant=alice}"] == 3
    assert c["frontend_images_delivered{tenant=bob}"] == 3
    trace = tr.to_chrome_trace()
    assert validate_chrome_trace(trace) == []
    # one tid row per tenant (the Tracer admits new tracks on first use)
    tracks = {e["args"]["name"] for e in trace["traceEvents"]
              if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"tenant:alice", "tenant:bob"} <= tracks
    # each request's async span opened AND closed
    begins = [e for e in trace["traceEvents"] if e["ph"] == "b"]
    ends = [e for e in trace["traceEvents"] if e["ph"] == "e"]
    assert len(begins) == len(ends) == 3


def test_report_round_trip_and_table(nets):
    name = "mini_mobilenet"
    cp, params = nets[name]
    fe = MultiTenantFrontEnd(
        {name: cp.serve(params, microbatch=4, credits=2)})
    fe.register_tenant("solo", network=name, weight=2.0,
                       deadline_ms=1e6)
    with fe:
        _, rep = fe.serve([("solo", b) for b in
                           _requests(CFGS[name], [2, 1], seed=5)])
    back = FrontEndReport.from_json(rep.to_json())
    assert back == rep
    assert isinstance(back.networks, tuple)
    assert isinstance(back.tenant_rows, tuple)
    assert FrontEndReport.from_json(rep.to_dict()) == rep
    text = rep.table()
    assert "fairness(Jain)" in text and "solo" in text
    assert "deadline promotions" in text


def test_validation_and_lifecycle(nets):
    name = "mini_resnet18"
    cp, params = nets[name]
    eng = cp.serve(params, microbatch=2, credits=2)
    with pytest.raises(ValueError, match="at least one"):
        MultiTenantFrontEnd({})
    fe = MultiTenantFrontEnd({name: eng})
    with pytest.raises(ValueError, match="unknown network"):
        fe.register_tenant("x", network="nope")
    fe.register_tenant("x", network=name)
    with pytest.raises(ValueError, match="already"):
        fe.register_tenant("x", network=name)
    spec = fe.tenants["x"]
    assert spec == TenantSpec("x", name, 1.0, None)
    img = _requests(CFGS[name], [1], seed=6)[0]
    with pytest.raises(RuntimeError, match="not started"):
        fe.submit("x", img)
    with fe:
        with pytest.raises(ValueError, match="unknown tenant"):
            fe.submit("ghost", img)
        req = fe.submit("x", img)
        assert req.result(timeout=60).shape[0] == 1
        assert req.latency_s > 0
    # single-use, like the engines it owns
    with pytest.raises(RuntimeError, match="single-use"):
        fe.start()
