"""Roofline tooling tests: jaxpr cost exactness, HLO collective parsing,
while-loop trip-count scaling."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline import hw
from repro.roofline.analysis import Roofline, collective_bytes
from repro.roofline.hlo_loops import scaled_collective_bytes, \
    split_computations
from repro.roofline.jaxpr_cost import cost_of


def test_dot_flops_exact():
    f = lambda a, b: a @ b
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = cost_of(f, a, b)
    assert c["flops"] == 2 * 64 * 128 * 32


def test_scan_multiplies_by_length():
    def f(x, ws):
        def body(x, w):
            return x @ w, None
        return jax.lax.scan(body, x, ws)[0]
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)
    c = cost_of(f, x, ws)
    assert c["flops"] >= 10 * 2 * 64**3
    assert c["flops"] < 11 * 2 * 64**3


def test_grad_counts_backward():
    f = lambda a, b: jnp.sum(a @ b)
    g = jax.grad(f)
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    fwd = cost_of(f, a, b)["flops"]
    bwd = cost_of(g, a, b)["flops"]
    assert bwd >= 2 * fwd * 0.9               # dA and dB matmuls


def test_conv_flops():
    def f(x, w):
        return jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    x = jax.ShapeDtypeStruct((1, 8, 8, 4), jnp.float32)
    w = jax.ShapeDtypeStruct((3, 3, 4, 16), jnp.float32)
    c = cost_of(f, x, w)
    assert c["flops"] == 2 * (8 * 8 * 16) * (3 * 3 * 4)


def test_fused_traffic_excludes_elementwise():
    f = lambda a: jnp.tanh(a) + 1.0
    a = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    c = cost_of(f, a)
    assert c["bytes"] == 0.0                  # pure elementwise fuses


HLO_SAMPLE = """
HloModule test

%region_body (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %ag = f32[128,256]{1,0} all-gather(%x), dimensions={1}
  ROOT %t = (s32[], f32[128,256]) tuple(%i, %ag)
}

%region_cond (p: (s32[], f32[128,256])) -> pred[] {
  %c = s32[] constant(12)
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %ar = f32[128,256]{1,0} all-reduce(%a), to_apply=%add
  %w = (s32[], f32[128,256]) while(%init), condition=%region_cond, body=%region_body
  ROOT %out = f32[128,256]{1,0} get-tuple-element(%w), index=1
}
"""


def test_collective_bytes_module_sum():
    out = collective_bytes(HLO_SAMPLE)
    assert out["all-reduce"] == 128 * 256 * 4
    assert out["all-gather"] == 128 * 256 * 4


def test_while_scaling():
    out = scaled_collective_bytes(HLO_SAMPLE)
    base = 128 * 256 * 4
    assert out["naive"] == 2 * base
    assert out["scaled"] == base + 12 * base   # AR once + AG x12


def test_split_computations():
    comps = split_computations(HLO_SAMPLE)
    assert "region_body" in comps and "main" in comps


def test_roofline_terms_and_dominance():
    r = Roofline(arch="x", shape="y", mesh="16x16", chips=256,
                 hlo_flops=256 * hw.PEAK_FLOPS_BF16,      # 1 s compute
                 hlo_bytes=256 * hw.HBM_BW * 0.5,         # 0.5 s memory
                 coll_bytes=hw.ICI_BW_PER_LINK * hw.ICI_LINKS * 0.25,
                 model_flops=0.8 * 256 * hw.PEAK_FLOPS_BF16)
    assert r.dominant == "compute"
    assert r.t_bound == pytest.approx(1.0)
    assert r.mfu_at_bound == pytest.approx(0.8)
    assert r.useful_fraction == pytest.approx(0.8)
