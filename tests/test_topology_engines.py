"""Differential test harness for full-topology engine coverage.

The contract under test (the topology-engine migration): the compiler
owns 100% of the CNN graph —

  * every topology node (convs, fc heads, maxpool, global-average-pool)
    has a compile-time engine assignment on the paper's three networks;
    nothing implicit is left in ``cnn_forward``, and no node silently
    lands on the ``jnp_ref`` safety net;
  * bottleneck residual blocks (1x1-3x3-1x1 + downsample — ResNet-50,
    the paper's 5.1x headline net) bind as fused ``res_block_int8``
    units on the real NX2100 target, under the tightened large-block
    VMEM model (member sum + identity + widest intermediate), and the
    fusion falls back per-layer EXACTLY when the unit cost exceeds the
    target budget (boundary-tested at budget±1 byte);
  * plan-side vs dispatch-side Eq. 2 words agree exactly for the whole
    net — hard-fail cross-check via ``eq2_report().verify()`` for the
    full-size nets (no execution needed: engines' stats are
    shape-static) and via a real executed report on the executable
    minis, where the template is also pinned equal to the traced stats;
  * the Pallas pool engines are bit-exact against the jnp references
    across shapes/strides/padding (hypothesis property tests — explicit
    deterministic cases under the stub when hypothesis is absent);
  * fused-vs-eager bit-identity still holds on nets whose graphs contain
    every node family, basic AND bottleneck blocks included.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro import compiler
from repro.compiler import NX2100, TPU_INTERPRET
from repro.configs import CNN_CONFIGS
from repro.configs.cnn import mini_resnet50, residual_blocks, stem_unit
from repro.kernels.pool_int8 import (global_avgpool_int8,
                                     global_avgpool_int8_ref, maxpool_int8,
                                     maxpool_int8_ref)
from repro.models.cnn import cnn_forward, cnn_input_shape, init_cnn_params

FULL_NETS = ("resnet18", "resnet50", "vgg16")
POOL_ENGINES = ("maxpool_int8", "global_avgpool_int8")

# the executable bottleneck net: stage-1 members are multi-M20K, so the
# TPU_INTERPRET target genuinely streams block members through HBM
MINI50 = mini_resnet50(hw=16, width=32, stages=2)


# ---------------------------------------------------------------------------
# full-size nets: coverage + the execution-free Eq. 2 cross-check
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", FULL_NETS)
def test_every_topology_node_has_an_engine(name):
    """100% of the graph is bound at compile time: each node either owns
    a per-layer Pallas engine or belongs to a fused block unit, pool
    nodes sit on the dedicated pool engines, and nothing falls to the
    jnp_ref safety net."""
    cfg = CNN_CONFIGS[name]
    cp = compiler.compile(cfg, NX2100)
    table = cp.engine_table()
    assert set(table) == {l.name for l in cfg.layers}
    assert "jnp_ref" not in table.values()
    su = stem_unit(cfg)
    stem_names = {m.name for m in su.members} if su is not None else set()
    for spec in cfg.layers:
        eng = table[spec.name]
        if spec.name in stem_names:
            # the ResNet-family stem conv + maxpool fuse as one unit
            assert eng == "stem_pool_int8"
        elif spec.kind == "maxpool":
            assert eng == "maxpool_int8"
        elif spec.kind == "gap":
            assert eng == "global_avgpool_int8"
        else:
            assert eng in ("conv2d_int8", "dwconv_int8", "stream_matmul",
                           "res_block_int8", "scanned_res_block_int8"), \
                (spec.name, eng)
    # pools exist in every paper net we compile here except none — each
    # of the three graphs carries at least one explicit pool node
    assert any(l.is_pool for l in cfg.layers)


def test_resnet50_bottleneck_blocks_fuse_on_nx2100():
    """The acceptance bar: ``compile(resnet50, NX2100)`` binds bottleneck
    blocks as fused ``res_block_int8`` units (all 16, under the
    tightened cost model), every unit within the device's VMEM budget."""
    cp = compiler.compile(CNN_CONFIGS["resnet50"], NX2100)
    bottlenecks = [b for b in cp.block_assignments
                   if sum(1 for m in b.members
                          if not m.endswith("ds")) == 3]
    assert len(bottlenecks) == 16
    for b in bottlenecks:
        assert b.engine == "res_block_int8"
        assert 0 < b.vmem_bytes <= NX2100.vmem_bytes


@pytest.mark.parametrize("name", FULL_NETS)
@pytest.mark.parametrize("batch", (1, 3))
def test_plan_vs_dispatch_eq2_words_full_net(name, batch):
    """The whole-net hard-fail cross-check, execution-free: the stats
    template the bound engines will report (shape-static — pinned equal
    to real traced reports on the executable minis below) must match the
    plan's Eq. 2 analytics node-for-node and block-for-block."""
    cp = compiler.compile(CNN_CONFIGS[name], NX2100)
    rep = cp.eq2_report(batch)
    rep.verify()                                   # raises on any drift
    assert len(rep.layers) == len(cp.schedules)
    assert rep.total_hbm_words == batch * sum(
        cp.hbm_words_per_image().values())
    # pool nodes dispatch (they appear in the template) but stream nothing
    for st_ in rep.layers:
        spec = cp.plan.schedule_for(st_.name).spec
        if spec.is_pool:
            assert st_.hbm_words == 0 and st_.mode == "pinned"
            # the stem maxpool reports under its fused unit's engine
            assert st_.kernel in POOL_ENGINES + ("stem_pool_int8",)


def test_verify_trips_on_drift():
    """``verify()`` is a real gate: corrupting one node's counted words,
    or dropping a node from the dispatch list, raises Eq2MismatchError."""
    cp = compiler.compile(CNN_CONFIGS["resnet50"], NX2100)
    good = cp.eq2_report()
    good.verify()
    bad = cp.eq2_report()
    streamed = next(i for i, st_ in enumerate(bad.layers)
                    if st_.hbm_words > 0)
    bad.layers[streamed] = dataclasses.replace(
        bad.layers[streamed], hbm_words=bad.layers[streamed].hbm_words + 1)
    with pytest.raises(compiler.Eq2MismatchError, match="!= plan"):
        bad.verify()
    short = cp.eq2_report()
    short.layers.pop()
    with pytest.raises(compiler.Eq2MismatchError, match="never dispatched"):
        short.verify()


# ---------------------------------------------------------------------------
# executable bottleneck net: bit-identity + executed == template
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mini50_setup():
    cp = compiler.compile(MINI50, TPU_INTERPRET)
    params = init_cnn_params(jax.random.PRNGKey(0), MINI50)
    x = jax.random.randint(jax.random.PRNGKey(1),
                           cnn_input_shape(MINI50, 2), -127, 128, jnp.int8)
    return cp, params, x


def test_bottleneck_net_fused_eager_reference_bit_identical(mini50_setup):
    """A net with every node family — stem conv, maxpool, BOTTLENECK
    blocks (fused, with streamed members), GAP, fc — executes
    bit-identically on the fused single-dispatch program, the eager
    per-layer walk, and the functional jnp reference."""
    cp, params, x = mini50_setup
    bottleneck = [b for b in cp.block_assignments
                  if sum(1 for m in b.members if not m.endswith("ds")) == 3]
    assert bottleneck                      # bottleneck units genuinely fuse
    assert cp.streamed_names               # and members genuinely stream
    ref = cnn_forward(params, MINI50, x)
    fused, rf = cp.run(params, x, backend="fused")
    eager, re_ = cp.run(params, x, backend="eager")
    assert bool(jnp.all(fused == eager))
    assert bool(jnp.all(fused == ref))
    assert rf.layers == re_.layers


def test_executed_report_equals_template_and_verifies(mini50_setup):
    """The executed stats ARE the template: a real traced run reports
    exactly ``stats_template(batch)``, and the report passes the
    hard-fail Eq. 2 verify — so the execution-free full-net checks above
    genuinely stand in for execution."""
    cp, params, x = mini50_setup
    batch = int(x.shape[0])
    for backend in ("fused", "eager"):
        _, rep = cp.run(params, x, backend=backend)
        assert tuple(rep.layers) == cp.stats_template(batch)
        rep.verify()
        assert rep.total_hbm_words > 0


def test_pool_nodes_execute_via_jnp_ref_when_engines_unregistered():
    """The safety net also covers the topology nodes: with the pool
    engines popped, pools bind to jnp_ref (visible in the table) and the
    pipeline still executes bit-identically via the pooling references."""
    cfg = mini_resnet50(hw=16, width=16, stages=1)
    popped = [compiler.unregister_engine(n) for n in POOL_ENGINES]
    try:
        cp = compiler.compile(cfg, TPU_INTERPRET)
        table = cp.engine_table()
        assert table["maxpool"] == "jnp_ref"
        assert table["gap"] == "jnp_ref"
        params = init_cnn_params(jax.random.PRNGKey(0), cfg)
        x = jax.random.randint(jax.random.PRNGKey(1),
                               cnn_input_shape(cfg, 1), -127, 128, jnp.int8)
        out, rep = cp.run(params, x)
        assert bool(jnp.all(out == cnn_forward(params, cfg, x)))
        rep.verify()
    finally:
        for name, eng in zip(POOL_ENGINES, popped):
            assert eng is not None
            compiler.register_engine(name, priority=10)(eng)
    assert compiler.compile(cfg, TPU_INTERPRET).engine_table()["gap"] \
        == "global_avgpool_int8"


# ---------------------------------------------------------------------------
# bottleneck fusion boundary: binds iff unit cost <= budget (±1 byte)
# ---------------------------------------------------------------------------


def _roomy_mini50():
    cp = compiler.compile(MINI50, TPU_INTERPRET)
    # boundary law covers the residual-block units only: the stem unit
    # binds independently (it obeys the same <= budget rule, but its
    # small cost never sits near these blocks' boundaries)
    costs = {b.block: b.vmem_bytes for b in cp.block_assignments
             if b.engine == "res_block_int8"}
    # precondition for clean boundary compiles: every per-layer binding
    # fits under the smallest (cost - 1) budget, so stage 5 never
    # re-places anything and the member schedules (hence unit costs)
    # stay identical across the boundary targets
    assert max(cp.vmem_report().values()) <= min(costs.values()) - 1
    return cp, costs


@given(block=st.sampled_from([b.name for b in residual_blocks(MINI50)]),
       delta=st.sampled_from([-1, 0, 1]))
@settings(max_examples=12, deadline=None)
def test_bottleneck_fusion_boundary_at_budget(block, delta):
    """Property (satellite): a bottleneck block binds as a fused unit
    EXACTLY when its tightened VMEM cost fits the target budget.  At
    ``vmem_bytes = cost + delta`` the block is fused for delta >= 0 and
    falls back to per-layer bindings at delta == -1 — with its members'
    per-layer assignments (and validation) still governing them."""
    roomy, costs = _roomy_mini50()
    cost = costs[block]
    target = TPU_INTERPRET.replace(vmem_bytes=cost + delta)
    cp = compiler.compile(MINI50, target)
    assert cp.plan == roomy.plan           # boundary never re-places
    bound = {b.block: b for b in cp.block_assignments}
    members = roomy.block_table()[block]
    if delta >= 0:
        assert block in bound
        assert bound[block].vmem_bytes == cost
        assert all(cp.engine_table()[m] == "res_block_int8"
                   for m in members)
    else:
        assert block not in bound
        for m in members:
            asn = cp.assignment_for(m)
            assert asn.block is None
            assert asn.engine in ("conv2d_int8", "dwconv_int8")
            assert asn.vmem_bytes <= target.vmem_bytes
    # other blocks obey the same law under this budget
    for other, c in costs.items():
        assert (other in bound) == (c <= cost + delta)


# ---------------------------------------------------------------------------
# pool engines: hypothesis differential vs the jnp reference
# ---------------------------------------------------------------------------


@given(h=st.integers(3, 12), w=st.integers(3, 12),
       c=st.sampled_from([1, 4, 8]), k=st.integers(1, 3),
       stride=st.integers(1, 3), batch=st.integers(1, 2))
@settings(max_examples=25, deadline=None)
def test_maxpool_engine_bit_exact_vs_reference(h, w, c, k, stride, batch):
    """The Pallas maxpool kernel is bit-exact against the float
    reference across spatial shapes, window sizes, strides and the SAME
    padding geometries they induce (including asymmetric pads and
    windows overhanging the map)."""
    x = jax.random.randint(jax.random.PRNGKey(h * 100 + w * 10 + k),
                           (batch, h, w, c), -127, 128, jnp.int8)
    got = maxpool_int8(x, k=k, stride=stride, interpret=True)
    want = maxpool_int8_ref(x, k=k, stride=stride)
    assert got.shape == want.shape
    assert got.dtype == jnp.int8
    assert bool(jnp.all(got == want)), (h, w, c, k, stride)


@given(h=st.integers(1, 9), w=st.integers(1, 9),
       c=st.sampled_from([1, 8, 16]),
       act_scale=st.sampled_from([0.05, 0.1, 0.02]))
@settings(max_examples=25, deadline=None)
def test_gap_engine_bit_exact_vs_reference(h, w, c, act_scale):
    """The Pallas GAP kernel (int32 accumulate, divide-by-count, model
    requantization) is bit-exact against the float32-mean reference —
    including 1x1 maps and non-power-of-two counts where reciprocal
    tricks would drift."""
    x = jax.random.randint(jax.random.PRNGKey(h * 10 + w),
                           (2, h, w, c), -127, 128, jnp.int8)
    got = global_avgpool_int8(x, act_scale=act_scale, interpret=True)
    want = global_avgpool_int8_ref(x, act_scale=act_scale)
    assert got.shape == want.shape == (2, 1, 1, c)
    assert bool(jnp.all(got == want)), (h, w, c, act_scale)


# ---------------------------------------------------------------------------
# pool nodes and the weight-stream machinery
# ---------------------------------------------------------------------------


def test_pool_nodes_never_hold_the_hbm_tier():
    """Weightless nodes cannot stream: a caller-forced pool offload is
    demoted by compile-time finalize (replace=True), rejected loudly
    under with_offload semantics, and the fifo_sim bridge refuses to
    fabricate word demand for zero-weight engines."""
    plan = compiler.plan_pipeline(MINI50, TPU_INTERPRET)
    forced = plan.with_offload(["maxpool"])
    demoted = compiler.finalize(forced, TPU_INTERPRET)
    assert "maxpool" not in demoted.streamed_names
    assert demoted.assignment_for("maxpool").mode == "pinned"
    with pytest.raises(compiler.CompileError, match="cannot stream"):
        compiler.finalize(forced, TPU_INTERPRET, replace=False)
    with pytest.raises(ValueError, match="no weight words"):
        forced.sim_config()
