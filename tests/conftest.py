import os

# Smoke tests see the real single CPU device (the dry-run, and only the
# dry-run, forces 512 host devices — in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
