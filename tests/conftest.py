import os
import sys

# Smoke tests see the real single CPU device (the dry-run, and only the
# dry-run, forces 512 host devices — in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# ``hypothesis`` is optional: when absent, register a deterministic stub so
# the property tests collect and replay fixed explicit cases instead
# (tests/_hypothesis_stub.py documents the semantics).
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    _hypothesis_stub.install(sys.modules)

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
