"""Tests for the staged compile() API: Target descriptors, the LayerEngine
registry, VMEM budget validation/re-placement, and the engine table.

The contract: ``compile(cfg, target)`` binds every layer to a registered
engine BEFORE execution (the table is inspectable and is exactly what
runs), validates every binding against the target's VMEM budget —
re-placing pinned layers to the HBM tier when only their streamed working
set fits, raising with a per-layer report when neither tier fits — and
the registry is the extension surface: user engines plug in (and out)
without touching the compiler.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import compiler
from repro.compiler import NX2100, TPU_INTERPRET, Target, TargetBudgetError
from repro.configs import CNN_CONFIGS
from repro.configs.cnn import mini_resnet18
from repro.models.cnn import cnn_forward, cnn_input_shape, init_cnn_params

MINI = mini_resnet18(hw=32, width=32)


# ---------------------------------------------------------------------------
# Target descriptors
# ---------------------------------------------------------------------------


def test_target_presets():
    assert NX2100.tb_budget == 1980 and NX2100.bram_m20ks == 6847
    assert NX2100.interpret is None                # auto backend
    assert TPU_INTERPRET.interpret is True         # forced interpreter
    assert compiler.get_target("nx2100") is NX2100
    with pytest.raises(KeyError):
        compiler.get_target("gpu3000")


def test_target_validation():
    with pytest.raises(ValueError):
        Target(name="bad", tb_budget=100, bram_m20ks=100, backend="vhdl")
    with pytest.raises(ValueError):
        Target(name="bad", tb_budget=0, bram_m20ks=100)


def test_target_replace_derives_variant():
    t = NX2100.replace(burst=16)
    assert t.burst == 16 and t.name == "nx2100*"
    assert NX2100.burst == 8                       # frozen original


# ---------------------------------------------------------------------------
# engine table: bindings are decided (and visible) at compile time
# ---------------------------------------------------------------------------


def test_engine_table_covers_every_layer():
    cp = compiler.compile(MINI, TPU_INTERPRET)
    table = cp.engine_table()
    assert set(table) == {l.name for l in MINI.layers}
    assert table["fc"] == "stream_matmul"
    # the stem conv + following maxpool fuse into ONE schedulable unit
    assert table["stem"] == "stem_pool_int8"
    assert table["maxpool"] == "stem_pool_int8"
    # the standalone pooling topology node keeps its own engine binding
    assert table["gap"] == "global_avgpool_int8"
    # every residual-block member is bound at BLOCK granularity (the
    # fused res_block_int8 unit — or the scanned run engine when the
    # block sits in a homogeneous run); the stem pair is a unit too
    in_blocks = {m for b in cp.block_assignments for m in b.members}
    assert in_blocks == set(table) - {"gap", "fc"}
    res_members = in_blocks - {"stem", "maxpool"}
    assert all(table[name] in ("res_block_int8", "scanned_res_block_int8")
               for name in res_members)
    # vmem report covers the same layers, all within budget
    report = cp.vmem_report()
    assert set(report) == set(table)
    assert all(0 < v <= TPU_INTERPRET.vmem_bytes for v in report.values())
    assert "engine" in cp.describe() and "stream_matmul" in cp.describe()


def test_block_units_bound_and_costed():
    """Stage 4 groups each residual block into one schedulable unit: the
    block table covers exactly the s{i}b{j} groups, each unit's VMEM
    cost is the sum of its members plus the identity buffer plus the
    widest intermediate activation map, and its Eq. 2 words are the
    streamed members' plan analytics."""
    from repro.configs.cnn import residual_blocks, stem_unit
    cp = compiler.compile(MINI, TPU_INTERPRET)
    blocks = {b.name: b for b in residual_blocks(MINI)}
    su = stem_unit(MINI)
    assert set(cp.block_table()) == set(blocks) | {su.name}
    eng = compiler.get_engine("conv2d_int8")
    for ba in cp.block_assignments:
        if ba.block == su.name:            # the stem pair: costed below
            continue
        blk = blocks[ba.block]
        assert ba.members == tuple(m.name for m in blk.members)
        scheds = cp.plan.schedules_for(ba.members)
        member_sum = sum(eng.vmem_bytes(s.spec, s) for s in scheds)
        first = blk.convs[0]
        widest = max(m.out_h * m.out_w * m.c_out for m in blk.members)
        assert ba.vmem_bytes == member_sum + first.in_h * first.in_w \
            * first.c_in + widest
        assert ba.vmem_bytes <= TPU_INTERPRET.vmem_bytes
        assert ba.hbm_words_per_image == sum(
            s.weight_words_per_image for s in scheds if s.streamed)
    # block_for resolves by block name and by member name
    ba = cp.block_for("s1b0")
    assert ba is not None and cp.block_for("s1b0c1") is ba
    # the stem conv + maxpool pair binds as its own fused unit
    sa = cp.block_for(su.name)
    assert sa is not None and sa.engine == "stem_pool_int8"
    assert sa.members == ("stem", "maxpool")
    assert cp.block_for("maxpool") is sa


def test_block_unit_over_vmem_falls_back_to_per_layer():
    """A block whose summed working set exceeds the target's VMEM budget
    is NOT bound as a unit — its layers keep their per-layer bindings
    (and per-layer validation still governs them)."""
    cp = compiler.compile(MINI, REPLACE_TARGET)
    assert cp.block_assignments == ()
    assert "res_block_int8" not in cp.engine_table().values()


def test_dwconv_layers_bind_to_registered_engine():
    """MobileNet depthwise layers get the grouped Pallas engine — no
    silent jnp fallback anywhere in the table — and execution is
    bit-identical to the reference."""
    cfg = CNN_CONFIGS["mobilenetv1"].reduced()
    cp = compiler.compile(cfg, TPU_INTERPRET.replace(bram_m20ks=10_000))
    table = cp.engine_table()
    dw = [l.name for l in cfg.layers if l.kind == "dwconv"]
    assert dw and all(table[name] == "dwconv_int8" for name in dw)
    assert "jnp_ref" not in table.values()

    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.randint(jax.random.PRNGKey(1), cnn_input_shape(cfg, 2),
                           -127, 128, jnp.int8)
    out, report = cp.run(params, x)
    assert bool(jnp.all(out == cnn_forward(params, cfg, x)))
    assert all(report.engines_used()[name] == "dwconv_int8" for name in dw)


def test_streamed_dwconv_accounts_eq2_traffic():
    """A depthwise layer forced onto the HBM tier streams through the
    grouped kernel's DMA ring and its Eq. 2 words hit the report."""
    cfg = CNN_CONFIGS["mobilenetv1"].reduced()
    cp = compiler.compile(cfg, TPU_INTERPRET.replace(bram_m20ks=10_000))
    dw = next(l.name for l in cfg.layers if l.kind == "dwconv")
    streamed = cp.with_offload([dw])
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.randint(jax.random.PRNGKey(1), cnn_input_shape(cfg, 2),
                           -127, 128, jnp.int8)
    ref = cnn_forward(params, cfg, x)
    out, report = streamed.run(params, x)
    assert bool(jnp.all(out == ref))
    sched = streamed.plan.schedule_for(dw)
    expected = sched.weight_words_per_image * int(x.shape[0])
    assert report.hbm_weight_words == {dw: expected}


# ---------------------------------------------------------------------------
# VMEM budget: re-placement and rejection
# ---------------------------------------------------------------------------


# A target whose BRAM is big enough that Algorithm 1 streams nothing
# (leaving the full chain pool free), but whose VMEM ceiling the three
# widest conv layers (42880 B pinned, 14208 B streamed) only clear in the
# HBM tier — the canonical stage-5 re-placement scenario.
REPLACE_TARGET = TPU_INTERPRET.replace(bram_m20ks=10_000, vmem_bytes=25_000)
WIDE_LAYERS = ("s1b0c1", "s1b1c0", "s1b1c1")


def test_compile_replaces_overbudget_pinned_layers():
    """Pinned layers whose working set only fits when streamed are moved
    to the HBM tier by stage 5 — and the pipeline still executes
    bit-identically."""
    cp = compiler.compile(MINI, REPLACE_TARGET)
    assert cp.replaced == WIDE_LAYERS
    for name in WIDE_LAYERS:
        assert cp.assignment_for(name).mode == "hbm"
    assert max(cp.vmem_report().values()) <= REPLACE_TARGET.vmem_bytes

    params = init_cnn_params(jax.random.PRNGKey(0), MINI)
    x = jax.random.randint(jax.random.PRNGKey(1), cnn_input_shape(MINI, 2),
                           -127, 128, jnp.int8)
    out, report = cp.run(params, x)
    assert bool(jnp.all(out == cnn_forward(params, MINI, x)))
    assert set(report.hbm_weight_words) == set(WIDE_LAYERS)


def test_with_offload_is_strict_no_silent_replacement():
    """A caller-forced offload set is honored verbatim: stage 5 must NOT
    quietly re-stream forced-pinned layers — on a target where a pinned
    layer cannot fit, the recompile fails loudly instead (the pinned-vs-
    hybrid benchmark comparison depends on this)."""
    cp = compiler.compile(MINI, REPLACE_TARGET)   # compile() may re-place...
    assert cp.replaced == WIDE_LAYERS
    with pytest.raises(TargetBudgetError) as ei:
        cp.with_offload([])                       # ...with_offload may not
    assert set(WIDE_LAYERS) <= set(ei.value.offenders)
    assert "forced weight tier" in str(ei.value)
    # and where everything fits pinned, the forced set IS the result
    roomy = compiler.compile(MINI, TPU_INTERPRET).with_offload([])
    assert roomy.streamed_names == () and roomy.replaced == ()


def test_compile_rejects_impossible_vmem_budget():
    """When a layer fits in NEITHER tier, compile() fails up front with
    the full per-layer VMEM report — not at dispatch time."""
    tiny = TPU_INTERPRET.replace(vmem_bytes=1024)
    with pytest.raises(TargetBudgetError) as ei:
        compiler.compile(MINI, tiny)
    err = ei.value
    assert err.offenders                            # names the layers
    assert set(err.vmem_report) == {l.name for l in MINI.layers}
    assert str(err.target.vmem_bytes) in str(err)


def test_pinned_tier_costs_more_vmem_than_streamed():
    """The accounting the re-placement pass relies on: for a conv layer,
    the pinned working set dominates the streamed one (whole kernel vs
    n_buffers ring)."""
    cp = compiler.compile(MINI, TPU_INTERPRET)
    sched = cp.plan.schedule_for("s1b1c1")
    eng = compiler.get_engine("conv2d_int8")
    pinned = dataclasses.replace(sched, mode="pinned")
    streamed = dataclasses.replace(sched, mode="hbm")
    assert eng.vmem_bytes(sched.spec, pinned) \
        > eng.vmem_bytes(sched.spec, streamed)


# ---------------------------------------------------------------------------
# registry: the extension surface round-trips
# ---------------------------------------------------------------------------


def test_engine_registry_override_round_trips():
    """A user engine registered at higher priority takes over the layers
    it claims; unregistering restores the built-in binding — no compiler
    edits either way."""
    calls = []
    builtin = compiler.get_engine("stream_matmul")

    @compiler.register_engine("fc_spy", priority=99)
    class SpyFCEngine:
        def supports(self, spec):
            return builtin.supports(spec)

        def vmem_bytes(self, spec, sched):
            return builtin.vmem_bytes(spec, sched)

        def run(self, ctx, sched, params, x, relu):
            calls.append(sched.spec.name)
            return builtin.run(ctx, sched, params, x, relu)

    try:
        cp = compiler.compile(MINI, TPU_INTERPRET)
        assert cp.engine_table()["fc"] == "fc_spy"
        params = init_cnn_params(jax.random.PRNGKey(0), MINI)
        x = jax.random.randint(jax.random.PRNGKey(1),
                               cnn_input_shape(MINI, 1), -127, 128, jnp.int8)
        out, _ = cp.run(params, x)
        assert calls == ["fc"]                     # the spy actually ran
        assert bool(jnp.all(out == cnn_forward(params, MINI, x)))
    finally:
        assert compiler.unregister_engine("fc_spy") is not None

    cp = compiler.compile(MINI, TPU_INTERPRET)
    assert cp.engine_table()["fc"] == "stream_matmul"


def test_same_name_override_restores_builtin_on_unregister():
    """Shadowing a built-in under its own name and popping the override
    restores the built-in — the registry is a stack per name, so user
    overrides cannot permanently delete shipped engines."""
    builtin = compiler.get_engine("conv2d_int8")

    @compiler.register_engine("conv2d_int8", priority=50)
    class ShadowEngine:
        def supports(self, spec):
            return builtin.supports(spec)

        def vmem_bytes(self, spec, sched):
            return builtin.vmem_bytes(spec, sched)

        def run(self, ctx, sched, params, x, relu):
            return builtin.run(ctx, sched, params, x, relu)

    try:
        assert compiler.get_engine("conv2d_int8") is not builtin
    finally:
        popped = compiler.unregister_engine("conv2d_int8")
    assert isinstance(popped, ShadowEngine)
    assert compiler.get_engine("conv2d_int8") is builtin
    table = compiler.compile(MINI, TPU_INTERPRET).engine_table()
    assert table["stem"] == "stem_pool_int8"


def test_replacement_respects_chain_bandwidth():
    """Stage-5 re-placement is bounded by Algorithm 1's hard constraint:
    moving a layer to HBM consumes its p_i*p_o chain feeds from the
    target's pseudo-channel pool.  On a 1-PC target the pool (3 chains)
    cannot feed the over-VMEM layers, so compile() must reject the
    mapping rather than silently oversubscribe the bandwidth the
    throughput model assumes."""
    starved = REPLACE_TARGET.replace(n_pc=1)
    with pytest.raises(TargetBudgetError) as ei:
        compiler.compile(MINI, starved)
    assert "bandwidth" in str(ei.value)
    # the same budgets with the full PC pool compile via re-placement
    assert compiler.compile(MINI, REPLACE_TARGET).replaced == WIDE_LAYERS


def test_fc_as_conv_binding_requires_valid_equivalence():
    """The conv engine SAME-pads while the reference applies fc layers
    VALID: it may only claim fc-as-conv heads whose SAME padding is zero
    (VGG's fc0: 7x7 kernel, 7x7 map, stride 7).  Other fc geometries
    bind to the explicit jnp_ref engine — visible in the table, never a
    wrong-padding execution."""
    from repro.configs.cnn import ConvLayerSpec
    fc0 = next(l for l in CNN_CONFIGS["vgg16"].layers if l.name == "fc0")
    assert compiler.select_engine(fc0).name == "conv2d_int8"
    odd = ConvLayerSpec("fcx", "fc", 3, 3, 8, 16, 1, 7, 7)  # SAME != VALID
    assert compiler.select_engine(odd).name == "jnp_ref"


def test_jnp_bound_layers_never_occupy_hbm_tier():
    """A layer bound to the reference engine (can_stream=False) must not
    hold the HBM tier — plan analytics and fifo_sim would charge Eq. 2
    traffic the engine never executes.  Compile-chosen placements are
    demoted to pinned; caller-forced ones are rejected loudly."""
    from repro.configs.cnn import CNNConfig, ConvLayerSpec
    cfg = CNNConfig("tiny-oddfc", (
        ConvLayerSpec("c0", "conv", 3, 3, 3, 8, 1, 8, 8),
        ConvLayerSpec("fcx", "fc", 3, 3, 8, 16, 1, 8, 8),  # SAME != VALID
    ), num_classes=16)
    plan = compiler.plan_pipeline(cfg, TPU_INTERPRET).with_offload(["fcx"])
    demoted = compiler.finalize(plan, TPU_INTERPRET)
    assert demoted.engine_table()["fcx"] == "jnp_ref"
    assert demoted.assignment_for("fcx").mode == "pinned"
    assert "fcx" not in demoted.streamed_names
    with pytest.raises(compiler.CompileError, match="cannot stream"):
        compiler.finalize(plan, TPU_INTERPRET, replace=False)


def test_unknown_engine_lookup_raises():
    with pytest.raises(KeyError):
        compiler.get_engine("winograd9000")


def test_selection_order_is_priority_then_age():
    names = list(compiler.registered_engines())
    assert names.index("jnp_ref") == len(names) - 1   # the safety net last


# ---------------------------------------------------------------------------
# plan data model
# ---------------------------------------------------------------------------


def test_schedule_for_dict_lookup():
    cp = compiler.compile(MINI, TPU_INTERPRET)
    for s in cp.schedules:
        assert cp.plan.schedule_for(s.spec.name) is s
    with pytest.raises(KeyError):
        cp.plan.schedule_for("nonexistent")
    # derived plans get fresh, correct indices
    flipped = cp.plan.with_offload(["fc"])
    assert flipped.schedule_for("fc").streamed
    assert not cp.plan.schedule_for("fc").streamed
