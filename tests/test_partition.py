"""Pipeline partitioning: the compiler's sharding stage.

Contract under test (compiler/partition.py + models/cnn.py layer_range):

  * stage programs tile the placed layer order exactly — contiguous,
    disjoint, covering [0, L) in order;
  * residual blocks are ATOMIC — no stage cut falls inside a block (the
    identity add in ``cnn_forward`` spans the whole block, fused or
    not), and ``cnn_forward`` itself rejects a mid-block ``layer_range``;
  * the balancer is EXACT — the linear-partition DP achieves the
    minimum possible max-stage cost over all contiguous unit cuts
    (checked against brute force);
  * per-stage Eq. 2 accounting conserves the whole-plan words and every
    stage's ExecutionReport hard-fail ``verify()`` passes
    (``verify_eq2``);
  * composing the stage forward functions sequentially is bit-identical
    to the unpartitioned fused run — partitioning changes scheduling,
    never an output bit.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compiler
from repro.compiler import TPU_INTERPRET, PartitionError, partition_pipeline
from repro.compiler.partition import _linear_partition, stage_forward_fns
from repro.configs.cnn import (mini_mobilenet, mini_resnet18, mini_resnet50,
                               residual_blocks)
from repro.models.cnn import cnn_forward, cnn_input_shape, init_cnn_params

MINI = mini_resnet18(hw=8, width=16, stages=4)


@pytest.fixture(scope="module")
def setup():
    cp = compiler.compile(MINI, TPU_INTERPRET)
    params = init_cnn_params(jax.random.PRNGKey(0), MINI)
    return cp, params


# -- cut structure -----------------------------------------------------------


def test_stages_tile_layer_order(setup):
    cp, _ = setup
    L = len(cp.plan.schedules)
    for n in (1, 2, 3, 4):
        part = cp.partition(n)
        assert part.n_stages == n
        ranges = [sp.layer_range for sp in part.stages]
        assert ranges[0][0] == 0 and ranges[-1][1] == L
        for (_, stop), (start, _) in zip(ranges, ranges[1:]):
            assert stop == start                    # contiguous, disjoint
        assert all(stop > start for start, stop in ranges)


def test_blocks_are_atomic(setup):
    cp, _ = setup
    blocks = residual_blocks(cp.plan.cfg)
    assert blocks                                   # resnet: non-trivial
    for n in (2, 3, 4):
        part = cp.partition(n)
        for b in blocks:
            owners = {s.stage for s in part.stages
                      if any(m.name in s.layers for m in b.members)}
            assert len(owners) == 1, \
                f"block {b.name} split across stages {owners}"


def test_partition_argument_validation(setup):
    cp, _ = setup
    with pytest.raises(PartitionError, match=">= 1"):
        cp.partition(0)
    units = len(residual_blocks(cp.plan.cfg)) + sum(
        1 for s in cp.plan.schedules
        if not any(s.spec.name in {m.name for m in b.members}
                   for b in residual_blocks(cp.plan.cfg)))
    with pytest.raises(PartitionError, match="atomic unit"):
        cp.partition(units + 1)
    assert partition_pipeline(cp, 2).n_stages == 2  # functional form too


def test_linear_partition_dp_is_optimal():
    """The DP's max-stage cost equals brute force over every contiguous
    cut, for a sweep of random cost vectors."""
    rng = np.random.default_rng(0)
    for trial in range(25):
        n = int(rng.integers(2, 9))
        k = int(rng.integers(1, n + 1))
        costs = [int(c) for c in rng.integers(1, 100, size=n)]
        cuts = _linear_partition(costs, k)
        got = max(sum(costs[a:b]) for a, b in cuts)
        best = min(
            max(sum(costs[a:b]) for a, b in
                zip((0,) + combo, combo + (n,)))
            for combo in itertools.combinations(range(1, n), k - 1))
        assert got == best, (costs, k, cuts)


# -- Eq. 2 accounting --------------------------------------------------------


@pytest.mark.parametrize("cfg_fn", [
    lambda: mini_resnet18(hw=8, width=16, stages=4),
    lambda: mini_resnet50(hw=8, width=16, stages=4),
    lambda: mini_mobilenet(hw=8, width=16, blocks=4),
])
def test_verify_eq2_per_stage(cfg_fn):
    """Splitting the graph never loosens the plan-vs-dispatch check:
    every stage report verifies, and the per-stage words sum to the
    whole plan's total."""
    cp = compiler.compile(cfg_fn(), TPU_INTERPRET)
    total = sum(cp.plan.hbm_words_per_image().values())
    for n in (1, 2, 4):
        part = cp.partition(n)
        reports = part.verify_eq2(batch=2)
        assert len(reports) == n
        assert sum(sp.hbm_words_per_image for sp in part.stages) == total


def test_single_stage_is_whole_plan(setup):
    cp, _ = setup
    part = cp.partition(1)
    assert part.total_cycles == part.max_stage_cycles
    assert part.balance == 1.0
    assert part.stages[0].layer_range == (0, len(cp.plan.schedules))


def test_modelled_throughput_shape(setup):
    cp, _ = setup
    part = cp.partition(4)
    tp = part.modelled_throughput(32)
    # the fill law applied to the cycle model: speedup = (total / max)
    # discounted by M / (M + S - 1)
    want = (part.total_cycles / part.max_stage_cycles) * 32 / (32 + 3)
    assert tp["sharded_speedup_x"] == pytest.approx(want)
    assert tp["scaling_efficiency"] == pytest.approx(want / 4)
    assert tp["sharded_images_per_s"] > tp["one_stage_images_per_s"]


# -- forward semantics -------------------------------------------------------


def test_cnn_forward_rejects_mid_block_range(setup):
    cp, params = setup
    cfg = cp.plan.cfg
    blocks = residual_blocks(cfg)
    names = [l.name for l in cfg.layers]
    # index INSIDE the first block (after its first member)
    inside = names.index(blocks[0].members[0].name) + 1
    x = jnp.zeros(cnn_input_shape(cfg, 1), jnp.int8)
    with pytest.raises(ValueError, match="atomic"):
        cnn_forward(params, cfg, x, layer_range=(0, inside))
    with pytest.raises(ValueError, match="atomic"):
        cnn_forward(params, cfg, x, layer_range=(inside, len(names)))
    with pytest.raises(ValueError, match="layer_range"):
        cnn_forward(params, cfg, x, layer_range=(3, 2))


def test_stage_forwards_compose_to_fused_run(setup):
    """Chaining the per-stage forward functions sequentially (no mesh)
    reproduces the unpartitioned fused run bit-for-bit."""
    cp, params = setup
    rng = np.random.default_rng(3)
    x = rng.integers(-8, 8, size=cnn_input_shape(cp.plan.cfg, 2),
                     dtype=np.int8)
    ref, _ = cp.run(params, jnp.asarray(x))
    for n in (2, 4):
        part = cp.partition(n)
        fns = stage_forward_fns(part, interpret=True)
        y = jnp.asarray(x)
        for fn in fns:
            y = fn(params, y)
        assert np.array_equal(np.asarray(y), np.asarray(ref))
