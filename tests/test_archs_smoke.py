"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs (deliverable (f))."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.models import transformer as tmod
from repro.models.layers import pad_vocab


def make_batch(cfg, key, B=2, S=32):
    tk = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tk, "labels": jnp.roll(tk, -1, 1)}
    if cfg.family == "vlm":
        batch["patches"] = 0.01 * jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model), jnp.float32)
    if cfg.enc_dec:
        batch["frames"] = 0.01 * jax.random.normal(
            key, (B, cfg.n_frames, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_and_loss(arch_id, rng_key):
    cfg = get_arch(arch_id).reduced()
    params = tmod.init_params(rng_key, cfg)
    batch = make_batch(cfg, rng_key)
    hidden, aux = tmod.forward(params, cfg, batch)
    B, S = batch["tokens"].shape
    assert hidden.shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden)))
    loss = tmod.loss_fn(params, cfg, batch, remat=False)
    assert jnp.isfinite(loss) and loss > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_step_grads(arch_id, rng_key):
    cfg = get_arch(arch_id).reduced()
    params = tmod.init_params(rng_key, cfg)
    batch = make_batch(cfg, rng_key)
    grads = jax.grad(lambda p: tmod.loss_fn(p, cfg, batch, remat=True))(
        params)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_shapes(arch_id, rng_key):
    cfg = get_arch(arch_id).reduced()
    params = tmod.init_params(rng_key, cfg)
    B, S = 2, 32
    batch = make_batch(cfg, rng_key, B, S)
    logits, cache = tmod.prefill(params, cfg, batch, max_seq=S + 8)
    assert logits.shape == (B, pad_vocab(cfg.vocab_size))
    logits2, cache2 = tmod.decode_step(
        params, cfg, cache, jnp.ones((B, 1), jnp.int32), jnp.int32(S))
    assert logits2.shape == (B, pad_vocab(cfg.vocab_size))
    assert bool(jnp.all(jnp.isfinite(logits2)))
    # cache structure preserved
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(cache2)


@pytest.mark.parametrize("arch_id", ["phi4-mini-3.8b", "gemma2-9b",
                                     "qwen2-moe-a2.7b", "xlstm-125m",
                                     "hymba-1.5b", "deepseek-v2-236b",
                                     "seamless-m4t-medium", "internvl2-26b"])
def test_decode_matches_forward(arch_id, rng_key):
    """Prefill(S) + decode(S) must agree with forward on S+1 tokens —
    the serving path equals the training path (greedy tokens match; allows
    small numeric divergence between the two attention implementations)."""
    cfg = get_arch(arch_id).reduced()
    params = tmod.init_params(rng_key, cfg)
    B, S = 2, 16
    batch_full = make_batch(cfg, rng_key, B, S + 1)
    batch_pre = {k: (v[:, :S] if k in ("tokens", "labels") else v)
                 for k, v in batch_full.items()}
    hidden, _ = tmod.forward(params, cfg, batch_full)
    ref_logits = tmod.logits_from_hidden(params, cfg, hidden[:, -1])

    _, cache = tmod.prefill(params, cfg, batch_pre, max_seq=S + 4)
    step_logits, _ = tmod.decode_step(
        params, cfg, cache, batch_full["tokens"][:, S:S + 1], jnp.int32(S))
    v = cfg.vocab_size
    ref = ref_logits[:, :v]
    got = step_logits[:, :v]
    assert jnp.argmax(ref, -1).tolist() == jnp.argmax(got, -1).tolist() or \
        float(jnp.max(jnp.abs(ref - got))) < 0.15 * float(
            jnp.max(jnp.abs(ref)) + 1e-6)


def test_param_specs_match_structure(rng_key):
    for arch_id in ARCH_IDS:
        cfg = get_arch(arch_id).reduced()
        params = tmod.init_params(rng_key, cfg)
        specs = tmod.param_specs(cfg)
        assert jax.tree_util.tree_structure(
            params, is_leaf=lambda x: False) == jax.tree_util.tree_structure(
            specs, is_leaf=lambda x: not isinstance(x, (dict, list)))


def test_full_config_param_counts():
    """Closed-form accounting sanity against the published sizes."""
    from repro.models.accounting import count_params
    expect = {
        "phi4-mini-3.8b": (3.3e9, 4.6e9),
        "qwen2-72b": (68e9, 76e9),
        "gemma2-9b": (8.0e9, 11e9),
        "command-r-plus-104b": (98e9, 112e9),
        "deepseek-v2-236b": (210e9, 250e9),
        "qwen2-moe-a2.7b": (13e9, 15.5e9),   # 14.3B total (2.7B active)
        # our xLSTM block accounting is lean vs the published 125M (no
        # per-head biases / norm-scales counted): accept 85-180M
        "xlstm-125m": (0.85e8, 1.8e8),
    }
    for aid, (lo, hi) in expect.items():
        n = count_params(get_arch(aid))
        assert lo <= n <= hi, (aid, f"{n:.3e}")
