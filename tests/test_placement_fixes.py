"""Regression tests for the placement-pass bugs the autotuner exposed.

Three defects, each pinned failing-before / passing-after:

  * ``hybrid_selection`` mutated its input plans in place (the
    copy-then-reset was ``dataclasses.replace(p) if False else p`` — a
    no-op), so any caller evaluating several candidate placements from
    one base allocation had its base corrupted after the first call;
  * ``assign_pseudo_channels`` filtered the clockwise walk with
    ``pc < n_pc or pc >= 16``, which keeps the whole far stack
    (PCs 16..31) regardless of ``n_pc`` — a target with 8 usable PCs
    handed out ids up to 31;
  * ``allocate_parallelism`` gave up the moment the *preferred*
    doubling dimension overflowed the AI-TB budget, without trying the
    other dimension (and computed a dead ``before`` snapshot while at
    it).
"""
import dataclasses

from repro.compiler.target import TPU_INTERPRET
from repro.configs.cnn import get_cnn, mini_resnet18
from repro.core import placement
from repro.core.placement import LayerPlan


def _base_plans():
    cfg = mini_resnet18(hw=8, width=16, stages=4)
    return placement.allocate_parallelism(cfg, TPU_INTERPRET.tb_budget)


# ---------------------------------------------------------------------------
# hybrid_selection must not mutate its input
# ---------------------------------------------------------------------------


class TestHybridSelectionPurity:
    def test_inputs_unmodified(self):
        plans = _base_plans()
        snapshot = [dataclasses.replace(p) for p in plans]
        out = placement.hybrid_selection(plans, bram_m20ks=1, n_pc=31)
        # tight budget forces offloads in the OUTPUT...
        assert any(p.offload for p in out)
        # ...while the caller's plans stay byte-identical
        assert plans == snapshot

    def test_output_is_fresh_objects(self):
        plans = _base_plans()
        out = placement.hybrid_selection(plans, bram_m20ks=1, n_pc=31)
        assert all(o is not p for o, p in zip(out, plans))

    def test_repeated_calls_identical(self):
        """The autotuner's usage pattern: many selections from one base.
        Before the fix, call 1 left offload flags set, so call 2 (which
        resets them on its *copies*) still worked — but the caller's
        base was dirty and any direct use of it saw phantom offloads."""
        plans = _base_plans()
        first = placement.hybrid_selection(plans, bram_m20ks=1, n_pc=31)
        assert not any(p.offload for p in plans)
        second = placement.hybrid_selection(plans, bram_m20ks=1, n_pc=31)
        assert [p.offload for p in first] == [p.offload for p in second]


# ---------------------------------------------------------------------------
# assign_pseudo_channels must respect n_pc
# ---------------------------------------------------------------------------


def _offloaded(n: int):
    cfg = mini_resnet18(hw=8, width=16, stages=4)
    plans = [LayerPlan(spec=l) for l in cfg.layers if not l.is_pool][:n]
    assert len(plans) == n, "config too small for this test"
    for p in plans:
        p.offload = True
    return plans


class TestPseudoChannelBounds:
    def test_n_pc_8_never_exceeds(self):
        # 12 offloads over 8 usable PCs: must wrap within 0..7, never
        # touch the far stack (the old filter handed out 31, 30, ...)
        plans = _offloaded(12)
        placement.assign_pseudo_channels(plans, n_pc=8)
        pcs = [p.pc for p in plans]
        assert all(pc is not None and 0 <= pc < 8 for pc in pcs)
        assert pcs == [0, 1, 2, 3, 4, 5, 6, 7, 0, 1, 2, 3]

    def test_n_pc_16_never_exceeds(self):
        plans = _offloaded(20)
        placement.assign_pseudo_channels(plans, n_pc=16)
        assert all(0 <= p.pc < 16 for p in plans)

    def test_full_device_order(self):
        """At n_pc=31 (the paper device: one of 32 PCs fails timing
        closure) the walk is 0->15 then the far stack high-to-low, and
        id 31 itself — outside the usable range — is never handed out
        (the old filter's ``pc >= 16`` arm kept the whole far stack)."""
        plans = _offloaded(20)
        placement.assign_pseudo_channels(plans, n_pc=31)
        assert [p.pc for p in plans] == \
            list(range(16)) + list(range(30, 26, -1))

    def test_with_offload_respects_small_n_pc(self):
        """End-to-end: a compiled plan on an 8-PC target variant, with a
        forced offload set wider than the PC count, must keep every
        assigned pseudo-channel inside the target's range."""
        from repro.compiler import plan_pipeline
        cfg = mini_resnet18(hw=8, width=16, stages=4)
        plan = plan_pipeline(cfg, TPU_INTERPRET.replace(n_pc=8))
        convs = [s.spec.name for s in plan.schedules
                 if not s.spec.is_pool][:10]
        forced = plan.with_offload(convs)
        pcs = [s.pc for s in forced.streamed]
        assert len(pcs) == 10
        assert all(0 <= pc < 8 for pc in pcs)


# ---------------------------------------------------------------------------
# allocate_parallelism budget handling
# ---------------------------------------------------------------------------


class TestAllocateParallelism:
    def test_budget_respected(self):
        for budget in (50, 120, 500, 2000):
            plans = placement.allocate_parallelism(
                mini_resnet18(hw=8, width=16, stages=4), budget)
            assert sum(p.tensor_blocks for p in plans) <= budget

    def test_fills_budget_greedily(self):
        """With the fallback, the allocator keeps doubling until NO
        dimension of the bottleneck fits — the result must use more
        than half the budget whenever any single doubling would fit
        (each doubling costs exactly the layer's current TB count)."""
        cfg = mini_resnet18(hw=8, width=16, stages=4)
        budget = 500
        plans = placement.allocate_parallelism(cfg, budget)
        used = sum(p.tensor_blocks for p in plans)
        bott = max((p for p in plans if not p.spec.is_pool),
                   key=lambda p: p.cycles_per_image)
        # the bottleneck is either maxed out in both dimensions or any
        # further doubling (in either dimension) would blow the budget
        s = bott.spec
        ci_eff = (s.c_in if s.kind != "dwconv" else 1) * s.k_h * s.k_w
        co_eff = s.c_out if s.kind != "dwconv" else s.c_in
        can_double = (bott.p_i * 10 < ci_eff) or (bott.p_o * 2 <= co_eff)
        if can_double:
            assert used + bott.tensor_blocks > budget

    def test_golden_placements_unchanged(self):
        """The fallback is behavior-neutral on the golden configs (both
        dimensions cost the same TBs, so whichever doubles, the budget
        check is identical): resnet50 @ NX2100 keeps its pinned
        placement table."""
        from repro.compiler import NX2100
        plans = placement.allocate_parallelism(
            get_cnn("resnet50"), NX2100.tb_budget)
        assert sum(p.tensor_blocks for p in plans) <= NX2100.tb_budget
        assert all(p.p_i >= 1 and p.p_o >= 1 for p in plans)
