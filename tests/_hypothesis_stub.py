"""Minimal stand-in for ``hypothesis`` so property tests degrade to fixed
explicit cases when the real package is absent.

The tier-1 suite must collect and pass in a bare container (see
tests/README.md).  When ``hypothesis`` is importable the stub is never
installed and the real property-based testing runs; otherwise
``tests/conftest.py`` registers this module as ``sys.modules["hypothesis"]``
before the test modules import it.

Semantics: each strategy carries a small deterministic example list
(bounds, midpoint, near-bounds).  ``@given`` replays a fixed set of
combined cases — examples are mixed with coprime strides so multi-argument
tests see varied tuples, not just the diagonal.  ``@settings`` is a no-op.
"""
from __future__ import annotations

import inspect
import types
from typing import Any, List

_N_CASES = 12                        # combined cases replayed per test
_STRIDES = (1, 3, 5, 7, 11, 13, 17, 19, 23, 29)   # coprime mixing strides


class _Strategy:
    def __init__(self, examples: List[Any]):
        assert examples, "stub strategy needs at least one example"
        self.examples = list(examples)

    def pick(self, i: int, j: int) -> Any:
        stride = _STRIDES[j % len(_STRIDES)]
        return self.examples[(i * stride) % len(self.examples)]


def integers(min_value: int, max_value: int) -> _Strategy:
    lo, hi = int(min_value), int(max_value)
    mid = (lo + hi) // 2
    return _Strategy(sorted({lo, min(lo + 1, hi), mid, max(hi - 1, lo), hi}))


def floats(min_value: float = -1e6, max_value: float = 1e6,
           allow_nan: bool = True, allow_infinity: bool = True,
           **_kw: Any) -> _Strategy:
    lo, hi = float(min_value), float(max_value)
    # quartile points: always inside [lo, hi] regardless of sign
    return _Strategy([lo + (hi - lo) * f for f in (0.0, .25, .5, .75, 1.0)])


def booleans() -> _Strategy:
    return _Strategy([False, True])


def sampled_from(elements) -> _Strategy:
    return _Strategy(list(elements))


def lists(elements: _Strategy, min_size: int = 0, max_size: int = None,
          **_kw: Any) -> _Strategy:
    ex = elements.examples
    if max_size is None:
        max_size = max(min_size, 8)
    sizes = sorted({min_size, max(min_size, 1), (min_size + max_size) // 2,
                    max_size})
    sizes = [s for s in sizes if min_size <= s <= max_size]
    built = []
    for n, size in enumerate(sizes):
        built.append([ex[(n + k) % len(ex)] for k in range(size)])
    return _Strategy(built or [[]])


def tuples(*strategies: _Strategy) -> _Strategy:
    n = max(len(s.examples) for s in strategies) if strategies else 1
    return _Strategy([tuple(s.pick(i, j) for j, s in enumerate(strategies))
                      for i in range(n)])


def just(value: Any) -> _Strategy:
    return _Strategy([value])


def given(*arg_strategies: _Strategy, **kw_strategies: _Strategy):
    """Replay ``_N_CASES`` deterministic example combinations."""
    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        # positional strategies fill the rightmost parameters (hypothesis
        # semantics); kwargs fill by name; what's left pytest treats as
        # fixtures — expose only those on the wrapper's signature.
        pos_names = []
        if arg_strategies:
            pos_names = [p.name for p in params[-len(arg_strategies):]]
            params = params[:len(params) - len(arg_strategies)]
        params = [p for p in params if p.name not in kw_strategies]

        def wrapper(*fixture_args, **fixture_kw):
            for i in range(_N_CASES):
                # bind positional strategies by their rightmost parameter
                # NAMES so fixtures (leftmost params) never collide
                kw = {name: s.pick(i, j)
                      for j, (name, s) in enumerate(zip(pos_names,
                                                        arg_strategies))}
                kw.update({name: s.pick(i, len(arg_strategies) + j)
                           for j, (name, s)
                           in enumerate(kw_strategies.items())})
                fn(*fixture_args, **fixture_kw, **kw)

        wrapper.__name__ = fn.__name__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        wrapper.__signature__ = sig.replace(parameters=params)
        # pytest's hypothesis integration probes fn.hypothesis.inner_test
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper
    return deco


def settings(*_a: Any, **_kw: Any):
    """Accepts and ignores max_examples / deadline / etc."""
    def deco(fn):
        return fn
    return deco


class HealthCheck:                    # referenced via settings(suppress_...)
    all = ()
    too_slow = None
    data_too_large = None


def install(sys_modules) -> None:
    """Register this module as ``hypothesis`` (+ ``.strategies``)."""
    import types

    root = types.ModuleType("hypothesis")
    root.given = given
    root.settings = settings
    root.HealthCheck = HealthCheck
    root.__stub__ = True

    strat = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "lists",
                 "tuples", "just"):
        setattr(strat, name, globals()[name])
    root.strategies = strat

    sys_modules["hypothesis"] = root
    sys_modules["hypothesis.strategies"] = strat
