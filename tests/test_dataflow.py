"""Pipeline executor tests.  Multi-stage tests need >1 device, so they run
in a subprocess with forced host devices (tests themselves keep seeing the
real single device, per the dry-run isolation rule)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.admission import replay_staged_schedule
from repro.core.dataflow import (pipeline_apply, pipeline_stats,
                                 split_stages, staged_pipeline_apply)
from repro.launch.mesh import compat_make_mesh


def test_split_stages():
    p = {"w": jnp.zeros((16, 4, 4))}
    s = split_stages(p, 8)
    assert s["w"].shape == (8, 2, 4, 4)
    with pytest.raises(ValueError, match="cannot split 15"):
        split_stages({"w": jnp.zeros((15, 4))}, 8)
    with pytest.raises(ValueError, match="n_stages"):
        split_stages(p, 0)


def test_pipeline_stats_credits():
    st = pipeline_stats(n_stages=8, n_microbatches=24)
    assert st["ticks"] == 31
    assert st["in_flight_credits"] == 8       # the §V-A credit bound
    assert 0 < st["bubble_fraction"] < 0.25


def test_fill_law_matches_staged_replay():
    """pipeline_stats' M + S - 1 tick count IS the staged admission
    replay's makespan, for a sweep of shapes — and the replay proves
    per-stage occupancy never exceeded one."""
    for S in (1, 2, 3, 5, 8):
        for M in (1, 2, 7, 24):
            st = pipeline_stats(n_stages=S, n_microbatches=M)
            tr = replay_staged_schedule(M, n_stages=S)
            assert tr.makespan == st["ticks"] == M + S - 1
            assert tr.max_in_flight <= st["in_flight_credits"]
            assert tr.max_stage_occupancy <= 1


def _toy(key, L, d):
    Ws = jax.random.normal(key, (L, d, d)) * 0.1

    def layer_fn(p, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        return jax.lax.scan(body, x, p["w"])[0]

    def ref(x):
        for i in range(L):
            x = jnp.tanh(x @ Ws[i])
        return x
    return Ws, layer_fn, ref


def test_pipeline_apply_validates_inputs():
    mesh = compat_make_mesh((1,), ("model",))
    Ws, layer_fn, _ = _toy(jax.random.PRNGKey(0), 4, 4)
    x_mb = jnp.zeros((3, 2, 4))
    with pytest.raises(ValueError, match="no axis 'data'"):
        pipeline_apply(layer_fn, split_stages({"w": Ws}, 1), x_mb,
                       mesh=mesh, axis="data")
    with pytest.raises(ValueError, match="split_stages"):
        # leading dim 4 != the 1-device axis size
        pipeline_apply(layer_fn, {"w": Ws}, x_mb, mesh=mesh)
    with pytest.raises(ValueError, match=r"\[M, mb, \.\.\.\]"):
        pipeline_apply(layer_fn, split_stages({"w": Ws}, 1),
                       jnp.zeros((3,)), mesh=mesh)


def test_pipeline_single_stage_matches_sequential():
    """Property (satellite of the sharded-serving PR): a 1-stage mesh
    pipeline is bit-identical to the sequential apply for every
    microbatch count — the pipeline machinery adds scheduling, never
    arithmetic."""
    mesh = compat_make_mesh((1,), ("model",))
    for i, (L, d, M, mb) in enumerate(
            [(4, 4, 1, 2), (6, 8, 3, 2), (2, 4, 5, 1)]):
        Ws, layer_fn, ref = _toy(jax.random.PRNGKey(i), L, d)
        x_mb = jax.random.normal(jax.random.PRNGKey(100 + i), (M, mb, d))
        with mesh:
            out = pipeline_apply(layer_fn, split_stages({"w": Ws}, 1),
                                 x_mb, mesh=mesh)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(jax.vmap(ref)(x_mb)))


def test_staged_pipeline_validates_inputs():
    mesh = compat_make_mesh((1,), ("model",))
    fn = lambda p, x: x
    x_mb = jnp.zeros((2, 3, 4))
    with pytest.raises(ValueError, match="stage programs"):
        staged_pipeline_apply([fn, fn], {}, x_mb, mesh=mesh,
                              boundary_shapes=[None, (3, 4)],
                              out_shape=(3, 4))
    with pytest.raises(ValueError, match="boundary_shapes"):
        staged_pipeline_apply([fn], {}, x_mb, mesh=mesh,
                              boundary_shapes=[], out_shape=(3, 4))


def test_staged_pipeline_single_stage_matches_sequential():
    """staged_pipeline_apply with ONE heterogeneous stage == the stage
    function applied per microbatch (bit-identical, float carry)."""
    mesh = compat_make_mesh((1,), ("model",))
    Ws, layer_fn, ref = _toy(jax.random.PRNGKey(7), 5, 4)
    params = {"w": Ws}
    x_mb = jax.random.normal(jax.random.PRNGKey(8), (4, 2, 4))
    with mesh:
        out = staged_pipeline_apply(
            [layer_fn], params, x_mb, mesh=mesh,
            boundary_shapes=[None], out_shape=(2, 4),
            out_dtype=jnp.float32, carry_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(jax.vmap(ref)(x_mb)))


MULTI_DEVICE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.dataflow import split_stages, pipeline_apply, \\
        gpipe_train_step
    from repro.launch.mesh import compat_make_mesh

    mesh = compat_make_mesh((8,), ("model",))
    L, d = 16, 8
    key = jax.random.PRNGKey(0)
    Ws = jax.random.normal(key, (L, d, d)) * 0.1
    staged = split_stages({"w": Ws}, 8)

    def layer_fn(p, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        return jax.lax.scan(body, x, p["w"])[0]

    M, mb = 4, 2
    x_mb = jax.random.normal(key, (M, mb, d))
    with mesh:
        out = pipeline_apply(layer_fn, staged, x_mb, mesh=mesh)
    def ref(x):
        for i in range(L):
            x = jnp.tanh(x @ Ws[i])
        return x
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jax.vmap(ref)(x_mb)),
                               rtol=1e-5, atol=1e-5)
    with mesh:
        loss, grads = gpipe_train_step(
            layer_fn, lambda o, y: jnp.mean((o - y) ** 2), staged, x_mb,
            jnp.ones_like(x_mb), mesh=mesh)
    gn = float(jnp.linalg.norm(grads["w"]))
    assert jnp.isfinite(loss) and gn > 0
    print("OK")
""")


def test_pipeline_matches_sequential_8stages():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", MULTI_DEVICE_SCRIPT],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


HETEROGENEOUS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.dataflow import staged_pipeline_apply
    from repro.launch.mesh import compat_make_mesh

    # four stages with DIFFERENT programs and DIFFERENT boundary widths
    # (the shape regime pipeline_apply cannot express)
    mesh = compat_make_mesh((4,), ("model",))
    widths = [6, 10, 3, 8, 5]            # stage s maps widths[s]->widths[s+1]
    key = jax.random.PRNGKey(0)
    Ws = [jax.random.normal(jax.random.PRNGKey(s), (widths[s], widths[s+1]))
          * 0.1 for s in range(4)]
    params = {f"w{s}": Ws[s] for s in range(4)}

    def make_stage(s):
        def fn(p, x):
            return jnp.tanh(x @ p[f"w{s}"])
        return fn

    M, mb = 7, 2
    x_mb = jax.random.normal(key, (M, mb, widths[0]))
    with mesh:
        out = staged_pipeline_apply(
            [make_stage(s) for s in range(4)], params, x_mb, mesh=mesh,
            boundary_shapes=[None] + [(mb, widths[s]) for s in (1, 2, 3)],
            out_shape=(mb, widths[4]), out_dtype=jnp.float32,
            carry_dtype=jnp.float32)

    def ref(x):
        for s in range(4):
            x = jnp.tanh(x @ Ws[s])
        return x
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jax.vmap(ref)(x_mb)),
                               rtol=1e-6, atol=1e-6)
    print("OK")
""")


def test_staged_pipeline_heterogeneous_4stages():
    """4-device staged pipeline with per-stage programs and changing
    boundary geometry matches the sequential composition."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", HETEROGENEOUS_SCRIPT],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
