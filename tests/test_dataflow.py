"""Pipeline executor tests.  Multi-stage tests need >1 device, so they run
in a subprocess with forced host devices (tests themselves keep seeing the
real single device, per the dry-run isolation rule)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dataflow import pipeline_stats, split_stages


def test_split_stages():
    p = {"w": jnp.zeros((16, 4, 4))}
    s = split_stages(p, 8)
    assert s["w"].shape == (8, 2, 4, 4)
    with pytest.raises(AssertionError):
        split_stages({"w": jnp.zeros((15, 4))}, 8)


def test_pipeline_stats_credits():
    st = pipeline_stats(n_stages=8, n_microbatches=24)
    assert st["ticks"] == 31
    assert st["in_flight_credits"] == 8       # the §V-A credit bound
    assert 0 < st["bubble_fraction"] < 0.25


MULTI_DEVICE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.dataflow import split_stages, pipeline_apply, \\
        gpipe_train_step
    from repro.launch.mesh import compat_make_mesh

    mesh = compat_make_mesh((8,), ("model",))
    L, d = 16, 8
    key = jax.random.PRNGKey(0)
    Ws = jax.random.normal(key, (L, d, d)) * 0.1
    staged = split_stages({"w": Ws}, 8)

    def layer_fn(p, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        return jax.lax.scan(body, x, p["w"])[0]

    M, mb = 4, 2
    x_mb = jax.random.normal(key, (M, mb, d))
    with mesh:
        out = pipeline_apply(layer_fn, staged, x_mb, mesh=mesh)
    def ref(x):
        for i in range(L):
            x = jnp.tanh(x @ Ws[i])
        return x
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jax.vmap(ref)(x_mb)),
                               rtol=1e-5, atol=1e-5)
    with mesh:
        loss, grads = gpipe_train_step(
            layer_fn, lambda o, y: jnp.mean((o - y) ** 2), staged, x_mb,
            jnp.ones_like(x_mb), mesh=mesh)
    gn = float(jnp.linalg.norm(grads["w"]))
    assert jnp.isfinite(loss) and gn > 0
    print("OK")
""")


def test_pipeline_matches_sequential_8stages():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", MULTI_DEVICE_SCRIPT],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
