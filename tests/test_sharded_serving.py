"""Sharded dataflow serving: the mesh-pipelined engine end to end.

Contract under test (runtime/sharded_serving.py):

  * serving results are BIT-IDENTICAL to sequential ``run()`` per
    request — shard-local queues, round packing, the staged ring and
    the padded short rounds change scheduling, never an output bit;
  * the §V-A cross-device credit bound holds through the UNCHANGED
    AdmissionController (invariant hooks + quiescence, not sampling);
  * start() hard-fails unless the per-stage Eq. 2 reports verify AND
    the staged trace's executed words equal the stage plans;
  * the :class:`ShardedServingReport` staged accounting holds (rounds,
    fill fraction, per-shard request counts, per-stage words).

Multi-stage runs need >1 device, so the 4-stage test runs in a
subprocess with forced host devices (the dry-run isolation rule);
everything else runs in-process on a 1-device mesh.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro import compiler
from repro.compiler import TPU_INTERPRET
from repro.configs.cnn import mini_resnet18
from repro.launch.mesh import compat_make_mesh
from repro.models.cnn import cnn_input_shape, init_cnn_params
from repro.runtime.sharded_serving import ShardedCnnServingEngine

MINI = mini_resnet18(hw=8, width=16, stages=4)


@pytest.fixture(scope="module")
def setup():
    cp = compiler.compile(MINI, TPU_INTERPRET)
    params = init_cnn_params(jax.random.PRNGKey(0), MINI)
    return cp, params


def _requests(sizes, seed=0):
    rng = np.random.default_rng(seed)
    shape = cnn_input_shape(MINI, 1)[1:]
    return [rng.integers(-127, 128, size=(n,) + shape,
                         dtype=np.int16).astype(np.int8) for n in sizes]


def test_sharded_bit_identical_one_stage(setup):
    """1-device mesh: the full sharded path (shard queues, packers,
    rounds, staged dispatch) against sequential run(), mixed request
    sizes spanning microbatch AND round boundaries."""
    cp, params = setup
    mesh = compat_make_mesh((1,), ("model",))
    batches = _requests([1, 3, 2, 7, 1, 4])        # 7 spans rounds of 4x?
    with cp.serve_sharded(params, mesh=mesh, microbatch=4,
                          round_microbatches=2) as eng:
        results, report = eng.serve(batches)
    big = np.concatenate(batches, axis=0)
    ref = np.asarray(cp.run(params, big)[0])
    off = 0
    for b, got in zip(batches, results):
        assert np.array_equal(got, ref[off:off + len(b)])
        off += len(b)
    assert report.requests == len(batches)
    assert report.images == sum(len(b) for b in batches)
    assert report.n_stages == 1
    assert report.rounds >= 1
    assert report.max_in_flight <= report.credits
    assert 0 < report.round_fill_fraction <= 1
    assert sum(report.shard_requests) == len(batches)
    assert report.stage_hbm_words_per_image == \
        (report.hbm_words_per_image,)
    # padding overhead is visible, not folded in
    assert report.hbm_words_executed >= report.hbm_words_useful


def test_sharded_validation_and_lifecycle(setup):
    cp, params = setup
    mesh = compat_make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="no axis"):
        ShardedCnnServingEngine(cp, params, mesh=mesh, axis="data")
    with pytest.raises(ValueError, match="credits"):
        ShardedCnnServingEngine(cp, params, mesh=mesh,
                                round_microbatches=8, credits=4)
    eng = ShardedCnnServingEngine(cp, params, mesh=mesh, microbatch=2,
                                  round_microbatches=2)
    with pytest.raises(RuntimeError, match="not started"):
        eng.submit(_requests([1])[0])
    with eng:
        with pytest.raises(ValueError, match="shard"):
            eng.submit(_requests([1])[0], shard=5)
        with pytest.raises(ValueError, match="expected images"):
            eng.submit(np.zeros((1, 3, 3, 3), np.int8))
        req = eng.submit(_requests([2])[0], shard=0)
        eng.drain()
        assert req.done and req.result().shape == (2, MINI.num_classes)
    eng.admission.assert_quiescent()
    with pytest.raises(RuntimeError, match="single-use"):
        eng.start()


def test_sharded_explicit_shard_routing(setup):
    """Explicit shard targeting lands requests on the chosen producer
    queue; results stay bit-identical regardless of routing."""
    cp, params = setup
    mesh = compat_make_mesh((1,), ("model",))
    batches = _requests([2, 3, 1], seed=5)
    with cp.serve_sharded(params, mesh=mesh, microbatch=2,
                          round_microbatches=2) as eng:
        reqs = [eng.submit(b, shard=0) for b in batches]
        eng.drain()
        rep = eng.report()
    assert rep.shard_requests == (len(batches),)
    for b, r in zip(batches, reqs):
        assert np.array_equal(r.result(), np.asarray(cp.run(params, b)[0]))


SHARDED_4DEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro import compiler
    from repro.compiler import TPU_INTERPRET
    from repro.configs.cnn import mini_resnet18
    from repro.launch.mesh import compat_make_mesh
    from repro.models.cnn import cnn_input_shape, init_cnn_params

    cfg = mini_resnet18(hw=8, width=16, stages=4)
    cp = compiler.compile(cfg, TPU_INTERPRET)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    mesh = compat_make_mesh((4,), ("model",))
    rng = np.random.default_rng(1)
    shape = cnn_input_shape(cfg, 1)[1:]
    batches = [rng.integers(-8, 8, size=(n,) + shape, dtype=np.int8)
               for n in (3, 4, 7, 2, 5, 1)]
    with cp.serve_sharded(params, mesh=mesh, microbatch=2,
                          round_microbatches=8) as eng:
        outs, rep = eng.serve(batches)
    assert rep.n_stages == 4, rep.n_stages
    assert rep.max_in_flight <= rep.credits
    assert len(rep.stage_hbm_words_per_image) == 4
    assert sum(rep.stage_hbm_words_per_image) == rep.hbm_words_per_image
    # shard-local producers: round-robin touched every queue
    assert all(c >= 1 for c in rep.shard_requests), rep.shard_requests
    for b, o in zip(batches, outs):
        ref = np.asarray(cp.run(params, b)[0])
        assert np.array_equal(o, ref), "sharded output != sequential run"
    eng.admission.assert_quiescent()
    print("OK")
""")


def test_sharded_serving_4stage_mesh():
    """The acceptance topology: 4 forced host devices, mini_resnet18
    partitioned 4 ways, bit-identity + credit bound + quiescence."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SHARDED_4DEV_SCRIPT],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=540)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_sharded_report_round_trip(setup):
    """``ShardedServingReport.from_json`` restores the tuple-typed
    staged fields (per-stage words, per-shard request counts) from
    JSON's lists — to EQUALITY, via the shared recursive restore law
    (``restore_tuple_fields``)."""
    from repro.runtime.sharded_serving import ShardedServingReport
    cp, params = setup
    mesh = compat_make_mesh((1,), ("model",))
    with cp.serve_sharded(params, mesh=mesh, microbatch=2,
                          round_microbatches=2) as eng:
        _, rep = eng.serve(_requests([1, 3, 2], seed=9))
    assert rep.stage_hbm_words_per_image and rep.shard_requests
    back = ShardedServingReport.from_json(rep.to_json())
    assert back == rep
    assert isinstance(back.stage_hbm_words_per_image, tuple)
    assert isinstance(back.shard_requests, tuple)
    # dict payloads (already-parsed artifacts) restore identically, and
    # the derived keys to_dict() adds never break construction
    assert ShardedServingReport.from_json(rep.to_dict()) == rep
