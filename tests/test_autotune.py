"""The search-based placement + FIFO co-optimizer (compiler.autotune).

Covers the acceptance contract of the search itself —

  * the tuned plan strictly beats the greedy Alg. 1 seed on credit-mode
    tail stall cycles OR on-chip M20Ks, at equal-or-better modelled
    images/s, on both executable mini networks (verified against the
    §V-A fifo_sim, not just the search's own bookkeeping);
  * a ``compile(..., autotune=...)`` pipeline is a normal
    :class:`CompiledPipeline`: stages 4-5 validated, whole-topology
    ``eq2_report().verify()`` still passing, ``with_offload`` /
    ``serve()`` behaving;

— plus the invariants (property-tested over seeds):

  * hard budgets are never exceeded: chain feeds within
    ``target.chain_budget``, on-chip M20Ks within
    ``max(target.bram_m20ks, seed footprint)``, per-engine VMEM within
    ``target.vmem_bytes``, FIFO depths at or above their §IV-A minima;
  * the search is deterministic per seed;
  * the tuned objective is never worse than the greedy seed's (the seed
    is the first candidate visited and best-so-far is returned);
  * ``solve_serving_credits`` returns the *smallest* credit bound whose
    §V-A replay still saturates dispatch.
"""
import functools

import pytest
from hypothesis import given, settings, strategies as st

from repro import compiler
from repro.compiler.autotune import (AutotuneConfig, AutotuneError,
                                     autotune_plan, solve_serving_credits)
from repro.configs.cnn import mini_resnet18, mini_resnet50
from repro.core import admission, hbm_model

R18 = functools.lru_cache(None)(
    lambda: mini_resnet18(hw=8, width=16, stages=4))
R50 = functools.lru_cache(None)(
    lambda: mini_resnet50(hw=8, width=16, stages=4))
TARGET = compiler.TPU_INTERPRET


@functools.lru_cache(None)
def tuned(net: str, seed: int = 0, iterations: int = 150):
    cfg = {"r18": R18, "r50": R50}[net]()
    return autotune_plan(cfg, TARGET,
                         AutotuneConfig(seed=seed, iterations=iterations))


# ---------------------------------------------------------------------------
# acceptance: strictly beats greedy on both executable configs
# ---------------------------------------------------------------------------


class TestBeatsGreedy:
    @pytest.mark.parametrize("net", ["r18", "r50"])
    def test_strict_improvement(self, net):
        r = tuned(net)
        assert r.tuned.feasible
        # strictly better on stalls or M20Ks...
        assert (r.tuned.stall_cycles < r.greedy.stall_cycles
                or r.tuned.onchip_m20ks < r.greedy.onchip_m20ks)
        assert r.improved
        # ...at equal-or-better modelled throughput
        assert r.tuned.images_per_s >= r.greedy.images_per_s

    @pytest.mark.parametrize("net", ["r18", "r50"])
    def test_stalls_verified_by_fifo_sim(self, net):
        """The reported tuned stall count is the fifo_sim's own verdict
        on the tuned plan, not search bookkeeping: re-simulate the plan
        with the search's fixed word_scale and compare exactly."""
        r = tuned(net)
        out = r.plan.predict_stalls(r.search.outputs_needed,
                                    word_scale=r.word_scale)
        assert out.completed and not out.deadlocked
        assert out.stall_cycles == r.tuned.stall_cycles
        # and the greedy side genuinely stalls more on the same sim
        assert out.stall_cycles < r.greedy.stall_cycles

    def test_objective_never_worse_than_seed(self):
        for net in ("r18", "r50"):
            r = tuned(net)
            assert r.tuned.objective <= r.greedy.objective


# ---------------------------------------------------------------------------
# compile() integration
# ---------------------------------------------------------------------------


class TestCompileIntegration:
    @functools.lru_cache(None)
    def _compiled(self=None):
        return compiler.compile(
            R18(), TARGET, autotune=AutotuneConfig(iterations=150))

    def test_returns_validated_pipeline_with_tuning(self):
        cp = self._compiled()
        assert isinstance(cp, compiler.CompiledPipeline)
        assert cp.tuning is not None
        assert cp.tuning.improved
        # stage 4 bound every node; stage 5 found nothing to re-place
        assert len(cp.assignments) == len(cp.plan.schedules)
        assert cp.replaced == ()

    def test_eq2_verify_passes(self):
        self._compiled().eq2_report(batch=2).verify()

    def test_plain_compile_unaffected(self):
        cp = compiler.compile(R18(), TARGET)
        assert cp.tuning is None
        cp2 = compiler.compile(R18(), TARGET, autotune=False)
        assert cp2.tuning is None
        assert cp2.plan.streamed_names == cp.plan.streamed_names

    def test_with_offload_drops_tuning(self):
        cp = self._compiled()
        forced = cp.with_offload(cp.streamed_names)
        assert forced.tuning is None

    def test_serve_defaults_to_tuned_credits(self):
        import jax
        from repro.models.cnn import init_cnn_params
        cp = self._compiled()
        params = init_cnn_params(jax.random.PRNGKey(0), R18())
        eng = cp.serve(params)                      # not started
        assert eng.admission.capacity == cp.tuning.serving_credits
        explicit = cp.serve(params, credits=7)
        assert explicit.admission.capacity == 7


# ---------------------------------------------------------------------------
# invariants (property-tested over search seeds)
# ---------------------------------------------------------------------------


class TestInvariants:
    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10_000))
    def test_budgets_never_exceeded(self, seed):
        r = autotune_plan(R50(), TARGET,
                          AutotuneConfig(seed=seed, iterations=60))
        plan, cand = r.plan, r.candidate
        chains = sum(p.chains for p in plan.placements if p.offload)
        assert chains <= TARGET.chain_budget
        assert r.tuned.onchip_m20ks <= max(TARGET.bram_m20ks,
                                           r.greedy.onchip_m20ks)
        assert cand.bm_words >= cand.burst
        assert cand.laststage >= \
            hbm_model.min_laststage_fifo_depth(cand.burst)
        for s in plan.schedules:
            eng = compiler.select_engine(s.spec)
            assert eng.vmem_bytes(s.spec, s) <= TARGET.vmem_bytes
        # every streamed layer got a pseudo-channel inside the target
        assert all(s.pc is not None and 0 <= s.pc < TARGET.n_pc
                   for s in plan.streamed)

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10_000))
    def test_never_worse_than_seed_any_seed(self, seed):
        r = autotune_plan(R18(), TARGET,
                          AutotuneConfig(seed=seed, iterations=60))
        assert r.tuned.objective <= r.greedy.objective
        assert r.tuned.images_per_s >= r.greedy.images_per_s

    @pytest.mark.parametrize("strategy", ["anneal", "greedy"])
    def test_deterministic_per_seed(self, strategy):
        at = AutotuneConfig(seed=3, iterations=80, strategy=strategy)
        a = autotune_plan(R18(), TARGET, at)
        b = autotune_plan(R18(), TARGET, at)
        assert a.candidate == b.candidate
        assert a.tuned == b.tuned
        assert a.accepted_moves == b.accepted_moves

    def test_zero_iterations_returns_seed(self):
        r = autotune_plan(R18(), TARGET, AutotuneConfig(iterations=0))
        assert r.candidate == r.seed_candidate
        assert r.tuned == r.greedy

    def test_bad_config_rejected(self):
        with pytest.raises(ValueError):
            AutotuneConfig(strategy="magic")
        with pytest.raises(ValueError):
            AutotuneConfig(iterations=-1)

    def test_cost_model_rejects_bad_candidates(self):
        """Structurally invalid candidates are infeasible with named
        violations, never silently costed (the annealer relies on this
        to discard bad proposals without corrupting best-so-far)."""
        import dataclasses
        from repro.compiler.autotune import _CostModel
        model = _CostModel(R18(), TARGET, AutotuneConfig())
        seed = model.seed_candidate

        def check(substr, **changes):
            ev = model.evaluate(dataclasses.replace(seed, **changes))
            assert not ev.feasible
            assert any(substr in v for v in ev.violations), ev.violations

        check("unstreamable", offload=seed.offload + ("gap",))
        check("uncharacterized burst", burst=5)
        check("bm_words", bm_words=seed.burst - 1)
        check("latency-covering minimum", laststage=seed.laststage // 2)

    def test_infeasible_target_raises(self):
        # a VMEM budget no engine fits makes even the greedy seed
        # infeasible -> AutotuneError, pointing callers at plain
        # compile() for the full TargetBudgetError diagnosis
        tiny = TARGET.replace(vmem_bytes=1)
        with pytest.raises(AutotuneError):
            autotune_plan(R18(), tiny, AutotuneConfig(iterations=5))


# ---------------------------------------------------------------------------
# serving-credit co-optimization
# ---------------------------------------------------------------------------


class TestServingCredits:
    @settings(max_examples=8, deadline=None)
    @given(latency=st.integers(min_value=0, max_value=8))
    def test_smallest_saturating(self, latency):
        c = solve_serving_credits(latency, items=32, max_credits=12)
        assert 1 <= c <= 12
        saturated = admission.replay_schedule(
            32, capacity=12, latency_ticks=latency).makespan
        assert admission.replay_schedule(
            32, capacity=c, latency_ticks=latency).makespan == saturated
        if c > 1:
            assert admission.replay_schedule(
                32, capacity=c - 1,
                latency_ticks=latency).makespan > saturated

    def test_attached_to_result(self):
        r = tuned("r18")
        assert r.serving_credits == solve_serving_credits(
            r.search.serving_latency_ticks,
            max_credits=r.search.max_serving_credits)
