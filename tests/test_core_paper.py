"""Tests for the paper's core artifacts: HBM model (Fig. 3), Eq. 1/Alg. 1
placement, Eq. 2 bounds (Fig. 6), and the Fig. 5 deadlock + credit fix."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import CNN_CONFIGS
from repro.core import bounds, fifo_sim, hbm_model, placement


# ---------------------------------------------------------------------------
# HBM model (Fig. 3)
# ---------------------------------------------------------------------------


def test_efficiency_monotone_in_burst():
    effs = [hbm_model.read_efficiency(b) for b in (1, 2, 4, 8, 16, 32)]
    assert all(a <= b for a, b in zip(effs, effs[1:]))
    # paper: ~50% below burst 4, 83% at 8, 93% at 32
    assert effs[2] < 0.6
    assert abs(hbm_model.read_efficiency(8) - 0.83) < 0.02
    assert abs(hbm_model.read_efficiency(32) - 0.93) < 0.02


def test_write_efficiency_below_read():
    """§III-A: write efficiency peaks ~15 points under read."""
    for b in (8, 16, 32):
        assert hbm_model.write_efficiency(b) < hbm_model.read_efficiency(b)


def test_latency_drops_with_burst():
    assert hbm_model.read_latency_ns(32, "avg") <= \
        hbm_model.read_latency_ns(8, "avg")
    assert abs(hbm_model.read_latency_ns(32, "avg") - 400) < 50


def test_fifo_depth_is_512():
    """§III-B: covering 1214 ns at 300 MHz needs 364 cycles -> 512 words."""
    assert hbm_model.min_laststage_fifo_depth(burst=8) == 512


def test_effective_bandwidth_279():
    """§VI-B: 31 PCs x 240 bits @ 300 MHz = 279 GB/s."""
    assert abs(hbm_model.EFFECTIVE_BW_BYTES / 1e9 - 279) < 1


def test_pc_simulator_efficiency_tracks_model():
    reqs = hbm_model.interleaved_stream(3, 200, burst=8)
    res = hbm_model.simulate_pc(reqs, burst=8)
    # words/cycle should be within ~10 points of the measured curve
    assert abs(res.efficiency - hbm_model.read_efficiency(8)) < 0.12
    assert set(res.per_consumer_words) == {0, 1, 2}


# ---------------------------------------------------------------------------
# Eq. 2 bounds (Fig. 6)
# ---------------------------------------------------------------------------


def test_eq2_bounds_match_paper():
    """Paper: VGG-16 hw 430 im/s is 78% of its all-HBM bound -> bound ~551;
    ResNet-50 hw 748 at 68% -> ~1100; ResNet-18 bound ~2400."""
    b_vgg = bounds.all_hbm_bound_ims(CNN_CONFIGS["vgg16"])
    b_r50 = bounds.all_hbm_bound_ims(CNN_CONFIGS["resnet50"])
    b_r18 = bounds.all_hbm_bound_ims(CNN_CONFIGS["resnet18"])
    assert abs(b_vgg - 551) / 551 < 0.05
    assert abs(b_r50 - 1100) / 1100 < 0.05
    assert abs(b_r18 - 2400) / 2400 < 0.10


def test_table1_memory_breakdown():
    """Activations < 35% of memory everywhere; VGG-16 ~1%; shaded rows
    (ResNet-50, VGG-16) exceed the 140 Mb device."""
    for name, cfg in CNN_CONFIGS.items():
        w = cfg.total_weight_bits()
        a = cfg.total_activation_bits()
        assert a / (a + w) < 0.35, name
    assert CNN_CONFIGS["vgg16"].total_activation_bits() / (
        CNN_CONFIGS["vgg16"].total_weight_bits()
        + CNN_CONFIGS["vgg16"].total_activation_bits()) < 0.03
    device_bits = 140e6
    assert CNN_CONFIGS["resnet50"].total_weight_bits() > device_bits
    assert CNN_CONFIGS["vgg16"].total_weight_bits() > device_bits
    assert CNN_CONFIGS["resnet18"].total_weight_bits() < device_bits


# ---------------------------------------------------------------------------
# Eq. 1 / Algorithm 1
# ---------------------------------------------------------------------------


def _plans(name="resnet50", frac=0.33):
    cfg = CNN_CONFIGS[name]
    return placement.allocate_parallelism(
        cfg, int(bounds.NX2100_TENSOR_BLOCKS * frac))


def test_algorithm1_budget_respected():
    plans = placement.algorithm1(_plans())
    used = sum(p.chains for p in plans if p.offload)
    assert used <= hbm_model.USABLE_PCS * placement.CHAINS_PER_PC


def test_algorithm1_prefers_high_score():
    plans = placement.algorithm1(_plans())
    scores_off = [placement.eq1_score(p) for p in plans if p.offload]
    scores_on = [placement.eq1_score(p) for p in plans if not p.offload]
    if scores_off and scores_on:
        # every offloaded layer scores >= any on-chip layer that would
        # still have fit in the leftover bandwidth
        free = hbm_model.USABLE_PCS * placement.CHAINS_PER_PC - \
            sum(p.chains for p in plans if p.offload)
        for p in plans:
            if not p.offload and p.chains <= free and \
                    placement.eq1_score(p) > 0:
                assert placement.eq1_score(p) <= max(scores_off) + 1e-9


def test_hybrid_keeps_activations_on_chip():
    """§III-B decision: only weights move; the hybrid selection never
    counts activations as offloadable."""
    plans = placement.hybrid_selection(_plans(), bounds.NX2100_M20KS)
    assert any(not p.offload for p in plans)


def test_clockwise_pc_assignment():
    plans = placement.algorithm1(_plans("vgg16", 0.40))
    placement.assign_pseudo_channels(plans)
    seq = [p.pc for p in plans if p.offload]
    clockwise = list(range(16)) + list(range(31, 15, -1))
    assert seq == clockwise[:len(seq)]


def test_throughput_hybrid_beats_all_hbm():
    """Fig. 6 headline: the hybrid memory system outperforms all-HBM on
    every network, ResNet-18 by the largest factor."""
    gains = {}
    for name, frac in (("resnet18", .51), ("resnet50", .33), ("vgg16", .4)):
        plans = _plans(name, frac)
        for p in plans:
            p.offload = True
        placement.assign_pseudo_channels(plans)
        all_hbm = placement.pipeline_throughput(plans)["images_per_s"]
        ph = placement.hybrid_selection(plans, bounds.NX2100_M20KS)
        placement.assign_pseudo_channels(ph)
        hyb = placement.pipeline_throughput(ph)["images_per_s"]
        assert hyb >= all_hbm, name
        gains[name] = hyb / all_hbm
    assert gains["resnet18"] == max(gains.values())


@given(st.integers(2, 40), st.integers(1, 30))
@settings(max_examples=30, deadline=None)
def test_algorithm1_property_budget(n_layers, n_pc):
    """Property: whatever the topology, Algorithm 1 never oversubscribes
    the chain pool and offload flags are deterministic."""
    from repro.configs.cnn import ConvLayerSpec
    layers = tuple(
        ConvLayerSpec(f"l{i}", "conv", 3, 3, 16 * (1 + i % 4),
                      16 * (1 + (i + 1) % 4), 1, 32, 32)
        for i in range(n_layers))
    from repro.configs.cnn import CNNConfig
    plans = placement.allocate_parallelism(CNNConfig("x", layers), 500)
    placement.algorithm1(plans, n_pc=n_pc)
    used = sum(p.chains for p in plans if p.offload)
    assert used <= n_pc * placement.CHAINS_PER_PC


# ---------------------------------------------------------------------------
# Fig. 5 deadlock / credits
# ---------------------------------------------------------------------------


def test_fig5_ready_valid_deadlocks():
    out = fifo_sim.demo()
    assert out["ready_valid"].deadlocked
    assert not out["credit"].deadlocked
    assert out["credit"].completed


@given(
    n_layers=st.integers(2, 5),
    burst=st.sampled_from([2, 4, 8]),
    bm_depth=st.integers(2, 16),
    act_depth=st.integers(1, 4),
    latency=st.integers(1, 30),
    w0=st.integers(1, 8),
)
@settings(max_examples=40, deadline=None)
def test_credit_mode_never_deadlocks(n_layers, burst, bm_depth, act_depth,
                                     latency, w0):
    """§V-A property: credit-based flow control is deadlock-free for ANY
    topology/sizing in which a burst fits the burst-matching FIFO."""
    bm_depth = max(bm_depth, burst)        # credits must cover one burst
    cfg = fifo_sim.SimConfig(
        n_layers=n_layers, burst=burst, bm_fifo_depth=bm_depth,
        act_fifo_depth=act_depth, dcfifo_depth=2 * burst,
        hbm_latency=latency,
        weights_per_act=tuple([w0] + [1] * (n_layers - 1)),
        outputs_needed=16)
    out = fifo_sim.simulate(cfg, "credit",
                            start_skew=[10 * i for i in range(n_layers)])
    assert not out.deadlocked
    assert out.completed
