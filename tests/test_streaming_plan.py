"""Tests for the LM-side placement plan (Eq. 1 / Alg. 1 on TPU tiers)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.core import streaming
from repro.models import transformer as tmod
from repro.models.layers import set_mesh_axis_sizes


@pytest.fixture
def mesh16x16():
    set_mesh_axis_sizes({"data": 16, "model": 16})
    yield
    set_mesh_axis_sizes({})


def _abstract(arch):
    return jax.eval_shape(lambda: tmod.init_params(jax.random.PRNGKey(0),
                                                   arch))


def test_plan_fits_budget_command_r(mesh16x16):
    """104B dense cannot replicate over data: the plan must dp-stream
    enough tensors to fit 16 GiB per chip."""
    arch = get_arch("command-r-plus-104b")
    params = _abstract(arch)
    specs = tmod.param_specs(arch)
    plan = streaming.plan_placement(params, specs, arch,
                                    hbm_per_device=16 * 2**30,
                                    reserve_bytes=6 * 2**30)
    assert plan.bytes_per_device() <= 10 * 2**30
    assert len(plan.streamed()) > 0


def test_small_arch_stays_replicated(mesh16x16):
    arch = get_arch("xlstm-125m")
    params = _abstract(arch)
    specs = tmod.param_specs(arch)
    plan = streaming.plan_placement(params, specs, arch)
    assert len(plan.streamed()) == 0          # 125M fits everywhere


def test_moe_experts_stream_first(mesh16x16):
    """Eq. 1 ordering: routed experts (low uses-per-step) must be chosen
    for streaming before any always-hot tensor."""
    arch = get_arch("deepseek-v2-236b")
    params = _abstract(arch)
    specs = tmod.param_specs(arch)
    plan = streaming.plan_placement(params, specs, arch)
    streamed = {t.path for t in plan.streamed()}
    assert streamed, "deepseek must stream something"
    hot_streamed = [p for p in streamed
                    if "router" in p or "ln" in p or "norm" in p]
    assert not hot_streamed


def test_apply_plan_divisibility(mesh16x16):
    arch = get_arch("command-r-plus-104b")
    params = _abstract(arch)
    specs = tmod.param_specs(arch)
    plan = streaming.plan_placement(params, specs, arch)
    new_specs = streaming.apply_plan_to_specs(specs, plan, params)
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_s = jax.tree_util.tree_flatten_with_path(
        new_specs, is_leaf=lambda x: isinstance(x, P))[0]
    for (kp, leaf), (_, spec) in zip(flat_p, flat_s):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= {"data": 16, "model": 16}.get(a, 1)
            assert dim % size == 0, (jax.tree_util.keystr(kp), leaf.shape,
                                     spec)


def test_vmem_residency_knapsack(mesh16x16):
    arch = get_arch("xlstm-125m").reduced()
    params = tmod.init_params(jax.random.PRNGKey(0), arch)
    pinned = streaming.plan_vmem_residency(params, arch,
                                           vmem_budget=64 * 2**10)
    used = sum(l.size * l.dtype.itemsize
               for (kp, l) in
               jax.tree_util.tree_flatten_with_path(params)[0]
               if pinned[jax.tree_util.keystr(kp)])
    assert used <= 64 * 2**10
