"""mini_mobilenet: end-to-end coverage for the depthwise topology.

The mini resnets exercise conv/pwconv/res_block engines end to end;
this config does the same for ``dwconv_int8`` — compile binds it, the
fused and eager backends run it bit-identically against the pure-JAX
reference, Algorithm 1 placement over the dw/pw alternation is pinned
by golden, and the Eq. 2 cross-check holds.  Regenerate the golden with

    PYTHONPATH=src python tests/regen_placement_goldens.py --mini

after a deliberate planner change (the script prints this literal too).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compiler
from repro.compiler import TPU_INTERPRET
from repro.configs.cnn import mini_mobilenet, residual_blocks
from repro.models.cnn import cnn_forward, cnn_input_shape, init_cnn_params

# the golden config: big enough that Algorithm 1 genuinely offloads
# (three streamed pwconvs), small enough for interpret mode
GOLDEN_CFG = dict(hw=16, width=32, blocks=6)
# (n_nodes, [(layer, pc, p_i, p_o), ...]) at the TPU_INTERPRET budgets
MOBILENET_MINI_GOLDEN = (15, [
    ("pw3", 0, 4, 4),
    ("pw4", 1, 8, 4),
    ("pw5", 2, 4, 8),
])

RUN_CFG = mini_mobilenet(hw=8, width=16, blocks=4)   # executable scale


@pytest.fixture(scope="module")
def setup():
    cp = compiler.compile(RUN_CFG, TPU_INTERPRET)
    params = init_cnn_params(jax.random.PRNGKey(0), RUN_CFG)
    return cp, params


def test_builder_validation():
    with pytest.raises(ValueError, match="odd"):
        mini_mobilenet(hw=6, width=16, blocks=4)     # 3x3 map at dw3
    with pytest.raises(ValueError, match="at least one"):
        mini_mobilenet(blocks=0)


def test_no_residual_structure():
    """MobileNet has no identity adds: no blocks to fuse, every
    partition cut is legal."""
    cfg = mini_mobilenet(**GOLDEN_CFG)
    assert residual_blocks(cfg) == ()
    assert cfg.name == "mobilenet-mini"
    assert cfg.num_classes == 10


def test_compile_binds_dwconv_engine(setup):
    cp, _ = setup
    table = cp.engine_table()
    dw = [l.name for l in RUN_CFG.layers if l.kind == "dwconv"]
    assert dw
    for name in dw:
        assert table[name] == "dwconv_int8"
    assert "jnp_ref" not in set(table.values())
    assert cp.block_assignments == ()                # nothing to fuse


def test_golden_placement():
    n_nodes, offloaded = MOBILENET_MINI_GOLDEN
    cp = compiler.compile(mini_mobilenet(**GOLDEN_CFG), TPU_INTERPRET)
    assert len(cp.schedules) == n_nodes
    got = [(s.spec.name, s.pc, s.p_i, s.p_o) for s in cp.plan.streamed]
    assert got == offloaded
    assert cp.replaced == ()


def test_fused_eager_reference_identical(setup):
    cp, params = setup
    rng = np.random.default_rng(0)
    x = rng.integers(-8, 8, size=cnn_input_shape(RUN_CFG, 2),
                     dtype=np.int8)
    yf, repf = cp.run(params, jnp.asarray(x))
    ye, repe = cp.run(params, jnp.asarray(x), backend="eager")
    yr = cnn_forward(params, RUN_CFG, jnp.asarray(x))
    assert np.array_equal(np.asarray(yf), np.asarray(ye))
    assert np.array_equal(np.asarray(yf), np.asarray(yr))
    repf.verify()
    repe.verify()


def test_eq2_report_verifies(setup):
    cp, _ = setup
    cp.eq2_report(batch=2).verify()
    # and per-stage when partitioned (no atomic units: any cut count
    # up to the node count is legal)
    cp.partition(3).verify_eq2(batch=2)
