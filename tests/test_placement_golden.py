"""Golden regression tests for Algorithm 1 placement.

These lock in the paper-facing planner outputs — which layers offload to
HBM, their pseudo-channel assignment, and the FIFO sizing — for the three
networks the paper evaluates, at the default NX2100 budgets used by
``build_pipeline_plan``.  A planner refactor that silently changes any of
these changes the reproduction's claims; update the goldens only with a
deliberate re-derivation.

Current goldens encode the paper's §VI-A structure: ResNet-18 fits
entirely on chip (no offload), while ResNet-50 and VGG-16 stream their
late heavy layers + fc heads, assigned clockwise PCs 0..5.
"""
import pytest

from repro.configs import CNN_CONFIGS
from repro.core import build_pipeline_plan

# name -> (n_layers, [(layer, pc, p_i, p_o), ...] for the offloaded set)
GOLDEN = {
    "resnet18": (21, []),
    "resnet50": (54, [
        ("s3b0c1", 0, 16, 1),
        ("s3b0c2", 1, 2, 4),
        ("s3b0ds", 2, 4, 4),
        ("s3b1c1", 3, 16, 1),
        ("s3b2c1", 4, 16, 1),
        ("fc", 5, 2, 1),
    ]),
    "vgg16": (16, [
        ("conv8", 0, 16, 1),
        ("conv9", 1, 16, 1),
        ("conv10", 2, 8, 1),
        ("fc0", 3, 16, 2),
        ("fc1", 4, 4, 2),
        ("fc2", 5, 1, 1),
    ]),
}


@pytest.mark.parametrize("name", sorted(GOLDEN))
def test_algorithm1_placement_golden(name):
    n_layers, offloaded = GOLDEN[name]
    plan = build_pipeline_plan(CNN_CONFIGS[name])
    assert len(plan.schedules) == n_layers
    got = [(s.spec.name, s.pc, s.p_i, s.p_o) for s in plan.streamed]
    assert got == offloaded


@pytest.mark.parametrize("name", sorted(GOLDEN))
def test_fifo_sizing_golden(name):
    """§IV-A sizing at burst 8: 512-deep last-stage FIFOs (the paper's
    1214 ns worst-case saturated latency at 300 MHz), 2-burst matching."""
    plan = build_pipeline_plan(CNN_CONFIGS[name])
    for s in plan.schedules:
        assert s.laststage_fifo_depth == 512
        assert s.bm_fifo_words == 16
        assert s.burst == 8


def test_resnet18_fits_on_chip():
    """§VI-A: ResNet-18's weights fit in NX2100 BRAM — hybrid selection
    must keep everything pinned at the real device budget."""
    plan = build_pipeline_plan(CNN_CONFIGS["resnet18"])
    assert plan.streamed_names == ()


def test_offloaded_pcs_clockwise_and_unique():
    for name in ("resnet50", "vgg16"):
        plan = build_pipeline_plan(CNN_CONFIGS[name])
        pcs = [s.pc for s in plan.streamed]
        assert pcs == sorted(pcs)                  # clockwise in layer order
        assert len(set(pcs)) == len(pcs)           # no PC shared here
