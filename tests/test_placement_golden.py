"""Golden regression tests for Algorithm 1 placement.

These lock in the paper-facing planner outputs — which layers offload to
HBM, their pseudo-channel assignment, and the FIFO sizing — for the three
networks the paper evaluates, at the NX2100 target's default budgets.  A
compiler refactor that silently changes any of these changes the
reproduction's claims; update the goldens only with a deliberate
re-derivation.

Current goldens encode the paper's §VI-A structure: ResNet-18 fits
entirely on chip (no offload), while ResNet-50 and VGG-16 stream their
late heavy layers + fc heads, assigned clockwise PCs 0..5.
"""
import warnings

import pytest

from repro import compiler
from repro.compiler import NX2100
from repro.configs import CNN_CONFIGS

# name -> (n_layers, [(layer, pc, p_i, p_o), ...] for the offloaded set)
GOLDEN = {
    "resnet18": (21, []),
    "resnet50": (54, [
        ("s3b0c1", 0, 16, 1),
        ("s3b0c2", 1, 2, 4),
        ("s3b0ds", 2, 4, 4),
        ("s3b1c1", 3, 16, 1),
        ("s3b2c1", 4, 16, 1),
        ("fc", 5, 2, 1),
    ]),
    "vgg16": (16, [
        ("conv8", 0, 16, 1),
        ("conv9", 1, 16, 1),
        ("conv10", 2, 8, 1),
        ("fc0", 3, 16, 2),
        ("fc1", 4, 4, 2),
        ("fc2", 5, 1, 1),
    ]),
}


@pytest.mark.parametrize("name", sorted(GOLDEN))
def test_algorithm1_placement_golden(name):
    n_layers, offloaded = GOLDEN[name]
    cp = compiler.compile(CNN_CONFIGS[name], NX2100)
    assert len(cp.schedules) == n_layers
    got = [(s.spec.name, s.pc, s.p_i, s.p_o) for s in cp.plan.streamed]
    assert got == offloaded
    # stage-5 validation must not have moved anything at the real device
    # budgets — the goldens are pure Algorithm 1 outputs
    assert cp.replaced == ()


@pytest.mark.parametrize("name", sorted(GOLDEN))
def test_fifo_sizing_golden(name):
    """§IV-A sizing at burst 8: 512-deep last-stage FIFOs (the paper's
    1214 ns worst-case saturated latency at 300 MHz), 2-burst matching."""
    cp = compiler.compile(CNN_CONFIGS[name], NX2100)
    for s in cp.schedules:
        assert s.laststage_fifo_depth == 512
        assert s.bm_fifo_words == 16
        assert s.burst == 8


def test_resnet18_fits_on_chip():
    """§VI-A: ResNet-18's weights fit in NX2100 BRAM — hybrid selection
    must keep everything pinned at the real device budget."""
    cp = compiler.compile(CNN_CONFIGS["resnet18"], NX2100)
    assert cp.streamed_names == ()


def test_offloaded_pcs_clockwise_and_unique():
    for name in ("resnet50", "vgg16"):
        cp = compiler.compile(CNN_CONFIGS[name], NX2100)
        pcs = [s.pc for s in cp.plan.streamed]
        assert pcs == sorted(pcs)                  # clockwise in layer order
        assert len(set(pcs)) == len(pcs)           # no PC shared here


@pytest.mark.parametrize("name", sorted(GOLDEN))
def test_deprecated_shim_equals_compile(name):
    """``build_pipeline_plan`` (the deprecation shim) returns a plan EQUAL
    to ``compile(cfg, NX2100).plan`` at the default budgets — old call
    sites keep the exact same placements while warning toward the new
    API."""
    from repro.core import build_pipeline_plan
    with pytest.deprecated_call():
        old = build_pipeline_plan(CNN_CONFIGS[name])
    assert old == compiler.compile(CNN_CONFIGS[name], NX2100).plan


def test_shim_preserves_pre_compiler_placements():
    """The shim runs stages 1-3 only: unlike compile(), it never applies
    stage-5 VMEM re-placement, so legacy callers with non-default budgets
    get the exact pre-compiler placements.  (vgg16 under a huge BRAM
    budget pins everything — including the 103 MB fc0 buffer compile()
    would re-place to the HBM tier.)"""
    from repro.core import build_pipeline_plan
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        plan = build_pipeline_plan(CNN_CONFIGS["vgg16"], bram_m20ks=10**6)
    assert plan.streamed_names == ()               # pre-PR behavior
    compiled = compiler.compile(
        CNN_CONFIGS["vgg16"], NX2100.replace(bram_m20ks=10**6))
    assert "fc0" in compiled.replaced              # compile() re-places


def test_shim_forwards_custom_budgets():
    """Keyword overrides on the shim map onto Target fields 1:1."""
    from repro.configs.cnn import mini_resnet18
    from repro.core import build_pipeline_plan
    cfg = mini_resnet18(hw=32, width=32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old = build_pipeline_plan(cfg, tb_budget=500, bram_m20ks=40)
    assert old == compiler.compile(cfg, compiler.TPU_INTERPRET).plan
