"""Golden regression tests for Algorithm 1 placement.

These lock in the paper-facing planner outputs — which layers offload to
HBM, their pseudo-channel assignment, the FIFO sizing, and the fused
residual-block units — for the three networks the paper evaluates, at
the NX2100 target's default budgets.  A compiler refactor that silently
changes any of these changes the reproduction's claims; update the
goldens only with a deliberate re-derivation:

    PYTHONPATH=src python tests/regen_placement_goldens.py

and paste its output over GOLDEN / GOLDEN_BLOCKS (the script prints both
literals; see its docstring).

Current goldens encode the paper's §VI-A structure over the FULL
topology — pool/GAP nodes included as first-class graph nodes since the
topology-engine migration: ResNet-18 (23 nodes: 20 convs/fc + maxpool +
GAP) fits entirely on chip, while ResNet-50 (56 nodes) and VGG-16 (21
nodes: 13 convs + 5 maxpools + 3 fc) stream their late heavy layers +
fc heads, assigned clockwise PCs from 0.  Pool nodes are weightless:
they are never offloaded (Eq. 1 score < 0), always bind the pool
engines, and contribute activation line buffers — not weights — to the
BRAM budget (their buffers are why ResNet-50 now streams s3b1c0 too).
All 16 ResNet-50 bottleneck blocks bind as fused ``res_block_int8``
units under the tightened (member sum + identity + widest intermediate)
VMEM model.
"""
import warnings

import pytest

from repro import compiler
from repro.compiler import NX2100
from repro.configs import CNN_CONFIGS
from repro.configs.cnn import stem_unit

# name -> (n_nodes, [(layer, pc, p_i, p_o), ...] for the offloaded set)
GOLDEN = {
    "resnet18": (23, []),
    "resnet50": (56, [
        ("s3b0c1", 0, 16, 1),
        ("s3b0c2", 1, 2, 4),
        ("s3b0ds", 2, 4, 4),
        ("s3b1c0", 3, 8, 1),
        ("s3b1c1", 4, 16, 1),
        ("s3b2c1", 5, 16, 1),
        ("fc", 6, 2, 1),
    ]),
    "vgg16": (21, [
        ("conv8", 0, 16, 1),
        ("conv9", 1, 16, 1),
        ("conv10", 2, 8, 1),
        ("fc0", 3, 16, 2),
        ("fc1", 4, 4, 2),
        ("fc2", 5, 1, 1),
    ]),
}

# name -> (fused block units, bottleneck units, plan-side Eq. 2 words
# over all block units per image) at the NX2100 defaults.  The unit
# counts include the fused stem conv+maxpool pair on the ResNet-family
# nets (8 residual + 1 stem, 16 + 1); VGG's conv-conv stem has no unit.
GOLDEN_BLOCKS = {
    "resnet18": (9, 0, 0),
    "resnet50": (17, 16, 7890554),
    "vgg16": (0, 0, 0),
}

POOL_ENGINES = {"maxpool": "maxpool_int8", "gap": "global_avgpool_int8"}


@pytest.mark.parametrize("name", sorted(GOLDEN))
def test_algorithm1_placement_golden(name):
    n_nodes, offloaded = GOLDEN[name]
    cp = compiler.compile(CNN_CONFIGS[name], NX2100)
    assert len(cp.schedules) == n_nodes
    got = [(s.spec.name, s.pc, s.p_i, s.p_o) for s in cp.plan.streamed]
    assert got == offloaded
    # stage-5 validation must not have moved anything at the real device
    # budgets — the goldens are pure Algorithm 1 outputs
    assert cp.replaced == ()


@pytest.mark.parametrize("name", sorted(GOLDEN))
def test_pool_nodes_placed_pinned_on_pool_engines(name):
    """The topology nodes are first-class in the plan: every pool/GAP
    node appears in the schedule, pinned (weightless — Algorithm 1 can
    never score them positive), bound to its dedicated pool engine."""
    cp = compiler.compile(CNN_CONFIGS[name], NX2100)
    table = cp.engine_table()
    pools = [l for l in CNN_CONFIGS[name].layers if l.is_pool]
    assert pools, f"{name} config carries no explicit pool nodes?"
    su = stem_unit(CNN_CONFIGS[name])
    stem_pool = su.pool.name if su is not None else None
    for spec in pools:
        sched = cp.plan.schedule_for(spec.name)
        assert not sched.streamed
        assert sched.weight_words_per_image == 0
        if spec.name == stem_pool:
            # the stem maxpool belongs to the fused stem unit
            assert table[spec.name] == "stem_pool_int8"
        else:
            assert table[spec.name] == POOL_ENGINES[spec.kind]


@pytest.mark.parametrize("name", sorted(GOLDEN_BLOCKS))
def test_fused_block_units_golden(name):
    """Block-unit golden: how many residual blocks bind as fused
    ``res_block_int8`` units at the NX2100 defaults, how many of those
    are BOTTLENECK (three-conv) units, and the plan-side Eq. 2 words the
    units own.  ResNet-50 — the paper's 5.1x headline net — must fuse
    every one of its 16 bottleneck blocks."""
    n_units, n_bottleneck, words = GOLDEN_BLOCKS[name]
    cp = compiler.compile(CNN_CONFIGS[name], NX2100)
    assert len(cp.block_assignments) == n_units
    got_bottleneck = sum(
        1 for b in cp.block_assignments
        if sum(1 for m in b.members if not m.endswith("ds")) == 3)
    assert got_bottleneck == n_bottleneck
    assert sum(b.hbm_words_per_image for b in cp.block_assignments) == words
    su = stem_unit(CNN_CONFIGS[name])
    for b in cp.block_assignments:
        if su is not None and b.block == su.name:
            assert b.engine == "stem_pool_int8"
        else:
            assert b.engine == "res_block_int8"
        assert b.vmem_bytes <= NX2100.vmem_bytes


@pytest.mark.parametrize("name", sorted(GOLDEN))
def test_fifo_sizing_golden(name):
    """§IV-A sizing at burst 8: 512-deep last-stage FIFOs (the paper's
    1214 ns worst-case saturated latency at 300 MHz), 2-burst matching."""
    cp = compiler.compile(CNN_CONFIGS[name], NX2100)
    for s in cp.schedules:
        assert s.laststage_fifo_depth == 512
        assert s.bm_fifo_words == 16
        assert s.burst == 8


def test_resnet18_fits_on_chip():
    """§VI-A: ResNet-18's weights fit in NX2100 BRAM — hybrid selection
    must keep everything pinned at the real device budget."""
    cp = compiler.compile(CNN_CONFIGS["resnet18"], NX2100)
    assert cp.streamed_names == ()


def test_offloaded_pcs_clockwise_and_unique():
    for name in ("resnet50", "vgg16"):
        cp = compiler.compile(CNN_CONFIGS[name], NX2100)
        pcs = [s.pc for s in cp.plan.streamed]
        assert pcs == sorted(pcs)                  # clockwise in layer order
        assert len(set(pcs)) == len(pcs)           # no PC shared here


@pytest.mark.parametrize("name", sorted(GOLDEN))
def test_deprecated_shim_equals_compile(name):
    """``build_pipeline_plan`` (the deprecation shim) returns a plan EQUAL
    to ``compile(cfg, NX2100).plan`` at the default budgets — old call
    sites keep the exact same placements while warning toward the new
    API."""
    from repro.core import build_pipeline_plan
    with pytest.deprecated_call():
        old = build_pipeline_plan(CNN_CONFIGS[name])
    assert old == compiler.compile(CNN_CONFIGS[name], NX2100).plan


def test_shim_preserves_pre_compiler_placements():
    """The shim runs stages 1-3 only: unlike compile(), it never applies
    stage-5 VMEM re-placement, so legacy callers with non-default budgets
    get the exact pre-compiler placements.  (vgg16 under a huge BRAM
    budget pins everything — including the 103 MB fc0 buffer compile()
    would re-place to the HBM tier.)"""
    from repro.core import build_pipeline_plan
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        plan = build_pipeline_plan(CNN_CONFIGS["vgg16"], bram_m20ks=10**6)
    assert plan.streamed_names == ()               # pre-PR behavior
    compiled = compiler.compile(
        CNN_CONFIGS["vgg16"], NX2100.replace(bram_m20ks=10**6))
    assert "fc0" in compiled.replaced              # compile() re-places


def test_shim_forwards_custom_budgets():
    """Keyword overrides on the shim map onto Target fields 1:1."""
    from repro.configs.cnn import mini_resnet18
    from repro.core import build_pipeline_plan
    cfg = mini_resnet18(hw=32, width=32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old = build_pipeline_plan(cfg, tb_budget=500, bram_m20ks=40)
    assert old == compiler.compile(cfg, compiler.TPU_INTERPRET).plan
