"""The stage-6 fused execution path: one jitted XLA dispatch per run.

Contract under test (compiler stage 6 + runtime/pipeline.py):

  * ``backend="fused"`` executes the whole pipeline as ONE compiled
    program and is bit-identical to the ``backend="eager"`` per-layer
    walk — on a net with genuinely mixed bindings, including
    ``res_block_int8``-fused residual blocks and streamed weight tiers;
  * the trace's stats template makes fused reports equal eager reports
    (post-hoc aggregation — engines return shape-static stats);
  * traces are cached per input shape on the CompiledPipeline: a warm
    shape never retraces, a new batch size retraces exactly once;
  * concurrent ``run()``\\ s on one pipeline keep their reports separate
    (per-run ExecutionReport, frozen EngineContext, stateless engines).
"""
import threading

import jax
import jax.numpy as jnp
import pytest

from repro import compiler
from repro.compiler import TPU_INTERPRET
from repro.configs.cnn import mini_resnet18
from repro.models.cnn import cnn_forward, cnn_input_shape, init_cnn_params
from repro.runtime.pipeline import PipelineExecutor

MINI = mini_resnet18(hw=16, width=32)


@pytest.fixture(scope="module")
def setup():
    cp = compiler.compile(MINI, TPU_INTERPRET)
    params = init_cnn_params(jax.random.PRNGKey(0), MINI)
    x = jax.random.randint(jax.random.PRNGKey(1), cnn_input_shape(MINI, 2),
                           -127, 128, jnp.int8)
    return cp, params, x


def test_fused_is_bit_identical_to_eager_and_reference(setup):
    """The golden contract: fusing the dispatch into one XLA program
    changes performance, never a single output bit — checked against
    both the eager walk and the functional jnp reference, on a plan
    that binds fused residual blocks AND streams several layers."""
    cp, params, x = setup
    assert cp.block_assignments            # res_block_int8 genuinely bound
    assert cp.streamed_names               # and weights genuinely stream
    ref = cnn_forward(params, MINI, x)
    fused, rf = cp.run(params, x, backend="fused")
    eager, re_ = cp.run(params, x, backend="eager")
    assert bool(jnp.all(fused == eager))
    assert bool(jnp.all(fused == ref))
    # and the reports agree entry-for-entry (same stats, same order)
    assert rf.layers == re_.layers
    assert rf.total_hbm_words == re_.total_hbm_words > 0


def test_fused_trace_cache_one_retrace_per_shape(setup):
    """Stage-6 traces are cached per (shape, dtype): warm shapes reuse
    the compiled program; a second batch size retraces exactly once."""
    cp, params, x = setup
    cp2 = compiler.compile(MINI, TPU_INTERPRET)    # fresh, empty cache
    assert cp2.trace_count == 0
    ex = PipelineExecutor(cp2)
    ex.run(params, x)
    assert cp2.trace_count == 1
    ex.run(params, x)                              # warm: no retrace
    ex.run(params, x)
    assert cp2.trace_count == 1
    ex.run(params, x[:1])                          # new batch: one retrace
    assert cp2.trace_count == 2
    ex.run(params, x[:1])
    assert cp2.trace_count == 2
    # executors share the pipeline's cache — a new executor never
    # recompiles a shape the pipeline has already traced
    PipelineExecutor(cp2).run(params, x)
    assert cp2.trace_count == 2


def test_fused_reports_scale_with_batch(setup):
    """Each shape's trace carries its own stats template: Eq. 2 words
    scale with the traced batch, never leak across shapes."""
    cp, params, x = setup
    per_image = sum(cp.plan.hbm_words_per_image().values())
    _, r2 = cp.run(params, x)
    _, r1 = cp.run(params, x[:1])
    assert r2.total_hbm_words == 2 * per_image
    assert r1.total_hbm_words == 1 * per_image


def test_concurrent_runs_do_not_cross_reports(setup):
    """Re-entrancy under the fused path: interleaved runs on ONE
    compiled pipeline from multiple threads produce independent,
    correct reports (the batched-serving prerequisite)."""
    cp, params, x = setup
    per_image = sum(cp.plan.hbm_words_per_image().values())
    ex = PipelineExecutor(cp)
    ex.run(params, x)                   # pre-trace batch 2
    ex.run(params, x[:1])               # pre-trace batch 1
    results = {}

    def worker(name, images):
        logits, report = ex.run(params, images)
        results[name] = (logits, report)

    threads = [threading.Thread(target=worker, args=(f"b2-{i}", x))
               for i in range(2)]
    threads += [threading.Thread(target=worker, args=(f"b1-{i}", x[:1]))
                for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    ref2 = cnn_forward(params, MINI, x)
    for name, (logits, report) in results.items():
        batch = 2 if name.startswith("b2") else 1
        assert report.images == batch, name
        assert len(report.layers) == len(cp.plan.schedules), name
        assert report.total_hbm_words == batch * per_image, name
        assert bool(jnp.all(logits == ref2[:batch])), name


def test_block_granular_eq2_rows(setup):
    """Fused ``res_block_int8`` units are first-class in the traffic
    cross-check: the report carries one Eq. 2 row per block unit, whose
    executed streamed words equal the plan-side
    ``BlockAssignment.hbm_words_per_image`` times the batch — on both
    backends."""
    cp, params, x = setup
    batch = int(x.shape[0])
    for backend in ("fused", "eager"):
        _, rep = cp.run(params, x, backend=backend)
        assert rep.block_assignments == cp.block_assignments
        rows = rep.block_rows()
        assert {r["block"] for r in rows} == set(cp.block_table())
        for row in rows:
            b = cp.block_for(row["block"])
            assert row["engine"] == b.engine
            assert row["members"] == list(b.members)
            assert row["hbm_words"] == batch * b.hbm_words_per_image
            assert row["hbm_words_per_image"] == b.hbm_words_per_image
            assert row["plan_hbm_words_per_image"] == b.hbm_words_per_image
        assert rep.hbm_block_words == {
            b.block: batch * b.hbm_words_per_image
            for b in cp.block_assignments}
        # block words are a subset of (not additional to) the layer total
        assert sum(rep.hbm_block_words.values()) <= rep.total_hbm_words
    # at least one block genuinely streams on this plan, or the test
    # proves nothing
    assert any(b.hbm_words_per_image for b in cp.block_assignments)


def test_unknown_backend_rejected(setup):
    cp, params, x = setup
    with pytest.raises(ValueError, match="backend"):
        PipelineExecutor(cp, backend="rtl")


def test_fused_engine_override_traces_once(setup):
    """A user engine override is traced exactly once per shape — the
    fused program embeds its computation, and warm runs never re-enter
    Python engine code."""
    cp, params, x = setup
    calls = []
    builtin = compiler.get_engine("stream_matmul")

    @compiler.register_engine("fc_probe", priority=99)
    class ProbeFCEngine:
        def supports(self, spec):
            return builtin.supports(spec)

        def vmem_bytes(self, spec, sched):
            return builtin.vmem_bytes(spec, sched)

        def run(self, ctx, sched, p, xx, relu):
            calls.append(sched.spec.name)
            return builtin.run(ctx, sched, p, xx, relu)

    try:
        probed = compiler.compile(MINI, TPU_INTERPRET)
        assert probed.engine_table()["fc"] == "fc_probe"
        out1, _ = probed.run(params, x)
        out2, _ = probed.run(params, x)
        assert calls == ["fc"]                 # one trace, zero re-entries
        assert bool(jnp.all(out1 == out2))
    finally:
        assert compiler.unregister_engine("fc_probe") is not None
