"""Per-kernel validation: sweep shapes/dtypes, assert_allclose against the
ref.py pure-jnp oracles (interpret=True executes kernels on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.conv2d_int8.ops import conv2d_int8
from repro.kernels.conv2d_int8.ref import conv2d_int8_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.stream_matmul.ops import stream_matmul, vmem_bytes
from repro.kernels.stream_matmul.ref import stream_matmul_ref


# ---------------------------------------------------------------------------
# stream_matmul
# ---------------------------------------------------------------------------

MM_SHAPES = [(128, 256, 128), (256, 1024, 384), (128, 512, 256)]


@pytest.mark.parametrize("shape", MM_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["stream", "fifo", "pinned"])
def test_stream_matmul(shape, dtype, mode, rng_key):
    M, K, N = shape
    k1, k2 = jax.random.split(rng_key)
    x = jax.random.normal(k1, (M, K), dtype)
    w = jax.random.normal(k2, (K, N), dtype)
    out = stream_matmul(x, w, mode=mode, bm=128, bk=128, bn=128,
                        n_buffers=3, interpret=True)
    ref = stream_matmul_ref(x, w)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol * float(jnp.max(jnp.abs(ref))))


@pytest.mark.parametrize("n_buffers", [1, 2, 4])
def test_stream_matmul_fifo_depth(n_buffers, rng_key):
    """The prefetch-window depth (the paper's FIFO depth knob) never
    changes results — only VMEM footprint."""
    x = jax.random.normal(rng_key, (128, 512), jnp.float32)
    w = jax.random.normal(rng_key, (512, 128), jnp.float32)
    ref = stream_matmul_ref(x, w)
    out = stream_matmul(x, w, mode="fifo", bk=128, n_buffers=n_buffers,
                        interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5,
                               atol=1e-4)
    assert vmem_bytes("fifo", 128, 512, 128, 4, bk=128,
                      n_buffers=n_buffers) > \
        vmem_bytes("fifo", 128, 512, 128, 4, bk=128, n_buffers=0)


def test_stream_matmul_int8(rng_key):
    x = jax.random.randint(rng_key, (128, 512), -127, 128, jnp.int8)
    w = jax.random.randint(rng_key, (512, 256), -127, 128, jnp.int8)
    ref = stream_matmul_ref(x, w)
    for mode in ("stream", "fifo"):
        out = stream_matmul(x, w, mode=mode, bk=128, interpret=True)
        assert out.dtype == jnp.int32
        assert bool(jnp.all(out == ref)), mode


# ---------------------------------------------------------------------------
# conv2d_int8
# ---------------------------------------------------------------------------

CONV_CASES = [
    (16, 16, 8, 16, 3, 1), (16, 16, 8, 16, 3, 2),
    (14, 14, 16, 32, 1, 1), (12, 12, 4, 8, 5, 2), (8, 8, 3, 16, 7, 2),
]


@pytest.mark.parametrize("case", CONV_CASES)
def test_conv2d_int8_exact(case, rng_key):
    H, W, C, Co, k, s = case
    x = jax.random.randint(rng_key, (2, H, W, C), -127, 128, jnp.int8)
    w = jax.random.randint(rng_key, (k, k, C, Co), -20, 21, jnp.int8)
    out = conv2d_int8(x, w, stride=s, interpret=True)
    ref = conv2d_int8_ref(x, w, stride=s)
    assert out.shape == ref.shape
    assert out.dtype == jnp.int32
    assert bool(jnp.all(out == ref)), case     # int math must be exact


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    dict(B=2, H=4, KV=4, S=256, hd=64, causal=True, window=0, softcap=0.0),
    dict(B=2, H=4, KV=2, S=256, hd=64, causal=True, window=64, softcap=0.0),
    dict(B=1, H=8, KV=2, S=128, hd=32, causal=True, window=0, softcap=50.0),
    dict(B=1, H=2, KV=2, S=128, hd=64, causal=False, window=0, softcap=0.0),
    dict(B=1, H=4, KV=1, S=128, hd=128, causal=True, window=32, softcap=30.0),
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(case, dtype, rng_key):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (case["B"], case["S"], case["H"],
                                  case["hd"]), dtype)
    k = jax.random.normal(ks[1], (case["B"], case["S"], case["KV"],
                                  case["hd"]), dtype)
    v = jax.random.normal(ks[2], (case["B"], case["S"], case["KV"],
                                  case["hd"]), dtype)
    out = flash_attention(q, k, v, causal=case["causal"],
                          window=case["window"], softcap=case["softcap"],
                          bq=64, bk=64, interpret=True)
    qt, kt, vt = (a.transpose(0, 2, 1, 3) for a in (q, k, v))
    ref = flash_attention_ref(qt, kt, vt, causal=case["causal"],
                              window=case["window"],
                              softcap=case["softcap"]).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol * 3)


def test_flash_matches_model_oracle(rng_key):
    """The kernel agrees with models.layers.blockwise_attention (the
    XLA-path oracle used by every arch)."""
    from repro.models.layers import blockwise_attention
    ks = jax.random.split(rng_key, 3)
    B, S, H, KV, hd = 2, 128, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    out_kernel = flash_attention(q, k, v, causal=True, bq=64, bk=64,
                                 interpret=True)
    out_oracle = blockwise_attention(q, k, v, causal=True, q_block=64,
                                     kv_block=64)
    np.testing.assert_allclose(np.asarray(out_kernel),
                               np.asarray(out_oracle), rtol=2e-5, atol=2e-5)
