"""Regenerate the GOLDEN tables in tests/test_placement_golden.py.

Run after a DELIBERATE planner change (new topology nodes, Eq. 1 tweaks,
block-cost model changes) and paste the output over the GOLDEN /
GOLDEN_BLOCKS literals — never regen to paper over an unexplained diff:

  PYTHONPATH=src python tests/regen_placement_goldens.py

Prints, per paper network at the NX2100 defaults:
  * total node count (convs + fc heads + pool topology nodes),
  * the offloaded set as (layer, pc, p_i, p_o) in pipeline order,
  * the fused-block golden: (n_blocks, bottleneck count, total plan-side
    Eq. 2 words over all block units).

With ``--mini``, instead prints the MOBILENET_MINI_GOLDEN literal for
tests/test_mini_mobilenet.py (the mini depthwise net at the
TPU_INTERPRET budgets).
"""
import sys

from repro import compiler
from repro.compiler import NX2100, TPU_INTERPRET
from repro.configs import CNN_CONFIGS

NETS = ("resnet18", "resnet50", "vgg16")


def golden_entry(name):
    cp = compiler.compile(CNN_CONFIGS[name], NX2100)
    offloaded = [(s.spec.name, s.pc, s.p_i, s.p_o)
                 for s in cp.plan.streamed]
    return len(cp.schedules), offloaded


def golden_blocks(name):
    cp = compiler.compile(CNN_CONFIGS[name], NX2100)
    bottlenecks = sum(
        1 for b in cp.block_assignments
        if sum(1 for m in b.members if not m.endswith("ds")) == 3)
    words = sum(b.hbm_words_per_image for b in cp.block_assignments)
    return len(cp.block_assignments), bottlenecks, words


def main_mini():
    from repro.configs.cnn import mini_mobilenet
    golden_cfg = dict(hw=16, width=32, blocks=6)    # = GOLDEN_CFG in the test
    cp = compiler.compile(mini_mobilenet(**golden_cfg), TPU_INTERPRET)
    print(f"# at GOLDEN_CFG = {golden_cfg!r}, TPU_INTERPRET budgets")
    print(f"MOBILENET_MINI_GOLDEN = ({len(cp.schedules)}, [")
    for s in cp.plan.streamed:
        print(f"    {(s.spec.name, s.pc, s.p_i, s.p_o)!r},")
    print("])")


def main():
    print("GOLDEN = {")
    for name in NETS:
        n, off = golden_entry(name)
        print(f"    {name!r}: ({n}, [")
        for row in off:
            print(f"        {row!r},")
        print("    ]),")
    print("}")
    print()
    print("# name -> (fused block units, bottleneck units, plan Eq. 2 words)")
    print("GOLDEN_BLOCKS = {")
    for name in NETS:
        print(f"    {name!r}: {golden_blocks(name)!r},")
    print("}")


if __name__ == "__main__":
    main_mini() if "--mini" in sys.argv[1:] else main()
