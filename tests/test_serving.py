"""Serving engine: credit admission, completion, greedy determinism."""
import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import transformer as tmod
from repro.runtime.serving import Request, ServingEngine


@pytest.fixture(scope="module")
def engine():
    arch = get_arch("phi4-mini-3.8b").reduced()
    params = tmod.init_params(jax.random.PRNGKey(0), arch)
    return ServingEngine(params, arch, batch_slots=2, max_seq=64)


def test_all_requests_complete(engine):
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, 100, size=6).astype(np.int32),
                    max_new=4) for i in range(5)]
    done = engine.run(reqs)
    assert len(done) == 5
    assert all(r.done and len(r.out) == 4 for r in done)


def test_credit_bound(engine):
    reqs = [Request(i, np.arange(4, dtype=np.int32), max_new=2)
            for i in range(10)]
    taken = engine.admit(reqs)
    assert len(taken) == engine.slots        # never exceeds free credits
    assert engine.credits == 0
    engine.admission.release(len(taken))     # return for other tests
    engine.admission.assert_quiescent()


def test_greedy_deterministic(engine):
    p = np.arange(6, dtype=np.int32)
    a = engine.run([Request(0, p, max_new=4)])[0].out
    b = engine.run([Request(1, p, max_new=4)])[0].out
    assert a == b
