"""Observability: tracing, metrics, stall attribution — the PR-9 surface.

Contract under test (obs/ + the instrumented serving/compiler paths):

  * a traced serving interval exports VALID Chrome Trace Event JSON —
    non-negative monotone ``ts`` per track, matched async begin/end
    pairs, and distinct tracks for admission / pack / dispatch /
    in-flight / delivery — while results stay bit-identical to
    sequential ``run()``;
  * ``ServingReport.bandwidth_efficiency`` carries the measured
    admission-wait / dispatch-gap fractions laid against ``fifo_sim``'s
    modelled stall cycles, on BOTH executable mini resnets;
  * everything is bounded for a long-lived server: the tracer ring
    evicts oldest-first with ``dropped`` counted, metric windows trim,
    the disabled :data:`NULL_TRACER` records nothing and allocates
    nothing per call;
  * reports round-trip through ``to_json``/``from_json`` exactly
    (including the new metrics / bandwidth_efficiency sections);
  * the injectable clock makes latency accounting testable with a
    :class:`ManualClock` instead of sleeps;
  * ``compile()`` records per-pass wall timings and trace-cache gauges
    into the default registry; ``autotune_plan`` records its
    per-iteration objective trajectory.
"""
import json
import threading

import jax
import numpy as np
import pytest

from repro import compiler
from repro.compiler import TPU_INTERPRET
from repro.configs.cnn import mini_resnet18, mini_resnet50
from repro.core.admission import AdmissionController
from repro.models.cnn import cnn_input_shape, init_cnn_params
from repro.obs import (NULL_TRACER, TRACKS, ManualClock, MetricsRegistry,
                       Tracer, default_registry, stall_attribution,
                       validate_chrome_trace)
from repro.obs.metrics import Histogram
from repro.obs.trace import _NULL_SPAN
from repro.runtime.cnn_serving import CnnServingEngine, ServingReport

MINI = mini_resnet18(hw=8, width=16, stages=4)

SERVING_TRACKS = ("request", "admission", "pack", "dispatch",
                  "in_flight", "delivery")


@pytest.fixture(scope="module")
def setup():
    cp = compiler.compile(MINI, TPU_INTERPRET)
    params = init_cnn_params(jax.random.PRNGKey(0), MINI)
    return cp, params


def _requests(cfg, sizes, seed=0):
    rng = np.random.default_rng(seed)
    shape = cnn_input_shape(cfg, 1)[1:]
    return [rng.integers(-127, 128, size=(n,) + shape,
                         dtype=np.int16).astype(np.int8) for n in sizes]


# ---------------------------------------------------------------------------
# tracer unit behavior
# ---------------------------------------------------------------------------


class TestTracer:
    def test_records_all_phases(self):
        clk = ManualClock(step=1.0)
        tr = Tracer(clock=clk)
        tr.instant("tick", "dispatch", k=1)
        tr.begin("mb", "in_flight", 7)
        with tr.span("work", "pack"):
            pass
        tr.end("mb", "in_flight", 7)
        tr.counter("depth", 3)
        phases = [ev[0] for ev in tr.events()]
        assert phases == ["i", "b", "X", "e", "C"]

    def test_ring_eviction_bounds_memory(self):
        tr = Tracer(capacity=8, clock=ManualClock(step=1.0))
        for i in range(20):
            tr.instant(f"e{i}")
        assert len(tr) == 8
        assert tr.dropped == 12
        # oldest evicted first: the retained ring is the 8 newest
        assert [ev[1] for ev in tr.events()] == [f"e{i}"
                                                for i in range(12, 20)]
        assert tr.stats() == {"events": 8, "capacity": 8, "dropped": 12}

    def test_capacity_must_be_positive(self):
        with pytest.raises(ValueError):
            Tracer(capacity=0)

    def test_chrome_export_rebases_and_names_tracks(self):
        clk = ManualClock(start=100.0, step=1.0)
        tr = Tracer(clock=clk, process_name="unit")
        tr.instant("a", "pack")
        tr.instant("b", "delivery")
        trace = tr.to_chrome_trace()
        assert validate_chrome_trace(trace) == []
        evs = trace["traceEvents"]
        meta = [e for e in evs if e["ph"] == "M"]
        names = {e["args"]["name"] for e in meta
                 if e["name"] == "thread_name"}
        assert set(TRACKS) <= names
        assert any(e["args"]["name"] == "unit" for e in meta
                   if e["name"] == "process_name")
        data = [e for e in evs if e["ph"] != "M"]
        assert data[0]["ts"] == 0.0              # rebased to the first event
        assert all(e["ts"] >= 0 for e in data)

    def test_cross_thread_push_order_still_validates(self):
        # ring order is push order; export sorts by timestamp, so an
        # async end pushed late (completer thread descheduled) cannot
        # make a track's ts go backwards
        clk = ManualClock(step=1.0)
        tr = Tracer(clock=clk)
        tr.begin("mb", "in_flight", 1)    # t=0
        t_end = clk()                     # t=1: end HAPPENS now...
        tr.begin("mb", "in_flight", 2)    # t=2: next begin pushed first
        tr._push(("e", "mb", "in_flight", t_end, None, 1, None))
        assert validate_chrome_trace(tr.to_chrome_trace()) == [
            "async begin without end for ('in_flight', 'mb', 2) (x1)"]

    def test_validator_catches_defects(self):
        bad = {"traceEvents": [
            {"ph": "X", "name": "s", "cat": "pack", "ts": -1.0,
             "dur": 1.0, "pid": 1, "tid": 0},
            {"ph": "e", "name": "mb", "cat": "in_flight", "ts": 2.0,
             "id": 9, "pid": 1, "tid": 1},
        ]}
        probs = validate_chrome_trace(bad, require_tracks=("delivery",))
        assert any("bad ts" in p for p in probs)
        assert any("end without begin" in p for p in probs)
        assert any("'delivery' has no events" in p for p in probs)
        assert validate_chrome_trace({}) == \
            ["traceEvents missing or not a list"]

    def test_null_tracer_records_nothing_and_allocates_nothing(self):
        assert NULL_TRACER.enabled is False
        NULL_TRACER.instant("x")
        NULL_TRACER.begin("x", "in_flight", 1)
        NULL_TRACER.end("x", "in_flight", 1)
        NULL_TRACER.counter("x", 1)
        assert NULL_TRACER.events() == []
        assert NULL_TRACER.to_chrome_trace()["traceEvents"] == []
        # span() hands back ONE shared no-op context manager — a
        # disabled engine pays no per-call allocation
        assert NULL_TRACER.span("a") is _NULL_SPAN
        assert NULL_TRACER.span("b", "pack") is NULL_TRACER.span("c")


class TestManualClock:
    def test_step_and_advance(self):
        clk = ManualClock(start=10.0, step=0.5)
        assert clk() == 10.0
        assert clk() == 10.5
        clk.advance(4.0)
        assert clk.now == 15.0
        with pytest.raises(ValueError):
            clk.advance(-1.0)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


class TestMetrics:
    def test_counter_gauge_histogram_snapshot(self):
        reg = MetricsRegistry()
        reg.counter("reqs", shard=1).inc()
        reg.counter("reqs", shard=1).inc(2)
        reg.gauge("depth").set(7)
        reg.histogram("lat_ms").observe(3.0)
        snap = reg.snapshot()
        assert snap["counters"] == {"reqs{shard=1}": 3.0}
        assert snap["gauges"] == {"depth": 7.0}
        h = snap["histograms"]["lat_ms"]
        assert h["count"] == 1 and h["sum"] == 3.0 and h["p50"] == 3.0
        assert json.loads(json.dumps(snap)) == snap      # JSON-safe

    def test_same_key_same_instrument(self):
        reg = MetricsRegistry()
        assert reg.counter("c", a=1, b=2) is reg.counter("c", b=2, a=1)
        assert reg.counter("c") is not reg.counter("d")

    def test_counter_rejects_negative(self):
        with pytest.raises(ValueError):
            MetricsRegistry().counter("c").inc(-1)

    def test_histogram_window_bounds_memory(self):
        h = Histogram("h", threading.Lock(), window=4)
        for v in range(10):
            h.observe(float(v))
        assert h.count == 10 and h.sum == 45.0          # exact lifetime
        assert len(h._window) == 4                      # bounded window
        assert h.percentile(0.5) == 7.0                 # over 6,7,8,9
        s = h.summary()
        assert s["window"] == 4 and s["min"] == 0.0 and s["max"] == 9.0

    def test_default_registry_is_process_wide(self):
        assert default_registry() is default_registry()


# ---------------------------------------------------------------------------
# stall attribution
# ---------------------------------------------------------------------------


class TestStallAttribution:
    def test_measured_fractions(self):
        out = stall_attribution(wall_s=10.0, admission_wait_s=2.5,
                                dispatch_gap_s=0.5)
        m = out["measured"]
        assert m["admission_wait_fraction"] == pytest.approx(0.25)
        assert m["dispatch_gap_fraction"] == pytest.approx(0.05)
        assert "modelled" not in out                   # nothing streamed

    def test_zero_wall_is_safe(self):
        out = stall_attribution(wall_s=0.0, admission_wait_s=1.0,
                                dispatch_gap_s=1.0)
        assert out["measured"]["admission_wait_fraction"] == 0.0

    class _Outcome:
        """Duck-typed fifo_sim.SimOutcome for the modelled section."""

        def __init__(self, per_layer_weight_words):
            self.cycles = 100
            self.stall_cycles = 10
            self.outputs = 4
            self.completed = True
            self.per_layer_weight_words = per_layer_weight_words

    def test_name_count_mismatch_raises(self):
        """Regression pin: ``dict(zip(names, words))`` silently
        TRUNCATED on a length mismatch, attributing words to the wrong
        engines when the streamed set and sim topology drifted apart.
        Now it hard-fails, both directions."""
        out = self._Outcome([10, 20, 30])
        with pytest.raises(ValueError, match="2 engine name"):
            stall_attribution(wall_s=1.0, admission_wait_s=0.0,
                              dispatch_gap_s=0.0, modelled=out,
                              engine_names=("a", "b"))
        with pytest.raises(ValueError, match="4 engine name"):
            stall_attribution(wall_s=1.0, admission_wait_s=0.0,
                              dispatch_gap_s=0.0, modelled=out,
                              engine_names=("a", "b", "c", "d"))

    def test_duplicate_engine_names_survive_in_rows(self):
        """Regression pin: duplicate engine names (two streamed layers
        sharing a spec name) collapsed in the dict view, losing a row's
        words.  The rows view preserves order AND duplicates; the dict
        stays as the documented lossy compat view (last row wins)."""
        out = self._Outcome([10, 20, 30])
        got = stall_attribution(wall_s=1.0, admission_wait_s=0.0,
                                dispatch_gap_s=0.0, modelled=out,
                                engine_names=("conv", "conv", "fc"))
        mo = got["modelled"]
        assert mo["per_engine_weight_word_rows"] == [
            ["conv", 10], ["conv", 20], ["fc", 30]]
        assert mo["per_engine_weight_words"] == {"conv": 20, "fc": 30}


# ---------------------------------------------------------------------------
# admission controller wait accounting (fake clock, no sleeps)
# ---------------------------------------------------------------------------


class TestAdmissionWaitClock:
    def test_blocked_acquire_accrues_wait_on_injected_clock(self):
        clk = ManualClock(step=0.25)
        ac = AdmissionController(1, clock=clk)
        assert ac.acquire()
        assert ac.blocked_acquires == 0                # fast path: no wait
        done = threading.Event()

        def blocked():
            assert ac.acquire()                        # parks on the credit
            done.set()

        t = threading.Thread(target=blocked)
        t.start()
        while ac.blocked_acquires == 0 and t.is_alive():
            pass                                       # waiter has entered
        ac.release()
        t.join(5.0)
        assert done.is_set()
        assert ac.blocked_acquires == 1
        assert ac.wait_seconds_total > 0               # clock-step accrual
        ac.release()
        ac.assert_quiescent()

    def test_unblocked_acquire_accrues_nothing(self):
        ac = AdmissionController(2, clock=ManualClock(step=1.0))
        assert ac.acquire() and ac.acquire()
        assert ac.wait_seconds_total == 0.0
        assert ac.blocked_acquires == 0
        ac.release(2)


# ---------------------------------------------------------------------------
# traced serving: schema, identity, stall report
# ---------------------------------------------------------------------------


class TestTracedServing:
    def test_trace_is_valid_and_results_bit_identical(self, setup):
        cp, params = setup
        reqs = _requests(MINI, [1, 2, 3, 1, 4, 2])
        tr = Tracer()
        with cp.serve(params, microbatch=4, credits=2, tracer=tr) as eng:
            assert eng.tracer is tr
            outs, rep = eng.serve(reqs)
        for o, r in zip(outs, reqs):
            assert np.array_equal(o, np.asarray(cp.run(params, r)[0]))
        trace = tr.to_chrome_trace()
        assert validate_chrome_trace(
            trace, require_tracks=SERVING_TRACKS) == []
        # every submitted request opened AND closed its async span
        evs = trace["traceEvents"]
        begins = [e for e in evs
                  if e["ph"] == "b" and e["cat"] == "request"]
        assert len(begins) == len(reqs)
        assert rep.requests == len(reqs)

    def test_bandwidth_efficiency_on_both_mini_resnets(self, setup):
        cp18, params18 = setup
        cfg50 = mini_resnet50(hw=8, width=16, stages=4)
        cp50 = compiler.compile(cfg50, TPU_INTERPRET)
        params50 = init_cnn_params(jax.random.PRNGKey(0), cfg50)
        for cfg, cp, params in ((MINI, cp18, params18),
                                (cfg50, cp50, params50)):
            with cp.serve(params, microbatch=4, credits=2) as eng:
                _, rep = eng.serve(_requests(cfg, [1, 2, 3, 2]))
            be = rep.bandwidth_efficiency
            m = be["measured"]
            assert 0.0 <= m["admission_wait_fraction"] <= 1.0
            assert 0.0 <= m["dispatch_gap_fraction"] <= 1.0
            assert be["wall_s"] == rep.wall_s
            # both mini resnets stream layers, so the modelled side of
            # the §VI attribution must be present and self-consistent
            mo = be["modelled"]
            assert mo["cycles"] > 0
            assert 0.0 <= mo["stall_fraction"] <= 1.0
            assert mo["stall_cycles"] <= mo["cycles"]
            assert set(mo["per_engine_weight_words"]) == {
                s.spec.name for s in cp.plan.streamed
                if s.weight_words_per_row > 0}

    def test_metrics_section_counts_match_report(self, setup):
        cp, params = setup
        reqs = _requests(MINI, [2, 1, 3])
        with cp.serve(params, microbatch=4, credits=2) as eng:
            _, rep = eng.serve(reqs)
        c = rep.metrics["counters"]
        assert c["serving_requests_submitted"] == len(reqs)
        assert c["serving_requests_done"] == len(reqs)
        assert c["serving_images_done"] == rep.images
        assert c["serving_microbatches"] == rep.microbatches
        g = rep.metrics["gauges"]
        assert g["trace_cache{counter=misses}"] >= 1
        h = rep.metrics["histograms"]["serving_latency_ms"]
        assert h["count"] == len(reqs)

    def test_disabled_tracer_by_default(self, setup):
        cp, params = setup
        with cp.serve(params, microbatch=4, credits=2) as eng:
            assert eng.tracer is NULL_TRACER
            eng.serve(_requests(MINI, [1, 2]))
        assert NULL_TRACER.events() == []

    def test_manual_clock_serving_is_sleep_free(self, setup):
        # the engine's injected clock drives request timestamps, the
        # latency percentiles, and the stall fractions — all computable
        # with a fake clock, no wall time involved
        cp, params = setup
        clk = ManualClock(step=0.001)
        with cp.serve(params, microbatch=4, credits=2,
                      clock=clk) as eng:
            _, rep = eng.serve(_requests(MINI, [1, 2, 2]))
        assert eng._clock is clk
        assert rep.wall_s > 0
        assert rep.p50_ms > 0
        assert rep.p50_ms <= rep.p95_ms <= rep.p99_ms
        # every latency is a multiple of the fake step — wall clock
        # never leaked into the accounting
        for row in rep.request_rows:
            ticks = row["latency_ms"] / (1e3 * 0.001)
            assert ticks == pytest.approx(round(ticks))

    def test_tracer_clock_is_engine_clock(self, setup):
        cp, params = setup
        clk = ManualClock(step=0.001)
        tr = Tracer(clock=clk)
        eng = CnnServingEngine(cp, params, microbatch=4, credits=2,
                               tracer=tr)
        assert eng._clock is clk


# ---------------------------------------------------------------------------
# long-lived-server memory bounds
# ---------------------------------------------------------------------------


class TestServingMemoryBounds:
    def test_metric_windows_trim(self, setup):
        cp, params = setup
        with cp.serve(params, microbatch=2, credits=2, metric_window=4,
                      request_row_window=3) as eng:
            _, rep = eng.serve(_requests(MINI, [1] * 10))
        assert rep.requests == 10                     # exact lifetime total
        assert rep.images == 10
        assert len(rep.request_rows) == 3             # bounded window
        assert len(eng._latencies) == 4
        assert len(eng._depth_samples) <= 4
        # the retained rows are the NEWEST
        assert [r["rid"] for r in rep.request_rows] == [8, 9, 10]

    def test_tracer_ring_bounds_sustained_load(self, setup):
        cp, params = setup
        tr = Tracer(capacity=16)
        with cp.serve(params, microbatch=2, credits=2, tracer=tr) as eng:
            eng.serve(_requests(MINI, [1] * 12))
        assert len(tr) <= 16
        assert tr.dropped > 0                         # it really evicted
        # a truncated trace still exports (unmatched async pairs are the
        # validator's business, not a crash)
        assert tr.to_chrome_trace()["traceEvents"]


# ---------------------------------------------------------------------------
# report serialization
# ---------------------------------------------------------------------------


class TestReportRoundTrip:
    def test_serving_report_round_trip(self, setup):
        cp, params = setup
        with cp.serve(params, microbatch=4, credits=2) as eng:
            _, rep = eng.serve(_requests(MINI, [1, 3, 2]))
        assert rep.metrics and rep.bandwidth_efficiency
        back = ServingReport.from_json(rep.to_json())
        assert back == rep
        # derived keys ride in the dict but never break construction
        d = rep.to_dict()
        assert d["pad_fraction"] == pytest.approx(rep.pad_fraction)
        assert d["effective_images_per_s"] == pytest.approx(
            rep.effective_images_per_s)
        assert ServingReport.from_json(d) == rep

    def test_table_renders_new_sections(self, setup):
        cp, params = setup
        with cp.serve(params, microbatch=4, credits=2) as eng:
            _, rep = eng.serve(_requests(MINI, [1, 2]))
        text = rep.table()
        assert "trace cache:" in text
        assert "effective=" in text
        assert "admission-wait" in text and "dispatch-gap" in text
        assert "modelled" in text


# ---------------------------------------------------------------------------
# compiler + autotune instrumentation
# ---------------------------------------------------------------------------


class TestCompilerMetrics:
    def test_compile_records_pass_timings(self, setup):
        # setup compiled MINI, so the default registry must hold every
        # pass's wall-seconds histogram and the trace-cache gauges
        snap = default_registry().snapshot()
        hists = snap["histograms"]
        for p in ("parallelism", "placement", "fifo_sizing", "finalize"):
            key = f"compile_pass_seconds{{pass={p}}}"
            assert key in hists, key
            assert hists[key]["count"] >= 1
            assert hists[key]["min"] >= 0.0

    def test_trace_cache_gauges_follow_stats(self, setup):
        cp, params = setup
        zeros = np.zeros(cnn_input_shape(MINI, 2), np.int8)
        cp.run(params, zeros)
        snap = default_registry().snapshot()
        stats = cp.trace_cache_stats()
        g = snap["gauges"]
        assert g["compile_trace_cache{counter=hits}"] == stats["hits"]
        assert g["compile_trace_cache{counter=misses}"] == stats["misses"]
        key = "compile_pass_seconds{pass=trace_fused}"
        assert snap["histograms"][key]["count"] >= 1

    def test_autotune_objective_trace(self):
        from repro.compiler.autotune import AutotuneConfig, autotune_plan
        r = autotune_plan(MINI, TPU_INTERPRET,
                          AutotuneConfig(seed=0, iterations=40))
        trace = r.objective_trace
        assert trace[0][0] == 0                       # greedy seed first
        assert trace[0][1] == trace[0][2]
        best = [b for _, _, b in trace]
        assert best == sorted(best, reverse=True)     # monotone improving
        assert best[-1] == pytest.approx(r.tuned.objective)
        # one row per feasible evaluation, iterations 1-indexed after seed
        assert all(0 <= i <= 40 for i, _, _ in trace)
        assert all(o >= b for _, o, b in trace)       # best <= visited
