"""The burst-aggregated credit-mode fifo_sim is the per-word reference.

``fifo_sim.simulate(cfg, "credit")`` runs on counters + an exact
periodic fast-forward; ``fifo_sim.simulate_reference`` is the original
one-deque-entry-per-word event loop.  The fast path must be
cycle-for-cycle identical — not just same verdict: same completion,
same cycle count, same tail stalls, same delivered words per layer —
across topologies, skews, latencies and word demands large enough to
engage the fast-forward.
"""
import itertools

import pytest

from repro.core import fifo_sim


def _outcomes_equal(a, b):
    return (a.completed, a.deadlocked, a.cycles, a.outputs, a.stall_cycles,
            list(a.per_layer_weight_words)) == \
           (b.completed, b.deadlocked, b.cycles, b.outputs, b.stall_cycles,
            list(b.per_layer_weight_words))


@pytest.mark.parametrize("L,burst,lat", [
    (1, 2, 1), (2, 4, 6), (3, 8, 30), (4, 4, 12),
])
@pytest.mark.parametrize("w0", [1, 7, 40, 600])
def test_fast_credit_sim_matches_reference(L, burst, lat, w0):
    """Cycle-exact equivalence over a topology/demand grid, including
    demands big enough (w0=600 >> bm depth) that the periodic
    fast-forward genuinely fires."""
    wpa = tuple([w0] + [max(1, w0 // 3)] * (L - 1))
    cfg = fifo_sim.SimConfig(
        n_layers=L, burst=burst, bm_fifo_depth=2 * burst,
        act_fifo_depth=2, dcfifo_depth=2 * burst, hbm_latency=lat,
        weights_per_act=wpa, outputs_needed=6)
    skew = [5 * i for i in range(L)]
    fast = fifo_sim.simulate(cfg, "credit", start_skew=skew)
    ref = fifo_sim.simulate_reference(cfg, "credit", start_skew=skew)
    assert _outcomes_equal(fast, ref)
    assert fast.completed and not fast.deadlocked


def test_fast_credit_sim_matches_reference_dense_grid():
    """A denser sweep of small configs (no skew) — every combination
    must be cycle-identical to the per-word loop."""
    for burst, bm, act, lat, w in itertools.product(
            (2, 8), (8, 16), (1, 2), (1, 24), (1, 5, 90)):
        cfg = fifo_sim.SimConfig(
            n_layers=3, burst=burst, bm_fifo_depth=bm, act_fifo_depth=act,
            dcfifo_depth=16, hbm_latency=lat,
            weights_per_act=(w, max(1, w // 2), w), outputs_needed=5)
        fast = fifo_sim.simulate(cfg, "credit")
        ref = fifo_sim.simulate_reference(cfg, "credit")
        assert _outcomes_equal(fast, ref), (burst, bm, act, lat, w)


def test_fig5_demo_unchanged():
    """The paper's Fig. 5 result survives the fast path: ready/valid
    deadlocks (per-word reference loop — HoL needs word tags), credit
    mode completes (fast path)."""
    out = fifo_sim.demo()
    assert out["ready_valid"].deadlocked
    assert out["credit"].completed and not out["credit"].deadlocked
    cfg = fifo_sim.fig5_scenario()
    skew = [0, 40, 80]
    ref = fifo_sim.simulate_reference(cfg, "credit", start_skew=skew)
    assert _outcomes_equal(out["credit"], ref)


def test_cycle_cap_scales_with_word_demand():
    """word_scale=1 full-net streams need ~10^7 cycles at the
    latency-bound delivery rate — the cap must scale with demand (and
    respect an explicit override)."""
    small = fifo_sim.SimConfig()
    assert fifo_sim._cycle_cap(small) == 500_000
    big = fifo_sim.SimConfig(weights_per_act=(200_000, 100_000),
                             n_layers=2, outputs_needed=2,
                             bm_fifo_depth=16, hbm_latency=168)
    assert fifo_sim._cycle_cap(big) > 10_000_000
    forced = fifo_sim.SimConfig(cycle_cap=1234)
    assert fifo_sim._cycle_cap(forced) == 1234
