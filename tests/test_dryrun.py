"""The multi-pod dry-run as a test: one small cell must lower + compile on
both production meshes in a subprocess (512 forced host devices — isolated
from this process, which keeps its single real device)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        capture_output=True, text=True, env=env, cwd=REPO, timeout=timeout)


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_dryrun_cell_compiles(mesh, tmp_path):
    r = _run(["--arch", "xlstm-125m", "--shape", "decode_32k",
              "--mesh", mesh, "--out", str(tmp_path / "r.json")])
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    assert "PASS xlstm-125m x decode_32k" in r.stdout
    assert "roofline:" in r.stdout


def test_dryrun_skip_is_documented(tmp_path):
    r = _run(["--arch", "gemma2-9b", "--shape", "long_500k",
              "--mesh", "single", "--out", str(tmp_path / "r.json")])
    assert r.returncode == 0
    assert "SKIP" in r.stdout and "long_500k" in r.stdout
