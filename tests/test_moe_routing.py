"""MoE routing invariants (property tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import ArchConfig, MoEConfig
from repro.models.ffn import (MOE_DENSE_T, _moe_dense_small, init_moe,
                              moe_ffn)


def _cfg(n_experts=8, top_k=2, d=16, f=8, shared=0):
    return ArchConfig(
        name="t", family="moe", n_layers=1, d_model=d, n_heads=2,
        n_kv_heads=2, d_ff=f, vocab_size=64,
        moe=MoEConfig(n_experts=n_experts, top_k=top_k, n_shared=shared,
                      d_ff_expert=f), dtype="float32")


@given(n_experts=st.sampled_from([4, 8, 16]),
       top_k=st.integers(1, 3),
       T=st.sampled_from([8, 32, 128]))
@settings(max_examples=15, deadline=None)
def test_moe_output_finite_and_bounded(n_experts, top_k, T):
    cfg = _cfg(n_experts, top_k)
    key = jax.random.PRNGKey(0)
    params = init_moe(key, cfg)
    x = jax.random.normal(key, (1, T, cfg.d_model), jnp.float32)
    y, aux = moe_ffn(params, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) >= 0.0                  # load-balance loss >= 1 ideal


def test_dense_small_equals_bruteforce():
    """The dropless path must equal explicit per-token expert sums."""
    cfg = _cfg(4, 2)
    key = jax.random.PRNGKey(1)
    params = init_moe(key, cfg)
    T = 8
    xt = jax.random.normal(key, (T, cfg.d_model), jnp.float32)
    y, _ = _moe_dense_small(params, cfg, xt, "silu")

    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, 2)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    ref = np.zeros((T, cfg.d_model), np.float32)
    for t in range(T):
        for j in range(2):
            e = int(top_e[t, j])
            g = jax.nn.silu(xt[t] @ params["w_gate"][e])
            u = xt[t] @ params["w_up"][e]
            ref[t] += float(top_p[t, j]) * np.asarray((g * u) @
                                                      params["w_down"][e])
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-4, atol=2e-4)


def test_grouped_path_capacity_drops_bounded():
    """Grouped path: dropped fraction stays small for balanced routing."""
    cfg = _cfg(8, 2)
    key = jax.random.PRNGKey(2)
    params = init_moe(key, cfg)
    T = 2048                                   # > MOE_DENSE_T -> grouped
    x = jax.random.normal(key, (1, T, cfg.d_model), jnp.float32)
    y, _ = moe_ffn(params, cfg, x)
    # tokens whose every expert choice was dropped produce zero routed
    # output; with cf=1.25 and near-uniform random routing this is rare
    routed_norm = jnp.linalg.norm(y.reshape(T, -1), axis=-1)
    zero_frac = float(jnp.mean(routed_norm < 1e-9))
    assert zero_frac < 0.2


def test_shared_experts_added():
    cfg_s = _cfg(4, 2, shared=2)
    key = jax.random.PRNGKey(3)
    params = init_moe(key, cfg_s)
    x = jax.random.normal(key, (1, 8, cfg_s.d_model), jnp.float32)
    y_with, _ = moe_ffn(params, cfg_s, x)
    p2 = dict(params)
    p2["shared"] = jax.tree.map(jnp.zeros_like, params["shared"])
    y_zero, _ = moe_ffn(p2, cfg_s, x)
    assert float(jnp.max(jnp.abs(y_with - y_zero))) > 1e-6
