"""§IV-C write-path tests: bit-exact packing round trip + register model."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import CNN_CONFIGS
from repro.core import write_path


@given(st.integers(1, 400_000))
@settings(max_examples=20, deadline=None)
def test_pack_roundtrip(n):
    rng = np.random.default_rng(n)
    w = rng.integers(-127, 128, size=n, dtype=np.int8)
    frames = write_path.pack_weights_as_images(w)
    assert frames.shape[1:] == (224, 224, 3)
    back = write_path.unpack_weights(frames, n)
    np.testing.assert_array_equal(back, w)


def test_registers_saved_over_3000():
    assert write_path.registers_saved(30) > 3000   # the paper's claim


def test_boot_time_reasonable():
    """VGG-16's 1.2 Gb of weights must load in under a minute at boot
    (the paper treats the write as non-timing-critical but one-shot)."""
    vgg_bytes = CNN_CONFIGS["vgg16"].total_weight_bits() // 8
    t = write_path.boot_time_s(vgg_bytes)
    assert 0.01 < t < 60.0


def test_narrower_is_cheaper_but_slower():
    assert write_path.write_path_registers(30) < \
        write_path.write_path_registers(256)
    assert write_path.boot_time_s(10**8, 30) >= \
        write_path.boot_time_s(10**8, 256)
