"""Substrate tests: data determinism, AdamW/ZeRO, checkpoint durability,
trainer crash recovery (bitwise resume)."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.ckpt import checkpoint as ckpt
from repro.configs import get_arch
from repro.data.pipeline import DataConfig, ImageDataset, TokenDataset
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.runtime.trainer import TrainConfig, Trainer


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_and_reshardable():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8)
    ds = TokenDataset(cfg)
    g = ds.global_batch(step=3)
    # any host partitioning reproduces the same global content
    for n_hosts in (1, 2, 4, 8):
        parts = [ds.host_batch(3, h, n_hosts) for h in range(n_hosts)]
        cat = np.concatenate([p["tokens"] for p in parts])
        np.testing.assert_array_equal(cat, g["tokens"])
    # step content differs
    assert not np.array_equal(ds.global_batch(4)["tokens"], g["tokens"])
    # labels are next-token
    ex = ds.example(0, 0)
    assert ex["tokens"].shape == (16,)


def test_image_dataset():
    ds = ImageDataset(shape=(8, 8, 3), num_classes=10)
    b = ds.batch(0, 4)
    assert b["images"].shape == (4, 8, 8, 3)
    assert b["images"].dtype == np.int8
    np.testing.assert_array_equal(ds.batch(0, 4)["images"], b["images"])


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr_peak=0.1, warmup_steps=5, total_steps=200,
                      weight_decay=0.0, clip_norm=10.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}          # d/dw ||w||^2
        params, state, m = adamw.apply(grads, state, params, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_adamw_clip():
    cfg = AdamWConfig(clip_norm=1.0, warmup_steps=1, total_steps=10)
    params = {"w": jnp.zeros(3)}
    state = adamw.init(params, cfg)
    _, _, m = adamw.apply({"w": jnp.full(3, 1e6)}, state, params, cfg)
    assert float(m["grad_norm"]) > 1e5          # reported pre-clip


@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=2,
                max_size=32))
@settings(max_examples=50, deadline=None)
def test_int8_compression_error_feedback(vals):
    """Property: with error feedback, quantization error does not accumulate
    (the residual carries it to the next step exactly)."""
    g = jnp.asarray(vals, jnp.float32)
    res = jnp.zeros_like(g)
    deq, new_res = adamw.compress_int8(g, res)
    np.testing.assert_allclose(np.asarray(deq + new_res), np.asarray(g),
                               rtol=1e-5, atol=1e-4)
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.max(jnp.abs(new_res))) <= scale + 1e-6


def test_zero1_specs_extra_axis():
    from jax.sharding import PartitionSpec as P
    from repro.models.layers import set_mesh_axis_sizes
    set_mesh_axis_sizes({"data": 4, "model": 2})
    try:
        params = {"w": jnp.zeros((8, 6))}
        pspecs = {"w": P(None, "model")}
        cfg = AdamWConfig()
        sspecs = adamw.state_specs(params, pspecs, cfg)
        assert sspecs["mu"]["w"] == P("data", "model")
    finally:
        set_mesh_axis_sizes({})


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}


def test_ckpt_roundtrip(tmp_path):
    p = str(tmp_path / "ck")
    ckpt.save(p, 7, _tree())
    got = ckpt.restore_latest(p, _tree())
    assert got is not None
    step, tree = got
    assert step == 7
    np.testing.assert_array_equal(np.asarray(tree["a"]),
                                  np.asarray(_tree()["a"]))


def test_ckpt_keep_n_and_latest(tmp_path):
    p = str(tmp_path / "ck")
    for s in (1, 2, 3, 4, 5):
        ckpt.save(p, s, _tree(), keep_n=3)
    assert ckpt.available_steps(p) == [3, 4, 5]


def test_ckpt_skips_corrupt(tmp_path):
    p = str(tmp_path / "ck")
    ckpt.save(p, 1, _tree())
    ckpt.save(p, 2, _tree())
    # corrupt the newest: delete a leaf file
    os.remove(os.path.join(p, "step_00000002", "leaf_00000.npy"))
    got = ckpt.restore_latest(p, _tree())
    assert got is not None and got[0] == 1


def test_ckpt_atomicity_tmp_never_visible(tmp_path):
    p = str(tmp_path / "ck")
    ckpt.save(p, 3, _tree())
    assert not any(d.endswith(".tmp") for d in os.listdir(p))


def test_async_checkpointer(tmp_path):
    p = str(tmp_path / "ck")
    ac = ckpt.AsyncCheckpointer(p)
    ac.save(11, _tree())
    ac.wait()
    assert ckpt.available_steps(p) == [11]


# ---------------------------------------------------------------------------
# trainer: loss decreases + crash recovery is bitwise identical
# ---------------------------------------------------------------------------


def _mk_trainer(path, fail=False):
    arch = get_arch("xlstm-125m").reduced()
    data = TokenDataset(DataConfig(vocab_size=arch.vocab_size, seq_len=32,
                                   global_batch=4))
    tcfg = TrainConfig(steps=8, microbatches=1, ckpt_every=3, log_every=1,
                       ckpt_path=path,
                       adamw=AdamWConfig(lr_peak=1e-3, warmup_steps=2,
                                         total_steps=8))
    return Trainer(arch, tcfg, data)


def test_trainer_crash_recovery_bitwise(tmp_path):
    pa, pb = str(tmp_path / "a"), str(tmp_path / "b")
    clean = _mk_trainer(pa)
    clean_hist = clean.run()
    crashed = _mk_trainer(pb)
    crash_hist = crashed.run(fail_at=5)       # restore from step-3 ckpt
    final_clean = {h["step"]: h["loss"] for h in clean_hist}
    final_crash = {h["step"]: h["loss"] for h in crash_hist}
    # deterministic data + replay => identical losses at every step
    for s in final_clean:
        assert final_crash[s] == pytest.approx(final_clean[s], abs=0.0), s
    assert crashed.step == clean.step == 8
