"""End-to-end tests for the HBM-streaming pipeline executor.

The contract under test (runtime/pipeline.py): executing a CNN under a
placement plan — any mix of pinned and HBM-streamed weight buffers — is
bit-identical to the functional jnp reference, and the executor's Eq. 2
traffic accounting agrees with the plan analytics and the §V-A fifo_sim
prediction machinery.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.cnn import mini_resnet18
from repro.core import build_pipeline_plan, fifo_sim
from repro.models.cnn import cnn_forward, cnn_input_shape, init_cnn_params
from repro.runtime.pipeline import PipelineExecutor, execute_cnn

MINI = mini_resnet18(hw=32, width=32)
# small BRAM budget models a smaller device -> Algorithm 1 must offload
PLAN = build_pipeline_plan(MINI, tb_budget=500, bram_m20ks=40)


@pytest.fixture(scope="module")
def mini_setup():
    params = init_cnn_params(jax.random.PRNGKey(0), MINI)
    x = jax.random.randint(jax.random.PRNGKey(1), cnn_input_shape(MINI, 2),
                           -127, 128, jnp.int8)
    ref = cnn_forward(params, MINI, x)
    return params, x, ref


def test_algorithm1_offloads_mini():
    """Eq. 1 scores go positive on multi-M20K buffers: the mini net at a
    40-M20K budget must genuinely stream several layers."""
    assert len(PLAN.streamed) >= 3
    assert len(PLAN.pinned) >= 1                  # and it stays hybrid
    for s in PLAN.streamed:
        assert s.pc is not None


def test_streamed_execution_bit_identical(mini_setup):
    params, x, ref = mini_setup
    out, report = execute_cnn(PLAN, params, x, interpret=True)
    assert bool(jnp.all(out == ref))
    assert report.streamed_layer_count == len(PLAN.streamed)


def test_pinned_execution_bit_identical(mini_setup):
    params, x, ref = mini_setup
    pinned = PLAN.with_offload([])
    out, report = execute_cnn(pinned, params, x, interpret=True)
    assert bool(jnp.all(out == ref))
    assert report.total_hbm_words == 0


def test_pinned_and_streamed_agree(mini_setup):
    """The tier decision is performance-only: flipping layers between
    M20K and HBM tiers never changes a single output bit."""
    params, x, _ = mini_setup
    a, _ = execute_cnn(PLAN.with_offload([]), params, x, interpret=True)
    names = list(PLAN.streamed_names) + ["fc"]    # exercise fc fifo path
    b, rep = execute_cnn(PLAN.with_offload(names), params, x,
                         interpret=True)
    assert bool(jnp.all(a == b))
    assert "fc" in rep.hbm_weight_words


def test_traffic_accounting_matches_plan(mini_setup):
    """Executed Eq. 2 traffic == plan analytics: words_per_row * out_h
    per image, for every streamed layer."""
    params, x, _ = mini_setup
    batch = int(x.shape[0])
    _, report = execute_cnn(PLAN, params, x, interpret=True)
    expected = {name: words * batch
                for name, words in PLAN.hbm_words_per_image().items()}
    assert report.hbm_weight_words == expected


def test_stalls_match_fifo_sim(mini_setup):
    """The report's stall prediction is exactly the §V-A credit-mode
    discrete-event sim over the plan's per-row word demands."""
    params, x, _ = mini_setup
    _, report = execute_cnn(PLAN, params, x, interpret=True)
    predicted = report.fifo_prediction(outputs_needed=8)
    cfg, scale = PLAN.sim_config(outputs_needed=8)
    direct = fifo_sim.simulate(cfg, "credit")
    assert predicted.stall_cycles == direct.stall_cycles
    assert predicted.completed and not predicted.deadlocked
    # tail engine consumed exactly its demand when the run completed
    tail_wpa = cfg.weights_per_act[-1]
    assert direct.per_layer_weight_words[-1] == tail_wpa * cfg.outputs_needed
    # sim word demands are the plan's Eq. 2 per-row words (scaled)
    wpr = [s.weight_words_per_row for s in PLAN.streamed]
    assert cfg.weights_per_act == tuple(max(1, w // scale) for w in wpr)


def test_executor_runs_full_family_reduced():
    """The executor handles the paper's other topologies (reduced scale):
    layers its engines can't run (depthwise) fall back to the reference
    path inside the same forward — wiring stays correct."""
    from repro.configs import CNN_CONFIGS
    for name in ("resnet18", "vgg16"):
        cfg = CNN_CONFIGS[name].reduced()
        plan = build_pipeline_plan(cfg, tb_budget=200, bram_m20ks=10_000)
        params = init_cnn_params(jax.random.PRNGKey(0), cfg)
        x = jax.random.randint(jax.random.PRNGKey(1),
                               cnn_input_shape(cfg, 2), -127, 128, jnp.int8)
        ref = cnn_forward(params, cfg, x)
        out, _ = execute_cnn(plan, params, x, interpret=True)
        assert bool(jnp.all(out == ref)), name


def test_fc_wide_k_int8_exact(rng_key):
    """Wide fc heads (c_in >= 2048, as resnet50/vgg16 stream) must stay
    exact: int8 dot-product sums exceed f32's 2^24 integer range, so the
    matmul kernels have to accumulate in int32 (regression for the fc
    bit-identity contract)."""
    from repro.kernels.stream_matmul.ops import stream_matmul
    from repro.kernels.stream_matmul.ref import stream_matmul_ref
    k1, k2 = jax.random.split(rng_key)
    # adversarial magnitudes: |sum| ~ 2048*127*127 >> 2^24
    x = jax.random.choice(k1, jnp.array([-127, 127], jnp.int8), (8, 2048))
    w = jax.random.choice(k2, jnp.array([-127, 127], jnp.int8), (2048, 128))
    ref = stream_matmul_ref(x, w)
    for mode in ("stream", "fifo", "pinned"):
        out = stream_matmul(x, w, mode=mode, bk=512, interpret=True)
        assert out.dtype == jnp.int32
        assert bool(jnp.all(out == ref)), mode


def test_single_streamed_conv_matches_oracle(rng_key):
    """The HBM-streamed conv kernel is exact against the jnp oracle for
    every double-buffer depth."""
    from repro.kernels.conv2d_int8.ops import conv2d_int8
    from repro.kernels.conv2d_int8.ref import conv2d_int8_ref
    x = jax.random.randint(rng_key, (2, 12, 12, 8), -127, 128, jnp.int8)
    w = jax.random.randint(rng_key, (3, 3, 8, 16), -20, 21, jnp.int8)
    ref = conv2d_int8_ref(x, w, stride=1)
    for nb in (1, 2, 4):
        out = conv2d_int8(x, w, stride=1, stream=True, n_buffers=nb,
                          interpret=True)
        assert bool(jnp.all(out == ref)), nb
