"""End-to-end tests for the compiled HBM-streaming pipeline.

The contract under test (compiler + runtime/pipeline.py): executing a CNN
under a compiled pipeline — any mix of pinned and HBM-streamed weight
buffers, each layer bound to a registered engine — is bit-identical to
the functional jnp reference, and the executor's Eq. 2 traffic accounting
agrees with the plan analytics and the §V-A fifo_sim prediction machinery.
"""
import jax
import jax.numpy as jnp
import pytest

from repro import compiler
from repro.compiler import TPU_INTERPRET
from repro.configs.cnn import mini_resnet18
from repro.core import fifo_sim
from repro.models.cnn import cnn_forward, cnn_input_shape, init_cnn_params
from repro.runtime.pipeline import PipelineExecutor, execute_cnn

MINI = mini_resnet18(hw=32, width=32)
# the TPU_INTERPRET target models a smaller device -> Algorithm 1 must
# offload (the old tb_budget=500, bram_m20ks=40 keyword defaults)
COMPILED = compiler.compile(MINI, TPU_INTERPRET)
PLAN = COMPILED.plan


@pytest.fixture(scope="module")
def mini_setup():
    params = init_cnn_params(jax.random.PRNGKey(0), MINI)
    x = jax.random.randint(jax.random.PRNGKey(1), cnn_input_shape(MINI, 2),
                           -127, 128, jnp.int8)
    ref = cnn_forward(params, MINI, x)
    return params, x, ref


def test_algorithm1_offloads_mini():
    """Eq. 1 scores go positive on multi-M20K buffers: the mini net on the
    TPU_INTERPRET target must genuinely stream several layers."""
    assert len(PLAN.streamed) >= 3
    assert len(PLAN.pinned) >= 1                  # and it stays hybrid
    for s in PLAN.streamed:
        assert s.pc is not None


def test_streamed_execution_bit_identical(mini_setup):
    params, x, ref = mini_setup
    out, report = COMPILED.run(params, x)
    assert bool(jnp.all(out == ref))
    assert report.streamed_layer_count == len(PLAN.streamed)


def test_pinned_execution_bit_identical(mini_setup):
    params, x, ref = mini_setup
    pinned = COMPILED.with_offload([])
    out, report = pinned.run(params, x)
    assert bool(jnp.all(out == ref))
    assert report.total_hbm_words == 0


def test_pinned_and_streamed_agree(mini_setup):
    """The tier decision is performance-only: flipping layers between
    M20K and HBM tiers never changes a single output bit."""
    params, x, _ = mini_setup
    a, _ = COMPILED.with_offload([]).run(params, x)
    names = list(PLAN.streamed_names) + ["fc"]    # exercise fc fifo path
    b, rep = COMPILED.with_offload(names).run(params, x)
    assert bool(jnp.all(a == b))
    assert "fc" in rep.hbm_weight_words


def test_traffic_accounting_matches_plan(mini_setup):
    """Executed Eq. 2 traffic == plan analytics: words_per_row * out_h
    per image, for every streamed layer."""
    params, x, _ = mini_setup
    batch = int(x.shape[0])
    _, report = COMPILED.run(params, x)
    expected = {name: words * batch
                for name, words in PLAN.hbm_words_per_image().items()}
    assert report.hbm_weight_words == expected


def test_engines_ran_as_compiled(mini_setup):
    """The compile-time engine table IS what executes: every dispatched
    layer ran on exactly the engine it was bound to — no dispatch-time
    fallbacks."""
    params, x, _ = mini_setup
    _, report = COMPILED.run(params, x)
    table = COMPILED.engine_table()
    used = report.engines_used()
    assert used == {name: table[name] for name in used}
    assert set(used) == set(table)                # every layer dispatched


def test_executor_is_reentrant(mini_setup):
    """Per-run EngineContext threading: interleaved runs on ONE executor
    never cross-contaminate reports (the batched-serving prerequisite)."""
    params, x, _ = mini_setup
    ex = PipelineExecutor(COMPILED)
    _, r1 = ex.run(params, x)
    _, r2 = ex.run(params, x[:1])
    assert r1.images == 2 and r2.images == 1
    assert len(r1.layers) == len(r2.layers) == len(PLAN.schedules)
    assert r1.total_hbm_words == 2 * sum(PLAN.hbm_words_per_image().values())
    assert r2.total_hbm_words == sum(PLAN.hbm_words_per_image().values())


def test_stalls_match_fifo_sim(mini_setup):
    """The report's stall prediction is exactly the §V-A credit-mode
    discrete-event sim over the plan's per-row word demands."""
    params, x, _ = mini_setup
    _, report = COMPILED.run(params, x)
    predicted = report.fifo_prediction(outputs_needed=8)
    cfg, scale = PLAN.sim_config(outputs_needed=8)
    direct = fifo_sim.simulate(cfg, "credit")
    assert predicted.stall_cycles == direct.stall_cycles
    assert predicted.completed and not predicted.deadlocked
    # tail engine consumed exactly its demand when the run completed
    tail_wpa = cfg.weights_per_act[-1]
    assert direct.per_layer_weight_words[-1] == tail_wpa * cfg.outputs_needed
    # sim word demands are the plan's Eq. 2 per-row words (scaled)
    wpr = [s.weight_words_per_row for s in PLAN.streamed]
    assert cfg.weights_per_act == tuple(max(1, w // scale) for w in wpr)


def test_fifo_sim_exact_mode_matches_scaled_verdict():
    """fifo_sim fidelity regression: simulating the FULL Eq. 2 word
    streams (word_scale=1, no downscaling) reaches the same completion +
    stall verdict as the auto-scaled fast path, on a small streamed
    config."""
    small = compiler.compile(mini_resnet18(hw=16, width=32), TPU_INTERPRET)
    assert small.streamed_names                   # genuinely streams
    scaled = small.predict_stalls(outputs_needed=4)
    exact = small.predict_stalls(outputs_needed=4, word_scale=1)
    _, auto_scale = small.plan.sim_config(outputs_needed=4)
    assert auto_scale > 1                         # the fast path DID scale
    assert exact.completed and scaled.completed
    assert not exact.deadlocked and not scaled.deadlocked
    assert (exact.stall_cycles > 0) == (scaled.stall_cycles > 0)


def test_fifo_sim_exact_mode_full_resnet18():
    """fifo_sim fidelity at FULL scale: the complete ResNet-18 Eq. 2
    word streams (word_scale=1 — up to ~236k words per activation, no
    downscaling) simulate exactly on the burst-aggregated credit path,
    reaching the same completion/stall verdict as the auto-scaled fast
    path.  This is the run the per-word reference loop cannot finish in
    CI time (~10^7 simulated cycles)."""
    from repro.configs import CNN_CONFIGS
    target = compiler.NX2100.replace(bram_m20ks=3000)   # forces streaming
    cp = compiler.compile(CNN_CONFIGS["resnet18"], target)
    assert len(cp.streamed_names) >= 3
    wpr = [s.weight_words_per_row for s in cp.plan.streamed]
    assert max(wpr) > 100_000                     # genuinely full streams
    exact = cp.predict_stalls(outputs_needed=2, word_scale=1)
    scaled = cp.predict_stalls(outputs_needed=2)
    _, auto_scale = cp.plan.sim_config(outputs_needed=2)
    assert auto_scale > 1                         # the fast path DID scale
    assert exact.completed and scaled.completed
    assert not exact.deadlocked and not scaled.deadlocked
    assert (exact.stall_cycles > 0) == (scaled.stall_cycles > 0)
    # every layer consumed its full exact demand: wpr * 2 activations
    assert exact.per_layer_weight_words == [w * 2 for w in wpr]


def test_executor_runs_full_family_reduced():
    """The compiled pipeline handles the paper's other topologies (reduced
    scale) — including MobileNet, whose depthwise layers now run through
    the registered dwconv engine instead of silently falling back."""
    from repro.configs import CNN_CONFIGS
    target = TPU_INTERPRET.replace(tb_budget=200, bram_m20ks=10_000)
    for name in ("resnet18", "vgg16", "mobilenetv1"):
        cfg = CNN_CONFIGS[name].reduced()
        cp = compiler.compile(cfg, target)
        params = init_cnn_params(jax.random.PRNGKey(0), cfg)
        x = jax.random.randint(jax.random.PRNGKey(1),
                               cnn_input_shape(cfg, 2), -127, 128, jnp.int8)
        ref = cnn_forward(params, cfg, x)
        out, _ = execute_cnn(cp, params, x)
        assert bool(jnp.all(out == ref)), name


def test_fc_wide_k_int8_exact(rng_key):
    """Wide fc heads (c_in >= 2048, as resnet50/vgg16 stream) must stay
    exact: int8 dot-product sums exceed f32's 2^24 integer range, so the
    matmul kernels have to accumulate in int32 (regression for the fc
    bit-identity contract)."""
    from repro.kernels.stream_matmul.ops import stream_matmul
    from repro.kernels.stream_matmul.ref import stream_matmul_ref
    k1, k2 = jax.random.split(rng_key)
    # adversarial magnitudes: |sum| ~ 2048*127*127 >> 2^24
    x = jax.random.choice(k1, jnp.array([-127, 127], jnp.int8), (8, 2048))
    w = jax.random.choice(k2, jnp.array([-127, 127], jnp.int8), (2048, 128))
    ref = stream_matmul_ref(x, w)
    for mode in ("stream", "fifo", "pinned"):
        out = stream_matmul(x, w, mode=mode, bk=512, interpret=True)
        assert out.dtype == jnp.int32
        assert bool(jnp.all(out == ref)), mode


def test_single_streamed_conv_matches_oracle(rng_key):
    """The HBM-streamed conv kernel is exact against the jnp oracle for
    every double-buffer depth."""
    from repro.kernels.conv2d_int8.ops import conv2d_int8
    from repro.kernels.conv2d_int8.ref import conv2d_int8_ref
    x = jax.random.randint(rng_key, (2, 12, 12, 8), -127, 128, jnp.int8)
    w = jax.random.randint(rng_key, (3, 3, 8, 16), -20, 21, jnp.int8)
    ref = conv2d_int8_ref(x, w, stride=1)
    for nb in (1, 2, 4):
        out = conv2d_int8(x, w, stride=1, stream=True, n_buffers=nb,
                          interpret=True)
        assert bool(jnp.all(out == ref)), nb


def test_depthwise_kernel_matches_reference(rng_key):
    """The grouped depthwise Pallas engine (pinned + streamed tiers) is
    exact against the jnp feature-group reference, for both strides."""
    k1, k2 = jax.random.split(rng_key)
    for stride in (1, 2):
        x = jax.random.randint(k1, (2, 12, 12, 8), -127, 128, jnp.int8)
        w = jax.random.randint(k2, (3, 3, 1, 8), -20, 21, jnp.int8)
        ref = jax.lax.conv_general_dilated(
            x, w, window_strides=(stride, stride), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=8, preferred_element_type=jnp.int32)
        from repro.kernels.conv2d_int8.ops import conv2d_int8
        for stream in (False, True):
            out = conv2d_int8(x, w, stride=stride, stream=stream,
                              depthwise=True, interpret=True)
            assert bool(jnp.all(out == ref)), (stride, stream)
