"""Scan-over-blocks fused trace: correctness, accounting, atomicity.

Contract under test (configs.homogeneous_block_runs +
core.detect_scan_groups + the scanned_res_block_int8 engine + compiler
scan binding + the bounded stage-6 trace cache):

  * scanned execution is BIT-IDENTICAL to the unrolled fused trace
    (``compile(..., scan=False)``), the eager per-layer walk, and the
    functional jnp reference — on every executable mini net;
  * ``mini_mobilenet`` compiles to ZERO scan groups (no residual
    repetition — the binding never fires where the topology has none);
  * the scanned trace is genuinely SMALLER: >= 2x fewer jaxpr equations
    than the unrolled trace on a deep mini-ResNet-50 (the 3x acceptance
    bar lives in benchmarks/compile_scaling.py on the 16-deep config);
  * Eq. 2 coverage stays whole: ``eq2_report().verify()`` passes, every
    member of every scanned block appears in the stats (per-iteration
    words AND summed), and executed reports equal the template;
  * partition: a scan group is ATOMIC — no stage cut lands inside one,
    at any feasible stage count;
  * the stage-6 trace cache is a bounded LRU with hit/miss/eviction
    counters, and its fill is ONE critical section: concurrent first
    runs of the same shape trace exactly once (no lost-race retrace).
"""
import threading

import jax
import jax.numpy as jnp
import pytest

from repro import compiler
from repro.compiler import TPU_INTERPRET
from repro.compiler import pipeline as pipeline_mod
from repro.configs.cnn import (homogeneous_block_runs, mini_mobilenet,
                               mini_resnet18, mini_resnet50, stem_unit)
from repro.core.schedule import detect_scan_groups
from repro.models.cnn import cnn_forward, cnn_input_shape, init_cnn_params

# deep enough for real scan groups, small enough to execute in CI
DEEP50 = mini_resnet50(hw=16, width=16, stages=2, blocks_per_stage=3)


@pytest.fixture(scope="module")
def deep50():
    cp = compiler.compile(DEEP50, TPU_INTERPRET)
    params = init_cnn_params(jax.random.PRNGKey(0), DEEP50)
    x = jax.random.randint(jax.random.PRNGKey(1),
                           cnn_input_shape(DEEP50, 2), -127, 128, jnp.int8)
    return cp, params, x


# -- detection ---------------------------------------------------------------


def test_homogeneous_runs_and_scan_groups_on_deep_net(deep50):
    cp, _, _ = deep50
    runs = homogeneous_block_runs(DEEP50)
    assert runs, "deep mini-ResNet-50 must have homogeneous block runs"
    for run in runs:
        assert len(run) >= 2
    groups = detect_scan_groups(cp.plan)
    assert groups
    for g in groups:
        # schedule-homogeneous sub-runs of the shape-homogeneous runs
        assert g.n_blocks >= 2
        start, stop = g.layer_range
        names = [l.name for l in DEEP50.layers[start:stop]]
        assert tuple(names) == g.member_names
    # ... and the compiler bound at least one of them
    assert cp.scan_assignments
    for a in cp.scan_assignments:
        assert a.engine == "scanned_res_block_int8"
        assert cp.scan_for(a.blocks[0]) is a
        assert cp.scan_for(a.member_names[-1]) is a


def test_mini_mobilenet_compiles_to_zero_scan_groups():
    cfg = mini_mobilenet()
    cp = compiler.compile(cfg, TPU_INTERPRET)
    assert cp.scan_assignments == ()
    assert detect_scan_groups(cp.plan) == ()


def test_scan_false_compiles_unrolled(deep50):
    cp, _, _ = deep50
    cpu = compiler.compile(DEEP50, TPU_INTERPRET, scan=False)
    assert cp.scan_assignments and not cpu.scan_assignments
    # member layers keep their block bindings in the unrolled compile
    for g in cp.scan_assignments:
        for m in g.member_names:
            assert cpu.assignment_for(m).scan is None
            assert cpu.assignment_for(m).engine == "res_block_int8"


# -- bit-identity ------------------------------------------------------------


def test_scanned_bit_identical_on_deep_resnet50(deep50):
    """The golden contract: the scan is a compile strategy — scanned
    fused == unrolled fused == eager == jnp reference, bit for bit."""
    cp, params, x = deep50
    assert cp.scan_assignments
    cpu = compiler.compile(DEEP50, TPU_INTERPRET, scan=False)
    ref = cnn_forward(params, DEEP50, x)
    y_scan, rep_scan = cp.run(params, x, backend="fused")
    y_unrl, _ = cpu.run(params, x, backend="fused")
    y_eagr, rep_eagr = cp.run(params, x, backend="eager")
    assert bool(jnp.all(y_scan == y_unrl))
    assert bool(jnp.all(y_scan == y_eagr))
    assert bool(jnp.all(y_scan == ref))
    # reports agree entry-for-entry between backends of the SAME compile
    assert rep_scan.layers == rep_eagr.layers


@pytest.mark.parametrize("cfg", [mini_resnet18(hw=16, width=32),
                                 mini_resnet50(hw=16, width=16, stages=2),
                                 mini_mobilenet()],
                         ids=["mini_resnet18", "mini_resnet50",
                              "mini_mobilenet"])
def test_scanned_bit_identical_all_minis(cfg):
    cp = compiler.compile(cfg, TPU_INTERPRET)
    cpu = compiler.compile(cfg, TPU_INTERPRET, scan=False)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.randint(jax.random.PRNGKey(1), cnn_input_shape(cfg, 2),
                           -127, 128, jnp.int8)
    ref = cnn_forward(params, cfg, x)
    y_scan, _ = cp.run(params, x, backend="fused")
    y_unrl, _ = cpu.run(params, x, backend="fused")
    y_eagr, _ = cp.run(params, x, backend="eager")
    assert bool(jnp.all(y_scan == y_unrl))
    assert bool(jnp.all(y_scan == y_eagr))
    assert bool(jnp.all(y_scan == ref))


# -- trace size --------------------------------------------------------------


def test_scanned_trace_is_smaller():
    cfg = mini_resnet50(hw=16, width=16, stages=2, blocks_per_stage=10)
    cps = compiler.compile(cfg, TPU_INTERPRET)
    cpu = compiler.compile(cfg, TPU_INTERPRET, scan=False)
    j_u, _ = compiler.trace_fused_abstract(cpu)
    j_s, _ = compiler.trace_fused_abstract(cps)
    n_s = compiler.count_jaxpr_eqns(j_s)
    n_u = compiler.count_jaxpr_eqns(j_u)
    assert n_u / n_s >= 2.0, (n_s, n_u)


# -- Eq. 2 coverage ----------------------------------------------------------


def test_eq2_verify_covers_scanned_groups(deep50):
    cp, params, x = deep50
    rep = cp.eq2_report(4).verify()
    # every member layer of every scanned block is in the template,
    # reported under the scan engine's name
    names = {st.name for st in rep.layers}
    for g in cp.scan_assignments:
        for m in g.member_names:
            assert m in names
    used = rep.engines_used()
    for g in cp.scan_assignments:
        for m in g.member_names:
            assert used[m] == "scanned_res_block_int8"
    # executed run equals the template exactly (fused AND eager)
    _, run_rep = cp.run(params, x)
    assert tuple(run_rep.layers) == cp.stats_template(int(x.shape[0]))
    run_rep.verify()
    # engines_used == engine_table over the whole graph
    assert run_rep.engines_used() == cp.engine_table()


def test_scan_rows_report_per_iteration_words(deep50):
    cp, params, x = deep50
    _, rep = cp.run(params, x)
    rows = rep.scan_rows()
    assert len(rows) == len(cp.scan_assignments)
    for row, g in zip(rows, cp.scan_assignments):
        assert len(row["hbm_words_per_block"]) == g.n_blocks
        assert sum(row["hbm_words_per_block"]) == row["hbm_words"]
        # per-iteration homogeneity: every block of the run streams the
        # same words (that is what made it scannable)
        per = row["plan_hbm_words_per_block"] * rep.images
        assert all(w == per for w in row["hbm_words_per_block"])
        assert row["hbm_words"] == g.hbm_words_per_image * rep.images


def test_scan_mismatch_hard_fails(deep50):
    cp, params, x = deep50
    streamed_scan = [g for g in cp.scan_assignments
                     if g.hbm_words_per_block > 0]
    if not streamed_scan:
        pytest.skip("no streamed scan groups under this placement")
    _, rep = cp.run(params, x)
    victim = streamed_scan[0].member_names[0]
    rep.layers = [st for st in rep.layers if st.name != victim]
    with pytest.raises(compiler.Eq2MismatchError):
        rep.verify()


# -- partition atomicity -----------------------------------------------------


def test_no_stage_cut_lands_inside_a_scan_group(deep50):
    cp, _, _ = deep50
    assert cp.scan_assignments
    from repro.compiler.partition import _atomic_units
    units = _atomic_units(cp)
    max_stages = len(units)
    for n in range(1, max_stages + 1):
        part = cp.partition(n)
        cuts = [s.layer_range[0] for s in part.stages[1:]]
        for g in cp.scan_assignments:
            start, stop = g.layer_range
            for c in cuts:
                assert not (start < c < stop), \
                    f"stage cut {c} inside scan group {g.group} " \
                    f"[{start},{stop})"
        part.verify_eq2()


def test_scan_group_is_one_atomic_unit(deep50):
    cp, _, _ = deep50
    from repro.compiler.partition import _atomic_units
    units = _atomic_units(cp)
    for g in cp.scan_assignments:
        assert g.layer_range in units
    # the stem conv+pool unit is atomic too
    su = stem_unit(DEEP50)
    names = [l.name for l in DEEP50.layers]
    stem_range = (names.index(su.conv.name), names.index(su.pool.name) + 1)
    assert stem_range in units


def test_sharded_stage_execution_bit_identical(deep50):
    """Stage programs over a scanned pipeline still execute the scan
    groups (layer_range slices never cut one), and chaining the stages
    reproduces the fused logits bit for bit."""
    from repro.compiler.partition import stage_forward_fns
    cp, params, x = deep50
    part = cp.partition(2)
    fns = stage_forward_fns(part, interpret=True)
    h = x
    for fn in fns:
        h = fn(params, h)
    fused, _ = cp.run(params, x)
    assert bool(jnp.all(h == fused))


# -- bounded LRU trace cache -------------------------------------------------


def test_trace_cache_lru_eviction_and_counters():
    cfg = mini_resnet18(hw=8, width=16, stages=2)
    cp = compiler.compile(cfg, TPU_INTERPRET, trace_cache_size=2)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    xs = [jax.random.randint(jax.random.PRNGKey(b),
                             cnn_input_shape(cfg, b), -127, 128, jnp.int8)
          for b in (1, 2, 3)]
    for x in xs:
        cp.run(params, x)
    st = cp.trace_cache_stats()
    assert st["max_entries"] == 2
    assert st["entries"] == 2 == cp.trace_count
    assert st["misses"] == 3
    assert st["evictions"] == 1          # batch-1 trace (LRU) evicted
    # warm shape: hit, no eviction
    cp.run(params, xs[2])
    st = cp.trace_cache_stats()
    assert st["hits"] == 1 and st["misses"] == 3 and st["evictions"] == 1
    # the evicted batch-1 shape retraces (miss), evicting batch-2
    cp.run(params, xs[0])
    st = cp.trace_cache_stats()
    assert st["misses"] == 4 and st["evictions"] == 2


def test_trace_cache_stats_surface_in_serving_report():
    cfg = mini_resnet18(hw=8, width=16, stages=2)
    cp = compiler.compile(cfg, TPU_INTERPRET)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    with cp.serve(params, microbatch=2) as eng:
        eng.submit(jax.random.randint(jax.random.PRNGKey(9),
                                      cnn_input_shape(cfg, 2)[1:],
                                      -127, 128, jnp.int8)[None]).result()
        rep = eng.report()
    assert rep.trace_cache["entries"] >= 1
    assert rep.trace_cache["max_entries"] == 8
    assert rep.trace_cache["misses"] >= 1


def test_concurrent_first_runs_trace_exactly_once(monkeypatch):
    """The single-critical-section contract: N threads hitting a COLD
    pipeline with the same shape produce exactly ONE trace — the old
    double-checked fill could trace twice and drop one (lost race)."""
    cfg = mini_resnet18(hw=8, width=16, stages=2)
    cp = compiler.compile(cfg, TPU_INTERPRET)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.randint(jax.random.PRNGKey(1), cnn_input_shape(cfg, 2),
                           -127, 128, jnp.int8)

    calls = []
    real = pipeline_mod.trace_fused
    barrier = threading.Barrier(4)

    def counting_trace(*a, **kw):
        calls.append(threading.get_ident())
        return real(*a, **kw)

    monkeypatch.setattr(pipeline_mod, "trace_fused", counting_trace)

    outs, errs = [], []

    def worker():
        try:
            barrier.wait(timeout=30)
            outs.append(cp.run(params, x)[0])
        except Exception as e:                       # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert len(calls) == 1, f"retraced {len(calls)} times"
    st = cp.trace_cache_stats()
    assert st["misses"] == 1 and st["hits"] == 3
    for y in outs[1:]:
        assert bool(jnp.all(y == outs[0]))


# -- stem conv+pool unit -----------------------------------------------------


def test_stem_unit_bound_and_bit_identical():
    cfg = mini_resnet18(hw=16, width=32)
    cp = compiler.compile(cfg, TPU_INTERPRET)
    su = stem_unit(cfg)
    assert su is not None
    basn = cp.block_for(su.name)
    assert basn is not None and basn.engine == "stem_pool_int8"
    assert basn.members == (su.conv.name, su.pool.name)
    assert cp.engine_table()[su.conv.name] == "stem_pool_int8"
    assert cp.engine_table()[su.pool.name] == "stem_pool_int8"
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.randint(jax.random.PRNGKey(1), cnn_input_shape(cfg, 2),
                           -127, 128, jnp.int8)
    ref = cnn_forward(params, cfg, x)
    y, rep = cp.run(params, x)
    assert bool(jnp.all(y == ref))
    rep.verify()
    assert rep.engines_used()[su.conv.name] == "stem_pool_int8"


def test_vgg_has_no_stem_unit():
    from repro.configs.cnn import get_cnn
    assert stem_unit(get_cnn("vgg16")) is None
