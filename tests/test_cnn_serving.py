"""Continuous-streaming CNN serving: the §V credit law at runtime.

Contract under test (runtime/cnn_serving.py + core/admission.py):

  * serving results are BIT-IDENTICAL to sequential ``run()`` per
    request — packing mixed-size requests into padded fixed-shape
    microbatches (rows spanning microbatch boundaries included) changes
    scheduling, never an output bit;
  * N producer threads submitting concurrently never exceed ``credits``
    in-flight microbatches — asserted through the admission controller's
    invariant hooks (high-water mark, conservation, quiescence), not by
    sampling;
  * the packed dispatch keeps the fused-trace cache at ONE warm entry
    no matter how mixed the request sizes are;
  * the :class:`ServingReport` accounting holds: per-request Eq. 2 HBM
    words are ``n_images x words/image``, the executed total includes
    the padded rows (overhead visible, not folded in), percentiles are
    ordered, queue depth is sampled.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compiler
from repro.compiler import TPU_INTERPRET
from repro.configs.cnn import mini_resnet18
from repro.models.cnn import cnn_input_shape, init_cnn_params
from repro.runtime.cnn_serving import CnnServingEngine

MINI = mini_resnet18(hw=8, width=16, stages=4)     # 21 engines, 3 streamed


@pytest.fixture(scope="module")
def setup():
    cp = compiler.compile(MINI, TPU_INTERPRET)
    assert cp.streamed_names                       # Eq. 2 words flow
    params = init_cnn_params(jax.random.PRNGKey(0), MINI)
    return cp, params


def _requests(sizes, seed=0):
    rng = np.random.default_rng(seed)
    shape = cnn_input_shape(MINI, 1)[1:]
    return [rng.integers(-127, 128, size=(n,) + shape,
                         dtype=np.int16).astype(np.int8) for n in sizes]


def _reference_rows(cp, params, batches):
    """Per-request reference logits from ONE sequential fused run over
    the concatenated images (batch-size independence is the established
    fused-path contract)."""
    big = np.concatenate(batches, axis=0)
    ref, _ = cp.run(params, jnp.asarray(big))
    ref = np.asarray(ref)
    out, off = [], 0
    for b in batches:
        out.append(ref[off:off + len(b)])
        off += len(b)
    return out


def test_serving_bit_identical_to_sequential_run(setup):
    """Mixed sizes, including requests larger than the microbatch (rows
    span dispatch boundaries): every request's logits equal the
    sequential ``run()`` result for its images."""
    cp, params = setup
    batches = _requests([1, 3, 2, 5, 1, 4, 2, 6])  # 6 > microbatch=4
    with cp.serve(params, microbatch=4, credits=3) as eng:
        results, report = eng.serve(batches)
    for got, want in zip(results, _reference_rows(cp, params, batches)):
        assert got.shape == want.shape
        assert np.array_equal(got, want)
    assert report.requests == len(batches)
    assert report.images == sum(len(b) for b in batches)
    assert report.max_in_flight <= 3


def test_one_warm_trace_for_any_request_mix(setup):
    """The whole point of pad+mask packing: one fused-trace cache entry
    serves every request size."""
    cp = compiler.compile(MINI, TPU_INTERPRET)     # fresh, empty cache
    _, params = setup
    assert cp.trace_count == 0
    with cp.serve(params, microbatch=4, credits=2) as eng:
        eng.serve(_requests([1, 3, 2, 4, 1]))
    assert cp.trace_count == 1


def test_threaded_stress_never_exceeds_credits(setup):
    """The satellite stress test: N producers submitting concurrently;
    the admission invariant hooks prove at most ``credits`` microbatches
    were EVER in flight, and every result is bit-identical to the
    sequential reference."""
    cp, params = setup
    rng = np.random.default_rng(7)
    sizes = [int(rng.integers(1, 6)) for _ in range(24)]
    batches = _requests(sizes, seed=7)
    credits, producers = 2, 6
    results = {}
    with cp.serve(params, microbatch=4, credits=credits) as eng:
        def producer(pid):
            for i in range(pid, len(batches), producers):
                results[i] = eng.submit(batches[i])
        threads = [threading.Thread(target=producer, args=(p,))
                   for p in range(producers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        eng.drain(timeout=120)
        report = eng.report()
    # invariant hooks, not sampling: the high-water mark held, the
    # accounting conserves, and stop() asserted quiescence already
    eng.admission.check_invariants()
    assert eng.admission.max_in_flight_seen <= credits
    assert report.max_in_flight <= credits
    assert eng.admission.admitted_total == eng.admission.completed_total \
        == report.microbatches
    refs = _reference_rows(cp, params, batches)
    for i, req in results.items():
        assert np.array_equal(req.result(), refs[i]), f"request {i}"
    assert report.requests == len(batches)


def test_report_accounting(setup):
    cp, params = setup
    batches = _requests([2, 1, 3, 1])              # 7 images
    with cp.serve(params, microbatch=4, credits=4) as eng:
        _, report = eng.serve(batches)
        per_image = eng.words_per_image
    assert per_image == sum(cp.plan.hbm_words_per_image().values()) > 0
    # per-request Eq. 2 rows: n * words/image, in completion order
    by_rid = {r["rid"]: r for r in report.request_rows}
    for rid, batch in enumerate(batches, start=1):
        assert by_rid[rid]["hbm_words"] == len(batch) * per_image
        assert by_rid[rid]["images"] == len(batch)
        assert by_rid[rid]["latency_ms"] > 0
    assert report.hbm_words_useful == 7 * per_image
    # how the 7 images split into microbatches is timing-dependent (the
    # packer flushes partial packs rather than wait), but the padding
    # accounting identity always holds — overhead visible, never hidden
    assert report.microbatches * 4 == report.images + report.padded_rows
    assert report.hbm_words_executed == \
        report.microbatches * 4 * per_image >= report.hbm_words_useful
    assert 0 <= report.pad_fraction < 1
    assert report.p50_ms <= report.p95_ms <= report.p99_ms
    assert report.images_per_s > 0
    assert report.queue_depth and all(d >= 0 for _, d in report.queue_depth)
    assert "images/s" in report.table()


def test_partial_pack_padding_deterministic(setup):
    """ONE 5-image request through microbatch 4 packs deterministically
    (a request arrives whole): a full pack, then a 1-row flush with 3
    padded rows."""
    cp, params = setup
    with cp.serve(params, microbatch=4, credits=2) as eng:
        per_image = eng.words_per_image
        results, report = eng.serve(_requests([5]))
    assert report.microbatches == 2 and report.padded_rows == 3
    assert report.hbm_words_executed == 8 * per_image
    assert report.hbm_words_useful == 5 * per_image
    assert np.array_equal(
        results[0], _reference_rows(cp, params, _requests([5]))[0])


def test_lifecycle_and_validation(setup):
    cp, params = setup
    eng = CnnServingEngine(cp, params, microbatch=2, credits=1)
    with pytest.raises(RuntimeError, match="not started"):
        eng.submit(_requests([1])[0])
    with eng:
        with pytest.raises(ValueError, match="expected images"):
            eng.submit(np.zeros((1, 5, 5, 3), np.int8))
        # a single [H,W,C] image is promoted to a 1-image request
        req = eng.submit(_requests([1])[0][0])
        assert req.result(timeout=60).shape[0] == 1
        assert req.latency_s > 0
    eng.admission.assert_quiescent()
    # single-use: a stopped engine refuses to restart (stale worker
    # state must not silently swallow requests)
    with pytest.raises(RuntimeError, match="single-use"):
        eng.start()
    with pytest.raises(ValueError, match="microbatch"):
        CnnServingEngine(cp, params, microbatch=0)


def test_compiled_pipeline_serve_entry_point(setup):
    cp, params = setup
    eng = cp.serve(params, microbatch=4, credits=2)
    assert isinstance(eng, CnnServingEngine)
    assert eng.admission.capacity == 2
    with eng:
        res, report = eng.serve(_requests([1, 2]))
    assert len(res) == 2 and report.images == 3


# ---------------------------------------------------------------------------
# serving-clock regressions (pinned bugs: truthiness rebase, submit/stop
# race accounting) and the adaptive microbatch ladder
# ---------------------------------------------------------------------------


class _FlippableClock:
    """Monotone fake clock whose step can be changed mid-run: step 0.0
    parks time exactly at ``start`` (so the FIRST request's t_submit —
    and with it the engine's ``_t0`` — is exactly 0.0), then a positive
    step lets time advance for later events."""

    def __init__(self, start=0.0, step=0.0):
        self.t = float(start)
        self.step = float(step)
        self._lock = threading.Lock()

    def __call__(self):
        with self._lock:
            t = self.t
            self.t += self.step
            return t


def test_depth_samples_rebase_with_clock_starting_at_zero(setup):
    """Regression pin: ``_dispatch`` rebased depth-sample timestamps
    with ``t - self._t0 if self._t0 else 0.0`` — truthiness, not an
    ``is not None`` check — so an injected clock that legitimately
    reads 0.0 at the first submit froze EVERY sample timestamp at 0.0.
    With the fix, samples taken after time advances carry positive
    rebased timestamps."""
    cp, params = setup
    clk = _FlippableClock(start=0.0, step=0.0)
    with cp.serve(params, microbatch=2, credits=2, clock=clk) as eng:
        first = eng.submit(_requests([1])[0])
        first.result(timeout=60)
        assert eng._t0 == 0.0                # the falsy-_t0 trigger
        clk.step = 0.001                     # now let time advance
        _, rep = eng.serve(_requests([1, 2, 1], seed=3))
    assert rep.queue_depth                   # samples were taken
    # every sample is rebased (never a raw clock reading from a clock
    # that only moved forward), and at least one post-advance sample
    # carries a REAL positive offset — all-zero means the rebase
    # silently collapsed, the exact pinned bug
    assert all(ts >= 0.0 for ts, _ in rep.queue_depth)
    assert any(ts > 0.0 for ts, _ in rep.queue_depth)


def test_submit_losing_race_to_stop_leaves_accounting_clean(setup):
    """Regression pin: a submit() that loses the race against stop()
    used to set ``_t0`` and bump ``serving_requests_submitted`` for a
    request ``_reject()`` then threw away — skewing wall_s and the
    counter.  Only requests that actually ENTER the queue may count."""
    cp, params = setup
    eng = cp.serve(params, microbatch=2, credits=2)
    eng.start()
    try:
        eng._accepting = False               # stop() won the race
        with pytest.raises(RuntimeError, match="stopping"):
            eng.submit(_requests([1])[0])
        assert eng._t0 is None               # wall clock never started
        counters = eng.metrics.snapshot()["counters"]
        assert counters.get("serving_requests_submitted", 0) == 0
        # the engine is still fully serviceable once accepting again
        eng._accepting = True
        batches = _requests([1, 2], seed=7)
        outs, rep = eng.serve(batches)
        for got, want in zip(outs, _reference_rows(cp, params, batches)):
            assert np.array_equal(got, want)
        assert rep.requests == 2
        counters = eng.metrics.snapshot()["counters"]
        assert counters["serving_requests_submitted"] == 2
    finally:
        eng.stop()
    eng.admission.assert_quiescent()


def test_adaptive_ladder_validation(setup):
    cp, params = setup
    with pytest.raises(ValueError, match="topping"):
        CnnServingEngine(cp, params, microbatch=4,
                         microbatch_ladder=[1, 2])      # doesn't reach 4
    with pytest.raises(ValueError, match="topping"):
        CnnServingEngine(cp, params, microbatch=4,
                         microbatch_ladder=[0, 4])      # non-positive rung
    # default power-of-two ladder for microbatch=1024 has 11 rungs —
    # more than the stage-6 trace cache holds; the ctor must refuse
    # rather than let the ladder thrash its own traces
    assert cp.trace_cache_size < 11
    with pytest.raises(ValueError, match="trace cache"):
        CnnServingEngine(cp, params, microbatch=1024, adaptive=True)
    # fixed-shape engines keep the single-rung ladder
    eng = CnnServingEngine(cp, params, microbatch=4)
    assert eng.microbatch_ladder == (4,) and not eng.adaptive
    # passing a ladder implies adaptive
    eng = CnnServingEngine(cp, params, microbatch=4,
                           microbatch_ladder=[1, 4])
    assert eng.adaptive and eng.microbatch_ladder == (1, 4)


def test_adaptive_shapes_follow_queue_depth(setup):
    """Light load dispatches the smallest fitting rung (low padding),
    a burst grows back to the full microbatch — and every shape stays
    inside the pipeline's bounded trace cache, bit-identical."""
    cp, params = setup
    with cp.serve(params, microbatch=4, credits=2, adaptive=True) as eng:
        assert eng.microbatch_ladder == (1, 2, 4)
        # strictly closed-loop singles (wait before the next submit, so
        # the packer sees exactly 1 row): the smallest rung each time
        singles = _requests([1, 1, 1], seed=11)
        single_reqs = []
        for b in singles:
            r = eng.submit(b)
            r.result(timeout=60)
            single_reqs.append(r)
        # a burst wider than the top rung: full-shape dispatches
        burst = _requests([8], seed=12)
        outs, rep = eng.serve(burst)
    shapes = rep.microbatch_shapes
    assert shapes.get("1", 0) >= 3           # singles used the small rung
    assert shapes.get("4", 0) >= 2           # the 8-row burst used 4+4
    # executed-word accounting follows the shapes actually dispatched
    assert rep.dispatched_rows == sum(
        int(k) * v for k, v in shapes.items())
    assert rep.hbm_words_executed == \
        rep.dispatched_rows * rep.hbm_words_per_image
    assert rep.padded_rows == rep.dispatched_rows - rep.images
    # bit-identity is untouched by shape changes
    for got, want in zip([r.result() for r in single_reqs],
                         _reference_rows(cp, params, singles)):
        assert np.array_equal(got, want)
    assert np.array_equal(outs[0],
                          _reference_rows(cp, params, burst)[0])
    # the rung population fits the bounded LRU — no eviction thrash
    tc = rep.trace_cache
    assert tc["entries"] <= tc["max_entries"]


def test_restore_tuple_fields_deep_nesting():
    """The shared deserialization law restores tuple-typed fields
    RECURSIVELY: nested rows (tuples of tuples, as the sharded and
    front-end reports carry) must round-trip to equality, not decay to
    lists one level down."""
    import dataclasses as dc
    import json
    from typing import Dict, Tuple

    from repro.runtime.cnn_serving import restore_tuple_fields

    @dc.dataclass
    class Nested:
        rows: Tuple[Tuple[int, ...], ...] = ()
        pairs: Tuple[Tuple[str, int], ...] = ()
        plain: Dict[str, int] = dc.field(default_factory=dict)

    orig = Nested(rows=((1, 2), (3,)), pairs=(("a", 1), ("b", 2)),
                  plain={"x": 1})
    payload = json.loads(json.dumps(dc.asdict(orig)))
    back = Nested(**restore_tuple_fields(Nested, payload))
    assert back == orig
    assert isinstance(back.rows[0], tuple)       # deep, not shallow
    assert isinstance(back.pairs[1], tuple)
    # unknown (derived) keys are dropped, not passed to the ctor
    payload["derived_rate"] = 123.0
    assert Nested(**restore_tuple_fields(Nested, payload)) == orig
