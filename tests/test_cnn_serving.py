"""Continuous-streaming CNN serving: the §V credit law at runtime.

Contract under test (runtime/cnn_serving.py + core/admission.py):

  * serving results are BIT-IDENTICAL to sequential ``run()`` per
    request — packing mixed-size requests into padded fixed-shape
    microbatches (rows spanning microbatch boundaries included) changes
    scheduling, never an output bit;
  * N producer threads submitting concurrently never exceed ``credits``
    in-flight microbatches — asserted through the admission controller's
    invariant hooks (high-water mark, conservation, quiescence), not by
    sampling;
  * the packed dispatch keeps the fused-trace cache at ONE warm entry
    no matter how mixed the request sizes are;
  * the :class:`ServingReport` accounting holds: per-request Eq. 2 HBM
    words are ``n_images x words/image``, the executed total includes
    the padded rows (overhead visible, not folded in), percentiles are
    ordered, queue depth is sampled.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compiler
from repro.compiler import TPU_INTERPRET
from repro.configs.cnn import mini_resnet18
from repro.models.cnn import cnn_input_shape, init_cnn_params
from repro.runtime.cnn_serving import CnnServingEngine

MINI = mini_resnet18(hw=8, width=16, stages=4)     # 21 engines, 3 streamed


@pytest.fixture(scope="module")
def setup():
    cp = compiler.compile(MINI, TPU_INTERPRET)
    assert cp.streamed_names                       # Eq. 2 words flow
    params = init_cnn_params(jax.random.PRNGKey(0), MINI)
    return cp, params


def _requests(sizes, seed=0):
    rng = np.random.default_rng(seed)
    shape = cnn_input_shape(MINI, 1)[1:]
    return [rng.integers(-127, 128, size=(n,) + shape,
                         dtype=np.int16).astype(np.int8) for n in sizes]


def _reference_rows(cp, params, batches):
    """Per-request reference logits from ONE sequential fused run over
    the concatenated images (batch-size independence is the established
    fused-path contract)."""
    big = np.concatenate(batches, axis=0)
    ref, _ = cp.run(params, jnp.asarray(big))
    ref = np.asarray(ref)
    out, off = [], 0
    for b in batches:
        out.append(ref[off:off + len(b)])
        off += len(b)
    return out


def test_serving_bit_identical_to_sequential_run(setup):
    """Mixed sizes, including requests larger than the microbatch (rows
    span dispatch boundaries): every request's logits equal the
    sequential ``run()`` result for its images."""
    cp, params = setup
    batches = _requests([1, 3, 2, 5, 1, 4, 2, 6])  # 6 > microbatch=4
    with cp.serve(params, microbatch=4, credits=3) as eng:
        results, report = eng.serve(batches)
    for got, want in zip(results, _reference_rows(cp, params, batches)):
        assert got.shape == want.shape
        assert np.array_equal(got, want)
    assert report.requests == len(batches)
    assert report.images == sum(len(b) for b in batches)
    assert report.max_in_flight <= 3


def test_one_warm_trace_for_any_request_mix(setup):
    """The whole point of pad+mask packing: one fused-trace cache entry
    serves every request size."""
    cp = compiler.compile(MINI, TPU_INTERPRET)     # fresh, empty cache
    _, params = setup
    assert cp.trace_count == 0
    with cp.serve(params, microbatch=4, credits=2) as eng:
        eng.serve(_requests([1, 3, 2, 4, 1]))
    assert cp.trace_count == 1


def test_threaded_stress_never_exceeds_credits(setup):
    """The satellite stress test: N producers submitting concurrently;
    the admission invariant hooks prove at most ``credits`` microbatches
    were EVER in flight, and every result is bit-identical to the
    sequential reference."""
    cp, params = setup
    rng = np.random.default_rng(7)
    sizes = [int(rng.integers(1, 6)) for _ in range(24)]
    batches = _requests(sizes, seed=7)
    credits, producers = 2, 6
    results = {}
    with cp.serve(params, microbatch=4, credits=credits) as eng:
        def producer(pid):
            for i in range(pid, len(batches), producers):
                results[i] = eng.submit(batches[i])
        threads = [threading.Thread(target=producer, args=(p,))
                   for p in range(producers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        eng.drain(timeout=120)
        report = eng.report()
    # invariant hooks, not sampling: the high-water mark held, the
    # accounting conserves, and stop() asserted quiescence already
    eng.admission.check_invariants()
    assert eng.admission.max_in_flight_seen <= credits
    assert report.max_in_flight <= credits
    assert eng.admission.admitted_total == eng.admission.completed_total \
        == report.microbatches
    refs = _reference_rows(cp, params, batches)
    for i, req in results.items():
        assert np.array_equal(req.result(), refs[i]), f"request {i}"
    assert report.requests == len(batches)


def test_report_accounting(setup):
    cp, params = setup
    batches = _requests([2, 1, 3, 1])              # 7 images
    with cp.serve(params, microbatch=4, credits=4) as eng:
        _, report = eng.serve(batches)
        per_image = eng.words_per_image
    assert per_image == sum(cp.plan.hbm_words_per_image().values()) > 0
    # per-request Eq. 2 rows: n * words/image, in completion order
    by_rid = {r["rid"]: r for r in report.request_rows}
    for rid, batch in enumerate(batches, start=1):
        assert by_rid[rid]["hbm_words"] == len(batch) * per_image
        assert by_rid[rid]["images"] == len(batch)
        assert by_rid[rid]["latency_ms"] > 0
    assert report.hbm_words_useful == 7 * per_image
    # how the 7 images split into microbatches is timing-dependent (the
    # packer flushes partial packs rather than wait), but the padding
    # accounting identity always holds — overhead visible, never hidden
    assert report.microbatches * 4 == report.images + report.padded_rows
    assert report.hbm_words_executed == \
        report.microbatches * 4 * per_image >= report.hbm_words_useful
    assert 0 <= report.pad_fraction < 1
    assert report.p50_ms <= report.p95_ms <= report.p99_ms
    assert report.images_per_s > 0
    assert report.queue_depth and all(d >= 0 for _, d in report.queue_depth)
    assert "images/s" in report.table()


def test_partial_pack_padding_deterministic(setup):
    """ONE 5-image request through microbatch 4 packs deterministically
    (a request arrives whole): a full pack, then a 1-row flush with 3
    padded rows."""
    cp, params = setup
    with cp.serve(params, microbatch=4, credits=2) as eng:
        per_image = eng.words_per_image
        results, report = eng.serve(_requests([5]))
    assert report.microbatches == 2 and report.padded_rows == 3
    assert report.hbm_words_executed == 8 * per_image
    assert report.hbm_words_useful == 5 * per_image
    assert np.array_equal(
        results[0], _reference_rows(cp, params, _requests([5]))[0])


def test_lifecycle_and_validation(setup):
    cp, params = setup
    eng = CnnServingEngine(cp, params, microbatch=2, credits=1)
    with pytest.raises(RuntimeError, match="not started"):
        eng.submit(_requests([1])[0])
    with eng:
        with pytest.raises(ValueError, match="expected images"):
            eng.submit(np.zeros((1, 5, 5, 3), np.int8))
        # a single [H,W,C] image is promoted to a 1-image request
        req = eng.submit(_requests([1])[0][0])
        assert req.result(timeout=60).shape[0] == 1
        assert req.latency_s > 0
    eng.admission.assert_quiescent()
    # single-use: a stopped engine refuses to restart (stale worker
    # state must not silently swallow requests)
    with pytest.raises(RuntimeError, match="single-use"):
        eng.start()
    with pytest.raises(ValueError, match="microbatch"):
        CnnServingEngine(cp, params, microbatch=0)


def test_compiled_pipeline_serve_entry_point(setup):
    cp, params = setup
    eng = cp.serve(params, microbatch=4, credits=2)
    assert isinstance(eng, CnnServingEngine)
    assert eng.admission.capacity == 2
    with eng:
        res, report = eng.serve(_requests([1, 2]))
    assert len(res) == 2 and report.images == 3
