"""``compile(cfg, target) -> CompiledPipeline`` — the staged H2PIPE compiler.

The paper's flow is a compiler pipeline, and this module makes each stage
an explicit pass over explicit values:

  1. **parallelism**   HPIPE balancing allocates (p_i, p_o) per layer under
                       ``target.tb_budget`` AI-TBs (§II-B);
  2. **placement**     hybrid selection (Eq. 1 order under the
                       pseudo-channel chain budget) picks the HBM-streamed
                       set until the on-chip remainder fits
                       ``target.bram_m20ks`` (Algorithm 1, §V-B), then
                       clockwise pseudo-channel assignment;
  3. **FIFO sizing**   last-stage + burst-matching depths from the measured
                       HBM latency/efficiency curves (§III/§IV-A), fused
                       into per-layer :class:`LayerSchedule`\\ s;
  4. **engine select** every graph node — convs, fc heads, AND the
                       pooling topology nodes (maxpool / GAP) — is bound
                       to a registered
                       :class:`~repro.compiler.engines.LayerEngine`;
                       the binding is *visible* (``engine_table()``)
                       before anything executes, and covers 100% of the
                       topology (no implicit wiring left in the model).
                       Residual blocks — basic and bottleneck — whose
                       members all land on Pallas conv engines are
                       additionally bound as ONE schedulable unit to a
                       block engine (``res_block_int8``), with the
                       unit's own (member sum + identity + widest
                       intermediate) VMEM cost and Eq. 2 words;
  5. **validation**    each binding's ``vmem_bytes`` is checked against
                       ``target.vmem_bytes``.  A pinned layer that does
                       not fit is re-placed to the HBM tier when its
                       streamed working set does; layers that fit in
                       neither tier abort compilation with a
                       :class:`TargetBudgetError` carrying the full
                       per-layer VMEM report.  Over-budget *block* units
                       simply fall back to their per-layer bindings;
  6. **trace**         the whole engine table is closed over
                       ``models.cnn.cnn_forward`` and compiled into ONE
                       ``jax.jit`` program per (input shape, dtype):
                       a warm ``run()`` is a single XLA dispatch, not a
                       Python walk over ~20 engine calls.  Tracing once
                       also yields the run's :class:`LayerExecStats`
                       (shape-static, so engines return them instead of
                       mutating a sink) — the template every warm run's
                       :class:`ExecutionReport` is built from.  Traces
                       are cached on the :class:`CompiledPipeline`; the
                       per-layer walk survives as ``backend="eager"``
                       (bit-identical, for debugging).

The result is immutable and reusable: ``CompiledPipeline.executor()``
(or ``.run``) executes it, ``engine_table()``/``vmem_report()``/
``block_table()`` expose the decisions, ``with_offload()`` recompiles
with a forced offload set.

Migration: ``repro.core.build_pipeline_plan(cfg, **kw)`` is now a
deprecation shim over ``plan_pipeline(cfg, NX2100.replace(**kw))`` —
stages 1-3 only, preserving pre-compiler placements verbatim; migrating
to ``compile()`` adds engine binding and VMEM validation on top.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import (TYPE_CHECKING, Any, Callable, Dict, List, Optional,
                    Sequence, Tuple, Union)

import jax
import jax.numpy as jnp

if TYPE_CHECKING:                     # import cycle guard: autotune uses
    from repro.compiler.autotune import (AutotuneConfig,  # pragma: no cover
                                         AutotuneResult)

from repro.compiler.engines import (EngineContext,  # noqa: F401 (re-export)
                                    LayerExecStats, get_engine,
                                    select_block_engine, select_engine,
                                    select_scan_engine, select_stem_engine)
from repro.compiler.target import NX2100, Target
from repro.configs.cnn import (CNNConfig, ResBlockSpec, StemUnitSpec,
                               residual_blocks, stem_unit)
from repro.core import fifo_sim, hbm_model, placement
from repro.core.schedule import (HBM, PINNED, LayerSchedule, PipelinePlan,
                                 ScanGroup, detect_scan_groups)
from repro.obs.metrics import default_registry


@contextlib.contextmanager
def _pass_timer(name: str):
    """Record one compile pass's wall seconds into the process-default
    metrics registry (``compile_pass_seconds{pass=<name>}``) — the
    observability counterpart of ``benchmarks/compile_scaling.py``:
    always on (a clock read plus one histogram insert per compile), so
    any session can ask where compile time went after the fact."""
    t0 = time.perf_counter()
    try:
        yield
    finally:
        default_registry().histogram(
            "compile_pass_seconds", **{"pass": name}).observe(
                time.perf_counter() - t0)


class CompileError(ValueError):
    """A stage of ``compile()`` rejected the (config, target) pair."""


class Eq2MismatchError(RuntimeError):
    """The hard-fail Eq. 2 cross-check tripped: a run's (or template's)
    per-node streamed words disagree with the plan analytics, or a graph
    node never dispatched.  Either means the compiled bindings and the
    executed network have drifted — a correctness bug, never a tolerance
    issue (the comparison is exact integers)."""


class TargetBudgetError(CompileError):
    """One or more layers exceed the target's VMEM budget in the weight
    tier they were compiled to.  Carries the per-layer report so callers
    see the whole picture, not just the first offender."""

    def __init__(self, target: Target, report: Dict[str, int],
                 offenders: Sequence[str], reason: str):
        self.target = target
        self.vmem_report = dict(report)
        self.offenders = tuple(offenders)
        lines = [f"{name}: {report[name]} B" for name in offenders]
        super().__init__(
            f"target {target.name!r}: {len(offenders)} layer(s) exceed the "
            f"per-engine VMEM budget ({target.vmem_bytes} B) {reason}: "
            + "; ".join(lines))


@dataclass(frozen=True)
class EngineAssignment:
    """The compile-time binding of one layer to one registered engine.
    ``block`` names the fused block unit owning the layer, when stage 4
    grouped it into one (the layer then dispatches at block granularity,
    under the block engine's name)."""

    layer: str
    engine: str                   # registry name (resolved at dispatch)
    mode: str                     # PINNED | HBM
    vmem_bytes: int               # working set the binding claims
    block: Optional[str] = None   # owning block unit, if any
    scan: Optional[str] = None    # owning scan group, if any


@dataclass(frozen=True)
class BlockAssignment:
    """One fused block unit: several layers bound to a single block
    engine, placed and costed together (the paper's engine granularity).
    """

    block: str                    # block name ("s0b0")
    engine: str                   # block engine registry name
    members: Tuple[str, ...]      # member layer names, config order
    vmem_bytes: int               # whole-unit working set
    hbm_words_per_image: int      # Eq. 2 words of the streamed members


@dataclass(frozen=True)
class ScanGroupAssignment:
    """One scanned block run: a shape- and schedule-homogeneous run of
    fused residual blocks bound to a scan engine, so the stage-6 trace
    emits ONE ``lax.scan`` body instead of ``n_blocks`` unrolled block
    bodies.  Eq. 2 accounting stays per-block AND summed: the scan is a
    compile strategy, never an accounting change."""

    group: str                              # scan group name ("scan:a..b")
    engine: str                             # scan engine registry name
    blocks: Tuple[str, ...]                 # member block names, order
    members: Tuple[Tuple[str, ...], ...]    # per-block member layer names
    layer_range: Tuple[int, int]            # [start, stop) into cfg.layers
    vmem_bytes: int                         # whole-run working set
    hbm_words_per_block: int                # Eq. 2 words, one iteration
    hbm_words_per_image: int                # Eq. 2 words, whole run

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    @property
    def member_names(self) -> Tuple[str, ...]:
        """All member layer names across the run, config order."""
        return tuple(n for ms in self.members for n in ms)


@dataclass(frozen=True)
class FusedTrace:
    """One stage-6 artifact: the XLA executable for a concrete input
    shape plus the stats template its trace produced."""

    fn: Callable                  # AOT-compiled (params, images) -> logits
    stats: Tuple[LayerExecStats, ...]


class _TraceCache:
    """The stage-6 trace cache: a bounded LRU keyed by (input shape,
    dtype, interpret, act_scale) with hit/miss/eviction counters.

    ``get_or_create`` holds the lock across the whole check-create-insert
    sequence — a SINGLE critical section, not double-checked locking.
    The old double-checked fill had a lost-race window: two threads could
    both miss, both trace, and the loser's compilation was thrown away
    (wasted work) — or worse, the two FusedTrace values could interleave
    with the eviction bookkeeping.  Tracing under the lock serializes
    compilation per pipeline, which is exactly the contract ``run()``
    wants: concurrent first calls on one shape share ONE trace (pinned by
    the threaded re-entrancy test counting retraces)."""

    def __init__(self, max_entries: int):
        if max_entries < 1:
            raise ValueError(f"trace cache needs >= 1 entry, "
                             f"got {max_entries}")
        self.max_entries = max_entries
        self._entries: "OrderedDict[Any, Any]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get_or_create(self, key, factory: Callable[[], Any]):
        with self._lock:
            hit = self._entries.get(key)
            if hit is not None:
                self.hits += 1
                self._entries.move_to_end(key)
                return hit
            self.misses += 1
            value = self._entries[key] = factory()
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
                self.evictions += 1
            return value

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"entries": len(self._entries),
                    "max_entries": self.max_entries,
                    "hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions}


@dataclass(frozen=True)
class CompiledPipeline:
    """An executable, validated pipeline: plan + engine bindings + target."""

    plan: PipelinePlan
    target: Optional[Target]
    assignments: Tuple[EngineAssignment, ...]
    replaced: Tuple[str, ...] = ()    # layers stage 5 moved pin -> stream
    block_assignments: Tuple[BlockAssignment, ...] = ()
    scan_assignments: Tuple[ScanGroupAssignment, ...] = ()
    #: bound on distinct stage-6 traces held live (LRU beyond it); see
    #: ``trace_cache_stats``.
    trace_cache_size: int = 8
    #: search provenance when the plan came from the placement + FIFO
    #: co-optimizer (``compile(..., autotune=...)``): the greedy-vs-tuned
    #: evaluations plus the co-optimized serving credit bound that
    #: ``serve()`` defaults to.  ``None`` for plain greedy compiles.
    tuning: Optional["AutotuneResult"] = None

    def __post_init__(self):
        # the stage-6 trace cache is created EAGERLY (not via
        # cached_property, whose lazy first evaluation races on
        # Python >= 3.12) so concurrent run()s on a fresh pipeline
        # always see the same cache and the same lock.  Frozen
        # dataclasses permit object.__setattr__ into __dict__.
        object.__setattr__(self, "_fused_cache",
                           _TraceCache(self.trace_cache_size))

    # -- introspection ------------------------------------------------------

    def engine_table(self) -> Dict[str, str]:
        """layer name -> registered engine name, in pipeline order."""
        return {a.layer: a.engine for a in self.assignments}

    def block_table(self) -> Dict[str, Tuple[str, ...]]:
        """fused block unit -> member layer names, in pipeline order."""
        return {b.block: b.members for b in self.block_assignments}

    def block_for(self, name: str) -> Optional[BlockAssignment]:
        """The block unit a block (or member layer) name belongs to."""
        return self._block_index.get(name)

    @functools.cached_property
    def _block_index(self) -> Dict[str, BlockAssignment]:
        idx: Dict[str, BlockAssignment] = {}
        for b in self.block_assignments:
            idx[b.block] = b
            for m in b.members:
                idx[m] = b
        return idx

    def scan_table(self) -> Dict[str, Tuple[str, ...]]:
        """scan group -> member block names, in pipeline order."""
        return {g.group: g.blocks for g in self.scan_assignments}

    def scan_for(self, name: str) -> Optional[ScanGroupAssignment]:
        """The scan group a group / block / member layer name belongs to."""
        return self._scan_index.get(name)

    @functools.cached_property
    def _scan_index(self) -> Dict[str, ScanGroupAssignment]:
        idx: Dict[str, ScanGroupAssignment] = {}
        for g in self.scan_assignments:
            idx[g.group] = g
            for b in g.blocks:
                idx[b] = g
            for m in g.member_names:
                idx[m] = g
        return idx

    @functools.cached_property
    def _unit_index(self) -> Dict[str, Union[ResBlockSpec, StemUnitSpec]]:
        """unit name -> the spec it fuses: every residual block by name,
        plus the stem unit (keyed by its conv's name) when the config
        has one — what ``stats_template`` and the scan dispatch use to
        recover the spec a :class:`BlockAssignment` binds."""
        idx: Dict[str, Union[ResBlockSpec, StemUnitSpec]] = {
            b.name: b for b in residual_blocks(self.plan.cfg)}
        su = stem_unit(self.plan.cfg)
        if su is not None:
            idx[su.name] = su
        return idx

    def vmem_report(self) -> Dict[str, int]:
        """layer name -> working-set bytes of its engine binding."""
        return {a.layer: a.vmem_bytes for a in self.assignments}

    def assignment_for(self, name: str) -> Optional[EngineAssignment]:
        return self._assignment_index.get(name)

    @functools.cached_property
    def _assignment_index(self) -> Dict[str, EngineAssignment]:
        """name -> assignment map (cached_property writes straight into
        ``__dict__``, which frozen dataclasses permit)."""
        return {a.layer: a for a in self.assignments}

    def describe(self) -> str:
        """Human-readable engine table (what runs where, before it runs)."""
        hdr = f"{'layer':12s} {'kind':7s} {'tier':7s} {'engine':14s} " \
              f"{'vmem':>10s}  pc"
        rows = [hdr, "-" * len(hdr)]
        for s, a in zip(self.plan.schedules, self.assignments):
            pc = f"PC{s.pc}" if s.pc is not None else "-"
            rows.append(f"{a.layer:12s} {s.spec.kind:7s} {a.mode:7s} "
                        f"{a.engine:14s} {a.vmem_bytes:>10d}  {pc}")
        return "\n".join(rows)

    # -- plan conveniences --------------------------------------------------

    @property
    def cfg(self) -> CNNConfig:
        return self.plan.cfg

    @property
    def schedules(self) -> Tuple[LayerSchedule, ...]:
        return self.plan.schedules

    @property
    def streamed_names(self) -> Tuple[str, ...]:
        return self.plan.streamed_names

    def hbm_words_per_image(self) -> Dict[str, int]:
        return self.plan.hbm_words_per_image()

    def throughput(self) -> Dict[str, float]:
        return self.plan.throughput()

    def predict_stalls(self, outputs_needed: int = 32,
                       word_scale: Optional[int] = None
                       ) -> fifo_sim.SimOutcome:
        return self.plan.predict_stalls(outputs_needed, word_scale)

    def with_offload(self, names: Sequence[str]) -> "CompiledPipeline":
        """Recompile (engine selection + validation) with the offload set
        forced to exactly ``names``.  The forced set is honored verbatim:
        stage 5 does NOT re-place layers here — a forced-pinned layer
        that exceeds the target's VMEM budget raises
        :class:`TargetBudgetError` instead of silently streaming."""
        return finalize(self.plan.with_offload(names), self.target,
                        replace=False,
                        trace_cache_size=self.trace_cache_size)

    # -- execution ----------------------------------------------------------

    def executor(self, *, interpret: Optional[bool] = None,
                 act_scale: float = 0.05, backend: str = "fused"):
        from repro.runtime.pipeline import PipelineExecutor
        return PipelineExecutor(self, interpret=interpret,
                                act_scale=act_scale, backend=backend)

    def run(self, params, images, *, interpret: Optional[bool] = None,
            backend: str = "fused"):
        """One-shot: (logits, ExecutionReport) for ``images``."""
        return self.executor(interpret=interpret,
                             backend=backend).run(params, images)

    # -- Eq. 2 template + hard-fail cross-check -----------------------------

    def stats_template(self, batch: int = 1) -> Tuple[LayerExecStats, ...]:
        """The shape-static :class:`LayerExecStats` sequence one run of
        ``batch`` images WILL report, assembled from the bound engines'
        ``stats`` accounting in dispatch order — no execution, no trace.
        Block-owned layers report under their block engine's name, same
        as the fused unit's ``run``.  Equality with an actual report's
        ``layers`` is pinned by test for executable configs, which is
        what lets the full-size nets be cross-checked without running
        224x224 images through the interpreter."""
        units = self._unit_index
        out: List[LayerExecStats] = []
        emitted = set()
        for a, s in zip(self.assignments, self.plan.schedules):
            if a.scan is not None:
                # scanned run: the scan engine owns EVERY member of EVERY
                # block in the run (summed-and-per-iteration Eq. 2 words);
                # the run is contiguous in config order, so emit it whole
                # at its first member
                if a.scan in emitted:
                    continue
                emitted.add(a.scan)
                g = self.scan_for(a.scan)
                out.extend(get_engine(g.engine).stats(
                    [units[b] for b in g.blocks],
                    [self.plan.schedules_for(ms) for ms in g.members],
                    batch))
            elif a.block is not None:
                # fused unit (residual block or stem pair): the unit
                # engine owns its members' stats accounting (ONE source —
                # the same method its run mirrors); members are
                # contiguous in config order, so emit the whole unit at
                # its first member
                if a.block in emitted:
                    continue
                emitted.add(a.block)
                basn = self.block_for(a.block)
                scheds = self.plan.schedules_for(basn.members)
                out.extend(get_engine(basn.engine).stats(
                    units[a.block], scheds, batch))
            else:
                out.append(get_engine(a.engine).stats(s, batch))
        return tuple(out)

    def eq2_report(self, batch: int = 1) -> "ExecutionReport":
        """An :class:`ExecutionReport` built from ``stats_template`` —
        what a run of ``batch`` images will report, without executing.
        ``eq2_report().verify()`` is the whole-net plan-vs-dispatch
        Eq. 2 cross-check at compile time."""
        rep = ExecutionReport(plan=self.plan, images=batch,
                              block_assignments=self.block_assignments,
                              scan_assignments=self.scan_assignments)
        rep.layers.extend(self.stats_template(batch))
        return rep

    def serve(self, params, *, microbatch: int = 8,
              credits: Optional[int] = None, **kw):
        """Continuous-streaming serving over this pipeline: a
        :class:`~repro.runtime.cnn_serving.CnnServingEngine` packing
        mixed-size requests into ``microbatch``-shaped fused dispatches,
        at most ``credits`` microbatches in flight (§V-A).  ``credits``
        defaults to the co-optimized bound when the pipeline was
        autotuned (``tuning.serving_credits`` — the smallest in-flight
        count that still saturates dispatch), else 4.  Use as a context
        manager, or call ``.start()``."""
        from repro.runtime.cnn_serving import CnnServingEngine
        if credits is None:
            credits = (self.tuning.serving_credits
                       if self.tuning is not None else 4)
        return CnnServingEngine(self, params, microbatch=microbatch,
                                credits=credits, **kw)

    # -- multi-device sharding ----------------------------------------------

    def partition(self, n_stages: int) -> "StagePartition":
        """Cut the placed schedule into ``n_stages`` device-local stage
        programs, balanced by the per-layer cycle model with fused
        residual blocks atomic (:mod:`repro.compiler.partition`).  The
        result carries per-stage Eq. 2 accounting and
        ``verify_eq2()`` — the same hard-fail plan-vs-dispatch
        cross-check, per stage."""
        from repro.compiler.partition import partition_pipeline
        return partition_pipeline(self, n_stages)

    def serve_sharded(self, params, *, mesh, axis: str = "model",
                      microbatch: int = 4, **kw):
        """Mesh-pipelined serving: stages span the ``axis`` devices of
        ``mesh`` (one stage per device, activations hopping stages via
        ``lax.ppermute``), each stage dispatching its slice of the
        compiled engine table, with shard-local producer queues and the
        shared §V-A :class:`~repro.core.admission.AdmissionController`
        bounding cross-device in-flight microbatches.  Returns a
        :class:`~repro.runtime.sharded_serving.ShardedCnnServingEngine`
        (context manager, like :meth:`serve`)."""
        from repro.runtime.sharded_serving import ShardedCnnServingEngine
        return ShardedCnnServingEngine(self, params, mesh=mesh, axis=axis,
                                       microbatch=microbatch, **kw)

    # -- stage 6: the fused whole-pipeline trace ----------------------------
    # _fused_cache: a bounded-LRU :class:`_TraceCache` keyed by (shape,
    # dtype, interpret, act_scale), created in __post_init__ so it lives
    # with the pipeline and every executor (and thread) shares the
    # compilations.

    @property
    def trace_count(self) -> int:
        """How many distinct (shape, dtype, config) traces stage 6 holds
        LIVE — a warm shape must NOT retrace (tested); bounded by
        ``trace_cache_size`` (LRU beyond it)."""
        return len(self._fused_cache)

    def trace_cache_stats(self) -> Dict[str, int]:
        """Stage-6 trace cache counters: ``entries`` / ``max_entries`` /
        ``hits`` / ``misses`` / ``evictions``.  Surfaced by
        :class:`~repro.runtime.cnn_serving.ServingReport` so serving
        exposes whether its shape population thrashes the bound."""
        return self._fused_cache.stats()

    def fused_trace(self, params, images, *, interpret: bool,
                    act_scale: float) -> FusedTrace:
        """The stage-6 artifact for this input shape: one jitted XLA
        program closing the whole engine table over ``cnn_forward``,
        plus the stats template collected while tracing it.  Cached per
        (shape, dtype, interpret, act_scale) in a bounded LRU
        (``trace_cache_size`` entries); the fill is ONE critical section,
        so concurrent ``run()``\\ s on one pipeline share a single
        compilation — never a lost-race duplicate trace."""
        key = (tuple(images.shape), str(images.dtype), interpret, act_scale)

        def _traced():
            with _pass_timer("trace_fused"):
                return trace_fused(self, params, images,
                                   interpret=interpret,
                                   act_scale=act_scale)

        out = self._fused_cache.get_or_create(key, _traced)
        reg = default_registry()
        for k, v in self._fused_cache.stats().items():
            reg.gauge("compile_trace_cache", counter=k).set(v)
        return out


@dataclass
class ExecutionReport:
    """What one execution did, cross-checked three ways (executed Eq. 2
    words at dispatch, the plan's analytic words, the §V-A fifo_sim).
    ``block_assignments`` carries the compile-time fused-block units so
    Eq. 2 traffic is reportable at block granularity too (fused
    ``res_block_int8`` units as first-class rows, not just their member
    layers)."""

    plan: PipelinePlan
    images: int = 0
    layers: list = dataclasses.field(default_factory=list)  # LayerExecStats
    block_assignments: Tuple["BlockAssignment", ...] = ()
    scan_assignments: Tuple["ScanGroupAssignment", ...] = ()

    @property
    def hbm_weight_words(self) -> Dict[str, int]:
        """Total streamed weight words per layer for the whole batch."""
        out: Dict[str, int] = {}
        for st in self.layers:
            if st.mode == HBM:
                out[st.name] = out.get(st.name, 0) + st.hbm_words
        return out

    @property
    def total_hbm_words(self) -> int:
        return sum(self.hbm_weight_words.values())

    @property
    def streamed_layer_count(self) -> int:
        return len({st.name for st in self.layers if st.mode == HBM})

    def engines_used(self) -> Dict[str, str]:
        """layer -> engine that actually ran (must equal the compile-time
        engine_table for layers the pipeline dispatched)."""
        return {st.name: st.kernel for st in self.layers}

    def block_rows(self) -> List[Dict[str, Any]]:
        """Block-granular Eq. 2 rows: one per fused block unit, with the
        EXECUTED streamed words of its members (from the dispatch
        counters) against the plan-side ``hbm_words_per_image`` the
        :class:`BlockAssignment` claims — the same executed-vs-analytic
        cross-check the per-layer report makes, at engine granularity."""
        executed = self.hbm_weight_words
        rows: List[Dict[str, Any]] = []
        for b in self.block_assignments:
            words = sum(executed.get(m, 0) for m in b.members)
            rows.append({
                "block": b.block,
                "engine": b.engine,
                "members": list(b.members),
                "hbm_words": words,
                "hbm_words_per_image": words // self.images
                if self.images else 0,
                "plan_hbm_words_per_image": b.hbm_words_per_image,
            })
        return rows

    @property
    def hbm_block_words(self) -> Dict[str, int]:
        """Executed streamed words per fused block unit, whole batch."""
        return {r["block"]: r["hbm_words"] for r in self.block_rows()}

    def scan_rows(self) -> List[Dict[str, Any]]:
        """Scan-group Eq. 2 rows: one per scanned block run, with the
        EXECUTED streamed words summed over the run AND per iteration
        (per member block), against the plan-side per-block and whole-run
        words the :class:`ScanGroupAssignment` claims.  The per-iteration
        column is what proves the scan did not collapse the accounting:
        every block of the run streams its own weights, homogeneously."""
        executed = self.hbm_weight_words
        rows: List[Dict[str, Any]] = []
        for g in self.scan_assignments:
            per_block = [sum(executed.get(m, 0) for m in ms)
                         for ms in g.members]
            rows.append({
                "group": g.group,
                "engine": g.engine,
                "blocks": list(g.blocks),
                "n_blocks": g.n_blocks,
                "hbm_words": sum(per_block),
                "hbm_words_per_block": per_block,
                "plan_hbm_words_per_block": g.hbm_words_per_block,
                "plan_hbm_words_per_image": g.hbm_words_per_image,
            })
        return rows

    def verify(self) -> "ExecutionReport":
        """HARD-FAIL Eq. 2 cross-check over the whole topology: every
        graph node dispatched exactly once per image, executed streamed
        words equal to the plan's ``weight_words_per_image`` analytics
        per node AND per fused block unit — exact integer equality,
        raising :class:`Eq2MismatchError` on the first drift.  Returns
        self so call sites can chain it."""
        names = [s.spec.name for s in self.plan.schedules]
        dispatched = {st.name for st in self.layers}
        missing = [n for n in names if n not in dispatched]
        if missing:
            raise Eq2MismatchError(
                f"{len(missing)} graph node(s) never dispatched: {missing}")
        # only nonzero demands: a (caller-forced) streamed zero-word node
        # never shows up in the HBM-mode dispatch counters, and zero
        # words planned == zero words executed is agreement, not drift
        expected = {n: w * self.images
                    for n, w in self.plan.hbm_words_per_image().items()
                    if w > 0}
        got = self.hbm_weight_words
        if got != expected:
            drift = {n: (expected.get(n), got.get(n))
                     for n in set(expected) | set(got)
                     if expected.get(n) != got.get(n)}
            raise Eq2MismatchError(
                f"executed Eq. 2 words != plan analytics "
                f"(plan, executed): {drift}")
        for row in self.block_rows():
            want = row["plan_hbm_words_per_image"] * self.images
            if row["hbm_words"] != want:
                raise Eq2MismatchError(
                    f"block {row['block']}: executed {row['hbm_words']} "
                    f"words != plan {want}")
        for row in self.scan_rows():
            want = row["plan_hbm_words_per_image"] * self.images
            if row["hbm_words"] != want:
                raise Eq2MismatchError(
                    f"scan group {row['group']}: executed "
                    f"{row['hbm_words']} words != plan {want}")
            per = row["plan_hbm_words_per_block"] * self.images
            for blk, w in zip(row["blocks"], row["hbm_words_per_block"]):
                if w != per:
                    raise Eq2MismatchError(
                        f"scan group {row['group']} iteration {blk}: "
                        f"executed {w} words != plan {per} (the scanned "
                        f"body must stream every iteration's weights)")
        return self

    def fifo_prediction(self, outputs_needed: int = 32,
                        word_scale: Optional[int] = None
                        ) -> fifo_sim.SimOutcome:
        """§V-A credit-mode stall/delivery prediction for the streamed set."""
        return self.plan.predict_stalls(outputs_needed, word_scale)

    def modelled_throughput(self) -> Dict[str, float]:
        return self.plan.throughput()


# ---------------------------------------------------------------------------
# the passes
# ---------------------------------------------------------------------------


def plan_pipeline(cfg: CNNConfig, target: Target) -> PipelinePlan:
    """Stages 1-3: parallelism, placement, FIFO sizing — the executable
    :class:`PipelinePlan` (no engine bindings yet)."""
    with _pass_timer("parallelism"):
        plans = placement.allocate_parallelism(cfg, target.tb_budget)
    with _pass_timer("placement"):
        plans = placement.hybrid_selection(plans, target.bram_m20ks,
                                           n_pc=target.n_pc,
                                           burst=target.burst)
        placement.assign_pseudo_channels(plans, n_pc=target.n_pc)

    with _pass_timer("fifo_sizing"):
        laststage = hbm_model.min_laststage_fifo_depth(target.burst)
        bm_words = hbm_model.burst_matching_fifo_words(target.burst)
        schedules = tuple(
            LayerSchedule(
                spec=p.spec,
                mode=HBM if p.offload else PINNED,
                p_i=p.p_i, p_o=p.p_o, pc=p.pc,
                burst=target.burst,
                laststage_fifo_depth=laststage,
                bm_fifo_words=bm_words,
                n_buffers=target.n_buffers,
            ) for p in plans)
        out = PipelinePlan(cfg=cfg, schedules=schedules,
                           placements=tuple(plans), burst=target.burst,
                           n_pc=target.n_pc)
    return out


def finalize(plan: PipelinePlan, target: Optional[Target], *,
             replace: bool = True,
             tuning: Optional["AutotuneResult"] = None,
             scan: bool = True,
             trace_cache_size: int = 8) -> CompiledPipeline:
    """Stages 4-5 over an existing plan: bind every layer to a registered
    engine, then enforce the target's VMEM budget — re-placing pinned
    layers whose working set only fits when streamed, and raising
    :class:`TargetBudgetError` for layers that fit in neither tier.

    ``scan=False`` disables scan-group binding (stage 4 then emits the
    unrolled fused trace of before — the differential baseline the
    scanned trace is pinned bit-identical against, and the knob
    ``benchmarks/compile_scaling.py`` measures the win over).
    ``trace_cache_size`` bounds the stage-6 LRU trace cache.

    Re-placement respects Algorithm 1's hard feasibility constraint: a
    move consumes the layer's ``p_i * p_o`` tensor-chain feeds from the
    target's pseudo-channel pool, and layers the pool cannot feed stay
    pinned (and fail validation) rather than silently oversubscribing
    the HBM bandwidth the throughput model assumes.

    ``replace=False`` keeps the plan's tier decisions verbatim (used by
    ``with_offload``: a caller-forced offload set must not be silently
    expanded — validation fails instead).  ``target=None`` binds engines
    without budget enforcement (the deprecation-compat path for raw
    ``PipelinePlan`` values).  ``tuning`` attaches the autotuner's
    provenance record when the plan came out of the co-optimizer.
    """
    # engine choice depends only on the spec, so bind once per layer and
    # reuse across the re-placement and assignment passes
    engines = {s.spec.name: select_engine(s.spec) for s in plan.schedules}

    moved = []
    if target is not None and replace:
        free_bw = target.chain_budget - sum(
            s.p_i * s.p_o for s in plan.streamed)
        for s in plan.schedules:
            eng = engines[s.spec.name]
            if s.streamed or eng.vmem_bytes(s.spec, s) <= target.vmem_bytes:
                continue
            streamed = dataclasses.replace(s, mode=HBM)
            chains = s.p_i * s.p_o
            if eng.vmem_bytes(s.spec, streamed) <= target.vmem_bytes \
                    and chains <= free_bw:
                moved.append(s.spec.name)
                free_bw -= chains
        if moved:
            plan = plan.with_offload(
                set(plan.streamed_names) | set(moved))

    # engines that cannot source weights from HBM (jnp_ref) must not hold
    # the HBM tier, or plan analytics/fifo_sim would charge Eq. 2 traffic
    # that never executes: demote compile-chosen placements to pinned,
    # reject caller-forced ones loudly.
    unstreamable = [s.spec.name for s in plan.streamed
                    if not getattr(engines[s.spec.name], "can_stream", True)]
    if unstreamable:
        if not replace:
            raise CompileError(
                f"layer(s) {unstreamable} are bound to engines that cannot "
                f"stream weights from HBM; remove them from the forced "
                f"offload set")
        plan = plan.with_offload(
            set(plan.streamed_names) - set(unstreamable))

    assignments = []
    offenders = []
    for s in plan.schedules:
        eng = engines[s.spec.name]
        vb = eng.vmem_bytes(s.spec, s)
        assignments.append(EngineAssignment(
            layer=s.spec.name, engine=eng.name, mode=s.mode, vmem_bytes=vb))
        if target is not None and vb > target.vmem_bytes:
            offenders.append(s.spec.name)
    if offenders:
        reason = ("in every feasible weight tier (pinned over budget; HBM "
                  "tier over budget or out of pseudo-channel bandwidth)"
                  if replace else
                  "in their forced weight tier (re-placement disabled by "
                  "with_offload)")
        raise TargetBudgetError(
            target, {a.layer: a.vmem_bytes for a in assignments}, offenders,
            reason)

    # residual blocks whose members all sit on Pallas conv engines become
    # ONE schedulable unit under a block engine (the paper's granularity:
    # an engine is a block of fabric).  The unit claims the sum of its
    # members' working sets + the identity buffer; when that exceeds the
    # target's VMEM ceiling, the block simply keeps per-layer bindings.
    blocks: List[BlockAssignment] = []
    by_layer = {a.layer: i for i, a in enumerate(assignments)}
    for blk in residual_blocks(plan.cfg):
        beng = select_block_engine(blk)
        if beng is None:
            continue
        scheds = plan.schedules_for([m.name for m in blk.members])
        vb = beng.vmem_bytes(blk, scheds)
        if target is not None and vb > target.vmem_bytes:
            continue
        blocks.append(BlockAssignment(
            block=blk.name, engine=beng.name,
            members=tuple(m.name for m in blk.members), vmem_bytes=vb,
            hbm_words_per_image=sum(s.weight_words_per_image
                                    for s in scheds if s.streamed)))
        for m in blk.members:
            i = by_layer[m.name]
            assignments[i] = dataclasses.replace(
                assignments[i], engine=beng.name, block=blk.name)

    # the stem conv + following maxpool pair rides the same block-unit
    # machinery: one BlockAssignment, one VMEM cost, members dispatching
    # under the stem engine's name.  Over budget (or members not on the
    # fused engines) -> per-layer bindings, like any block.
    su = stem_unit(plan.cfg)
    if su is not None:
        seng = select_stem_engine(su)
        if seng is not None:
            scheds = plan.schedules_for([m.name for m in su.members])
            vb = seng.vmem_bytes(su, scheds)
            if target is None or vb <= target.vmem_bytes:
                blocks.append(BlockAssignment(
                    block=su.name, engine=seng.name,
                    members=tuple(m.name for m in su.members),
                    vmem_bytes=vb,
                    hbm_words_per_image=sum(s.weight_words_per_image
                                            for s in scheds if s.streamed)))
                for m in su.members:
                    i = by_layer[m.name]
                    assignments[i] = dataclasses.replace(
                        assignments[i], engine=seng.name, block=su.name)

    # scan-group binding: homogeneous runs of block-bound residual blocks
    # (same shapes, same schedules, same block engine) become ONE
    # lax.scan over the fused body — the jaxpr cost of the run collapses
    # to one iteration while the Eq. 2 accounting stays per block.
    scans: List[ScanGroupAssignment] = []
    if scan:
        basn_by_name = {b.block: b for b in blocks}
        blk_specs = {b.name: b for b in residual_blocks(plan.cfg)}
        for g in detect_scan_groups(plan):
            basns = [basn_by_name.get(bn) for bn in g.blocks]
            if any(b is None for b in basns):
                continue                  # some block fell back per-layer
            if len({b.engine for b in basns}) != 1:
                continue                  # mixed block engines: no one body
            group_blocks = [blk_specs[bn] for bn in g.blocks]
            sceng = select_scan_engine(group_blocks)
            if sceng is None:
                continue
            scheds_pb = [plan.schedules_for(ms) for ms in g.members]
            vb = sceng.vmem_bytes(group_blocks, scheds_pb)
            if target is not None and vb > target.vmem_bytes:
                continue                  # stacked weights over budget
            per_block = sum(s.weight_words_per_image
                            for s in scheds_pb[0] if s.streamed)
            scans.append(ScanGroupAssignment(
                group=g.name, engine=sceng.name, blocks=g.blocks,
                members=g.members, layer_range=g.layer_range,
                vmem_bytes=vb, hbm_words_per_block=per_block,
                hbm_words_per_image=per_block * g.n_blocks))
            for ms in g.members:
                for m in ms:
                    i = by_layer[m]
                    assignments[i] = dataclasses.replace(
                        assignments[i], engine=sceng.name, scan=g.name)

    return CompiledPipeline(plan=plan, target=target,
                            assignments=tuple(assignments),
                            replaced=tuple(moved),
                            block_assignments=tuple(blocks),
                            scan_assignments=tuple(scans),
                            trace_cache_size=trace_cache_size,
                            tuning=tuning)


def make_dispatchers(compiled: CompiledPipeline, ctx: EngineContext,
                     collect: Optional[List[LayerExecStats]]
                     ) -> Tuple[Callable, Callable, Callable]:
    """The (layer, block, scan) dispatch hooks ``cnn_forward`` routes
    through: each offered layer/block/run executes on its compile-time
    binding, with the returned :class:`LayerExecStats` appended to
    ``collect``.  Used by both the eager per-layer walk (collecting per
    call) and the stage-6 trace (collecting once, at trace time)."""
    plan = compiled.plan

    def dispatch(spec, p, x, relu: bool):
        asn = compiled.assignment_for(spec.name)
        if asn is None or asn.block is not None:
            # unknown to the plan, or owned by a fused block unit (the
            # block hook handles it) -> decline, jnp reference runs it
            return None
        y_q, y_f, st = get_engine(asn.engine).run(
            ctx, plan.schedule_for(spec.name), p, x, relu)
        if collect is not None:
            collect.append(st)
        return y_q, y_f

    def block_dispatch(block, params, x):
        basn = compiled.block_for(block.name)
        if basn is None:
            return None
        scheds = plan.schedules_for(basn.members)
        y, stats = get_engine(basn.engine).run(ctx, block, scheds, params, x)
        if collect is not None:
            collect.extend(stats)
        return y

    def scan_dispatch(block, params, x, limit: int):
        # offered at every residual block's lead conv: accept only when
        # this block LEADS a bound scan group and the whole run fits the
        # active layer_range (partitioning keeps groups atomic, so a
        # truncated offer means a caller-forced odd range — decline and
        # let per-block execution cover it, bit-identically)
        g = compiled.scan_for(block.name)
        if g is None or g.blocks[0] != block.name:
            return None
        n = len(g.member_names)
        if n > limit:
            return None
        blocks = [compiled._unit_index[bn] for bn in g.blocks]
        scheds = [plan.schedules_for(ms) for ms in g.members]
        y, stats = get_engine(g.engine).run(ctx, blocks, scheds, params, x)
        if collect is not None:
            collect.extend(stats)
        return y, n

    return dispatch, block_dispatch, scan_dispatch


def trace_fused(compiled: CompiledPipeline, params, images, *,
                interpret: bool, act_scale: float) -> FusedTrace:
    """Stage 6: close the engine table over ``cnn_forward`` and compile
    the WHOLE pipeline into one XLA program for this input shape.

    The single trace also runs every dispatch hook once, which is where
    the :class:`LayerExecStats` come from: engines return them as
    shape-static metadata, so the trace yields both the executable and
    the exact stats template every warm run reports (executed Eq. 2
    words from the traced counters; analytic words stay on the plan).

    ``images`` is donated to the executable on real backends (the
    activation buffer is dead after dispatch); under the interpreter /
    CPU, donation is skipped so callers can reuse input arrays.
    """
    from repro.models.cnn import cnn_forward

    ctx = EngineContext(interpret=interpret, act_scale=act_scale)
    stats: List[LayerExecStats] = []
    dispatch, block_dispatch, scan_dispatch = make_dispatchers(
        compiled, ctx, stats)
    cfg = compiled.plan.cfg

    def forward(p, x):
        return cnn_forward(p, cfg, x, engine=dispatch,
                           block_engine=block_dispatch,
                           scan_engine=scan_dispatch)

    donate = () if interpret else (1,)
    jitted = jax.jit(forward, donate_argnums=donate)
    fn = jitted.lower(params, images).compile()     # the ONE trace
    return FusedTrace(fn=fn, stats=tuple(stats))


def trace_fused_abstract(compiled: CompiledPipeline, batch: int = 1, *,
                         interpret: bool = True, act_scale: float = 0.05):
    """Trace the stage-6 fused program with ABSTRACT params and inputs:
    returns ``(closed_jaxpr, trace_seconds)`` — no weights materialized,
    nothing executed, nothing lowered to XLA.  This is the
    compile-scaling instrument (``benchmarks/compile_scaling.py``):
    full-size 224x224 nets trace in seconds without allocating a single
    parameter, and :func:`count_jaxpr_eqns` on the result measures the
    scan-over-blocks equation-count win directly on the IR the compiler
    would consume."""
    from repro.models.cnn import (cnn_forward, cnn_input_shape,
                                  init_cnn_params)

    ctx = EngineContext(interpret=interpret, act_scale=act_scale)
    dispatch, block_dispatch, scan_dispatch = make_dispatchers(
        compiled, ctx, None)
    cfg = compiled.plan.cfg

    def forward(p, x):
        return cnn_forward(p, cfg, x, engine=dispatch,
                           block_engine=block_dispatch,
                           scan_engine=scan_dispatch)

    params = jax.eval_shape(
        lambda: init_cnn_params(jax.random.PRNGKey(0), cfg))
    x = jax.ShapeDtypeStruct(cnn_input_shape(cfg, batch), jnp.int8)
    t0 = time.perf_counter()
    traced = jax.jit(forward).trace(params, x)
    seconds = time.perf_counter() - t0
    return traced.jaxpr, seconds


def count_jaxpr_eqns(jaxpr) -> int:
    """Total equations in ``jaxpr``, recursing into sub-jaxprs nested in
    equation params (scan/cond/pjit bodies) — each sub-jaxpr counted
    ONCE, which is exactly the quantity the scan-over-blocks trace
    shrinks: a ``lax.scan`` body's equations appear once regardless of
    how many blocks the run iterates."""
    if hasattr(jaxpr, "jaxpr"):                       # ClosedJaxpr
        jaxpr = jaxpr.jaxpr
    n = 0
    for eqn in jaxpr.eqns:
        n += 1
        for v in eqn.params.values():
            n += _count_sub_eqns(v)
    return n


def _count_sub_eqns(v) -> int:
    if isinstance(v, (list, tuple)):
        return sum(_count_sub_eqns(x) for x in v)
    if hasattr(v, "jaxpr") or hasattr(v, "eqns"):
        return count_jaxpr_eqns(v)
    return 0


def compile(cfg: CNNConfig, target: Target = NX2100, *,
            autotune: Union[None, bool, "AutotuneConfig"] = None,
            scan: bool = True, trace_cache_size: int = 8
            ) -> CompiledPipeline:
    """Compile a CNN for a target: passes 1-5 up front, validated and
    executable; the stage-6 fused trace is instantiated (and cached) per
    input shape on first ``run()``.

    ``autotune`` swaps stage 2-3's one-shot greedy placement + §IV-A
    FIFO sizing for the search-based co-optimizer
    (:mod:`repro.compiler.autotune`): ``True`` runs it with defaults, an
    :class:`AutotuneConfig` carries explicit search knobs.  The result
    is a normal, fully validated pipeline — same stages 4-5, same
    ``eq2_report().verify()`` guarantees — whose tier decisions are
    taken verbatim from the search (no stage-5 re-placement: the tuned
    plan already satisfies the VMEM budget per layer), with the search
    record attached as ``.tuning``.

    ``scan=False`` compiles the unrolled fused trace (no scan-group
    binding) — the differential baseline; ``trace_cache_size`` bounds
    the stage-6 LRU trace cache."""
    if autotune is None or autotune is False:
        plan = plan_pipeline(cfg, target)
        with _pass_timer("finalize"):
            return finalize(plan, target, scan=scan,
                            trace_cache_size=trace_cache_size)
    from repro.compiler.autotune import AutotuneConfig, autotune_plan
    at = AutotuneConfig() if autotune is True else autotune
    with _pass_timer("autotune"):
        result = autotune_plan(cfg, target, at)
    with _pass_timer("finalize"):
        return finalize(result.plan, target, replace=False, tuning=result,
                        scan=scan, trace_cache_size=trace_cache_size)
