"""``compile(cfg, target) -> CompiledPipeline`` — the staged H2PIPE compiler.

The paper's flow is a compiler pipeline, and this module makes each stage
an explicit pass over explicit values:

  1. **parallelism**   HPIPE balancing allocates (p_i, p_o) per layer under
                       ``target.tb_budget`` AI-TBs (§II-B);
  2. **placement**     hybrid selection (Eq. 1 order under the
                       pseudo-channel chain budget) picks the HBM-streamed
                       set until the on-chip remainder fits
                       ``target.bram_m20ks`` (Algorithm 1, §V-B), then
                       clockwise pseudo-channel assignment;
  3. **FIFO sizing**   last-stage + burst-matching depths from the measured
                       HBM latency/efficiency curves (§III/§IV-A), fused
                       into per-layer :class:`LayerSchedule`\\ s;
  4. **engine select** every layer is bound to a registered
                       :class:`~repro.compiler.engines.LayerEngine` —
                       the binding is *visible* (``engine_table()``)
                       before anything executes;
  5. **validation**    each binding's ``vmem_bytes`` is checked against
                       ``target.vmem_bytes``.  A pinned layer that does
                       not fit is re-placed to the HBM tier when its
                       streamed working set does; layers that fit in
                       neither tier abort compilation with a
                       :class:`TargetBudgetError` carrying the full
                       per-layer VMEM report.

The result is immutable and reusable: ``CompiledPipeline.executor()``
(or ``.run``) executes it, ``engine_table()``/``vmem_report()`` expose
the decisions, ``with_offload()`` recompiles with a forced offload set.

Migration: ``repro.core.build_pipeline_plan(cfg, **kw)`` is now a
deprecation shim over ``plan_pipeline(cfg, NX2100.replace(**kw))`` —
stages 1-3 only, preserving pre-compiler placements verbatim; migrating
to ``compile()`` adds engine binding and VMEM validation on top.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

from repro.compiler.engines import (EngineContext,  # noqa: F401 (re-export)
                                    LayerExecStats, get_engine,
                                    select_engine)
from repro.compiler.target import NX2100, Target
from repro.configs.cnn import CNNConfig
from repro.core import fifo_sim, hbm_model, placement
from repro.core.schedule import (HBM, PINNED, LayerSchedule, PipelinePlan)


class CompileError(ValueError):
    """A stage of ``compile()`` rejected the (config, target) pair."""


class TargetBudgetError(CompileError):
    """One or more layers exceed the target's VMEM budget in the weight
    tier they were compiled to.  Carries the per-layer report so callers
    see the whole picture, not just the first offender."""

    def __init__(self, target: Target, report: Dict[str, int],
                 offenders: Sequence[str], reason: str):
        self.target = target
        self.vmem_report = dict(report)
        self.offenders = tuple(offenders)
        lines = [f"{name}: {report[name]} B" for name in offenders]
        super().__init__(
            f"target {target.name!r}: {len(offenders)} layer(s) exceed the "
            f"per-engine VMEM budget ({target.vmem_bytes} B) {reason}: "
            + "; ".join(lines))


@dataclass(frozen=True)
class EngineAssignment:
    """The compile-time binding of one layer to one registered engine."""

    layer: str
    engine: str                   # registry name (resolved at dispatch)
    mode: str                     # PINNED | HBM
    vmem_bytes: int               # working set the binding claims


@dataclass(frozen=True)
class CompiledPipeline:
    """An executable, validated pipeline: plan + engine bindings + target."""

    plan: PipelinePlan
    target: Optional[Target]
    assignments: Tuple[EngineAssignment, ...]
    replaced: Tuple[str, ...] = ()    # layers stage 5 moved pin -> stream

    # -- introspection ------------------------------------------------------

    def engine_table(self) -> Dict[str, str]:
        """layer name -> registered engine name, in pipeline order."""
        return {a.layer: a.engine for a in self.assignments}

    def vmem_report(self) -> Dict[str, int]:
        """layer name -> working-set bytes of its engine binding."""
        return {a.layer: a.vmem_bytes for a in self.assignments}

    def assignment_for(self, name: str) -> Optional[EngineAssignment]:
        return self._assignment_index.get(name)

    @functools.cached_property
    def _assignment_index(self) -> Dict[str, EngineAssignment]:
        """name -> assignment map (cached_property writes straight into
        ``__dict__``, which frozen dataclasses permit)."""
        return {a.layer: a for a in self.assignments}

    def describe(self) -> str:
        """Human-readable engine table (what runs where, before it runs)."""
        hdr = f"{'layer':12s} {'kind':7s} {'tier':7s} {'engine':14s} " \
              f"{'vmem':>10s}  pc"
        rows = [hdr, "-" * len(hdr)]
        for s, a in zip(self.plan.schedules, self.assignments):
            pc = f"PC{s.pc}" if s.pc is not None else "-"
            rows.append(f"{a.layer:12s} {s.spec.kind:7s} {a.mode:7s} "
                        f"{a.engine:14s} {a.vmem_bytes:>10d}  {pc}")
        return "\n".join(rows)

    # -- plan conveniences --------------------------------------------------

    @property
    def cfg(self) -> CNNConfig:
        return self.plan.cfg

    @property
    def schedules(self) -> Tuple[LayerSchedule, ...]:
        return self.plan.schedules

    @property
    def streamed_names(self) -> Tuple[str, ...]:
        return self.plan.streamed_names

    def hbm_words_per_image(self) -> Dict[str, int]:
        return self.plan.hbm_words_per_image()

    def throughput(self) -> Dict[str, float]:
        return self.plan.throughput()

    def predict_stalls(self, outputs_needed: int = 32,
                       word_scale: Optional[int] = None
                       ) -> fifo_sim.SimOutcome:
        return self.plan.predict_stalls(outputs_needed, word_scale)

    def with_offload(self, names: Sequence[str]) -> "CompiledPipeline":
        """Recompile (engine selection + validation) with the offload set
        forced to exactly ``names``.  The forced set is honored verbatim:
        stage 5 does NOT re-place layers here — a forced-pinned layer
        that exceeds the target's VMEM budget raises
        :class:`TargetBudgetError` instead of silently streaming."""
        return finalize(self.plan.with_offload(names), self.target,
                        replace=False)

    # -- execution ----------------------------------------------------------

    def executor(self, *, interpret: Optional[bool] = None,
                 act_scale: float = 0.05):
        from repro.runtime.pipeline import PipelineExecutor
        return PipelineExecutor(self, interpret=interpret,
                                act_scale=act_scale)

    def run(self, params, images, *, interpret: Optional[bool] = None):
        """One-shot: (logits, ExecutionReport) for ``images``."""
        return self.executor(interpret=interpret).run(params, images)


@dataclass
class ExecutionReport:
    """What one execution did, cross-checked three ways (executed Eq. 2
    words at dispatch, the plan's analytic words, the §V-A fifo_sim)."""

    plan: PipelinePlan
    images: int = 0
    layers: list = dataclasses.field(default_factory=list)  # LayerExecStats

    @property
    def hbm_weight_words(self) -> Dict[str, int]:
        """Total streamed weight words per layer for the whole batch."""
        out: Dict[str, int] = {}
        for st in self.layers:
            if st.mode == HBM:
                out[st.name] = out.get(st.name, 0) + st.hbm_words
        return out

    @property
    def total_hbm_words(self) -> int:
        return sum(self.hbm_weight_words.values())

    @property
    def streamed_layer_count(self) -> int:
        return len({st.name for st in self.layers if st.mode == HBM})

    def engines_used(self) -> Dict[str, str]:
        """layer -> engine that actually ran (must equal the compile-time
        engine_table for layers the pipeline dispatched)."""
        return {st.name: st.kernel for st in self.layers}

    def fifo_prediction(self, outputs_needed: int = 32,
                        word_scale: Optional[int] = None
                        ) -> fifo_sim.SimOutcome:
        """§V-A credit-mode stall/delivery prediction for the streamed set."""
        return self.plan.predict_stalls(outputs_needed, word_scale)

    def modelled_throughput(self) -> Dict[str, float]:
        return self.plan.throughput()


# ---------------------------------------------------------------------------
# the passes
# ---------------------------------------------------------------------------


def plan_pipeline(cfg: CNNConfig, target: Target) -> PipelinePlan:
    """Stages 1-3: parallelism, placement, FIFO sizing — the executable
    :class:`PipelinePlan` (no engine bindings yet)."""
    plans = placement.allocate_parallelism(cfg, target.tb_budget)
    plans = placement.hybrid_selection(plans, target.bram_m20ks,
                                       n_pc=target.n_pc, burst=target.burst)
    placement.assign_pseudo_channels(plans, n_pc=target.n_pc)

    laststage = hbm_model.min_laststage_fifo_depth(target.burst)
    bm_words = hbm_model.burst_matching_fifo_words(target.burst)
    schedules = tuple(
        LayerSchedule(
            spec=p.spec,
            mode=HBM if p.offload else PINNED,
            p_i=p.p_i, p_o=p.p_o, pc=p.pc,
            burst=target.burst,
            laststage_fifo_depth=laststage,
            bm_fifo_words=bm_words,
            n_buffers=target.n_buffers,
        ) for p in plans)
    return PipelinePlan(cfg=cfg, schedules=schedules,
                        placements=tuple(plans), burst=target.burst,
                        n_pc=target.n_pc)


def finalize(plan: PipelinePlan, target: Optional[Target], *,
             replace: bool = True) -> CompiledPipeline:
    """Stages 4-5 over an existing plan: bind every layer to a registered
    engine, then enforce the target's VMEM budget — re-placing pinned
    layers whose working set only fits when streamed, and raising
    :class:`TargetBudgetError` for layers that fit in neither tier.

    Re-placement respects Algorithm 1's hard feasibility constraint: a
    move consumes the layer's ``p_i * p_o`` tensor-chain feeds from the
    target's pseudo-channel pool, and layers the pool cannot feed stay
    pinned (and fail validation) rather than silently oversubscribing
    the HBM bandwidth the throughput model assumes.

    ``replace=False`` keeps the plan's tier decisions verbatim (used by
    ``with_offload``: a caller-forced offload set must not be silently
    expanded — validation fails instead).  ``target=None`` binds engines
    without budget enforcement (the deprecation-compat path for raw
    ``PipelinePlan`` values).
    """
    # engine choice depends only on the spec, so bind once per layer and
    # reuse across the re-placement and assignment passes
    engines = {s.spec.name: select_engine(s.spec) for s in plan.schedules}

    moved = []
    if target is not None and replace:
        free_bw = target.chain_budget - sum(
            s.p_i * s.p_o for s in plan.streamed)
        for s in plan.schedules:
            eng = engines[s.spec.name]
            if s.streamed or eng.vmem_bytes(s.spec, s) <= target.vmem_bytes:
                continue
            streamed = dataclasses.replace(s, mode=HBM)
            chains = s.p_i * s.p_o
            if eng.vmem_bytes(s.spec, streamed) <= target.vmem_bytes \
                    and chains <= free_bw:
                moved.append(s.spec.name)
                free_bw -= chains
        if moved:
            plan = plan.with_offload(
                set(plan.streamed_names) | set(moved))

    # engines that cannot source weights from HBM (jnp_ref) must not hold
    # the HBM tier, or plan analytics/fifo_sim would charge Eq. 2 traffic
    # that never executes: demote compile-chosen placements to pinned,
    # reject caller-forced ones loudly.
    unstreamable = [s.spec.name for s in plan.streamed
                    if not getattr(engines[s.spec.name], "can_stream", True)]
    if unstreamable:
        if not replace:
            raise CompileError(
                f"layer(s) {unstreamable} are bound to engines that cannot "
                f"stream weights from HBM; remove them from the forced "
                f"offload set")
        plan = plan.with_offload(
            set(plan.streamed_names) - set(unstreamable))

    assignments = []
    offenders = []
    for s in plan.schedules:
        eng = engines[s.spec.name]
        vb = eng.vmem_bytes(s.spec, s)
        assignments.append(EngineAssignment(
            layer=s.spec.name, engine=eng.name, mode=s.mode, vmem_bytes=vb))
        if target is not None and vb > target.vmem_bytes:
            offenders.append(s.spec.name)
    if offenders:
        reason = ("in every feasible weight tier (pinned over budget; HBM "
                  "tier over budget or out of pseudo-channel bandwidth)"
                  if replace else
                  "in their forced weight tier (re-placement disabled by "
                  "with_offload)")
        raise TargetBudgetError(
            target, {a.layer: a.vmem_bytes for a in assignments}, offenders,
            reason)
    return CompiledPipeline(plan=plan, target=target,
                            assignments=tuple(assignments),
                            replaced=tuple(moved))


def compile(cfg: CNNConfig, target: Target = NX2100) -> CompiledPipeline:
    """Compile a CNN for a target: all five passes, validated, executable."""
    return finalize(plan_pipeline(cfg, target), target)
