"""The H2PIPE compiler package — the repo's stable extension surface.

Public API:

  * :func:`compile` — ``compile(cfg, target) -> CompiledPipeline``: the
    staged flow (parallelism -> Alg. 1 placement -> FIFO sizing -> engine
    binding -> VMEM validation);
  * :class:`Target` + presets :data:`NX2100` / :data:`TPU_INTERPRET` —
    explicit device resource descriptors;
  * :func:`register_engine` / :class:`LayerEngine` — the pluggable
    per-layer kernel registry (conv2d_int8, dwconv_int8, stream_matmul,
    maxpool_int8, global_avgpool_int8, res_block_int8, jnp_ref built in;
    ``is_block = True`` engines bind whole residual blocks — basic and
    bottleneck — as one schedulable unit);
  * :class:`CompiledPipeline` — immutable result: ``engine_table()``,
    ``block_table()``, ``vmem_report()``, ``describe()``, ``run()``
    (``backend="fused"`` one-dispatch jit per input shape, cached;
    ``backend="eager"`` per-layer walk), plus ``stats_template()`` /
    ``eq2_report().verify()`` — the hard-fail plan-vs-dispatch Eq. 2
    cross-check over 100% of the topology, execution-free;
  * :func:`partition_pipeline` / :class:`StagePartition` — the sharding
    stage (``CompiledPipeline.partition(n_stages)``): the placed
    schedule cut into contiguous device-local stage programs, balanced
    by the per-layer cycle model with fused residual blocks atomic,
    carrying per-stage Eq. 2 accounting and the per-stage hard-fail
    ``verify_eq2()`` cross-check; ``serve_sharded(params, mesh=...)``
    runs the partition as a mesh pipeline (one stage per device over
    ``lax.ppermute``, shard-local producers, shared §V-A credits);
  * :func:`autotune_plan` / :class:`AutotuneConfig` — the search-based
    placement + FIFO co-optimizer (``compile(cfg, target,
    autotune=...)`` is the integrated path): joint exploration of the
    offload set, burst length, burst-matching / last-stage FIFO depths
    and serving credits, seeded by the greedy Alg. 1 plan, costed by
    the exact credit-mode ``fifo_sim`` + §VI throughput model + M20K
    accounting, never worse than the seed and deterministic per seed.

``repro.core.build_pipeline_plan`` remains as a deprecation shim over
``plan_pipeline(cfg, NX2100.replace(**kwargs))`` — stages 1-3 only,
preserving pre-compiler placements verbatim; ``compile()`` adds engine
binding and VMEM validation on top.
"""
from repro.compiler.autotune import (AutotuneConfig,  # noqa: F401
                                     AutotuneError, AutotuneResult,
                                     Candidate, Evaluation, autotune_plan,
                                     solve_serving_credits)
from repro.compiler.engines import (EngineContext, LayerEngine,  # noqa: F401
                                    LayerExecStats, get_engine,
                                    register_engine, registered_engines,
                                    select_block_engine, select_engine,
                                    select_scan_engine, select_stem_engine,
                                    unregister_engine)
from repro.compiler.partition import (PartitionError,  # noqa: F401
                                      StagePartition, StageProgram,
                                      partition_pipeline, stage_forward_fns)
from repro.compiler.pipeline import (BlockAssignment,  # noqa: F401
                                     CompileError, CompiledPipeline,
                                     EngineAssignment, Eq2MismatchError,
                                     ExecutionReport, FusedTrace,
                                     ScanGroupAssignment,
                                     TargetBudgetError, compile,
                                     count_jaxpr_eqns, finalize,
                                     make_dispatchers, plan_pipeline,
                                     trace_fused, trace_fused_abstract)
from repro.compiler.target import (DEFAULT_VMEM_BYTES, NX2100,  # noqa: F401
                                   PRESETS, TPU_INTERPRET, Target,
                                   get_target)
