"""Search-based placement + FIFO co-optimization over the greedy Alg. 1 seed.

Eq. 1 / Algorithm 1 is a greedy heuristic: it scores each layer once and
offloads down the score order until the on-chip remainder fits.  That
ignores every interaction the real pipeline has — which streamed layers
share the prefetcher, how deep the burst-matching FIFOs are, what burst
length the HBM controller runs at — all of which move the §V-A stall
count and the on-chip M20K bill without changing Eq. 1's ranking.  Since
the burst-aggregated credit-mode :mod:`repro.core.fifo_sim` evaluates a
full-net word stream in well under a second, it is now a viable
inner-loop cost model, and this module searches where Algorithm 1
guessed ("Memory-Efficient Dataflow Inference for Deep CNNs on FPGA" is
the reference point for buffer-minimizing placement; HPIPE's balancing
pass still supplies the per-layer parallelism and the greedy plan seeds
the search).

The search space (one :class:`Candidate`) is joint over

  * the **offload set** — which streamable layers hold the HBM tier;
  * the **burst length** — §III-A efficiency/latency both move with it;
  * the **burst-matching FIFO depth** — the per-layer credit pool of the
    §V-A flow control: deeper = fewer tail stalls, more M20Ks;
  * the **last-stage FIFO depth** — hard-bounded below by the §IV-A
    latency-covering minimum for the candidate burst; pure M20K cost in
    the deterministic cost model (it exists to absorb latency *jitter*,
    which the fixed-latency sim abstracts away), so the search keeps it
    at the floor unless a burst move shifts the floor itself.

Serving credits are co-optimized after the plan search: the §V-A credit
law (`repro.core.admission.replay_schedule`) is swept downward to the
smallest in-flight bound that still saturates the dispatch pipeline, and
``CompiledPipeline.serve()`` picks that bound up as its default.

Hard constraints (a candidate violating any is infeasible, never
objective-traded):

  * tensor blocks — untouched: parallelism comes from the stage-1
    allocation under ``target.tb_budget`` and is never re-opened here;
  * ``target.chain_budget`` — offloaded ``p_i*p_o`` chain feeds within
    the pseudo-channel pool (Alg. 1's own feasibility rule);
  * ``target.bram_m20ks`` — activations + pinned weights + FIFO plumbing
    at the *candidate's* depths (``hbm_model.fifo_m20k_cost``).  When the
    greedy seed itself overflows the budget (it gives up once every
    positive-score layer streams), the bound relaxes to the seed's own
    footprint: the tuned plan may never be *worse* than the seed;
  * ``target.vmem_bytes`` — every layer's engine working set in its
    candidate tier (same allowance relaxation as BRAM);
  * modelled throughput — the §VI model may never drop below the seed's
    images/s: stalls and BRAM are only ever bought at equal-or-better
    throughput.

The objective is the seed-normalized sum of credit-mode tail-engine
stall cycles and on-chip M20Ks; the optimizer (simulated annealing, or
plain hill-climbing with ``strategy="greedy"``) is deterministic under a
fixed ``AutotuneConfig.seed``, and the returned plan is the best
*feasible* candidate ever visited — the seed is visited first, so the
result is never worse than greedy on the objective.

Entry points: :func:`autotune_plan` for the raw search, or
``compiler.compile(cfg, target, autotune=AutotuneConfig(...))`` to get a
normal, fully validated :class:`CompiledPipeline` whose plan still
passes ``eq2_report().verify()``.
"""
from __future__ import annotations

import dataclasses
import math
import random
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.compiler.engines import select_engine
from repro.compiler.target import Target
from repro.configs.cnn import CNNConfig
from repro.core import admission, fifo_sim, hbm_model, placement
from repro.core.placement import CHAIN_BITS, M20K_BITS, LayerPlan
from repro.core.schedule import HBM, PINNED, LayerSchedule, PipelinePlan

BURSTS = (4, 8, 16, 32)               # §III-A characterized burst lengths


@dataclass(frozen=True)
class AutotuneConfig:
    """Search knobs — everything the co-optimizer may vary and how long
    it looks.  Deterministic per ``seed``."""

    seed: int = 0
    iterations: int = 400             # proposal steps (evals are cached)
    strategy: str = "anneal"          # "anneal" | "greedy" (hill-climb)
    initial_temp: float = 0.25        # in seed-normalized objective units
    outputs_needed: int = 32          # fifo_sim stream length per eval
    word_scale: Optional[int] = None  # None -> fixed once from the config
    max_bm_words: int = 256           # burst-matching FIFO ceiling (words)
    max_laststage_mult: int = 4       # last-stage ceiling, x the §IV-A min
    serving_latency_ticks: int = 3    # dispatch depth for the credit sweep
    max_serving_credits: int = 16

    def __post_init__(self):
        if self.strategy not in ("anneal", "greedy"):
            raise ValueError(f"unknown strategy {self.strategy!r}")
        if self.iterations < 0:
            raise ValueError("iterations must be >= 0")


@dataclass(frozen=True)
class Candidate:
    """One point of the joint search space."""

    offload: Tuple[str, ...]          # sorted streamed-layer names
    burst: int
    bm_words: int                     # burst-matching FIFO depth (words)
    laststage: int                    # last-stage FIFO depth (words)


@dataclass(frozen=True)
class Evaluation:
    """The cost model's verdict on one candidate."""

    feasible: bool
    violations: Tuple[str, ...] = ()
    stall_cycles: int = 0             # credit-mode tail-engine stalls
    sim_cycles: int = 0
    onchip_m20ks: int = 0
    images_per_s: float = 0.0         # §VI throughput model
    hbm_words_per_image: int = 0      # Eq. 2 total over the streamed set
    objective: float = math.inf       # seed-normalized stall + M20K sum


@dataclass(frozen=True)
class AutotuneResult:
    """The search outcome: tuned vs greedy, plus the plan to compile."""

    cfg_name: str
    target_name: str
    search: AutotuneConfig
    candidate: Candidate
    seed_candidate: Candidate
    tuned: Evaluation
    greedy: Evaluation
    plan: PipelinePlan                # the tuned, executable plan
    serving_credits: int              # smallest saturating §V-A bound
    evaluations: int = 0
    accepted_moves: int = 0
    word_scale: int = 1
    #: per-feasible-evaluation search trajectory, ``(iteration,
    #: objective, best_objective)`` with the greedy seed at iteration 0 —
    #: the observability record of *how* the annealer got to ``tuned``
    #: (plotted/asserted without re-running the search).
    objective_trace: Tuple[Tuple[int, float, float], ...] = ()

    @property
    def improved(self) -> bool:
        """Strictly better than greedy on stalls or M20Ks (the bench
        acceptance bar; throughput parity is a feasibility constraint,
        so it never needs re-checking here)."""
        return (self.tuned.stall_cycles < self.greedy.stall_cycles
                or self.tuned.onchip_m20ks < self.greedy.onchip_m20ks)

    def summary(self) -> Dict[str, object]:
        """JSON-ready greedy-vs-tuned record (the BENCH artifact row)."""
        return {
            "net": self.cfg_name,
            "target": self.target_name,
            "seed": self.search.seed,
            "iterations": self.search.iterations,
            "evaluations": self.evaluations,
            "accepted_moves": self.accepted_moves,
            "word_scale": self.word_scale,
            "outputs_needed": self.search.outputs_needed,
            "greedy_streamed": len(self.seed_candidate.offload),
            "greedy_stall_cycles": self.greedy.stall_cycles,
            "greedy_m20ks": self.greedy.onchip_m20ks,
            "greedy_images_per_s": round(self.greedy.images_per_s, 1),
            "greedy_hbm_words_per_image": self.greedy.hbm_words_per_image,
            "tuned_streamed": len(self.candidate.offload),
            "tuned_stall_cycles": self.tuned.stall_cycles,
            "tuned_m20ks": self.tuned.onchip_m20ks,
            "tuned_images_per_s": round(self.tuned.images_per_s, 1),
            "tuned_hbm_words_per_image": self.tuned.hbm_words_per_image,
            "tuned_burst": self.candidate.burst,
            "tuned_bm_words": self.candidate.bm_words,
            "tuned_laststage": self.candidate.laststage,
            "tuned_objective": round(self.tuned.objective, 4),
            "greedy_objective": round(self.greedy.objective, 4),
            "serving_credits": self.serving_credits,
            "improved": self.improved,
        }


class AutotuneError(ValueError):
    """The search could not produce a feasible plan for the target."""


# ---------------------------------------------------------------------------
# the cost model
# ---------------------------------------------------------------------------


class _CostModel:
    """Evaluates candidates against one (config, target) pair.

    Everything a candidate shares — the stage-1 parallelism, the engine
    bindings, the activation M20Ks, the fifo_sim ``word_scale`` — is
    computed once here; evaluations are cached per candidate so the
    annealer revisiting a state costs a dict lookup."""

    def __init__(self, cfg: CNNConfig, target: Target, at: AutotuneConfig):
        self.cfg = cfg
        self.target = target
        self.at = at
        self.base: List[LayerPlan] = placement.allocate_parallelism(
            cfg, target.tb_budget)
        self.engines = {p.spec.name: select_engine(p.spec) for p in self.base}
        self.act_m20ks = sum(
            -(-p.spec.activation_window_bits(8) // M20K_BITS)
            for p in self.base)
        # layers the search may flip: weight-bearing, streamable engines
        self.streamable = tuple(
            p.spec.name for p in self.base
            if not p.spec.is_pool
            and -(-p.spec.weight_bits(8) // CHAIN_BITS) > 0
            and getattr(self.engines[p.spec.name], "can_stream", True))
        # one word_scale for EVERY candidate: stall counts are only
        # comparable across plans when they divide word demands alike
        wpr = [-(-p.spec.weight_bits(8) // CHAIN_BITS) for p in self.base
               if not p.spec.is_pool]
        self.word_scale = at.word_scale or max(1, max(wpr, default=1) // 64)

        # the greedy Alg. 1 seed (hybrid selection copies, so self.base
        # stays pristine for every later candidate build)
        seeded = placement.hybrid_selection(
            self.base, target.bram_m20ks, n_pc=target.n_pc,
            burst=target.burst)
        self.seed_candidate = Candidate(
            offload=tuple(sorted(p.spec.name for p in seeded if p.offload)),
            burst=target.burst,
            bm_words=hbm_model.burst_matching_fifo_words(target.burst),
            laststage=hbm_model.min_laststage_fifo_depth(target.burst))

        self._cache: Dict[Candidate, Evaluation] = {}
        self.evaluations = 0

        # seed references: evaluated without the vs-seed constraints,
        # then used to normalize/bound every other candidate
        self._seed_eval: Optional[Evaluation] = None
        self._seed_eval = self.evaluate(self.seed_candidate)

    # -- plan construction --------------------------------------------------

    def build_plan(self, cand: Candidate) -> PipelinePlan:
        """The executable plan a candidate denotes — same shape as
        ``compiler.plan_pipeline`` output, with the tuned knobs in the
        schedules so ``sim_config``/M20K accounting see them."""
        offload = set(cand.offload)
        plans = []
        for p in self.base:
            q = dataclasses.replace(p)
            q.offload = p.spec.name in offload
            q.pc = None
            plans.append(q)
        placement.assign_pseudo_channels(plans, n_pc=self.target.n_pc)
        schedules = tuple(
            LayerSchedule(
                spec=q.spec,
                mode=HBM if q.offload else PINNED,
                p_i=q.p_i, p_o=q.p_o, pc=q.pc,
                burst=cand.burst,
                laststage_fifo_depth=cand.laststage,
                bm_fifo_words=cand.bm_words,
                n_buffers=self.target.n_buffers,
            ) for q in plans)
        return PipelinePlan(cfg=self.cfg, schedules=schedules,
                            placements=tuple(plans), burst=cand.burst,
                            n_pc=self.target.n_pc)

    # -- accounting ---------------------------------------------------------

    def onchip_m20ks(self, cand: Candidate, plan: PipelinePlan) -> int:
        """Hybrid selection's BRAM bill at the candidate's FIFO depths."""
        total = self.act_m20ks
        fifo = hbm_model.fifo_m20k_cost(cand.burst, cand.laststage,
                                        cand.bm_words)
        for p in plan.placements:
            if p.offload:
                total += fifo * -(-p.spec.out_w // 18)
            else:
                total += p.weight_m20ks
        return total

    def _stalls(self, plan: PipelinePlan) -> Tuple[int, int]:
        streamed = [s for s in plan.streamed if s.weight_words_per_row > 0]
        if not streamed:
            return 0, 0
        sim_cfg, _ = plan.sim_config(self.at.outputs_needed,
                                     word_scale=self.word_scale)
        out = fifo_sim.simulate(sim_cfg, "credit")
        return out.stall_cycles, out.cycles

    # -- evaluation ---------------------------------------------------------

    def evaluate(self, cand: Candidate) -> Evaluation:
        hit = self._cache.get(cand)
        if hit is not None:
            return hit
        self.evaluations += 1
        ev = self._evaluate(cand)
        self._cache[cand] = ev
        return ev

    def _evaluate(self, cand: Candidate) -> Evaluation:
        seed = self._seed_eval            # None only for the seed itself
        violations: List[str] = []

        unknown = [n for n in cand.offload if n not in self.streamable]
        if unknown:
            violations.append(f"unstreamable layer(s) {unknown}")
        if cand.burst not in BURSTS:
            violations.append(f"uncharacterized burst {cand.burst}")
        if cand.bm_words < cand.burst:
            violations.append(
                f"bm_words {cand.bm_words} < burst {cand.burst} "
                f"(prefetcher could never issue)")
        min_ls = hbm_model.min_laststage_fifo_depth(cand.burst)
        if cand.laststage < min_ls:
            violations.append(
                f"laststage {cand.laststage} below the §IV-A "
                f"latency-covering minimum {min_ls} for burst {cand.burst}")
        if violations:
            return Evaluation(feasible=False, violations=tuple(violations))

        plan = self.build_plan(cand)
        chains = sum(p.chains for p in plan.placements if p.offload)
        if chains > self.target.chain_budget:
            violations.append(
                f"{chains} chain feeds exceed the pseudo-channel pool "
                f"{self.target.chain_budget}")

        m20ks = self.onchip_m20ks(cand, plan)
        # the seed sets the BRAM allowance when it overflows the target:
        # hybrid selection legitimately exceeds small budgets once every
        # positive-score layer already streams, and "never worse than the
        # seed" is the contract the search enforces from there
        bram_allow = max(self.target.bram_m20ks,
                         m20ks if seed is None else seed.onchip_m20ks)
        if m20ks > bram_allow:
            violations.append(
                f"{m20ks} on-chip M20Ks exceed the allowance {bram_allow}")

        for s in plan.schedules:
            vb = self.engines[s.spec.name].vmem_bytes(s.spec, s)
            if vb > self.target.vmem_bytes:
                violations.append(
                    f"{s.spec.name}: {vb} B exceeds the per-engine VMEM "
                    f"budget {self.target.vmem_bytes}")

        thr = plan.throughput()["images_per_s"]
        if seed is not None and thr < seed.images_per_s * (1 - 1e-9):
            violations.append(
                f"modelled {thr:.1f} images/s below the greedy seed's "
                f"{seed.images_per_s:.1f}")

        stall, cycles = self._stalls(plan)
        words = sum(plan.hbm_words_per_image().values())
        stall_ref = max(1, stall if seed is None else seed.stall_cycles)
        m20k_ref = max(1, m20ks if seed is None else seed.onchip_m20ks)
        return Evaluation(
            feasible=not violations,
            violations=tuple(violations),
            stall_cycles=stall,
            sim_cycles=cycles,
            onchip_m20ks=m20ks,
            images_per_s=thr,
            hbm_words_per_image=words,
            objective=stall / stall_ref + m20ks / m20k_ref,
        )

    # -- move proposal ------------------------------------------------------

    def propose(self, rng: random.Random, cand: Candidate) -> Candidate:
        """One neighbor: flip a layer's tier, step the burst, or resize a
        FIFO.  Knobs are re-clamped so a burst move keeps the candidate
        structurally valid (bm >= burst, laststage >= its new minimum)."""
        moves: List[Tuple[str, object]] = [("flip", n)
                                           for n in self.streamable]
        bi = BURSTS.index(cand.burst)
        if bi > 0:
            moves.append(("burst", BURSTS[bi - 1]))
        if bi < len(BURSTS) - 1:
            moves.append(("burst", BURSTS[bi + 1]))
        if cand.bm_words * 2 <= self.at.max_bm_words:
            moves.append(("bm", cand.bm_words * 2))
        if cand.bm_words // 2 >= cand.burst:
            moves.append(("bm", cand.bm_words // 2))
        min_ls = hbm_model.min_laststage_fifo_depth(cand.burst)
        if cand.laststage * 2 <= self.at.max_laststage_mult * min_ls:
            moves.append(("laststage", cand.laststage * 2))
        if cand.laststage // 2 >= min_ls:
            moves.append(("laststage", cand.laststage // 2))

        kind, val = moves[rng.randrange(len(moves))]
        if kind == "flip":
            offload = set(cand.offload)
            offload.symmetric_difference_update({val})
            return dataclasses.replace(cand, offload=tuple(sorted(offload)))
        if kind == "burst":
            burst = int(val)
            return dataclasses.replace(
                cand, burst=burst,
                bm_words=max(cand.bm_words, burst),
                laststage=max(cand.laststage,
                              hbm_model.min_laststage_fifo_depth(burst)))
        if kind == "bm":
            return dataclasses.replace(cand, bm_words=int(val))
        return dataclasses.replace(cand, laststage=int(val))


# ---------------------------------------------------------------------------
# serving-credit co-optimization (§V-A on the dispatch pipeline)
# ---------------------------------------------------------------------------


def solve_serving_credits(latency_ticks: int, *, items: int = 64,
                          max_credits: int = 16) -> int:
    """The smallest in-flight bound that still saturates a dispatch
    pipeline of ``latency_ticks`` depth, by replaying the §V-A credit
    law itself (``admission.replay_schedule``) rather than trusting the
    closed form: makespan is non-increasing in credits, so walk down
    from ``max_credits`` while the saturated makespan holds."""
    if latency_ticks < 0:
        raise ValueError("latency_ticks must be >= 0")
    best = max_credits
    saturated = None
    for c in range(max_credits, 0, -1):
        tr = admission.replay_schedule(items, capacity=c,
                                       latency_ticks=latency_ticks)
        if saturated is None:
            saturated = tr.makespan
        if tr.makespan == saturated:
            best = c
        else:
            break
    return best


# ---------------------------------------------------------------------------
# the search
# ---------------------------------------------------------------------------


def autotune_plan(cfg: CNNConfig, target: Target,
                  at: AutotuneConfig = AutotuneConfig()) -> AutotuneResult:
    """Run the co-optimization and return the best feasible plan found.

    Deterministic per ``at.seed``; the greedy Alg. 1 seed is the first
    candidate visited, so the result is never worse than greedy on the
    objective.  Raises :class:`AutotuneError` when not even the seed is
    feasible (a target whose budgets reject every plan should go through
    plain ``compile()`` to get the full :class:`TargetBudgetError`
    diagnosis instead)."""
    model = _CostModel(cfg, target, at)
    rng = random.Random(at.seed)

    cur = model.seed_candidate
    cur_ev = model.evaluate(cur)
    if not cur_ev.feasible:
        raise AutotuneError(
            f"greedy seed for {cfg.name!r} on {target.name!r} is "
            f"infeasible: {'; '.join(cur_ev.violations)}")
    best, best_ev = cur, cur_ev
    accepted = 0
    trace = [(0, cur_ev.objective, best_ev.objective)]

    for i in range(at.iterations):
        cand = model.propose(rng, cur)
        ev = model.evaluate(cand)
        if not ev.feasible:
            continue
        delta = ev.objective - cur_ev.objective
        if at.strategy == "greedy":
            take = delta < 0
        else:
            temp = max(1e-6, at.initial_temp
                       * (1.0 - i / max(1, at.iterations)))
            take = delta <= 0 or rng.random() < math.exp(-delta / temp)
        if take:
            cur, cur_ev = cand, ev
            accepted += 1
            if ev.objective < best_ev.objective:
                best, best_ev = cand, ev
        trace.append((i + 1, ev.objective, best_ev.objective))

    return AutotuneResult(
        cfg_name=cfg.name,
        target_name=target.name,
        search=at,
        candidate=best,
        seed_candidate=model.seed_candidate,
        tuned=best_ev,
        greedy=model._seed_eval,
        plan=model.build_plan(best),
        serving_credits=solve_serving_credits(
            at.serving_latency_ticks, max_credits=at.max_serving_credits),
        evaluations=model.evaluations,
        accepted_moves=accepted,
        word_scale=model.word_scale,
        objective_trace=tuple(trace),
    )
