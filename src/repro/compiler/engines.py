"""LayerEngine protocol + registry — the compiler's extension surface.

H2PIPE emits *layer-specific* hardware: every layer gets its own engine,
chosen by what the layer is (dense conv, depthwise conv, fc head) and
where its weights live (pinned M20K vs HBM-streamed).  This module is the
software analogue: a :class:`LayerEngine` wraps one Pallas kernel family
and declares

  * ``supports(spec)``            which :class:`ConvLayerSpec` shapes it
                                  can run (checked at *compile* time — no
                                  more discovering fallbacks at dispatch);
  * ``vmem_bytes(spec, sched)``   the working set one dispatch claims, so
                                  ``compile()`` can validate every layer
                                  against the Target's VMEM budget and
                                  re-place (pin -> stream) the ones that
                                  do not fit;
  * ``run(ctx, sched, params, x, relu)``
                                  the actual dispatch.  ``ctx`` is a
                                  per-execution :class:`EngineContext`
                                  (interpret flag, activation scale) —
                                  engines hold NO mutable state and
                                  RETURN their :class:`LayerExecStats`
                                  instead of mutating a sink, so a run
                                  can be traced into one jitted program
                                  (stats are shape-static metadata the
                                  executor aggregates post-hoc) and one
                                  compiled pipeline can serve concurrent
                                  requests.

Engines register under a short name with :func:`register_engine`; the
compiler picks, per layer, the highest-priority registered engine whose
``supports`` accepts the spec.  Registering your own engine (a sparse
conv, a Winograd path, an FPGA RTL emitter...) requires no edits here:

    @register_engine("myconv", priority=20)
    class MyConvEngine:
        def supports(self, spec): ...
        def vmem_bytes(self, spec, sched): ...
        def run(self, ctx, sched, params, x, relu): ...

Block engines (``is_block = True``) bind a whole :class:`ResBlockSpec`
instead of one layer: ``supports``/``vmem_bytes``/``run`` take the block
(and the member schedules), and the compiler emits one schedulable unit
for the group — ``res_block_int8`` fuses a residual block's conv chain,
downsample, add and relu the way the paper places whole engines.

Built-in engines: ``conv2d_int8`` (dense/pointwise conv + big fc-as-conv
heads), ``dwconv_int8`` (grouped depthwise — the MobileNet path),
``stream_matmul`` (1x1 fc heads), ``maxpool_int8`` / ``global_avgpool_int8``
(the weightless pooling topology nodes — line-buffer comparators and
channel accumulators, never streamed, zero Eq. 2 words), ``res_block_int8``
(fused residual blocks — basic AND bottleneck), ``jnp_ref`` (XLA
reference, priority 0 safety net).

Every engine also exposes ``stats(sched, batch)`` — the shape-static
:class:`LayerExecStats` a dispatch of that schedule WILL return, without
executing anything.  ``CompiledPipeline.stats_template`` assembles these
into the full-net Eq. 2 template the plan-vs-executed cross-check hard-
fails against.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import (Any, Dict, List, Optional, Protocol, Sequence, Tuple,
                    runtime_checkable)

import jax
import jax.numpy as jnp

from repro.configs.cnn import (POOL_KINDS, ConvLayerSpec, ResBlockSpec,
                               StemUnitSpec)
from repro.core.schedule import HBM, PINNED, LayerSchedule
from repro.kernels.conv2d_int8.ops import conv2d_int8, same_padded_width
from repro.kernels.pool_int8.ops import global_avgpool_int8, maxpool_int8
from repro.kernels.quant import requant_epilogue
from repro.kernels.stream_matmul import ops as sm_ops

Params = Dict[str, Any]

# the ONE dequant+bias+relu+requant epilogue (kernels/quant.py), jitted so
# its float ops compile exactly like the reference path's
_requant = functools.partial(jax.jit, static_argnames=("act_scale", "relu"))(
    requant_epilogue)


@functools.lru_cache(maxsize=None)
def _block(n: int, cap: int) -> int:
    """Largest divisor of n not exceeding cap (Pallas block sizing).
    Cached: compile() probes this from every ``supports``/``vmem_bytes``
    call, and the divisor scan is linear in n."""
    for b in range(min(n, cap), 0, -1):
        if n % b == 0:
            return b
    return 1


def _padded_width(spec: ConvLayerSpec) -> int:
    """SAME-padded input width (what the line buffer actually holds) —
    from the kernel module's own padding formula, so validation and
    allocation cannot drift apart."""
    return same_padded_width(spec.in_w, spec.k_w, spec.stride)


# ---------------------------------------------------------------------------
# execution context + per-dispatch stats
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerExecStats:
    """What one layer dispatch did (which engine, which tier, Eq. 2 words).

    Frozen and shape-static: engines *return* these alongside their
    arrays (every field derives from the schedule and the input shape,
    never from array values), so collecting them works identically under
    eager per-layer dispatch and under the whole-pipeline jit trace —
    one trace yields the stats template every warm run reuses."""

    name: str
    mode: str                     # "pinned" | "hbm"
    kernel: str                   # engine name that actually ran
    hbm_words: int = 0            # Eq. 2 words streamed for this dispatch

    @classmethod
    def for_dispatch(cls, sched: LayerSchedule, *, kernel: str, batch: int,
                     rows: int = 0, mode: Optional[str] = None
                     ) -> "LayerExecStats":
        mode = sched.mode if mode is None else mode
        words = 0
        if mode == HBM and batch:
            # Eq. 2 accounting: kernels re-read once per output row, per
            # image.  (On TPU the matmul amortizes the batch dim; the
            # paper's accelerator is batch-1, so we report paper units.)
            words = sched.weight_words_per_row * rows * batch
        return cls(name=sched.spec.name, mode=mode, kernel=kernel,
                   hbm_words=words)


@dataclass(frozen=True)
class EngineContext:
    """Per-execution configuration threaded through every engine call.

    Frozen and side-effect free: engines read the interpret flag and the
    activation scale from it and return everything they produce —
    including :class:`LayerExecStats` — so one context can sit inside a
    jit trace, and concurrent executions of one compiled pipeline cannot
    corrupt each other's reports (the re-entrancy contract batched
    serving builds on)."""

    interpret: bool
    act_scale: float


# ---------------------------------------------------------------------------
# the protocol + registry
# ---------------------------------------------------------------------------


@runtime_checkable
class LayerEngine(Protocol):
    """One layer-engine family the compiler can instantiate.

    Engines may additionally declare ``can_stream = False`` (default
    True) when they cannot source weights from the HBM tier; stage 5
    keeps such bindings pinned so plan analytics never charge Eq. 2
    traffic an engine will not execute.

    Engines declaring ``is_block = True`` bind a whole
    :class:`ResBlockSpec` instead of one layer; their methods take the
    block (and a tuple of member schedules, in ``block.members`` order)
    and ``run`` returns ``(int8 activations, per-member stats tuple)``.
    """

    name: str

    def supports(self, spec: ConvLayerSpec) -> bool:
        """Can this engine execute the layer (decided at compile time)?"""
        ...

    def vmem_bytes(self, spec: ConvLayerSpec, sched: LayerSchedule) -> int:
        """Working-set bytes one dispatch claims (batch-1 convention)."""
        ...

    def stats(self, sched: LayerSchedule, batch: int) -> LayerExecStats:
        """The shape-static stats one dispatch of ``sched`` WILL return,
        without executing — the template the plan-vs-executed Eq. 2
        cross-check (``CompiledPipeline.stats_template``) is built from.
        Must equal what ``run`` returns for the same schedule/batch."""
        ...

    def run(self, ctx: EngineContext, sched: LayerSchedule, params: Params,
            x: jnp.ndarray, relu: bool
            ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray], LayerExecStats]:
        """Execute the layer; returns (int8 activations, float pre-quant,
        dispatch stats).  Stats are shape-static — safe under a trace."""
        ...


# name -> stack of (priority, insertion_seq, engine); the TOP of each
# stack is live.  Re-registering a name pushes (shadowing the previous
# engine), unregistering pops (restoring it) — so overrides of built-ins
# round-trip without touching this module.  Selection order over the live
# engines is priority DESC then insertion order.
_REGISTRY: Dict[str, List[Tuple[int, int, LayerEngine]]] = {}
_SEQ = 0


def register_engine(name: str, *, priority: int = 10):
    """Class decorator: instantiate and register a LayerEngine under
    ``name``.  Registering an existing name shadows the previous engine
    (how tests/users override a built-in); :func:`unregister_engine`
    pops the override and restores what it shadowed."""
    def deco(cls):
        global _SEQ
        engine = cls() if isinstance(cls, type) else cls
        engine.name = name
        _SEQ += 1
        _REGISTRY.setdefault(name, []).append((priority, _SEQ, engine))
        return cls
    return deco


def unregister_engine(name: str) -> Optional[LayerEngine]:
    """Pop the live engine for ``name`` (restoring any engine it
    shadowed); returns it, or None if the name is unknown."""
    stack = _REGISTRY.get(name)
    if not stack:
        return None
    _, _, engine = stack.pop()
    if not stack:
        del _REGISTRY[name]
    return engine


def get_engine(name: str) -> LayerEngine:
    try:
        return _REGISTRY[name][-1][2]
    except KeyError:
        raise KeyError(f"no engine registered under {name!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None


def registered_engines() -> Dict[str, LayerEngine]:
    """Live registered engines in selection order (priority DESC, age)."""
    tops = {name: stack[-1] for name, stack in _REGISTRY.items()}
    items = sorted(tops.items(), key=lambda kv: (-kv[1][0], kv[1][1]))
    return {name: eng for name, (_, _, eng) in items}


def select_engine(spec: ConvLayerSpec) -> LayerEngine:
    """The compile-time choice: highest-priority engine claiming the spec.
    Unit-granular engines (``is_block`` / ``is_scan`` / ``is_stem``) bind
    groups, not layers — skipped here."""
    for eng in registered_engines().values():
        if (getattr(eng, "is_block", False) or getattr(eng, "is_scan", False)
                or getattr(eng, "is_stem", False)):
            continue
        if eng.supports(spec):
            return eng
    raise LookupError(f"no registered engine supports layer {spec.name!r} "
                      f"(kind={spec.kind!r})")


def select_block_engine(block: ResBlockSpec) -> Optional[LayerEngine]:
    """Highest-priority *block* engine claiming the residual block, or
    None — in which case the block's layers keep their per-layer
    bindings (the always-valid fallback)."""
    for eng in registered_engines().values():
        if getattr(eng, "is_block", False) and eng.supports(block):
            return eng
    return None


def select_scan_engine(blocks: Sequence[ResBlockSpec]
                       ) -> Optional[LayerEngine]:
    """Highest-priority *scan* engine (``is_scan = True``) claiming a
    homogeneous run of residual blocks, or None — the run's blocks then
    keep their per-block (or per-layer) bindings."""
    for eng in registered_engines().values():
        if getattr(eng, "is_scan", False) and eng.supports(blocks):
            return eng
    return None


def select_stem_engine(unit: StemUnitSpec) -> Optional[LayerEngine]:
    """Highest-priority *stem* engine (``is_stem = True``) claiming the
    stem conv + maxpool unit, or None — the stem layers then keep their
    per-layer bindings."""
    for eng in registered_engines().values():
        if getattr(eng, "is_stem", False) and eng.supports(unit):
            return eng
    return None


def _is_1x1_fc(spec: ConvLayerSpec) -> bool:
    """fc heads that run as a [B, c_in] x [c_in, c_out] matmul: 1x1 kernel
    on a 1x1 (pooled) map.  Big fc-as-conv heads (VGG's 7x7 fc0) keep the
    conv engine."""
    return (spec.kind == "fc" and spec.k_h == 1 and spec.k_w == 1
            and spec.in_h == 1 and spec.in_w == 1)


def _fc_conv_is_valid_equivalent(spec: ConvLayerSpec) -> bool:
    """The reference applies fc layers with VALID padding while the conv
    engine SAME-pads, so the conv engine may only claim fc-as-conv heads
    whose SAME padding computes to zero in both dims (then SAME == VALID
    bit-for-bit — e.g. VGG's fc0: 7x7 kernel on a 7x7 map, stride 7).
    Anything else binds to the explicit jnp_ref engine instead of
    executing with the wrong padding."""
    return (same_padded_width(spec.in_h, spec.k_h, spec.stride) == spec.in_h
            and same_padded_width(spec.in_w, spec.k_w, spec.stride)
            == spec.in_w)


# ---------------------------------------------------------------------------
# built-in engines
# ---------------------------------------------------------------------------


@register_engine("conv2d_int8", priority=10)
class Conv2DInt8Engine:
    """The line-buffer conv Pallas kernel as an engine; weights pinned in
    VMEM or streamed through the n_buffers-deep HBM ring per the
    schedule.  ``depthwise=False`` covers dense/pointwise convs (and
    fc-as-conv heads); the ``depthwise=True`` instance (registered as
    ``dwconv_int8``) is the grouped MobileNet path, where each channel
    MACs against its own [k_h, k_w] filter — elementwise VPU taps instead
    of MXU dots, [1, C] ring slots instead of [C, C_out]."""

    def __init__(self, depthwise: bool = False):
        self.depthwise = depthwise

    def supports(self, spec: ConvLayerSpec) -> bool:
        if self.depthwise:
            return spec.kind == "dwconv"
        return spec.kind in ("conv", "pwconv") or (
            spec.kind == "fc" and not _is_1x1_fc(spec)
            and _fc_conv_is_valid_equivalent(spec))

    def vmem_bytes(self, spec: ConvLayerSpec, sched: LayerSchedule) -> int:
        # channel factors of one weight tap: [1, C] depthwise, [C, C_out]
        # dense.  Widths use the kernel's SAME-pad ceil, not spec's floor.
        tap_in = 1 if self.depthwise else spec.c_in
        c_out = spec.c_in if self.depthwise else spec.c_out
        out_w = spec.out_w                  # SAME ceil, == kernel output
        line_buf = spec.k_h * _padded_width(spec) * spec.c_in      # int8
        if sched.streamed:
            w = min(sched.n_buffers, spec.k_h * spec.k_w) \
                * tap_in * c_out                                   # ring
        else:
            w = spec.k_h * spec.k_w * tap_in * c_out               # pinned
        out_row = out_w * c_out * 4                                # int32
        return line_buf + w + 2 * out_row                          # + acc

    def stats(self, sched: LayerSchedule, batch: int) -> LayerExecStats:
        """The shape-static stats one dispatch returns: the kernel emits
        ``spec.out_h`` SAME-padded output rows per image (out_h is the
        ceil the kernels produce, so template == executed == plan)."""
        return LayerExecStats.for_dispatch(sched, kernel=self.name,
                                           batch=batch,
                                           rows=sched.spec.out_h)

    def run(self, ctx: EngineContext, sched: LayerSchedule, params: Params,
            x, relu: bool):
        spec = sched.spec
        y = conv2d_int8(x, params["w"], stride=spec.stride,
                        stream=sched.streamed, n_buffers=sched.n_buffers,
                        depthwise=self.depthwise, interpret=ctx.interpret)
        y_q, y_f = _requant(y, params["w_scale"], params["bias"],
                            act_scale=ctx.act_scale, relu=relu)
        stats = LayerExecStats.for_dispatch(
            sched, kernel=self.name, batch=int(x.shape[0]),
            rows=int(y.shape[1]))
        return y_q, y_f, stats


# the grouped depthwise path is the same engine with the flag flipped
register_engine("dwconv_int8", priority=10)(Conv2DInt8Engine(depthwise=True))


@register_engine("stream_matmul", priority=10)
class StreamMatmulFCEngine:
    """1x1 fc heads as a streamed matmul: ``pinned`` mode keeps W resident
    in VMEM for the call, ``fifo`` prefetches K-blocks through an explicit
    credit ring — the same two weight tiers, matmul-shaped."""

    BM, BK, BN = 128, 512, 128

    def supports(self, spec: ConvLayerSpec) -> bool:
        return _is_1x1_fc(spec)

    def vmem_bytes(self, spec: ConvLayerSpec, sched: LayerSchedule) -> int:
        mode = "fifo" if sched.streamed else "pinned"
        return sm_ops.vmem_bytes(
            mode, 1, spec.c_in, spec.c_out, 1,
            bm=1, bk=_block(spec.c_in, self.BK),
            bn=_block(spec.c_out, self.BN),
            n_buffers=max(2, sched.n_buffers))

    def stats(self, sched: LayerSchedule, batch: int) -> LayerExecStats:
        """One matmul dispatch == one output 'row' of weight reads."""
        return LayerExecStats.for_dispatch(sched, kernel=self.name,
                                           batch=batch, rows=1)

    def run(self, ctx: EngineContext, sched: LayerSchedule, params: Params,
            x, relu: bool):
        spec = sched.spec
        B = int(x.shape[0])
        c_in, c_out = spec.c_in, spec.c_out
        x2 = x.reshape(B, c_in)
        w2 = params["w"].reshape(c_in, c_out)
        mode = "fifo" if sched.streamed else "pinned"
        y = sm_ops.stream_matmul(x2, w2, mode=mode,
                                 bm=_block(B, self.BM),
                                 bk=_block(c_in, self.BK),
                                 bn=_block(c_out, self.BN),
                                 n_buffers=max(2, sched.n_buffers),
                                 interpret=ctx.interpret)
        y_q, y_f = _requant(y.reshape(B, 1, 1, c_out), params["w_scale"],
                            params["bias"], act_scale=ctx.act_scale,
                            relu=relu)
        stats = LayerExecStats.for_dispatch(sched, kernel=self.name,
                                            batch=B, rows=1)
        return y_q, y_f, stats


@register_engine("maxpool_int8", priority=10)
class MaxPoolInt8Engine:
    """The maxpool topology node as a first-class engine: a k_h-row line
    buffer feeding comparator trees (``kernels/pool_int8``) — the paper
    places a dedicated pooling engine per node exactly like a conv
    engine, just with zero weight memory.  Never streams (there are no
    weights to stream: ``can_stream = False``), Eq. 2 words are 0 by
    construction, and the VMEM claim is the real line buffer + the
    double-buffered output row."""

    can_stream = False

    def supports(self, spec: ConvLayerSpec) -> bool:
        return spec.kind == "maxpool"

    def vmem_bytes(self, spec: ConvLayerSpec, sched: LayerSchedule) -> int:
        line_buf = spec.k_h * _padded_width(spec) * spec.c_in      # int8
        out_row = spec.out_w * spec.c_in                           # int8
        return line_buf + 2 * out_row

    def stats(self, sched: LayerSchedule, batch: int) -> LayerExecStats:
        return LayerExecStats.for_dispatch(sched, kernel=self.name,
                                           batch=batch,
                                           rows=sched.spec.out_h,
                                           mode=PINNED)

    def run(self, ctx: EngineContext, sched: LayerSchedule, params: Params,
            x, relu: bool):
        spec = sched.spec
        y = maxpool_int8(x, k=spec.k_h, stride=spec.stride,
                         interpret=ctx.interpret)
        stats = LayerExecStats.for_dispatch(
            sched, kernel=self.name, batch=int(x.shape[0]),
            rows=int(y.shape[1]), mode=PINNED)
        return y, None, stats


@register_engine("global_avgpool_int8", priority=10)
class GlobalAvgPoolInt8Engine:
    """The global-average-pool node as an engine: per-channel int32
    accumulators + the activation requantizer (``kernels/pool_int8``).
    Weightless like maxpool (``can_stream = False``, zero Eq. 2 words);
    the VMEM claim is the resident spatial map the kernel reduces plus
    the accumulator bank and the 1x1 output row."""

    can_stream = False

    def supports(self, spec: ConvLayerSpec) -> bool:
        return spec.kind == "gap"

    def vmem_bytes(self, spec: ConvLayerSpec, sched: LayerSchedule) -> int:
        in_map = spec.in_h * spec.in_w * spec.c_in                 # int8
        acc = spec.c_in * 4                                        # int32
        return in_map + acc + 2 * spec.c_in

    def stats(self, sched: LayerSchedule, batch: int) -> LayerExecStats:
        return LayerExecStats.for_dispatch(sched, kernel=self.name,
                                           batch=batch, rows=1, mode=PINNED)

    def run(self, ctx: EngineContext, sched: LayerSchedule, params: Params,
            x, relu: bool):
        y = global_avgpool_int8(x, act_scale=ctx.act_scale,
                                interpret=ctx.interpret)
        stats = LayerExecStats.for_dispatch(
            sched, kernel=self.name, batch=int(x.shape[0]), rows=1,
            mode=PINNED)
        return y, None, stats


@register_engine("jnp_ref", priority=0)
class JnpReferenceEngine:
    """The XLA reference path as an explicit, lowest-priority engine: it
    supports every layer and claims no VMEM (XLA manages its own), so a
    layer only lands here when no Pallas engine claims it — and the
    engine table SAYS so at compile time instead of a silent dispatch
    fallback.  Streams nothing (``can_stream = False``: stage 5 pins any
    placement that lands here), and accounting records the pinned tier
    that actually ran.  Pool nodes route to the jnp pooling references
    (same numerics the Pallas pool engines are differential-tested
    against), everything else to ``conv_layer_forward``."""

    can_stream = False

    def supports(self, spec: ConvLayerSpec) -> bool:
        return True

    def vmem_bytes(self, spec: ConvLayerSpec, sched: LayerSchedule) -> int:
        return 0

    def stats(self, sched: LayerSchedule, batch: int) -> LayerExecStats:
        return LayerExecStats.for_dispatch(sched, kernel=self.name,
                                           batch=0, mode=PINNED)

    def run(self, ctx: EngineContext, sched: LayerSchedule, params: Params,
            x, relu: bool):
        from repro.models.cnn import conv_layer_forward, pool_forward
        spec = sched.spec
        stats = LayerExecStats.for_dispatch(sched, kernel=self.name,
                                            batch=0, mode=PINNED)
        if spec.kind in POOL_KINDS:
            return pool_forward(spec, x, act_scale=ctx.act_scale), None, stats
        y_q, y_f = conv_layer_forward(params, spec, x,
                                      act_scale=ctx.act_scale, relu=relu)
        return y_q, y_f, stats


@register_engine("res_block_int8", priority=10)
class ResBlockInt8Engine:
    """A whole residual block — conv chain, identity downsample, int32
    add, clip and relu — as ONE schedulable unit, the granularity the
    paper actually places: an engine is a block of fabric, not a Python
    loop iteration.  Member convs execute on their per-layer engines
    (pinned or HBM-streamed per the member schedules), the join runs
    in-engine, and the unit reports per-member Eq. 2 stats under this
    engine's name — the compile-time binding is exactly what runs.

    The block claims the SUM of its members' working sets plus the
    identity buffer (the skip path holds the block input while the conv
    chain runs), plus the WIDEST intermediate activation map handed
    between members — the chain is sequential inside the unit, so one
    extra staging buffer sized by the widest producer covers every
    member-to-member handoff.  This tightened large-block model is what
    lets bottleneck (1x1-3x3-1x1 + downsample) blocks bind on real
    targets instead of falling back per-layer early; ``compile()`` only
    binds the block when the total fits the target's VMEM budget, else
    the layers keep per-layer bindings.
    """

    is_block = True

    def _member_engines(self, block: ResBlockSpec):
        return [select_engine(m) for m in block.members]

    def supports(self, block: ResBlockSpec) -> bool:
        # every member must land on a Pallas conv engine: a jnp_ref (or
        # otherwise non-conv) member means the block's padding/precision
        # contract is not the line-buffer kernel's, so bind per-layer.
        if not block.convs:
            return False
        return all(eng.name in ("conv2d_int8", "dwconv_int8")
                   for eng in self._member_engines(block))

    def vmem_bytes(self, block: ResBlockSpec,
                   scheds: Tuple[LayerSchedule, ...]) -> int:
        first = block.convs[0]
        identity = first.in_h * first.in_w * first.c_in          # int8 skip
        members = sum(
            eng.vmem_bytes(s.spec, s)
            for eng, s in zip(self._member_engines(block), scheds))
        widest = max(m.out_h * m.out_w * m.c_out                 # int8 stage
                     for m in block.members)
        return members + identity + widest

    def stats(self, block: ResBlockSpec, scheds: Tuple[LayerSchedule, ...],
              batch: int) -> Tuple[LayerExecStats, ...]:
        """Per-member stats template in dispatch order (convs then ds),
        each reported under this block engine's name — exactly what one
        ``run`` returns, without executing anything."""
        by_name = {s.spec.name: s for s in scheds}
        order = list(block.convs) + ([block.ds] if block.ds is not None
                                     else [])
        return tuple(
            dataclasses.replace(
                select_engine(m).stats(by_name[m.name], batch),
                kernel=self.name)
            for m in order)

    def run(self, ctx: EngineContext, block: ResBlockSpec,
            scheds: Tuple[LayerSchedule, ...], params: Params, x
            ) -> Tuple[jnp.ndarray, Tuple[LayerExecStats, ...]]:
        by_name = {s.spec.name: s for s in scheds}
        stats: List[LayerExecStats] = []

        def member(spec: ConvLayerSpec, xin, relu: bool):
            y_q, _, st = select_engine(spec).run(
                ctx, by_name[spec.name], params[spec.name], xin, relu)
            # the block IS the binding: members report under its name
            stats.append(dataclasses.replace(st, kernel=self.name))
            return y_q

        h = x
        last = len(block.convs) - 1
        for ci, cspec in enumerate(block.convs):
            h = member(cspec, h, relu=ci != last)
        identity = x
        if block.ds is not None:
            identity = member(block.ds, identity, relu=False)
        y = h.astype(jnp.int32) + identity.astype(jnp.int32)
        y = jnp.clip(y, -127, 127).astype(jnp.int8)
        y = jnp.where(y > 0, y, 0)                    # relu on int8
        return y, tuple(stats)


@register_engine("scanned_res_block_int8", priority=10)
class ScannedResBlockInt8Engine:
    """A homogeneous RUN of residual blocks as one ``lax.scan`` over the
    fused block body — the haliax ``Stacked`` scan-over-layers idiom at
    the compiler's engine granularity.  The representative (first)
    block's body is traced ONCE through the same block engine that runs
    each block individually (so scanned execution is the per-block
    execution, verbatim); every block's member params stack along a new
    leading axis and become the scanned-over xs.  The jaxpr cost of the
    run collapses from ``n_blocks`` bodies to one — the compile-scaling
    win full-size nets need — while the outputs stay bit-identical to
    the unrolled trace (same kernels, same order, same values).

    Methods take the block run (and per-block member schedules, outer
    index = block): ``run`` returns ``(int8 activations, stats)`` where
    the stats list EVERY member of EVERY block (the scan is a compile
    strategy, not an accounting change — the Eq. 2 cross-check still
    covers 100% of the graph, per iteration and summed).

    VMEM: the traced body claims one block's working set; the stacked
    pinned weights of the remaining ``n_blocks - 1`` iterations stay
    resident for the whole scan (streamed members re-read from HBM per
    iteration exactly as before, nothing extra held)."""

    is_scan = True

    def supports(self, blocks: Sequence[ResBlockSpec]) -> bool:
        if len(blocks) < 2:
            return False
        engs = [select_block_engine(b) for b in blocks]
        return all(e is not None and e.name == engs[0].name for e in engs)

    def vmem_bytes(self, blocks: Sequence[ResBlockSpec],
                   scheds_per_block: Sequence[Tuple[LayerSchedule, ...]]
                   ) -> int:
        body = select_block_engine(blocks[0]).vmem_bytes(
            blocks[0], scheds_per_block[0])
        pinned = sum(s.spec.weight_count for s in scheds_per_block[0]
                     if not s.streamed)
        return body + (len(blocks) - 1) * pinned

    def stats(self, blocks: Sequence[ResBlockSpec],
              scheds_per_block: Sequence[Tuple[LayerSchedule, ...]],
              batch: int) -> Tuple[LayerExecStats, ...]:
        """Every member of every block, config order, under this engine's
        name — the scan changes how the graph compiles, never what the
        accounting covers."""
        out: List[LayerExecStats] = []
        for blk, scheds in zip(blocks, scheds_per_block):
            beng = select_block_engine(blk)
            out.extend(dataclasses.replace(st, kernel=self.name)
                       for st in beng.stats(blk, scheds, batch))
        return tuple(out)

    def run(self, ctx: EngineContext, blocks: Sequence[ResBlockSpec],
            scheds_per_block: Sequence[Tuple[LayerSchedule, ...]],
            params: Params, x
            ) -> Tuple[jnp.ndarray, Tuple[LayerExecStats, ...]]:
        rep = blocks[0]
        beng = select_block_engine(rep)
        order = rep.members
        # per member position: stack that member's params across the run's
        # blocks along a new leading axis (the scanned xs — lax.scan
        # slices one block's weights per iteration)
        stacked = tuple(
            jax.tree_util.tree_map(
                lambda *leaves: jnp.stack(leaves),
                *[params[b.members[j].name] for b in blocks])
            for j in range(len(order)))

        def body(h, per_iter):
            # one iteration IS one block: route the representative
            # block's specs/schedules through the block engine with this
            # iteration's weights (homogeneity makes the shapes agree)
            fake = {m.name: p for m, p in zip(order, per_iter)}
            y, _ = beng.run(ctx, rep, scheds_per_block[0], fake, h)
            return y, None

        y, _ = jax.lax.scan(body, x, stacked)
        return y, self.stats(blocks, scheds_per_block, int(x.shape[0]))


@register_engine("stem_pool_int8", priority=10)
class StemPoolInt8Engine:
    """The stem conv + following maxpool as ONE schedulable unit — the
    carried-over ROADMAP nicety: the stem pair rides the block-unit
    machinery (one dispatch, one VMEM cost, contiguous member stats)
    instead of two separate nodes.  Members execute on their per-layer
    engine bindings (the conv pinned or HBM-streamed per its schedule,
    the pool weightless), joined by the conv's output map as the only
    intermediate the unit stages."""

    is_stem = True

    def supports(self, unit: StemUnitSpec) -> bool:
        try:
            ce = select_engine(unit.conv)
            pe = select_engine(unit.pool)
        except LookupError:                            # pragma: no cover
            return False
        # both members must land on the Pallas engines this unit fuses;
        # anything else (jnp_ref fallback after an unregister) keeps the
        # per-layer bindings so the engine table says what truly runs
        return (ce.name in ("conv2d_int8", "dwconv_int8")
                and pe.name == "maxpool_int8")

    def vmem_bytes(self, unit: StemUnitSpec,
                   scheds: Tuple[LayerSchedule, ...]) -> int:
        cs, ps = scheds
        handoff = unit.conv.out_h * unit.conv.out_w * unit.conv.c_out  # int8
        return (select_engine(unit.conv).vmem_bytes(unit.conv, cs)
                + select_engine(unit.pool).vmem_bytes(unit.pool, ps)
                + handoff)

    def stats(self, unit: StemUnitSpec, scheds: Tuple[LayerSchedule, ...],
              batch: int) -> Tuple[LayerExecStats, ...]:
        return tuple(
            dataclasses.replace(select_engine(m).stats(s, batch),
                                kernel=self.name)
            for m, s in zip(unit.members, scheds))

    def run(self, ctx: EngineContext, unit: StemUnitSpec,
            scheds: Tuple[LayerSchedule, ...], params: Params, x
            ) -> Tuple[jnp.ndarray, Tuple[LayerExecStats, ...]]:
        cs, ps = scheds
        stats: List[LayerExecStats] = []
        y, _, st = select_engine(unit.conv).run(
            ctx, cs, params[unit.conv.name], x, True)
        stats.append(dataclasses.replace(st, kernel=self.name))
        y, _, st = select_engine(unit.pool).run(ctx, ps, {}, y, False)
        stats.append(dataclasses.replace(st, kernel=self.name))
        return y, tuple(stats)
