"""LayerEngine protocol + registry — the compiler's extension surface.

H2PIPE emits *layer-specific* hardware: every layer gets its own engine,
chosen by what the layer is (dense conv, depthwise conv, fc head) and
where its weights live (pinned M20K vs HBM-streamed).  This module is the
software analogue: a :class:`LayerEngine` wraps one Pallas kernel family
and declares

  * ``supports(spec)``            which :class:`ConvLayerSpec` shapes it
                                  can run (checked at *compile* time — no
                                  more discovering fallbacks at dispatch);
  * ``vmem_bytes(spec, sched)``   the working set one dispatch claims, so
                                  ``compile()`` can validate every layer
                                  against the Target's VMEM budget and
                                  re-place (pin -> stream) the ones that
                                  do not fit;
  * ``run(ctx, sched, params, x, relu)``
                                  the actual dispatch.  ``ctx`` is a
                                  per-execution :class:`EngineContext`
                                  (interpret flag, activation scale, stats
                                  sink) — engines hold NO mutable state,
                                  so one compiled pipeline can serve
                                  concurrent requests.

Engines register under a short name with :func:`register_engine`; the
compiler picks, per layer, the highest-priority registered engine whose
``supports`` accepts the spec.  Registering your own engine (a sparse
conv, a Winograd path, an FPGA RTL emitter...) requires no edits here:

    @register_engine("myconv", priority=20)
    class MyConvEngine:
        def supports(self, spec): ...
        def vmem_bytes(self, spec, sched): ...
        def run(self, ctx, sched, params, x, relu): ...

Built-in engines: ``conv2d_int8`` (dense/pointwise conv + big fc-as-conv
heads), ``dwconv_int8`` (grouped depthwise — the MobileNet path),
``stream_matmul`` (1x1 fc heads), ``jnp_ref`` (XLA reference, priority 0
safety net).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp

from repro.configs.cnn import ConvLayerSpec
from repro.core.schedule import HBM, PINNED, LayerSchedule
from repro.kernels.conv2d_int8.ops import conv2d_int8, same_padded_width
from repro.kernels.quant import requant_epilogue
from repro.kernels.stream_matmul import ops as sm_ops

Params = Dict[str, Any]

# the ONE dequant+bias+relu+requant epilogue (kernels/quant.py), jitted so
# its float ops compile exactly like the reference path's
_requant = functools.partial(jax.jit, static_argnames=("act_scale", "relu"))(
    requant_epilogue)


def _block(n: int, cap: int) -> int:
    """Largest divisor of n not exceeding cap (Pallas block sizing)."""
    for b in range(min(n, cap), 0, -1):
        if n % b == 0:
            return b
    return 1


def _padded_width(spec: ConvLayerSpec) -> int:
    """SAME-padded input width (what the line buffer actually holds) —
    from the kernel module's own padding formula, so validation and
    allocation cannot drift apart."""
    return same_padded_width(spec.in_w, spec.k_w, spec.stride)


# ---------------------------------------------------------------------------
# execution context + per-dispatch stats
# ---------------------------------------------------------------------------


@dataclass
class LayerExecStats:
    """What one layer dispatch did (which engine, which tier, Eq. 2 words)."""

    name: str
    mode: str                     # "pinned" | "hbm"
    kernel: str                   # engine name that actually ran
    hbm_words: int = 0            # Eq. 2 words streamed for this dispatch


@dataclass
class EngineContext:
    """Per-execution state threaded through every engine call.

    Created fresh by each ``PipelineExecutor.run`` (never shared between
    runs), so concurrent executions of one compiled pipeline cannot
    corrupt each other's reports — the re-entrancy contract batched
    serving builds on.
    """

    interpret: bool
    act_scale: float
    stats: Optional[List[LayerExecStats]] = field(default=None)

    def record(self, sched: LayerSchedule, *, kernel: str, batch: int,
               rows: int = 0, mode: Optional[str] = None) -> None:
        if self.stats is None:
            return
        mode = sched.mode if mode is None else mode
        words = 0
        if mode == HBM and batch:
            # Eq. 2 accounting: kernels re-read once per output row, per
            # image.  (On TPU the matmul amortizes the batch dim; the
            # paper's accelerator is batch-1, so we report paper units.)
            words = sched.weight_words_per_row * rows * batch
        self.stats.append(LayerExecStats(
            name=sched.spec.name, mode=mode, kernel=kernel, hbm_words=words))


# ---------------------------------------------------------------------------
# the protocol + registry
# ---------------------------------------------------------------------------


@runtime_checkable
class LayerEngine(Protocol):
    """One layer-engine family the compiler can instantiate.

    Engines may additionally declare ``can_stream = False`` (default
    True) when they cannot source weights from the HBM tier; stage 5
    keeps such bindings pinned so plan analytics never charge Eq. 2
    traffic an engine will not execute."""

    name: str

    def supports(self, spec: ConvLayerSpec) -> bool:
        """Can this engine execute the layer (decided at compile time)?"""
        ...

    def vmem_bytes(self, spec: ConvLayerSpec, sched: LayerSchedule) -> int:
        """Working-set bytes one dispatch claims (batch-1 convention)."""
        ...

    def run(self, ctx: EngineContext, sched: LayerSchedule, params: Params,
            x: jnp.ndarray, relu: bool
            ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
        """Execute the layer; returns (int8 activations, float pre-quant)."""
        ...


# name -> stack of (priority, insertion_seq, engine); the TOP of each
# stack is live.  Re-registering a name pushes (shadowing the previous
# engine), unregistering pops (restoring it) — so overrides of built-ins
# round-trip without touching this module.  Selection order over the live
# engines is priority DESC then insertion order.
_REGISTRY: Dict[str, List[Tuple[int, int, LayerEngine]]] = {}
_SEQ = 0


def register_engine(name: str, *, priority: int = 10):
    """Class decorator: instantiate and register a LayerEngine under
    ``name``.  Registering an existing name shadows the previous engine
    (how tests/users override a built-in); :func:`unregister_engine`
    pops the override and restores what it shadowed."""
    def deco(cls):
        global _SEQ
        engine = cls() if isinstance(cls, type) else cls
        engine.name = name
        _SEQ += 1
        _REGISTRY.setdefault(name, []).append((priority, _SEQ, engine))
        return cls
    return deco


def unregister_engine(name: str) -> Optional[LayerEngine]:
    """Pop the live engine for ``name`` (restoring any engine it
    shadowed); returns it, or None if the name is unknown."""
    stack = _REGISTRY.get(name)
    if not stack:
        return None
    _, _, engine = stack.pop()
    if not stack:
        del _REGISTRY[name]
    return engine


def get_engine(name: str) -> LayerEngine:
    try:
        return _REGISTRY[name][-1][2]
    except KeyError:
        raise KeyError(f"no engine registered under {name!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None


def registered_engines() -> Dict[str, LayerEngine]:
    """Live registered engines in selection order (priority DESC, age)."""
    tops = {name: stack[-1] for name, stack in _REGISTRY.items()}
    items = sorted(tops.items(), key=lambda kv: (-kv[1][0], kv[1][1]))
    return {name: eng for name, (_, _, eng) in items}


def select_engine(spec: ConvLayerSpec) -> LayerEngine:
    """The compile-time choice: highest-priority engine claiming the spec."""
    for eng in registered_engines().values():
        if eng.supports(spec):
            return eng
    raise LookupError(f"no registered engine supports layer {spec.name!r} "
                      f"(kind={spec.kind!r})")


def _is_1x1_fc(spec: ConvLayerSpec) -> bool:
    """fc heads that run as a [B, c_in] x [c_in, c_out] matmul: 1x1 kernel
    on a 1x1 (pooled) map.  Big fc-as-conv heads (VGG's 7x7 fc0) keep the
    conv engine."""
    return (spec.kind == "fc" and spec.k_h == 1 and spec.k_w == 1
            and spec.in_h == 1 and spec.in_w == 1)


def _fc_conv_is_valid_equivalent(spec: ConvLayerSpec) -> bool:
    """The reference applies fc layers with VALID padding while the conv
    engine SAME-pads, so the conv engine may only claim fc-as-conv heads
    whose SAME padding computes to zero in both dims (then SAME == VALID
    bit-for-bit — e.g. VGG's fc0: 7x7 kernel on a 7x7 map, stride 7).
    Anything else binds to the explicit jnp_ref engine instead of
    executing with the wrong padding."""
    return (same_padded_width(spec.in_h, spec.k_h, spec.stride) == spec.in_h
            and same_padded_width(spec.in_w, spec.k_w, spec.stride)
            == spec.in_w)


# ---------------------------------------------------------------------------
# built-in engines
# ---------------------------------------------------------------------------


@register_engine("conv2d_int8", priority=10)
class Conv2DInt8Engine:
    """The line-buffer conv Pallas kernel as an engine; weights pinned in
    VMEM or streamed through the n_buffers-deep HBM ring per the
    schedule.  ``depthwise=False`` covers dense/pointwise convs (and
    fc-as-conv heads); the ``depthwise=True`` instance (registered as
    ``dwconv_int8``) is the grouped MobileNet path, where each channel
    MACs against its own [k_h, k_w] filter — elementwise VPU taps instead
    of MXU dots, [1, C] ring slots instead of [C, C_out]."""

    def __init__(self, depthwise: bool = False):
        self.depthwise = depthwise

    def supports(self, spec: ConvLayerSpec) -> bool:
        if self.depthwise:
            return spec.kind == "dwconv"
        return spec.kind in ("conv", "pwconv") or (
            spec.kind == "fc" and not _is_1x1_fc(spec)
            and _fc_conv_is_valid_equivalent(spec))

    def vmem_bytes(self, spec: ConvLayerSpec, sched: LayerSchedule) -> int:
        # channel factors of one weight tap: [1, C] depthwise, [C, C_out]
        # dense.  Widths use the kernel's SAME-pad ceil, not spec's floor.
        tap_in = 1 if self.depthwise else spec.c_in
        c_out = spec.c_in if self.depthwise else spec.c_out
        out_w = -(-spec.in_w // spec.stride)
        line_buf = spec.k_h * _padded_width(spec) * spec.c_in      # int8
        if sched.streamed:
            w = min(sched.n_buffers, spec.k_h * spec.k_w) \
                * tap_in * c_out                                   # ring
        else:
            w = spec.k_h * spec.k_w * tap_in * c_out               # pinned
        out_row = out_w * c_out * 4                                # int32
        return line_buf + w + 2 * out_row                          # + acc

    def run(self, ctx: EngineContext, sched: LayerSchedule, params: Params,
            x, relu: bool):
        spec = sched.spec
        y = conv2d_int8(x, params["w"], stride=spec.stride,
                        stream=sched.streamed, n_buffers=sched.n_buffers,
                        depthwise=self.depthwise, interpret=ctx.interpret)
        y_q, y_f = _requant(y, params["w_scale"], params["bias"],
                            act_scale=ctx.act_scale, relu=relu)
        ctx.record(sched, kernel=self.name, batch=int(x.shape[0]),
                   rows=int(y.shape[1]))
        return y_q, y_f


# the grouped depthwise path is the same engine with the flag flipped
register_engine("dwconv_int8", priority=10)(Conv2DInt8Engine(depthwise=True))


@register_engine("stream_matmul", priority=10)
class StreamMatmulFCEngine:
    """1x1 fc heads as a streamed matmul: ``pinned`` mode keeps W resident
    in VMEM for the call, ``fifo`` prefetches K-blocks through an explicit
    credit ring — the same two weight tiers, matmul-shaped."""

    BM, BK, BN = 128, 512, 128

    def supports(self, spec: ConvLayerSpec) -> bool:
        return _is_1x1_fc(spec)

    def vmem_bytes(self, spec: ConvLayerSpec, sched: LayerSchedule) -> int:
        mode = "fifo" if sched.streamed else "pinned"
        return sm_ops.vmem_bytes(
            mode, 1, spec.c_in, spec.c_out, 1,
            bm=1, bk=_block(spec.c_in, self.BK),
            bn=_block(spec.c_out, self.BN),
            n_buffers=max(2, sched.n_buffers))

    def run(self, ctx: EngineContext, sched: LayerSchedule, params: Params,
            x, relu: bool):
        spec = sched.spec
        B = int(x.shape[0])
        c_in, c_out = spec.c_in, spec.c_out
        x2 = x.reshape(B, c_in)
        w2 = params["w"].reshape(c_in, c_out)
        mode = "fifo" if sched.streamed else "pinned"
        y = sm_ops.stream_matmul(x2, w2, mode=mode,
                                 bm=_block(B, self.BM),
                                 bk=_block(c_in, self.BK),
                                 bn=_block(c_out, self.BN),
                                 n_buffers=max(2, sched.n_buffers),
                                 interpret=ctx.interpret)
        y_q, y_f = _requant(y.reshape(B, 1, 1, c_out), params["w_scale"],
                            params["bias"], act_scale=ctx.act_scale,
                            relu=relu)
        ctx.record(sched, kernel=self.name, batch=B, rows=1)
        return y_q, y_f


@register_engine("jnp_ref", priority=0)
class JnpReferenceEngine:
    """The XLA reference path as an explicit, lowest-priority engine: it
    supports every layer and claims no VMEM (XLA manages its own), so a
    layer only lands here when no Pallas engine claims it — and the
    engine table SAYS so at compile time instead of a silent dispatch
    fallback.  Streams nothing (``can_stream = False``: stage 5 pins any
    placement that lands here), and accounting records the pinned tier
    that actually ran."""

    can_stream = False

    def supports(self, spec: ConvLayerSpec) -> bool:
        return True

    def vmem_bytes(self, spec: ConvLayerSpec, sched: LayerSchedule) -> int:
        return 0

    def run(self, ctx: EngineContext, sched: LayerSchedule, params: Params,
            x, relu: bool):
        from repro.models.cnn import conv_layer_forward
        y_q, y_f = conv_layer_forward(params, sched.spec, x,
                                      act_scale=ctx.act_scale, relu=relu)
        ctx.record(sched, kernel=self.name, batch=0, mode=PINNED)
        return y_q, y_f
