"""Target descriptors — the resource envelope ``compile()`` plans against.

H2PIPE is a compiler: the same CNN maps to different hardware depending on
how many tensor blocks, how much on-chip RAM, and how many HBM
pseudo-channels the device offers.  A :class:`Target` makes that envelope
an explicit, immutable value instead of the keyword-argument defaults the
old ``build_pipeline_plan`` scattered over call sites:

  * ``tb_budget``      AI tensor blocks the parallelism allocator may spend
                       (the HPIPE balancing pass, §II-B);
  * ``bram_m20ks``     on-chip weight/activation RAM in M20K blocks — the
                       budget Algorithm 1's hybrid selection fills (§V-B);
  * ``vmem_bytes``     per-layer-engine working-set ceiling in bytes (the
                       TPU VMEM analogue of one engine's M20K slice);
                       ``compile()`` re-places or rejects layers whose
                       chosen engine exceeds it;
  * ``n_pc``/``burst`` HBM pseudo-channels usable and words per read
                       request (§III);
  * ``n_buffers``      double-buffer ring depth of streamed weight paths;
  * ``backend``        where the compiled pipeline executes: "interpret"
                       (Pallas interpreter — CPU CI), "compiled" (Mosaic on
                       a real TPU), or "auto" (interpret unless a TPU is
                       attached, via ``pallas_compat.resolve_interpret``).

Presets
-------
``NX2100``        the paper's Stratix 10 NX2100 at half AI-TB utilization —
                  the defaults ``build_pipeline_plan`` used to hard-code.
``TPU_INTERPRET`` an executable-scale device model for interpret-mode runs:
                  small BRAM so Algorithm 1 genuinely streams layers of the
                  mini networks, forced-interpret backend (the old
                  ``tb_budget=500, bram_m20ks=40`` test/example defaults).

Derive variants with :func:`dataclasses.replace` (Targets are frozen), e.g.
``dataclasses.replace(NX2100, burst=16)``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

from repro.core import bounds, hbm_model

#: Per-core VMEM on the TPU generations we execute on (and, coincidentally,
#: about the NX2100's total M20K capacity: 6847 x 20480 bits ~ 17.5 MB).
DEFAULT_VMEM_BYTES = 16 * 1024 * 1024


@dataclass(frozen=True)
class Target:
    """Immutable resource descriptor one pipeline is compiled against."""

    name: str
    tb_budget: int                     # AI tensor blocks for parallelism
    bram_m20ks: int                    # on-chip RAM budget (M20K blocks)
    vmem_bytes: int = DEFAULT_VMEM_BYTES   # per-engine working-set ceiling
    n_pc: int = hbm_model.USABLE_PCS   # usable HBM pseudo-channels
    burst: int = 8                     # HBM words per read request
    n_buffers: int = 2                 # streamed-weight ring depth
    backend: str = "auto"              # "auto" | "interpret" | "compiled"

    def __post_init__(self):
        if self.backend not in ("auto", "interpret", "compiled"):
            raise ValueError(f"unknown backend {self.backend!r}")
        for f in ("tb_budget", "bram_m20ks", "vmem_bytes", "n_pc", "burst",
                  "n_buffers"):
            if getattr(self, f) <= 0:
                raise ValueError(f"{f} must be positive")

    @property
    def interpret(self) -> Optional[bool]:
        """The ``interpret`` value kernel calls should resolve against:
        ``None`` (auto-detect) for the "auto" backend, else forced."""
        return {"auto": None, "interpret": True, "compiled": False}[
            self.backend]

    @property
    def chain_budget(self) -> int:
        """HBM bandwidth pool in 80-bit tensor-chain feeds (Alg. 1 units)."""
        from repro.core.placement import CHAINS_PER_PC
        return self.n_pc * CHAINS_PER_PC

    def replace(self, **changes) -> "Target":
        """``dataclasses.replace`` convenience; renames the variant unless
        the caller overrides ``name`` too."""
        if "name" not in changes:
            changes["name"] = self.name + "*"
        return dataclasses.replace(self, **changes)


#: The paper's device: Stratix 10 NX2100 at half AI-TB utilization, full
#: M20K budget, 31 usable pseudo-channels, burst 8 (§VI defaults).
NX2100 = Target(
    name="nx2100",
    tb_budget=bounds.NX2100_TENSOR_BLOCKS // 2,
    bram_m20ks=bounds.NX2100_M20KS,
)

#: Executable scale for CPU CI / dev machines: BRAM small enough that
#: Algorithm 1 streams several layers of ``mini_resnet18``, Pallas engines
#: forced through the interpreter.
TPU_INTERPRET = Target(
    name="tpu-interpret",
    tb_budget=500,
    bram_m20ks=40,
    backend="interpret",
)

PRESETS = {t.name: t for t in (NX2100, TPU_INTERPRET)}


def get_target(name: str) -> Target:
    try:
        return PRESETS[name]
    except KeyError:
        raise KeyError(
            f"unknown target {name!r}; presets: {sorted(PRESETS)}") from None
