"""Cutting a compiled pipeline into device-local stage programs.

H2PIPE instantiates every layer engine on one die; the mesh analogue
pipelines the SAME compiled schedule across devices — stage ``s`` owns a
contiguous slice of the placed layer order (§V-B: pipeline order is
placement order) and streams its own weights, exactly like a
pseudo-channel feeding its region of the die.  This module is the
compiler stage that produces those slices:

:func:`partition_pipeline`
    Cuts ``CompiledPipeline.plan`` into ``n_stages`` contiguous
    :class:`StageProgram`\\ s, balanced by the per-layer cycle model
    (``LayerPlan.cycles_per_image`` — the same §II-B cost the
    parallelism allocator balances within a die) with an exact
    linear-partition DP.  Fused residual blocks are ATOMIC: the identity
    add spans the block, so a cut inside one would break the topology
    (``cnn_forward`` rejects such ranges too).

:class:`StagePartition`
    The result: per-stage layer ranges, cycles, Eq. 2 words and
    boundary activation shapes, plus the per-stage plan-vs-dispatch
    cross-check — :meth:`StagePartition.verify_eq2` builds one
    :class:`~repro.compiler.pipeline.ExecutionReport` per stage from the
    sliced plan and the sliced stats template and hard-fails
    (:class:`~repro.compiler.pipeline.Eq2MismatchError`) on any drift,
    so splitting the graph never loosens the Eq. 2 guarantee.

:func:`stage_forward_fns`
    The stage programs as callables: stage ``s`` runs its
    ``cnn_forward`` slice through the SAME compile-time engine bindings
    (``make_dispatchers``) the fused whole-net trace uses — the sharded
    executor dispatches heterogeneous per-stage engine tables, not a
    re-derived model.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import TYPE_CHECKING, Callable, List, Optional, Sequence, Tuple

from repro.compiler.engines import EngineContext
from repro.configs.cnn import residual_blocks
from repro.core import hbm_model, placement

if TYPE_CHECKING:                                      # pragma: no cover
    from repro.compiler.pipeline import CompiledPipeline, ExecutionReport


class PartitionError(ValueError):
    """The (pipeline, n_stages) pair cannot be partitioned."""


@dataclass(frozen=True)
class StageProgram:
    """One device-local stage: a contiguous slice of the placed layer
    order, carrying the slice's modelled cost and Eq. 2 accounting."""

    stage: int
    layer_range: Tuple[int, int]      # [start, stop) into cfg.layers
    layers: Tuple[str, ...]           # layer names, pipeline order
    cycles: int                       # sum of members' cycles_per_image
    hbm_words_per_image: int          # Eq. 2 words of streamed members


@dataclass(frozen=True)
class StagePartition:
    """A compiled pipeline cut into ``n_stages`` stage programs."""

    compiled: "CompiledPipeline"
    stages: Tuple[StageProgram, ...]

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    @property
    def total_cycles(self) -> int:
        return sum(s.cycles for s in self.stages)

    @property
    def max_stage_cycles(self) -> int:
        return max(s.cycles for s in self.stages)

    @property
    def balance(self) -> float:
        """sum/max stage cycles — the pipeline's parallel efficiency
        ceiling (``n_stages`` when perfectly balanced)."""
        return self.total_cycles / self.max_stage_cycles

    def describe(self) -> str:
        rows = [f"{'stage':>5s} {'layers':>6s} {'cycles':>8s} "
                f"{'eq2 words/img':>13s}  members"]
        rows.append("-" * len(rows[0]))
        for s in self.stages:
            names = ",".join(s.layers[:4]) + ("..." if len(s.layers) > 4
                                              else "")
            rows.append(f"{s.stage:>5d} {len(s.layers):>6d} {s.cycles:>8d} "
                        f"{s.hbm_words_per_image:>13d}  {names}")
        return "\n".join(rows)

    # -- stage boundaries ----------------------------------------------------

    def boundary_shape(self, stage: int, microbatch: int
                       ) -> Tuple[int, int, int, int]:
        """The per-microbatch activation shape ENTERING ``stage``: the
        declared input geometry of the stage's first layer (config
        geometries are validated self-consistent by the builders)."""
        start, _ = self.stages[stage].layer_range
        spec = self.compiled.plan.cfg.layers[start]
        return (microbatch, spec.in_h, spec.in_w, spec.c_in)

    def out_shape(self, microbatch: int) -> Tuple[int, int]:
        return (microbatch, self.compiled.plan.cfg.num_classes)

    # -- modelled throughput (the deterministic benchmark numbers) -----------

    def modelled_throughput(self, round_microbatches: int,
                            fabric_mhz: float = hbm_model.FABRIC_MHZ
                            ) -> dict:
        """§VI-style modelled serving throughput of the staged pipeline
        vs the same schedule on one device — purely from the cycle model
        and the M + S - 1 fill law (``pipeline_stats``), so the numbers
        are deterministic and diff-gateable (wall clocks on a shared
        host cannot expose device parallelism; the model is the claim,
        as for the single-die §VI numbers).

        Stage time is ``max_stage_cycles`` (the slowest stage paces the
        ring); a round of M microbatches completes in M + S - 1 stage
        times, against M whole-net times for the 1-stage baseline:
        speedup = balance * M / (M + S - 1).
        """
        M = round_microbatches
        S = self.n_stages
        rate = fabric_mhz * 1e6 * placement.PIPELINE_EFF
        sharded = rate * M / ((M + S - 1) * self.max_stage_cycles)
        one_stage = rate / self.total_cycles
        return {
            "round_microbatches": M,
            "n_stages": S,
            "max_stage_cycles": self.max_stage_cycles,
            "total_cycles": self.total_cycles,
            "balance": self.balance,
            "sharded_images_per_s": sharded,
            "one_stage_images_per_s": one_stage,
            "sharded_speedup_x": sharded / one_stage,
            "scaling_efficiency": sharded / one_stage / S,
        }

    # -- per-stage Eq. 2 cross-check -----------------------------------------

    def stage_report(self, stage: int, batch: int = 1) -> "ExecutionReport":
        """The :class:`ExecutionReport` stage ``stage`` will produce for
        ``batch`` images: the plan sliced to the stage's layers, the
        stats template sliced to the same range (template order is
        config order — fused blocks emit contiguous member stats), and
        the block units wholly owned by the stage.  ``.verify()`` on it
        is the per-stage plan-vs-dispatch Eq. 2 cross-check."""
        from repro.compiler.pipeline import ExecutionReport
        cp = self.compiled
        start, stop = self.stages[stage].layer_range
        names = set(self.stages[stage].layers)
        subplan = dataclasses.replace(
            cp.plan, schedules=cp.plan.schedules[start:stop],
            placements=cp.plan.placements[start:stop])
        stage_blocks = tuple(b for b in cp.block_assignments
                             if set(b.members) <= names)
        stage_scans = tuple(g for g in cp.scan_assignments
                            if set(g.member_names) <= names)
        rep = ExecutionReport(plan=subplan, images=batch,
                              block_assignments=stage_blocks,
                              scan_assignments=stage_scans)
        rep.layers.extend(cp.stats_template(batch)[start:stop])
        return rep

    def verify_eq2(self, batch: int = 1) -> Tuple["ExecutionReport", ...]:
        """Hard-fail Eq. 2 verification over the SPLIT graph: every
        stage's report verifies (plan-vs-dispatch, per node and per
        fused block), the stage ranges tile the layer order exactly, and
        the per-stage words conserve the whole-plan total.  Returns the
        per-stage reports so callers can inspect the split accounting."""
        cp = self.compiled
        L = len(cp.plan.schedules)
        pos = 0
        for s in self.stages:
            if s.layer_range[0] != pos:
                raise PartitionError(
                    f"stage {s.stage} starts at {s.layer_range[0]}, "
                    f"expected {pos}: stages must tile the layer order")
            pos = s.layer_range[1]
        if pos != L:
            raise PartitionError(
                f"stages cover [0, {pos}) of {L} layers")
        reports = tuple(self.stage_report(s.stage, batch)
                        for s in self.stages)
        for rep in reports:
            rep.verify()
        whole = sum(cp.plan.hbm_words_per_image().values())
        split = sum(s.hbm_words_per_image for s in self.stages)
        if split != whole:
            raise PartitionError(
                f"per-stage Eq. 2 words ({split}) do not conserve the "
                f"whole-plan total ({whole})")
        return reports


# ---------------------------------------------------------------------------
# the partition pass
# ---------------------------------------------------------------------------


def _atomic_units(compiled: "CompiledPipeline") -> List[Tuple[int, int]]:
    """Contiguous [start, stop) index ranges that stage cuts must not
    split: scan groups are ONE unit (the run is one ``lax.scan`` body —
    a cut inside it would have to unroll the scan, defeating the trace
    win), residual blocks (fused or not — the identity add spans the
    block either way) are one unit, fused non-residual units (the stem
    conv+pool pair) are one unit, everything else is its own."""
    cfg = compiled.plan.cfg
    owner = {}
    # coarsest granularity wins: claim scan groups first, then residual
    # blocks not inside one, then the remaining fused units (stem pair)
    for g in compiled.scan_assignments:
        for m in g.member_names:
            owner[m] = g.group
    for b in residual_blocks(cfg):
        for m in b.members:
            owner.setdefault(m.name, b.name)
    for ba in compiled.block_assignments:
        for m in ba.members:
            owner.setdefault(m, ba.block)
    units: List[Tuple[int, int]] = []
    names = [l.name for l in cfg.layers]
    i = 0
    while i < len(names):
        if names[i] in owner:
            unit = owner[names[i]]
            j = i
            while j < len(names) and owner.get(names[j]) == unit:
                j += 1
            units.append((i, j))
            i = j
        else:
            units.append((i, i + 1))
            i += 1
    return units


def _linear_partition(costs: Sequence[int], k: int) -> List[Tuple[int, int]]:
    """Exact contiguous k-way partition minimizing the max group sum
    (classic linear-partition DP — unit counts are ~dozens, so O(n^2 k)
    is instant)."""
    n = len(costs)
    pre = [0] * (n + 1)
    for i, c in enumerate(costs):
        pre[i + 1] = pre[i] + c
    inf = float("inf")
    best = [[inf] * (k + 1) for _ in range(n + 1)]
    cut = [[0] * (k + 1) for _ in range(n + 1)]
    best[0][0] = 0
    for s in range(1, k + 1):
        for i in range(s, n + 1):
            for j in range(s - 1, i):
                v = max(best[j][s - 1], pre[i] - pre[j])
                if v < best[i][s]:
                    best[i][s] = v
                    cut[i][s] = j
    groups: List[Tuple[int, int]] = []
    i, s = n, k
    while s > 0:
        j = cut[i][s]
        groups.append((j, i))
        i, s = j, s - 1
    return list(reversed(groups))


def partition_pipeline(compiled: "CompiledPipeline",
                       n_stages: int) -> StagePartition:
    """Cut a compiled pipeline into ``n_stages`` balanced stage programs
    (see module docstring).  Raises :class:`PartitionError` when the
    request is infeasible (more stages than atomic units)."""
    if n_stages < 1:
        raise PartitionError(f"n_stages must be >= 1, got {n_stages}")
    units = _atomic_units(compiled)
    if n_stages > len(units):
        raise PartitionError(
            f"cannot cut {len(units)} atomic unit(s) (fused residual "
            f"blocks count as one) into {n_stages} non-empty stages; "
            f"use at most {len(units)} stages for "
            f"{compiled.plan.cfg.name!r}")
    cycles = [p.cycles_per_image for p in compiled.plan.placements]
    unit_costs = [sum(cycles[a:b]) for a, b in units]
    groups = _linear_partition(unit_costs, n_stages)

    plan = compiled.plan
    stages: List[StageProgram] = []
    for s, (ua, ub) in enumerate(groups):
        start, stop = units[ua][0], units[ub - 1][1]
        scheds = plan.schedules[start:stop]
        stages.append(StageProgram(
            stage=s,
            layer_range=(start, stop),
            layers=tuple(sc.spec.name for sc in scheds),
            cycles=sum(cycles[start:stop]),
            hbm_words_per_image=sum(sc.weight_words_per_image
                                    for sc in scheds if sc.streamed)))
    return StagePartition(compiled=compiled, stages=tuple(stages))


# ---------------------------------------------------------------------------
# stage programs as callables (what the sharded executor dispatches)
# ---------------------------------------------------------------------------


def stage_forward_fns(part: StagePartition, *, interpret: bool,
                      act_scale: float = 0.05,
                      collect: Optional[Sequence[list]] = None
                      ) -> List[Callable]:
    """One ``(params, x) -> y`` callable per stage: the stage's
    ``cnn_forward`` slice routed through the pipeline's compile-time
    engine bindings.  ``collect[s]`` (when given) receives stage ``s``'s
    :class:`LayerExecStats` at trace time — the executed-side Eq. 2
    counters the sharded engine cross-checks against the per-stage plan.
    """
    from repro.compiler.pipeline import make_dispatchers
    from repro.models.cnn import cnn_forward
    compiled = part.compiled
    cfg = compiled.plan.cfg
    ctx = EngineContext(interpret=interpret, act_scale=act_scale)
    fns: List[Callable] = []
    for s, sp in enumerate(part.stages):
        sink = None if collect is None else collect[s]
        dispatch, block_dispatch, scan_dispatch = make_dispatchers(
            compiled, ctx, sink)

        def fn(params, x, _range=sp.layer_range, _d=dispatch,
               _b=block_dispatch, _s=scan_dispatch):
            return cnn_forward(params, cfg, x, engine=_d, block_engine=_b,
                               scan_engine=_s, layer_range=_range)
        fns.append(fn)
    return fns
