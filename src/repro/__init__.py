"""H2PIPE-JAX: hybrid-memory layer-pipelined dataflow framework.

Reproduction of "H2PIPE: High Throughput CNN Inference on FPGAs with
High-Bandwidth Memory" (FPL 2024), adapted to the TPU memory hierarchy,
plus a production LM training/serving substrate.  See README.md.
"""
