"""Deterministic, shardable data pipeline.

Every (step, example-index) pair maps to content by a counter-based PRNG,
so ANY host can materialize ANY shard of ANY step without coordination.
This is the property that makes the fault-tolerance story work at scale:

  * restart: resuming at step k regenerates exactly the batches the failed
    run would have seen (bitwise-identical training);
  * elastic rescale: when the data-parallel world changes from D to D',
    hosts re-partition the same global index space — no redistribution;
  * straggler mitigation: a hot-spare host can take over a dead host's
    shard mid-step because shard content is a pure function of indices.

For the CNN examples the same machinery yields deterministic synthetic
image/label pairs (ImageNet-shaped); swapping in a real tokenized corpus
means replacing ``_token`` with an index into a memory-mapped array — the
sharding math is unchanged.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


def _counter_rng(seed: int, step: int, index: int) -> np.random.Generator:
    # counter-based: one Philox stream per (seed, step, index)
    return np.random.Generator(np.random.Philox(key=seed,
                                                counter=[0, 0, step, index]))


class TokenDataset:
    """Synthetic LM corpus: per-example Markov-ish token streams (enough
    structure that loss decreases during the example training runs)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def example(self, step: int, index: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = _counter_rng(cfg.seed, step, index)
        # mixture of a narrow and a broad distribution -> learnable bigrams
        base = rng.integers(0, cfg.vocab_size, size=cfg.seq_len + 1)
        walk = np.cumsum(rng.integers(0, 17, size=cfg.seq_len + 1)) % \
            cfg.vocab_size
        use_walk = rng.random(cfg.seq_len + 1) < 0.7
        toks = np.where(use_walk, walk, base).astype(np.int32)
        return {"tokens": toks[:-1], "labels": toks[1:]}

    def global_batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        exs = [self.example(step, i) for i in range(cfg.global_batch)]
        return {k: np.stack([e[k] for e in exs]) for k in exs[0]}

    def host_batch(self, step: int, host_id: int,
                   n_hosts: int) -> Dict[str, np.ndarray]:
        """The shard this host materializes: a contiguous slice of the
        global index space (re-partitioned trivially when n_hosts changes)."""
        cfg = self.cfg
        assert cfg.global_batch % n_hosts == 0
        per = cfg.global_batch // n_hosts
        lo = host_id * per
        exs = [self.example(step, lo + i) for i in range(per)]
        return {k: np.stack([e[k] for e in exs]) for k in exs[0]}


class ImageDataset:
    """Synthetic int8 image/label pairs for the CNN examples."""

    def __init__(self, shape: Tuple[int, int, int] = (224, 224, 3),
                 num_classes: int = 1000, seed: int = 0):
        self.shape = shape
        self.num_classes = num_classes
        self.seed = seed

    def batch(self, step: int, batch_size: int) -> Dict[str, np.ndarray]:
        rng = _counter_rng(self.seed, step, 0)
        imgs = rng.integers(-127, 128, size=(batch_size,) + self.shape,
                            dtype=np.int8)
        labels = rng.integers(0, self.num_classes, size=(batch_size,),
                              dtype=np.int32)
        return {"images": imgs, "labels": labels}


def device_batch(host_batch: Dict[str, np.ndarray], sharding=None):
    """Put a host batch on device (with an optional NamedSharding)."""
    if sharding is None:
        return {k: jnp.asarray(v) for k, v in host_batch.items()}
    return {k: jax.device_put(v, sharding) for k, v in host_batch.items()}
