"""Training launcher.

Runs a (reduced or full) architecture on whatever devices this process has,
with the full production substrate: deterministic data, ZeRO AdamW,
async atomic checkpoints, crash recovery.

  PYTHONPATH=src python -m repro.launch.train --arch phi4-mini-3.8b \
      --reduced --steps 200 --seq-len 64 --batch 8

On a real TPU slice the same entry point is used with --mesh production
(16x16 per pod); the dry-run (launch/dryrun.py) is the no-hardware proof
that those programs lower and fit.
"""
from __future__ import annotations

import argparse
import json
import sys

import jax

from repro.configs import get_arch
from repro.data.pipeline import DataConfig, TokenDataset
from repro.launch.mesh import make_local_mesh
from repro.optim.adamw import AdamWConfig
from repro.runtime.trainer import TrainConfig, Trainer


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a crash at this step (chaos drill)")
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    if args.reduced:
        arch = arch.reduced()
    mesh = make_local_mesh()
    data = TokenDataset(DataConfig(vocab_size=arch.vocab_size,
                                   seq_len=args.seq_len,
                                   global_batch=args.batch))
    tcfg = TrainConfig(
        steps=args.steps, microbatches=args.microbatches,
        ckpt_every=args.ckpt_every, ckpt_path=args.ckpt,
        adamw=AdamWConfig(lr_peak=args.lr, warmup_steps=max(args.steps // 20, 1),
                          total_steps=args.steps))
    tr = Trainer(arch, tcfg, data, mesh=mesh)
    if args.resume and tr.restore():
        print(f"resumed from step {tr.step}")
    hist = tr.run(fail_at=args.fail_at)
    for h in hist:
        print(json.dumps(h))
    if len(hist) >= 2 and hist[-1]["loss"] >= hist[0]["loss"]:
        print("WARNING: loss did not decrease")
    tr.save(sync=True)
    print(f"done at step {tr.step}; checkpoint in {args.ckpt}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
