"""Serving launcher: batched prefill+decode with credit-bounded admission.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --reduced \
      --requests 6 --max-new 8
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as tmod
from repro.runtime.serving import Request, ServingEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    if args.reduced:
        arch = arch.reduced()
    make_local_mesh()
    params = tmod.init_params(jax.random.PRNGKey(0), arch)
    engine = ServingEngine(params, arch, batch_slots=args.slots,
                           max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, arch.vocab_size, size=8).astype(
        np.int32), max_new=args.max_new) for i in range(args.requests)]
    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    for r in done:
        print(f"req {r.rid}: {r.out}")
    print(f"{toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s, "
          f"{args.slots} slots, credit-bounded admission)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
