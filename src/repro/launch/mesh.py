"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.models.layers import set_mesh_axis_sizes


def compat_make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """``jax.make_mesh`` with explicit Auto axis types where the installed
    jax supports them (``jax.sharding.AxisType`` only exists in newer
    releases; older versions are Auto-only, so omitting it is equivalent)."""
    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    mesh = compat_make_mesh(shape, axes)
    set_mesh_axis_sizes(dict(zip(axes, shape)))
    return mesh


def make_local_mesh(model: int = 1, data: Optional[int] = None) -> Mesh:
    """Whatever this process has: (data, model) covering jax.device_count().
    Used by examples and tests; on the CPU container this is (1, 1)."""
    n = jax.device_count()
    data = data or (n // model)
    assert data * model == n, (data, model, n)
    mesh = compat_make_mesh((data, model), ("data", "model"))
    set_mesh_axis_sizes({"data": data, "model": model})
    return mesh


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
