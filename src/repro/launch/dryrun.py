import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each of the 10 assigned architectures x its applicable input shapes,
this builds abstract params (jax.eval_shape — nothing is allocated),
applies the H2PIPE placement plan to the shardings, and runs
``jit(step).lower(...).compile()`` on the production meshes:

  * 16 x 16            (data, model)       — single pod, 256 chips
  * 2 x 16 x 16        (pod, data, model)  — two pods, 512 chips

``train_*`` cells lower the full train step (fwd + bwd + ZeRO AdamW);
``prefill_*`` cells lower the prompt-processing serve step; ``decode_*`` /
``long_*`` cells lower one-token decode against a KV cache of seq_len.

Per cell it prints ``memory_analysis()`` (proves the program fits) and
``cost_analysis()`` FLOPs/bytes, plus the three roofline terms derived by
``repro.roofline.analysis``.  Results are appended to a JSON report that
EXPERIMENTS.md §Dry-run / §Roofline consume.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                    # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b \
      --shape train_4k --mesh single --stream-plan on
"""
import argparse
import dataclasses
import json
import sys
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_arch, shape_applicable
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import streaming
from repro.launch.mesh import make_production_mesh, mesh_axis_sizes
from repro.models import transformer as tmod
from repro.models.accounting import count_params, model_flops_per_token
from repro.models.layers import dp_spec, set_mesh_axis_sizes
from repro.optim import adamw
from repro.roofline import analysis
from repro.roofline.jaxpr_cost import cost_of
from repro.runtime.trainer import TrainConfig, make_train_step


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------


def train_microbatches(shape: ShapeConfig) -> int:
    """Gradient-accumulation factor for the train dry-run: keeps the live
    residual set (saved layer-scan carries) to ~1/M of the global batch —
    the activation-tier budget, exactly the paper's line-buffer reasoning
    applied to training."""
    for m in (8, 4, 2):
        if shape.global_batch % m == 0 and shape.global_batch // m >= 8:
            return m
    return 1


def input_specs(arch: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        mb = train_microbatches(shape) if shape.kind == "train" else 1
        lead = (mb, B // mb) if mb > 1 else (B,)
        feed = {
            "tokens": jax.ShapeDtypeStruct(lead + (S,), jnp.int32),
        }
        if shape.kind == "train":
            feed["labels"] = jax.ShapeDtypeStruct(lead + (S,), jnp.int32)
        if arch.family == "vlm":
            feed["patches"] = jax.ShapeDtypeStruct(
                lead + (arch.n_patches, arch.d_model), jnp.float32)
        if arch.enc_dec:
            feed["frames"] = jax.ShapeDtypeStruct(
                lead + (arch.n_frames, arch.d_model), jnp.float32)
        return feed
    # decode: one new token + cache of length S
    return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


def batch_specs(arch: ArchConfig, shape: ShapeConfig) -> Dict[str, P]:
    mb = train_microbatches(shape) if shape.kind == "train" else 1
    per = shape.global_batch // mb
    dp = dp_spec(per) or None
    lead = (None, dp) if mb > 1 and shape.kind == "train" else (dp,)
    out = {"tokens": P(*lead, None)}
    if shape.kind == "train":
        out["labels"] = P(*lead, None)
    if shape.kind in ("train", "prefill"):
        if arch.family == "vlm":
            out["patches"] = P(*lead, None, None)
        if arch.enc_dec:
            out["frames"] = P(*lead, None, None)
    return out


# ---------------------------------------------------------------------------
# cell lowering
# ---------------------------------------------------------------------------


def _named(mesh: Mesh, tree_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh, *,
               stream_plan: bool = True,
               donate: bool = True) -> Tuple[Any, Dict[str, Any]]:
    """Lower+compile one cell.  Returns (compiled, info)."""
    set_mesh_axis_sizes(mesh_axis_sizes(mesh))
    abstract_params = jax.eval_shape(
        lambda: tmod.init_params(jax.random.PRNGKey(0), arch))
    pspecs = tmod.param_specs(arch)
    plan_notes = "off"
    if stream_plan:
        plan = streaming.plan_placement(abstract_params, pspecs, arch)
        pspecs = streaming.apply_plan_to_specs(pspecs, plan, abstract_params)
        plan_notes = plan.notes
    p_shard = _named(mesh, pspecs)
    feed = input_specs(arch, shape)
    b_shard = _named(mesh, batch_specs(arch, shape))

    info: Dict[str, Any] = {"plan": plan_notes}

    with mesh:
        if shape.kind == "train":
            from repro.models.layers import kernel_mode_enabled
            from repro.optim.adamw import AdamWConfig
            tcfg = TrainConfig(
                microbatches=train_microbatches(shape),
                adamw=AdamWConfig(grad_wire_bf16=kernel_mode_enabled()))
            abstract_opt = jax.eval_shape(
                lambda p: adamw.init(p, tcfg.adamw), abstract_params)
            o_specs = adamw.state_specs(abstract_params, pspecs, tcfg.adamw)
            o_shard = _named(mesh, o_specs)
            step = make_train_step(arch, tcfg)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(abstract_params, abstract_opt, feed)
            jc = cost_of(step, abstract_params, abstract_opt, feed)
            tokens = shape.global_batch * shape.seq_len
        elif shape.kind == "prefill":
            def serve_step(params, batch):
                logits, cache = tmod.prefill(params, arch, batch,
                                             max_seq=shape.seq_len)
                return logits, cache
            c_specs = tmod.cache_specs(arch, shape.global_batch)
            c_shard = _named(mesh, c_specs)
            jitted = jax.jit(serve_step,
                             in_shardings=(p_shard, b_shard),
                             out_shardings=(None, c_shard))
            lowered = jitted.lower(abstract_params, feed)
            jc = cost_of(serve_step, abstract_params, feed)
            tokens = shape.global_batch * shape.seq_len
        else:                                          # decode
            enc_len = arch.n_frames if arch.enc_dec else 0
            abstract_cache = jax.eval_shape(
                lambda: tmod.init_cache(arch, shape.global_batch,
                                        shape.seq_len, enc_len=enc_len))
            c_specs = tmod.cache_specs(arch, shape.global_batch)
            c_shard = _named(mesh, c_specs)

            def serve_step(params, cache, tokens):
                return tmod.decode_step(params, arch, cache, tokens,
                                        jnp.int32(shape.seq_len - 1))
            jitted = jax.jit(
                serve_step,
                in_shardings=(p_shard, c_shard, b_shard["tokens"]),
                out_shardings=(None, c_shard),
                donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(abstract_params, abstract_cache,
                                   feed["tokens"])
            jc = cost_of(serve_step, abstract_params, abstract_cache,
                         feed["tokens"])
            tokens = shape.global_batch
        compiled = lowered.compile()

    # model flops: 6*N_active*tokens for train (x3 fwd+bwd), 2*N_active*t
    # for inference (fwd only)
    n_act = count_params(arch, active_only=True)
    if shape.kind == "train":
        mf = 6 * n_act * tokens
    else:
        mf = 2 * n_act * tokens
    info["model_flops"] = float(mf)
    info["tokens"] = tokens
    info["global_flops"] = jc["flops"]
    info["global_bytes"] = jc["bytes"]
    return compiled, info


def run_cell(arch_id: str, shape_id: str, mesh_kind: str, *,
             stream_plan: bool = True, kernels: bool = False,
             verbose: bool = True) -> Optional[Dict[str, Any]]:
    from repro.models.layers import set_kernel_mode
    set_kernel_mode(kernels, interpret=True)
    arch = get_arch(arch_id)
    shape = SHAPES[shape_id]
    ok, why = shape_applicable(arch, shape)
    if not ok:
        if verbose:
            print(f"SKIP {arch_id} x {shape_id}: {why}")
        return {"arch": arch_id, "shape": shape_id, "mesh": mesh_kind,
                "skipped": why}
    if arch.enc_dec and shape.kind == "decode" and shape.seq_len > 32768:
        pass
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(np.prod(mesh.devices.shape))
    t0 = time.time()
    compiled, info = lower_cell(arch, shape, mesh, stream_plan=stream_plan)
    dt = time.time() - t0
    roof = analysis.analyze(
        compiled, arch=arch_id, shape=shape_id,
        mesh_name="x".join(map(str, mesh.devices.shape)), chips=chips,
        model_flops=info["model_flops"],
        global_flops=info["global_flops"],
        global_bytes=info["global_bytes"])
    row = roof.row()
    row.update({"compile_s": dt, "plan": info["plan"],
                "coll_detail": {k: int(v) for k, v in
                                roof.coll_detail.items()},
                "skipped": None})
    if verbose:
        ma = compiled.memory_analysis()
        print(f"PASS {arch_id} x {shape_id} on {row['mesh']}  "
              f"compile={dt:.1f}s")
        print(f"  memory_analysis: args={ma.argument_size_in_bytes/2**30:.2f}"
              f"GiB out={ma.output_size_in_bytes/2**30:.2f}GiB "
              f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB (per device)")
        print(f"  cost: flops/dev={roof.hlo_flops:.3e} "
              f"bytes/dev={roof.hlo_bytes:.3e} coll/dev={roof.coll_bytes:.3e}")
        print(f"  roofline: compute={roof.t_compute*1e3:.2f}ms "
              f"memory={roof.t_memory*1e3:.2f}ms "
              f"collective={roof.t_collective*1e3:.2f}ms "
              f"-> {roof.dominant}-bound, useful={roof.useful_fraction:.2f} "
              f"mfu@bound={roof.mfu_at_bound:.3f}")
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default all)")
    ap.add_argument("--shape", default=None, help="one shape id (default all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--stream-plan", default="on", choices=["on", "off"])
    ap.add_argument("--kernels", default="off", choices=["on", "off"],
                    help="route attention through the Pallas flash kernels")
    ap.add_argument("--out", default="dryrun_report.json")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    rows = []
    failures = []
    for mk in meshes:
        for a in archs:
            for s in shapes:
                try:
                    row = run_cell(a, s, mk,
                                   stream_plan=args.stream_plan == "on",
                                   kernels=args.kernels == "on")
                    if row:
                        rows.append(row)
                except Exception as e:                       # noqa: BLE001
                    failures.append((a, s, mk, repr(e)))
                    print(f"FAIL {a} x {s} on {mk}: {e!r}")
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1, default=str)
    print(f"\n{len(rows)} cells recorded -> {args.out}; "
          f"{len(failures)} failures")
    for f_ in failures:
        print("  FAIL:", *f_)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
