"""Boot-time weight write path (§IV-C).

At boot, H2PIPE ships weights from the host over PCIe, REUSING the
224x224x3 image input buffer and its datapath (no new BRAM), through a
deliberately NARROW bus (default 30 bits) that is deserialized to 256 bits
only at the HBM AXI controller — saving >3000 registers versus a full-width
path, acceptable because the write happens once.

We reproduce both halves:
  * the compiler side: ``pack_weights_as_images`` formats a weight blob as
    a sequence of image-shaped int8 frames (exactly the binary the H2PIPE
    compiler generates), ``unpack`` inverts it, and the round trip is
    bit-exact (tests/test_write_path.py);
  * the cost side: ``write_path_registers`` models the pipelined-bus
    register cost vs width, reproducing the ">3000 registers saved at 30
    bits" claim, and ``boot_time_s`` the one-time write latency given the
    Fig. 3a write efficiency.

The TPU analogue of the whole §IV-C is ``jax.device_put`` at model load —
kept as documentation (DESIGN.md §2) — but the packing format itself is
hardware-neutral and is what a host-side loader would stream.
"""
from __future__ import annotations

import math
from typing import Iterator, List, Tuple

import numpy as np

from repro.core import hbm_model

IMAGE_BYTES = 224 * 224 * 3          # the reused input buffer, int8
DEFAULT_WIDTH_BITS = 30
FULL_WIDTH_BITS = 256
# distance from PCIe/input buffer to each HBM stack, in pipeline stages
# (deeply pipelined to meet timing across the die, §IV-C)
PIPELINE_STAGES_PER_STACK = 24


def pack_weights_as_images(weights: np.ndarray) -> np.ndarray:
    """Weight blob (any int8 array) -> [n_frames, 224, 224, 3] int8, padded
    with zeros; frames stream through the existing image input path."""
    flat = np.ascontiguousarray(weights, dtype=np.int8).reshape(-1)
    n_frames = -(-flat.size // IMAGE_BYTES)
    padded = np.zeros(n_frames * IMAGE_BYTES, np.int8)
    padded[:flat.size] = flat
    return padded.reshape(n_frames, 224, 224, 3)


def unpack_weights(frames: np.ndarray, size: int,
                   dtype=np.int8) -> np.ndarray:
    return frames.reshape(-1)[:size].astype(dtype)


def write_path_registers(width_bits: int = DEFAULT_WIDTH_BITS,
                         stacks: int = hbm_model.N_STACKS) -> int:
    """Register cost of the pipelined write bus: width x stages x stacks
    (plus the deserializer at the controller, one 256-bit stage)."""
    return width_bits * PIPELINE_STAGES_PER_STACK * stacks + FULL_WIDTH_BITS


def registers_saved(width_bits: int = DEFAULT_WIDTH_BITS) -> int:
    """§IV-C: 'saves over 3000 registers compared to a straightforward
    256-bit wide interface'."""
    return write_path_registers(FULL_WIDTH_BITS) - \
        write_path_registers(width_bits)


def boot_time_s(weight_bytes: int, width_bits: int = DEFAULT_WIDTH_BITS,
                burst: int = 8,
                fabric_mhz: float = hbm_model.FABRIC_MHZ) -> float:
    """One-time weight load latency: narrow-bus transfer then HBM writes at
    the measured write efficiency (the slower of the two pipelines)."""
    t_bus = weight_bytes * 8 / (width_bits * fabric_mhz * 1e6)
    w_bw = hbm_model.PC_BW_BYTES * hbm_model.write_efficiency(burst)
    t_hbm = weight_bytes / (w_bw * hbm_model.USABLE_PCS)
    return max(t_bus, t_hbm)
