"""Layer-pipelined dataflow executor — H2PIPE's architecture on the mesh.

The paper's accelerator assigns consecutive CNN layers to specialized
engines placed around the die, with activations flowing through small FIFOs
between them.  On the TPU mesh the analogue is pipeline parallelism over the
``model`` axis: each device group owns a contiguous group of layers (a
*stage*), and activations move stage-to-stage with ``lax.ppermute`` inside a
``shard_map`` while every stage computes on a different microbatch — all
stages busy in parallel, exactly Fig. 1.

Key H2PIPE semantics carried over:
  * **continuous streaming** (serving): the static schedule admits one
    microbatch per tick with at most ``n_stages`` in flight — the credit
    bound of §V-A (a static schedule cannot head-of-line block, which is
    the program-level proof of the credit property ``fifo_sim`` checks
    dynamically);
  * **pipeline order = placement order** (§V-B): stage s holds layers
    [s*L/S, (s+1)*L/S) — the clockwise pseudo-channel assignment becomes
    the identity stage mapping;
  * **GPipe-style training**: microbatch gradients accumulate; the bubble
    fraction (S-1)/(M+S-1) is reported by ``pipeline_stats``.

The executor is generic over the per-stage function so the CNN engines, the
transformer layers and the tests' toy layers all use the same machinery.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def _validate_mesh_axis(mesh: Mesh, axis: str) -> int:
    """The pipeline axis must actually exist on the mesh — shard_map's
    own error for a missing axis name is an opaque tracer failure, so
    check up front and say what was available."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if axis not in sizes:
        raise ValueError(
            f"mesh has no axis {axis!r}; available axes: {sizes} "
            f"(pass axis=<name> matching the mesh the pipeline runs on)")
    return sizes[axis]


def split_stages(stacked_params, n_stages: int):
    """[L, ...] stacked layer params -> [S, L/S, ...]."""
    if n_stages < 1:
        raise ValueError(f"n_stages must be >= 1, got {n_stages}")

    def re(x):
        L = x.shape[0]
        if L % n_stages != 0:
            raise ValueError(
                f"cannot split {L} stacked layers into {n_stages} equal "
                f"stages ({L} % {n_stages} != 0); pad the stack or pick a "
                f"stage count that divides the layer count")
        return x.reshape((n_stages, L // n_stages) + x.shape[1:])
    return jax.tree.map(re, stacked_params)


def pipeline_stats(n_stages: int, n_microbatches: int) -> Dict[str, float]:
    total = n_microbatches + n_stages - 1
    return {
        "ticks": total,
        "bubble_fraction": (n_stages - 1) / total,
        "in_flight_credits": n_stages,
    }


def pipeline_apply(layer_fn: Callable, params_staged, x_mb, *, mesh: Mesh,
                   axis: str = "model"):
    """Run microbatches through the stage pipeline.

    layer_fn(stage_params, x) -> x   applies one stage's layer group; it is
        called with the [L/S, ...] slice owned by the local stage.
    params_staged: [S, L/S, ...] pytree (see ``split_stages``).
    x_mb: [M, mb, ...] microbatched input (replicated over ``axis``).

    Returns [M, mb, ...] outputs, valid on every device (the last stage's
    results are broadcast back, like the paper's output DMA).
    """
    n_stages = _validate_mesh_axis(mesh, axis)
    if x_mb.ndim < 2 or x_mb.shape[0] < 1:
        raise ValueError(
            f"x_mb must be [M, mb, ...] with M >= 1 microbatches, got "
            f"shape {tuple(x_mb.shape)}")
    bad = [tuple(a.shape) for a in jax.tree.leaves(params_staged)
           if a.shape[:1] != (n_stages,)]
    if bad:
        raise ValueError(
            f"params_staged leaves must carry a leading stage dimension of "
            f"{n_stages} (the {axis!r} mesh axis size); got leading dims "
            f"{sorted({s[0] if s else None for s in bad})} — build them "
            f"with split_stages(params, {n_stages})")
    M = x_mb.shape[0]
    S = n_stages

    def stage_body(params_local, x_local):
        p = jax.tree.map(lambda a: a[0], params_local)   # drop stage dim
        idx = jax.lax.axis_index(axis)
        zero = jnp.zeros_like(x_local[0])

        def tick(buf, t):
            # stage 0 admits microbatch t (one credit per tick; at most S
            # microbatches live at once by the static schedule)
            mb_in = jax.lax.dynamic_index_in_dim(
                x_local, jnp.clip(t, 0, M - 1), keepdims=False)
            my_in = jnp.where(idx == 0, mb_in, buf)
            out = layer_fn(p, my_in)
            # hand off to the next stage around the ring
            perm = [(i, (i + 1) % S) for i in range(S)]
            nxt = jax.lax.ppermute(out, axis, perm)
            # the last stage's output this tick is a finished microbatch
            done = jnp.where(idx == S - 1, out, jnp.zeros_like(out))
            return nxt, done

        _, outs = jax.lax.scan(tick, zero, jnp.arange(M + S - 1))
        outs = outs[S - 1:]                  # microbatch m done at tick m+S-1
        # broadcast the last stage's results to every device
        outs = jax.lax.psum(
            jnp.where(idx == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    p_specs = jax.tree.map(lambda _: P(axis), params_staged)
    fn = shard_map(stage_body, mesh=mesh,
                   in_specs=(p_specs, P()), out_specs=P(),
                   check_rep=False)
    return fn(params_staged, x_mb)


def staged_pipeline_apply(stage_fns: Sequence[Callable], params, x_mb, *,
                          mesh: Mesh, axis: str = "model",
                          boundary_shapes: Sequence[Optional[Tuple[int, ...]]],
                          out_shape: Tuple[int, ...],
                          out_dtype=jnp.float32,
                          carry_dtype=jnp.int8):
    """``pipeline_apply`` generalized to HETEROGENEOUS stages.

    ``pipeline_apply`` requires every stage to run the same ``layer_fn``
    over a same-shaped activation; a partitioned CNN has neither — stage
    boundaries change the activation geometry (stride-2 transitions,
    GAP) and each stage runs a different slice of the compiled engine
    table.  Here every device runs its OWN program, selected by
    ``lax.switch`` on the stage index, and the ring still moves
    activations with ``lax.ppermute``: boundary activations are
    flattened into one fixed-size ``carry_dtype`` buffer (sized to the
    widest stage boundary) so the carry has a single static shape even
    though each hop reshapes to a different geometry.

    stage_fns[s](params, x) -> y   runs stage ``s``'s layer slice;
        ``params`` is the full (replicated) parameter pytree — each
        stage program reads only its own layers' entries.
    x_mb: [M, mb, ...] microbatched input, replicated over ``axis``.
    boundary_shapes[s]: the per-microbatch activation shape ENTERING
        stage ``s`` (``boundary_shapes[0]`` is unused — stage 0 reads
        ``x_mb`` directly — and may be None).  Inter-stage activations
        must be ``carry_dtype`` (int8 for the quantized CNN pipeline).
    out_shape/out_dtype: the last stage's per-microbatch output.

    Returns [M, *out_shape] outputs, valid on every device (the last
    stage's results are summed back over the axis, like the paper's
    output DMA).  Admission follows the same static schedule as
    ``pipeline_apply``: one microbatch per tick, at most S in flight
    (§V-A), microbatch m completing at tick m + S - 1.
    """
    S = _validate_mesh_axis(mesh, axis)
    if len(stage_fns) != S:
        raise ValueError(
            f"{len(stage_fns)} stage programs for a {S}-device {axis!r} "
            f"axis; the partition's n_stages must equal the mesh axis size")
    if len(boundary_shapes) != S:
        raise ValueError(
            f"boundary_shapes must carry one entry per stage "
            f"({S}), got {len(boundary_shapes)}")
    if x_mb.ndim < 2 or x_mb.shape[0] < 1:
        raise ValueError(
            f"x_mb must be [M, mb, ...] with M >= 1 microbatches, got "
            f"shape {tuple(x_mb.shape)}")
    M = x_mb.shape[0]
    flat = max([math.prod(boundary_shapes[s]) for s in range(1, S)],
               default=1)
    out_shape = tuple(out_shape)

    def stage_body(p, x_local):
        idx = jax.lax.axis_index(axis)
        zero_carry = jnp.zeros((flat,), carry_dtype)
        zero_out = jnp.zeros(out_shape, out_dtype)

        def make_branch(s):
            fn = stage_fns[s]

            def branch(buf, mb_in):
                if s == 0:
                    xin = mb_in
                else:
                    shape = tuple(boundary_shapes[s])
                    xin = buf[:math.prod(shape)].reshape(shape)
                y = fn(p, xin)
                if s == S - 1:
                    if tuple(y.shape) != out_shape:
                        raise ValueError(
                            f"stage {s} produced {tuple(y.shape)}, "
                            f"expected out_shape {out_shape}")
                    return zero_carry, y.astype(out_dtype)
                want = tuple(boundary_shapes[s + 1])
                if tuple(y.shape) != want:
                    raise ValueError(
                        f"stage {s} produced {tuple(y.shape)}, but stage "
                        f"{s + 1} declares boundary shape {want}")
                f = y.astype(carry_dtype).reshape(-1)
                return jnp.pad(f, (0, flat - f.size)), zero_out
            return branch

        branches = [make_branch(s) for s in range(S)]

        def tick(buf, t):
            mb_in = jax.lax.dynamic_index_in_dim(
                x_local, jnp.clip(t, 0, M - 1), keepdims=False)
            nxt, done = jax.lax.switch(idx, branches, buf, mb_in)
            perm = [(i, (i + 1) % S) for i in range(S)]
            nxt = jax.lax.ppermute(nxt, axis, perm)
            return nxt, done

        _, outs = jax.lax.scan(tick, zero_carry, jnp.arange(M + S - 1))
        outs = outs[S - 1:]                  # microbatch m done at tick m+S-1
        # non-last stages emitted zeros, so the sum IS the last stage's
        # results, broadcast to every device
        return jax.lax.psum(outs, axis)

    p_specs = jax.tree.map(lambda _: P(), params)
    fn = shard_map(stage_body, mesh=mesh,
                   in_specs=(p_specs, P()), out_specs=P(),
                   check_rep=False)
    return fn(params, x_mb)


def gpipe_train_step(layer_fn: Callable, loss_fn: Callable, params_staged,
                     x_mb, y_mb, *, mesh: Mesh, axis: str = "model"):
    """GPipe: forward all microbatches through the pipeline, mean loss over
    microbatches, grads by autodiff through the ppermute schedule (XLA
    overlaps the stage-boundary collectives with compute — the paper's
    prefetch-overlap trick applied to activations)."""
    def mean_loss(params):
        outs = pipeline_apply(layer_fn, params, x_mb, mesh=mesh, axis=axis)
        return jnp.mean(jax.vmap(loss_fn)(outs, y_mb))

    return jax.value_and_grad(mean_loss)(params_staged)
