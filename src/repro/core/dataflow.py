"""Layer-pipelined dataflow executor — H2PIPE's architecture on the mesh.

The paper's accelerator assigns consecutive CNN layers to specialized
engines placed around the die, with activations flowing through small FIFOs
between them.  On the TPU mesh the analogue is pipeline parallelism over the
``model`` axis: each device group owns a contiguous group of layers (a
*stage*), and activations move stage-to-stage with ``lax.ppermute`` inside a
``shard_map`` while every stage computes on a different microbatch — all
stages busy in parallel, exactly Fig. 1.

Key H2PIPE semantics carried over:
  * **continuous streaming** (serving): the static schedule admits one
    microbatch per tick with at most ``n_stages`` in flight — the credit
    bound of §V-A (a static schedule cannot head-of-line block, which is
    the program-level proof of the credit property ``fifo_sim`` checks
    dynamically);
  * **pipeline order = placement order** (§V-B): stage s holds layers
    [s*L/S, (s+1)*L/S) — the clockwise pseudo-channel assignment becomes
    the identity stage mapping;
  * **GPipe-style training**: microbatch gradients accumulate; the bubble
    fraction (S-1)/(M+S-1) is reported by ``pipeline_stats``.

The executor is generic over the per-stage function so the CNN engines, the
transformer layers and the tests' toy layers all use the same machinery.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def split_stages(stacked_params, n_stages: int):
    """[L, ...] stacked layer params -> [S, L/S, ...]."""
    def re(x):
        L = x.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return x.reshape((n_stages, L // n_stages) + x.shape[1:])
    return jax.tree.map(re, stacked_params)


def pipeline_stats(n_stages: int, n_microbatches: int) -> Dict[str, float]:
    total = n_microbatches + n_stages - 1
    return {
        "ticks": total,
        "bubble_fraction": (n_stages - 1) / total,
        "in_flight_credits": n_stages,
    }


def pipeline_apply(layer_fn: Callable, params_staged, x_mb, *, mesh: Mesh,
                   axis: str = "model"):
    """Run microbatches through the stage pipeline.

    layer_fn(stage_params, x) -> x   applies one stage's layer group; it is
        called with the [L/S, ...] slice owned by the local stage.
    params_staged: [S, L/S, ...] pytree (see ``split_stages``).
    x_mb: [M, mb, ...] microbatched input (replicated over ``axis``).

    Returns [M, mb, ...] outputs, valid on every device (the last stage's
    results are broadcast back, like the paper's output DMA).
    """
    n_stages = mesh.shape[axis]
    M = x_mb.shape[0]
    S = n_stages

    def stage_body(params_local, x_local):
        p = jax.tree.map(lambda a: a[0], params_local)   # drop stage dim
        idx = jax.lax.axis_index(axis)
        zero = jnp.zeros_like(x_local[0])

        def tick(buf, t):
            # stage 0 admits microbatch t (one credit per tick; at most S
            # microbatches live at once by the static schedule)
            mb_in = jax.lax.dynamic_index_in_dim(
                x_local, jnp.clip(t, 0, M - 1), keepdims=False)
            my_in = jnp.where(idx == 0, mb_in, buf)
            out = layer_fn(p, my_in)
            # hand off to the next stage around the ring
            perm = [(i, (i + 1) % S) for i in range(S)]
            nxt = jax.lax.ppermute(out, axis, perm)
            # the last stage's output this tick is a finished microbatch
            done = jnp.where(idx == S - 1, out, jnp.zeros_like(out))
            return nxt, done

        _, outs = jax.lax.scan(tick, zero, jnp.arange(M + S - 1))
        outs = outs[S - 1:]                  # microbatch m done at tick m+S-1
        # broadcast the last stage's results to every device
        outs = jax.lax.psum(
            jnp.where(idx == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    p_specs = jax.tree.map(lambda _: P(axis), params_staged)
    fn = shard_map(stage_body, mesh=mesh,
                   in_specs=(p_specs, P()), out_specs=P(),
                   check_rep=False)
    return fn(params_staged, x_mb)


def gpipe_train_step(layer_fn: Callable, loss_fn: Callable, params_staged,
                     x_mb, y_mb, *, mesh: Mesh, axis: str = "model"):
    """GPipe: forward all microbatches through the pipeline, mean loss over
    microbatches, grads by autodiff through the ppermute schedule (XLA
    overlaps the stage-boundary collectives with compute — the paper's
    prefetch-overlap trick applied to activations)."""
    def mean_loss(params):
        outs = pipeline_apply(layer_fn, params, x_mb, mesh=mesh, axis=axis)
        return jnp.mean(jax.vmap(loss_fn)(outs, y_mb))

    return jax.value_and_grad(mean_loss)(params_staged)
