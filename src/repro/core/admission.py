"""Credit-based admission control — the §V-A flow-control law as a value.

H2PIPE never runs one image at a time: the accelerator admits a new
image every initiation interval, with the number in flight bounded by
FIFO credits so no stage can be overrun and no head-of-line blocking is
possible (§V-A; the static schedule in ``core/dataflow.py`` is the same
law compiled into a ``lax.scan``).  Two runtimes need that law at
serving time — the LM batch engine in ``runtime/serving.py`` and the
CNN streaming engine in ``runtime/cnn_serving.py`` — so the slot/credit
bookkeeping they share lives here, once:

:class:`AdmissionController`
    The thread-safe runtime object: ``capacity`` credits, blocking /
    non-blocking ``acquire``, ``release`` on completion, and invariant
    hooks (``max_in_flight_seen``, admitted/completed totals,
    :meth:`check_invariants`) that stress tests assert against — the
    observable proof that producers never exceed the credit bound.

:func:`replay_schedule`
    The same controller driven on a discrete clock: at most one
    admission per tick when a credit is free, completion (and credit
    return) ``latency_ticks`` after admission, completions processed
    after the tick's admission — exactly the cycle ordering of
    ``fifo_sim``'s credit-mode prefetcher (issue before consume within
    a cycle).  The property tests replay this against
    ``fifo_sim.simulate(..., "credit")`` on the single-engine law
    topology and against ``core.dataflow.pipeline_stats`` — the runtime
    admission law and the cycle model provably agree.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional

from repro.obs.trace import monotonic_clock


class AdmissionError(RuntimeError):
    """A credit-accounting invariant was violated (over-release, or a
    closed controller still holding in-flight work)."""


class AdmissionController:
    """Bounded in-flight admission: ``capacity`` credits, one per unit of
    in-flight work (a decode slot, a dispatched microbatch).

    Thread-safe and observable: concurrent producers block in
    :meth:`acquire` until a credit frees; completions :meth:`release`.
    ``max_in_flight_seen`` records the high-water mark so tests can
    assert the credit bound held over an entire concurrent run, not just
    at sample points.

    Credit *wait time* is first-class observability: every blocking
    :meth:`acquire` measures how long the caller sat without a credit on
    the injectable ``clock`` (default ``time.perf_counter``), summed in
    ``wait_seconds_total`` with ``blocked_acquires`` counting acquires
    that had to wait at all — the measured half of the §V-A credit
    stalls that ``fifo_sim`` models, surfaced by the serving reports'
    ``bandwidth_efficiency`` section.
    """

    def __init__(self, capacity: int, *, name: str = "admission",
                 clock: Optional[Callable[[], float]] = None):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.name = name
        self.capacity = capacity
        self.clock = monotonic_clock if clock is None else clock
        self._cv = threading.Condition()
        self._free = capacity
        self._closed = False
        self.max_in_flight_seen = 0
        self.admitted_total = 0
        self.completed_total = 0
        self.wait_seconds_total = 0.0
        self.blocked_acquires = 0

    # -- credit operations ---------------------------------------------------

    @property
    def free_credits(self) -> int:
        with self._cv:
            return self._free

    @property
    def in_flight(self) -> int:
        with self._cv:
            return self.capacity - self._free

    def try_acquire(self) -> bool:
        """Take a credit if one is free; never blocks."""
        with self._cv:
            if self._closed or self._free == 0:
                return False
            self._take_locked()
            return True

    def acquire(self, timeout: Optional[float] = None) -> bool:
        """Block until a credit frees (or ``timeout`` elapses / the
        controller closes).  Returns whether a credit was taken.  Time
        spent blocked accrues to ``wait_seconds_total``."""
        with self._cv:
            if self._free == 0 and not self._closed:
                # counted BEFORE parking, so a watcher can observe a
                # blocked dispatcher while it is still blocked
                self.blocked_acquires += 1
                t0 = self.clock()
                ok = self._cv.wait_for(
                    lambda: self._free > 0 or self._closed, timeout)
                self.wait_seconds_total += self.clock() - t0
                if not ok:
                    return False
            if self._closed:
                return False
            self._take_locked()
            return True

    def release(self, n: int = 1) -> None:
        """Return ``n`` credits (one completed unit each)."""
        with self._cv:
            if n < 0 or self._free + n > self.capacity:
                raise AdmissionError(
                    f"{self.name}: release({n}) with {self._free}/"
                    f"{self.capacity} credits free — more completions "
                    f"than admissions")
            self._free += n
            self.completed_total += n
            self._cv.notify_all()

    @contextmanager
    def slot(self, timeout: Optional[float] = None):
        """``with controller.slot(): ...`` — acquire/release bracket."""
        if not self.acquire(timeout):
            raise AdmissionError(f"{self.name}: no credit within {timeout}s")
        try:
            yield
        finally:
            self.release()

    @property
    def closed(self) -> bool:
        with self._cv:
            return self._closed

    def close(self) -> None:
        """Wake all blocked acquirers; subsequent acquires fail."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    def _take_locked(self) -> None:
        self._free -= 1
        self.admitted_total += 1
        inflight = self.capacity - self._free
        if inflight > self.max_in_flight_seen:
            self.max_in_flight_seen = inflight

    # -- invariant hooks -----------------------------------------------------

    def check_invariants(self) -> None:
        """Raise :class:`AdmissionError` unless every credit law held:
        0 <= free <= capacity, high-water mark within capacity, and
        conservation (admitted - completed == in flight)."""
        with self._cv:
            free = self._free
            if not 0 <= free <= self.capacity:
                raise AdmissionError(
                    f"{self.name}: {free} free credits outside "
                    f"[0, {self.capacity}]")
            if self.max_in_flight_seen > self.capacity:
                raise AdmissionError(
                    f"{self.name}: {self.max_in_flight_seen} in flight "
                    f"exceeded capacity {self.capacity}")
            if self.admitted_total - self.completed_total \
                    != self.capacity - free:
                raise AdmissionError(
                    f"{self.name}: admitted {self.admitted_total} - "
                    f"completed {self.completed_total} != "
                    f"{self.capacity - free} in flight")

    def assert_quiescent(self) -> None:
        """All admitted work completed and every credit returned."""
        self.check_invariants()
        with self._cv:
            if self._free != self.capacity:
                raise AdmissionError(
                    f"{self.name}: {self.capacity - self._free} unit(s) "
                    f"still in flight at shutdown")


# ---------------------------------------------------------------------------
# the admission law on a discrete clock
# ---------------------------------------------------------------------------


@dataclass
class AdmissionTrace:
    """What the tick-law replay did: per-item admission/completion ticks
    plus the aggregates the cycle model predicts."""

    capacity: int
    latency_ticks: int
    admit_ticks: List[int] = field(default_factory=list)
    complete_ticks: List[int] = field(default_factory=list)
    makespan: int = 0                 # tick the last item completed
    max_in_flight: int = 0
    idle_ticks: int = 0               # ticks with no completion (= stalls)


def replay_schedule(n_items: int, *, capacity: int,
                    latency_ticks: int,
                    controller: Optional[AdmissionController] = None
                    ) -> AdmissionTrace:
    """Drive an :class:`AdmissionController` through the static admission
    schedule: one admission per tick when a credit is free; the item
    admitted at tick ``a`` completes (returning its credit) at tick
    ``a + latency_ticks``, processed *after* that tick's admission —
    fifo_sim's credit-mode cycle ordering (prefetcher issue precedes
    engine consume within a cycle), and ``core/dataflow.py``'s schedule
    when ``latency_ticks = n_stages - 1`` (microbatch ``m`` admitted at
    tick ``m`` leaves the pipe at tick ``m + S - 1``: makespan
    ``M + S - 1``, ``pipeline_stats``'s tick count).

    Passing a ``controller`` verifies that *instance*'s bookkeeping tick
    for tick; by default a fresh one of ``capacity`` credits is used.
    The law is real code, not a closed form — the property tests equate
    it with ``fifo_sim.simulate(..., "credit")`` on the single-engine
    topology (makespan, stalls and the in-flight bound all match).
    """
    if latency_ticks < 0:
        raise ValueError("latency_ticks must be >= 0")
    ctl = controller if controller is not None \
        else AdmissionController(capacity, name="replay")
    if ctl.capacity != capacity:
        raise ValueError(f"controller capacity {ctl.capacity} != {capacity}")
    if ctl.closed or ctl.free_credits < capacity:
        raise ValueError(
            f"controller must be open and idle to replay the schedule "
            f"(closed={ctl.closed}, {ctl.free_credits}/{capacity} free)")
    trace = AdmissionTrace(capacity=capacity, latency_ticks=latency_ticks)
    inflight: dict = {}               # completion tick -> count
    pending = n_items
    tick = 0
    while len(trace.complete_ticks) < n_items:
        tick += 1
        if pending and ctl.try_acquire():
            pending -= 1
            trace.admit_ticks.append(tick)
            done_at = tick + latency_ticks
            inflight[done_at] = inflight.get(done_at, 0) + 1
        trace.max_in_flight = max(trace.max_in_flight, ctl.in_flight)
        done = inflight.pop(tick, 0)
        if done:
            ctl.release(done)
            trace.complete_ticks.extend([tick] * done)
        else:
            trace.idle_ticks += 1
        ctl.check_invariants()
    trace.makespan = tick
    if controller is None:
        ctl.assert_quiescent()
    return trace


# ---------------------------------------------------------------------------
# weighted-fair, deadline-aware tenant scheduling (the front-end tier)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HeadOfQueue:
    """What the scheduler needs to know about one backlogged tenant:
    the service cost of its head request (images — the currency the
    weights are fair over) and, optionally, that request's ABSOLUTE
    deadline on the caller's clock."""

    cost: float
    deadline: Optional[float] = None


class WeightedFairScheduler:
    """Deficit round-robin over registered tenants, with deadline-aware
    promotion — the multi-tenant scheduling tier LAYERED OVER the
    unchanged :class:`AdmissionController` (the §V-A credit invariants
    and their property tests stay exactly as they are; this class only
    decides *whose* request is offered to the credit bound next).

    The law, per :meth:`pick` call over the currently backlogged tenants:

      * a tenant whose head request's slack (``deadline - now``) has gone
        NEGATIVE is promoted immediately, most-overdue first, regardless
        of weights — its cost is still charged against its deficit (which
        may go negative), so a tenant cannot use deadlines to escape its
        long-run weighted share;
      * otherwise classic DRR: visiting a backlogged tenant grants it
        ``quantum * weight`` of deficit once per visit; it is served
        while its deficit covers the head cost, then the cursor moves
        on.  Long-run delivered cost is proportional to weight for
        continuously backlogged tenants (property-tested);
      * a tenant observed with an EMPTY queue has its deficit reset —
        an idle tenant must not hoard credit and then burst past its
        share (standard DRR).

    Thread-compatibility: calls are expected from ONE scheduling thread
    (the front-end dispatcher); the class keeps no locks of its own.
    """

    def __init__(self, *, quantum: float = 1.0):
        if quantum <= 0:
            raise ValueError(f"quantum must be positive, got {quantum}")
        self.quantum = quantum
        self._weights: Dict[Any, float] = {}
        self._deficit: Dict[Any, float] = {}
        self._ring: List[Any] = []        # registration order
        self._cursor = 0
        self._granted = False             # quantum granted at this stop?
        self.picks: Dict[Any, int] = {}
        self.served_cost: Dict[Any, float] = {}
        self.promotions = 0

    # -- registration --------------------------------------------------------

    def register(self, key: Any, weight: float = 1.0) -> None:
        if weight <= 0:
            raise ValueError(
                f"tenant {key!r}: weight must be positive, got {weight}")
        if key in self._weights:
            raise ValueError(f"tenant {key!r} already registered")
        self._weights[key] = float(weight)
        self._deficit[key] = 0.0
        self._ring.append(key)
        self.picks[key] = 0
        self.served_cost[key] = 0.0

    def unregister(self, key: Any) -> None:
        if key not in self._weights:
            raise ValueError(f"tenant {key!r} not registered")
        at = self._ring.index(key)
        del self._ring[at]
        del self._weights[key]
        del self._deficit[key]
        if not self._ring:
            self._cursor = 0
            self._granted = False
            return
        if at < self._cursor:
            self._cursor -= 1
        elif at == self._cursor:
            self._granted = False
        self._cursor %= len(self._ring)

    @property
    def tenants(self) -> List[Any]:
        return list(self._ring)

    def weight(self, key: Any) -> float:
        return self._weights[key]

    # -- scheduling ----------------------------------------------------------

    def pick(self, backlog: Mapping[Any, HeadOfQueue], *,
             now: float = 0.0) -> Any:
        """Choose which backlogged tenant's head request is served next
        and charge its cost.  ``backlog`` maps registered tenant keys to
        their :class:`HeadOfQueue`; tenants absent from it are treated
        as idle (deficit reset).  Raises :class:`ValueError` on an empty
        or unknown backlog."""
        if not backlog:
            raise ValueError("pick() needs at least one backlogged tenant")
        for key in backlog:
            if key not in self._weights:
                raise ValueError(f"tenant {key!r} not registered")
        # deadline promotion: any head whose slack went negative is
        # served now, most overdue first (ties: registration order)
        overdue = sorted(
            (h.deadline - now, self._ring.index(k), k)
            for k, h in backlog.items()
            if h.deadline is not None and h.deadline - now <= 0.0)
        if overdue:
            _, _, key = overdue[0]
            self.promotions += 1
            self._serve(key, backlog[key].cost)
            return key
        # classic DRR from the cursor
        idle = [k for k in self._ring if k not in backlog]
        for k in idle:
            self._deficit[k] = 0.0
        # each full ring pass grants every backlogged tenant one quantum,
        # so the loop terminates in <= max(cost / (quantum * weight))
        # passes; the cap only trips on a pathological cost/quantum ratio
        for _ in range(1000 * max(1, len(self._ring))):
            key = self._ring[self._cursor]
            head = backlog.get(key)
            if head is None:
                self._advance()
                continue
            if not self._granted:
                self._deficit[key] += self.quantum * self._weights[key]
                self._granted = True
            if self._deficit[key] >= head.cost - 1e-9:
                self._serve(key, head.cost)
                return key
            self._advance()
        raise RuntimeError(
            "WeightedFairScheduler.pick did not converge — head cost "
            "vastly exceeds quantum * weight; raise the quantum")

    def _advance(self) -> None:
        self._cursor = (self._cursor + 1) % len(self._ring)
        self._granted = False

    def _serve(self, key: Any, cost: float) -> None:
        self._deficit[key] -= cost
        self.picks[key] += 1
        self.served_cost[key] += cost


def jain_fairness(shares: Mapping[Any, float]) -> float:
    """Jain's fairness index over per-tenant normalized shares
    (``sum(x)^2 / (n * sum(x^2))``): 1.0 when every share is equal,
    ``1/n`` when one tenant holds everything.  Used by the front-end
    report over delivered images/s divided by tenant weight."""
    xs = [float(v) for v in shares.values()]
    if not xs:
        return 1.0
    sq = sum(x * x for x in xs)
    if sq == 0.0:
        return 1.0
    return (sum(xs) ** 2) / (len(xs) * sq)


# ---------------------------------------------------------------------------
# the admission law over a STAGED topology (the sharded mesh pipeline)
# ---------------------------------------------------------------------------


@dataclass
class StagedTrace:
    """What the staged replay did: :class:`AdmissionTrace` aggregates
    plus the per-stage occupancy proof for the S-stage ring."""

    n_stages: int
    capacity: int
    admit_ticks: List[int] = field(default_factory=list)
    complete_ticks: List[int] = field(default_factory=list)
    makespan: int = 0
    max_in_flight: int = 0
    idle_ticks: int = 0
    #: max simultaneous microbatches observed on any single stage — the
    #: staged law says a stage holds at most ONE per tick (checked,
    #: not assumed)
    max_stage_occupancy: int = 0


def replay_staged_schedule(n_items: int, *, n_stages: int,
                           capacity: Optional[int] = None,
                           controller: Optional[AdmissionController] = None
                           ) -> StagedTrace:
    """Drive the (unchanged) :class:`AdmissionController` through the
    STAGED static schedule of ``core/dataflow.py``'s mesh pipeline: one
    admission per tick when a credit is free, the admitted microbatch
    hopping one stage per tick (stage ``s`` at tick ``a + s``) and
    returning its credit after the last stage, at tick
    ``a + n_stages - 1`` — ``staged_pipeline_apply``'s schedule, and
    :func:`replay_schedule` at ``latency_ticks = n_stages - 1``.

    Beyond the flat replay this checks the law the split topology adds:
    every stage of the ring holds at most ONE microbatch per tick
    (computed from the admission ticks, raising
    :class:`AdmissionError` on violation), so a ``capacity >= n_stages``
    bound admits back-to-back with makespan ``M + S - 1``
    (``pipeline_stats``'s tick count) and a tighter bound only ever
    STALLS admission — it can never overrun a stage.
    """
    if n_stages < 1:
        raise ValueError(f"n_stages must be >= 1, got {n_stages}")
    capacity = n_stages if capacity is None else capacity
    ctl = controller if controller is not None \
        else AdmissionController(capacity, name="staged-replay")
    if ctl.capacity != capacity:
        raise ValueError(f"controller capacity {ctl.capacity} != {capacity}")
    if ctl.closed or ctl.free_credits < capacity:
        raise ValueError(
            f"controller must be open and idle to replay the schedule "
            f"(closed={ctl.closed}, {ctl.free_credits}/{capacity} free)")
    trace = StagedTrace(n_stages=n_stages, capacity=capacity)
    live: List[int] = []              # admit ticks of in-flight items
    pending = n_items
    tick = 0
    while len(trace.complete_ticks) < n_items:
        tick += 1
        if pending and ctl.try_acquire():
            pending -= 1
            trace.admit_ticks.append(tick)
            live.append(tick)
        trace.max_in_flight = max(trace.max_in_flight, ctl.in_flight)
        # ring occupancy this tick: item admitted at a sits on stage
        # tick - a while 0 <= tick - a < S
        stages = [tick - a for a in live if 0 <= tick - a < n_stages]
        occupancy = max((stages.count(s) for s in set(stages)), default=0)
        trace.max_stage_occupancy = max(trace.max_stage_occupancy,
                                        occupancy)
        if occupancy > 1:
            raise AdmissionError(
                f"staged replay: a stage held {occupancy} microbatches "
                f"at tick {tick} — the static schedule was violated")
        done = [a for a in live if tick - a == n_stages - 1]
        if done:
            live = [a for a in live if tick - a != n_stages - 1]
            ctl.release(len(done))
            trace.complete_ticks.extend([tick] * len(done))
        else:
            trace.idle_ticks += 1
        ctl.check_invariants()
    trace.makespan = tick
    if controller is None:
        ctl.assert_quiescent()
    return trace
