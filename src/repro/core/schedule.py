"""Executable pipeline schedule — Algorithm 1 fused with FIFO sizing.

The planner pieces each answer one question: ``placement`` decides *which*
layers stream weights from HBM (Eq. 1 / Algorithm 1) and how much
parallelism each engine gets; ``hbm_model`` sizes the FIFOs that make the
streams safe (§III-B/§IV-A); ``fifo_sim`` proves the flow control live
(§V-A).  The staged compiler (``repro.compiler.compile``) fuses all three
into one *executable* schedule: per layer, the weight tier (pinned vs
HBM-streamed), the pseudo-channel, the burst length, and the
FIFO/double-buffer depths the runtime executor
(``repro.runtime.pipeline``) instantiates as Pallas kernel
configurations.  This module owns the schedule *data model*
(:class:`LayerSchedule` / :class:`PipelinePlan`) plus the deprecated
``build_pipeline_plan`` shim; the passes themselves live in
``repro.compiler.pipeline``.

Units: weight traffic is counted in 80-bit tensor-chain words (the
granularity a pseudo-channel feeds, §III-B); a streamed layer re-reads its
kernel once per output row (Eq. 2), so
``weight_words_per_image = weight_words_per_row * out_h``.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.cnn import CNNConfig, ConvLayerSpec
from repro.core import fifo_sim, hbm_model, placement
from repro.core.placement import CHAIN_BITS, LayerPlan

PINNED = "pinned"                 # weights resident on chip (M20K / VMEM)
HBM = "hbm"                       # weights double-buffer-streamed from HBM


@dataclass(frozen=True)
class LayerSchedule:
    """Everything the runtime needs to instantiate one layer engine."""

    spec: ConvLayerSpec
    mode: str                     # PINNED | HBM
    p_i: int
    p_o: int
    pc: Optional[int]             # pseudo-channel when streamed
    burst: int                    # HBM words per read request
    laststage_fifo_depth: int     # words; §IV-A latency-covering FIFO
    bm_fifo_words: int            # burst-matching SCFIFO depth
    n_buffers: int                # executable double-buffer ring depth

    @property
    def streamed(self) -> bool:
        return self.mode == HBM

    @property
    def weight_words_per_row(self) -> int:
        """80-bit chain words one weight re-read costs (Eq. 2 numerator)."""
        return -(-self.spec.weight_bits(8) // CHAIN_BITS)

    @property
    def weight_words_per_image(self) -> int:
        """Streamed layers re-read kernels once per output row (Eq. 2)."""
        return self.weight_words_per_row * self.spec.out_h


@dataclass(frozen=True)
class PipelinePlan:
    """The fused, executable schedule for one CNN."""

    cfg: CNNConfig
    schedules: Tuple[LayerSchedule, ...]
    placements: Tuple[LayerPlan, ...]     # Algorithm 1 output (read-only)
    burst: int
    n_pc: int

    @functools.cached_property
    def _schedule_index(self) -> Dict[str, LayerSchedule]:
        """name -> schedule map, built once per plan (plans are frozen;
        ``dataclasses.replace`` derivatives get a fresh cache)."""
        return {s.spec.name: s for s in self.schedules}

    def schedule_for(self, name: str) -> LayerSchedule:
        return self._schedule_index[name]

    def schedules_for(self, names: Sequence[str]
                      ) -> Tuple[LayerSchedule, ...]:
        """Member schedules of a fused unit (e.g. a residual block bound
        to one block engine), in the given order — the granularity the
        compiler costs and the block engines execute."""
        return tuple(self._schedule_index[n] for n in names)

    @property
    def streamed(self) -> Tuple[LayerSchedule, ...]:
        return tuple(s for s in self.schedules if s.streamed)

    @property
    def pinned(self) -> Tuple[LayerSchedule, ...]:
        return tuple(s for s in self.schedules if not s.streamed)

    @property
    def streamed_names(self) -> Tuple[str, ...]:
        return tuple(s.spec.name for s in self.streamed)

    def hbm_words_per_image(self) -> Dict[str, int]:
        """Eq. 2 weight traffic per image, per streamed layer."""
        return {s.spec.name: s.weight_words_per_image for s in self.streamed}

    def throughput(self) -> Dict[str, float]:
        """The §VI throughput model over this plan's placements."""
        return placement.pipeline_throughput(
            self.placements, burst=self.burst, n_pc=self.n_pc)

    # -- fifo_sim bridge ----------------------------------------------------

    def sim_config(self, outputs_needed: int = 32,
                   word_scale: Optional[int] = None
                   ) -> Tuple[fifo_sim.SimConfig, int]:
        """Map the streamed layers onto the §V-A weight-distribution sim:
        engines in pipeline order share one DCFIFO, each consuming
        ``weight_words_per_row`` words per activation (one activation ==
        one output row).  ``word_scale`` divides word counts so big layers
        simulate quickly (auto-picked to keep <=64 words/act); returns
        (config, scale) so callers can rescale totals back."""
        # only nodes with nonzero Eq. 2 demand enter the sim: weightless
        # topology nodes (maxpool / GAP) never hold the HBM tier under
        # compile(), but a caller-forced plan could place one there — a
        # zero-word engine would otherwise round up to 1 word/act and
        # corrupt the counters, so they are filtered here
        streamed = tuple(s for s in self.streamed
                         if s.weight_words_per_row > 0)
        if not streamed:
            raise ValueError("plan streams no weight words; "
                             "nothing to simulate")
        wpr = [s.weight_words_per_row for s in streamed]
        if word_scale is None:
            word_scale = max(1, max(wpr) // 64)
        wpa = tuple(max(1, w // word_scale) for w in wpr)
        lat_cycles = max(1, int(hbm_model.read_latency_ns(self.burst, "avg")
                                * hbm_model.FABRIC_MHZ / 1e3))
        # the per-layer credit pool is the burst-matching FIFO the
        # schedules actually carry (identical to the §IV-A 2-burst sizing
        # for compiler-built plans; the autotuner deepens it per plan),
        # never smaller than one burst or the prefetcher could not issue
        bm_depth = max(min(s.bm_fifo_words for s in streamed), self.burst)
        cfg = fifo_sim.SimConfig(
            n_layers=len(streamed),
            burst=self.burst,
            bm_fifo_depth=bm_depth,
            act_fifo_depth=2,
            dcfifo_depth=max(2 * self.burst, 16),
            hbm_latency=lat_cycles,
            weights_per_act=wpa,
            outputs_needed=outputs_needed,
        )
        return cfg, word_scale

    def predict_stalls(self, outputs_needed: int = 32,
                       word_scale: Optional[int] = None
                       ) -> fifo_sim.SimOutcome:
        """Credit-mode discrete-event prediction of tail-engine stalls for
        the streamed subset (the §V-A liveness + §IV-A sizing check)."""
        cfg, _ = self.sim_config(outputs_needed, word_scale)
        return fifo_sim.simulate(cfg, "credit")

    # -- overrides ----------------------------------------------------------

    def with_offload(self, names: Sequence[str]) -> "PipelinePlan":
        """Plan with the offload set forced to exactly ``names`` — used by
        tests and demos to exercise the streamed path on configs whose
        Eq. 1 scores keep everything on chip."""
        names = set(names)
        unknown = names - {s.spec.name for s in self.schedules}
        if unknown:
            raise KeyError(sorted(unknown))
        new_places = []
        for p in self.placements:
            q = dataclasses.replace(p)
            q.offload = p.spec.name in names
            q.pc = None
            new_places.append(q)
        placement.assign_pseudo_channels(new_places, n_pc=self.n_pc)
        scheds = tuple(
            dataclasses.replace(
                s, mode=HBM if s.spec.name in names else PINNED,
                pc=q.pc)
            for s, q in zip(self.schedules, new_places))
        return dataclasses.replace(self, schedules=scheds,
                                   placements=tuple(new_places))


@dataclass(frozen=True)
class ScanGroup:
    """A run of consecutive residual blocks the fused trace compiles as
    ONE scanned body: identical member shapes (``block_shape_signature``)
    AND identical member schedules (weight tier, buffer ring depth, FIFO
    depths — everything that changes the executed computation; the
    pseudo-channel may differ, it is bandwidth bookkeeping).  Per-block
    params stack along a leading axis and ``lax.scan`` iterates the one
    traced body over them, so the jaxpr size is independent of the run
    length — the haliax ``Stacked`` scan-over-layers idiom at block
    granularity."""

    name: str                               # "scan:s2b1..s2b5"
    blocks: Tuple[str, ...]                 # member block names, order
    members: Tuple[Tuple[str, ...], ...]    # per-block member layer names
    layer_range: Tuple[int, int]            # [start, stop) into cfg.layers

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    @property
    def member_names(self) -> Tuple[str, ...]:
        """All member layer names across the group, config order."""
        return tuple(n for ms in self.members for n in ms)


def _schedule_signature(s: LayerSchedule) -> Tuple:
    """The schedule fields that change what a member dispatch COMPUTES
    (tier, parallelism, burst, FIFO/buffer depths).  ``pc`` is excluded:
    which pseudo-channel feeds a streamed engine is plan bookkeeping,
    not execution semantics."""
    return (s.mode, s.p_i, s.p_o, s.burst, s.laststage_fifo_depth,
            s.bm_fifo_words, s.n_buffers)


def detect_scan_groups(plan: "PipelinePlan") -> Tuple[ScanGroup, ...]:
    """The plan's scannable block runs: each shape-homogeneous run
    (:func:`repro.configs.cnn.homogeneous_block_runs`) split into maximal
    sub-runs of >= 2 blocks whose member schedules also agree position by
    position — Algorithm 1 may pin one repeat of a stage and stream
    another, and such blocks must NOT share a scanned body (the body is
    traced once, so every iteration executes the same tier/buffer
    configuration)."""
    from repro.configs.cnn import homogeneous_block_runs
    idx = {l.name: i for i, l in enumerate(plan.cfg.layers)}
    groups: List[ScanGroup] = []

    def sched_sig(block) -> Tuple:
        return tuple(_schedule_signature(plan.schedule_for(m.name))
                     for m in block.members)

    def flush(cur) -> None:
        if len(cur) < 2:
            return
        blocks = tuple(b.name for b in cur)
        groups.append(ScanGroup(
            name=f"scan:{blocks[0]}..{blocks[-1]}",
            blocks=blocks,
            members=tuple(tuple(m.name for m in b.members) for b in cur),
            layer_range=(idx[cur[0].members[0].name],
                         idx[cur[-1].members[-1].name] + 1)))

    for run in homogeneous_block_runs(plan.cfg):
        cur = [run[0]]
        for prev, b in zip(run, run[1:]):
            if sched_sig(b) == sched_sig(prev):
                cur.append(b)
            else:
                flush(cur)
                cur = [b]
        flush(cur)
    return tuple(groups)


def build_pipeline_plan(cfg: CNNConfig, *,
                        tb_budget: Optional[int] = None,
                        bram_m20ks: Optional[int] = None,
                        burst: int = 8,
                        n_pc: int = hbm_model.USABLE_PCS,
                        n_buffers: int = 2) -> PipelinePlan:
    """DEPRECATED shim over the staged compiler (``repro.compiler``).

    Use ``repro.compiler.compile(cfg, target)`` instead: the keyword
    defaults this function hard-coded are now explicit :class:`Target`
    descriptors (``NX2100`` reproduces these defaults exactly), and the
    compiler additionally binds every layer to a registered engine and
    validates the VMEM budget.  This shim preserves the PRE-compiler
    behavior verbatim: it runs stages 1-3 only
    (``compiler.plan_pipeline``) — no engine binding, no VMEM
    validation/re-placement — so existing callers keep their exact
    placements for any budget.  ``compile()`` adds the new checks.
    """
    warnings.warn(
        "build_pipeline_plan is deprecated; use repro.compiler.compile("
        "cfg, target) with a Target descriptor (repro.compiler.NX2100 "
        "reproduces the old defaults)", DeprecationWarning, stacklevel=2)
    from repro import compiler
    changes: Dict[str, object] = dict(burst=burst, n_pc=n_pc,
                                      n_buffers=n_buffers)
    if tb_budget is not None:
        changes["tb_budget"] = tb_budget
    if bram_m20ks is not None:
        changes["bram_m20ks"] = bram_m20ks
    return compiler.plan_pipeline(cfg, compiler.NX2100.replace(**changes))
