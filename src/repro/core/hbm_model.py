"""HBM efficiency / latency model — the paper's §III-A characterization.

The paper measures an HBM2 pseudo-channel on Stratix 10 NX under the
accelerator's own address pattern (interleaved, non-sequential reads from
several consumers): read/write efficiency as a function of burst length
(Fig. 3a) and *saturated* read latency (Fig. 3b).  We encode those curves as
a calibrated analytic model plus a cycle-level traffic simulator so every
downstream artifact (FIFO sizing, Alg. 1 budgets, Table II, Fig. 6) derives
from the same characterization, exactly as in the paper.

Hardware constants (Stratix 10 NX2100, -2 speed grade, §II-C):
  * 2 stacks x 16 pseudo-channels, 256-bit controller interface @ 400 MHz
  * 204.8 GB/s per stack -> 409.6 GB/s total raw
  * fabric (layer-engine) clock: 300 MHz

The TPU-v5e analogues used by the LM side of the framework live in
``repro.roofline.hw`` — this module is deliberately kept in the paper's own
units so the reproduction is checkable against the paper's numbers.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# constants (paper values)
# ---------------------------------------------------------------------------

N_STACKS = 2
PCS_PER_STACK = 16
N_PCS = N_STACKS * PCS_PER_STACK                 # 32
PC_IF_BITS = 256                                 # controller word
PC_IF_MHZ = 400.0                                # controller clock
FABRIC_MHZ = 300.0                               # layer-engine clock
PC_BW_BYTES = PC_IF_BITS / 8 * PC_IF_MHZ * 1e6   # 12.8 GB/s per PC
STACK_BW_BYTES = PC_BW_BYTES * PCS_PER_STACK     # 204.8 GB/s
TOTAL_BW_BYTES = STACK_BW_BYTES * N_STACKS       # 409.6 GB/s

# §VI-B effective bandwidth: 31 of 32 PCs usable (PC16 timing closure),
# 240 of 256 bits consumed (80-bit tensor-chain granularity), fabric clock.
USABLE_PCS = 31
USABLE_BITS = 240
EFFECTIVE_BW_BYTES = USABLE_PCS * USABLE_BITS / 8 * FABRIC_MHZ * 1e6  # 279 GB/s

# Fig. 3a measured read efficiency at saturation, random/interleaved pattern.
# Keys are burst lengths (controller words per request).
READ_EFFICIENCY: Dict[int, float] = {
    1: 0.44, 2: 0.46, 4: 0.49, 8: 0.83, 16: 0.89, 32: 0.93,
}
# Write efficiency peaks ~15 points below read (§III-A).
WRITE_EFFICIENCY: Dict[int, float] = {
    1: 0.40, 2: 0.42, 4: 0.45, 8: 0.68, 16: 0.74, 32: 0.78,
}
# Fig. 3b saturated read latency (ns): (min, avg, max) per burst length.
READ_LATENCY_NS: Dict[int, Tuple[float, float, float]] = {
    4: (180.0, 680.0, 1950.0),
    8: (180.0, 560.0, 1214.0),
    16: (180.0, 470.0, 1100.0),
    32: (180.0, 400.0, 1000.0),
}
IDLE_LATENCY_NS = 450.0          # unsaturated / sequential, any burst length


def _interp(table: Dict[int, float], burst: int) -> float:
    keys = sorted(table)
    if burst <= keys[0]:
        return table[keys[0]]
    if burst >= keys[-1]:
        return table[keys[-1]]
    for lo, hi in zip(keys, keys[1:]):
        if lo <= burst <= hi:
            f = (burst - lo) / (hi - lo)
            return table[lo] * (1 - f) + table[hi] * f
    raise AssertionError


def read_efficiency(burst: int) -> float:
    """Fraction of controller cycles that accept a read at saturation."""
    return _interp(READ_EFFICIENCY, burst)


def write_efficiency(burst: int) -> float:
    return _interp(WRITE_EFFICIENCY, burst)


def read_latency_ns(burst: int, which: str = "avg") -> float:
    idx = {"min": 0, "avg": 1, "max": 2}[which]
    keys = sorted(READ_LATENCY_NS)
    b = min(keys, key=lambda k: abs(k - max(burst, keys[0])))
    if burst <= 2:
        b = 4
    return READ_LATENCY_NS[b][idx]


def pc_effective_read_bw(burst: int) -> float:
    """Bytes/s one pseudo-channel sustains for the interleaved read pattern."""
    return PC_BW_BYTES * read_efficiency(burst)


# ---------------------------------------------------------------------------
# FIFO sizing (§III-B / §IV-A)
# ---------------------------------------------------------------------------


def min_laststage_fifo_depth(burst: int = 8,
                             fabric_mhz: float = FABRIC_MHZ) -> int:
    """Words needed to keep a tensor chain fed across the worst-case
    saturated read latency.  Paper: 1214 ns @ 300 MHz -> 364 cycles ->
    512-deep FIFOs (next power of two)."""
    worst_ns = read_latency_ns(burst, "max")
    cycles = int(worst_ns * fabric_mhz / 1e3) + 1
    depth = 1
    while depth < cycles:
        depth *= 2
    return depth


def burst_matching_fifo_words(burst: int) -> int:
    """Burst-matching SCFIFO depth grows proportionally to burst length
    (§IV-A): hold 2 bursts (ping/pong) of 256-bit words."""
    return 2 * burst


def fifo_m20k_cost(burst: int, laststage_depth: Optional[int] = None,
                   bm_words: Optional[int] = None) -> int:
    """On-chip RAM cost (M20K blocks) of one layer's HBM plumbing: the
    80-bit last-stage FIFO costs 2 M20Ks per 512 of depth (two 512x40
    blocks side by side), burst-matching adds ceil(words*256b / 20kb).

    Depths default to the §IV-A sizing for ``burst`` (the pre-autotuner
    behavior: 512-deep last stage, 2-burst matching); the placement/FIFO
    co-optimizer passes its tuned depths explicitly so deeper FIFOs are
    charged against the BRAM budget they actually occupy."""
    if laststage_depth is None:
        laststage_depth = min_laststage_fifo_depth(burst)
    if bm_words is None:
        bm_words = burst_matching_fifo_words(burst)
    last_stage = 2 * -(-laststage_depth // 512)
    bm_bits = bm_words * 256
    return last_stage + -(-bm_bits // 20480)


# ---------------------------------------------------------------------------
# cycle-level pseudo-channel traffic simulator
# ---------------------------------------------------------------------------


@dataclass
class ReadRequest:
    consumer: int          # which layer engine / tensor-chain group
    burst: int             # controller words
    issue_cycle: int


@dataclass
class SimResult:
    cycles: int
    accepted: int                 # transactions accepted
    words_delivered: int
    efficiency: float             # accepted-cycles / total-cycles
    mean_latency_cycles: float
    max_latency_cycles: float
    per_consumer_words: Dict[int, int]


def simulate_pc(requests: Sequence[ReadRequest], burst: int,
                seed: int = 0) -> SimResult:
    """Simulate one pseudo-channel controller servicing an interleaved
    read stream at saturation.

    The controller accepts one request per cycle with probability
    eff(burst) (bank conflicts / refresh are folded into the acceptance
    process, as the paper's measured efficiency does); data is returned
    ``latency`` cycles later over ``burst`` consecutive cycles.  A simple
    LCG supplies deterministic pseudo-randomness.
    """
    eff = read_efficiency(burst)
    lat_cyc = int(read_latency_ns(burst, "avg") * PC_IF_MHZ / 1e3)
    jitter = int((read_latency_ns(burst, "max")
                  - read_latency_ns(burst, "avg")) * PC_IF_MHZ / 1e3)
    state = (seed * 6364136223846793005 + 1442695040888963407) % 2**64
    accepted = 0
    words = 0
    latencies: List[int] = []
    per_consumer: Dict[int, int] = {}
    cycle = 0
    queue = list(requests)
    while queue:
        req = queue[0]
        cycle = max(cycle + 1, req.issue_cycle)
        # the data bus moves one 256-bit word per cycle with probability
        # eff(burst) — bank conflicts/refresh folded into the acceptance
        # process, so sustained words/cycle == the measured curve
        state = (state * 6364136223846793005 + 1442695040888963407) % 2**64
        u = (state >> 33) / 2**31
        if u < eff:
            words += 1
            per_consumer[req.consumer] = \
                per_consumer.get(req.consumer, 0) + 1
            # a request completes after its burst-th word
            if not hasattr(req, "_served"):
                req._served = 0
            req._served += 1
            if req._served >= req.burst:
                queue.pop(0)
                accepted += 1
                state = (state * 6364136223846793005
                         + 1442695040888963407) % 2**64
                extra = int(((state >> 33) / 2**31) * jitter)
                latencies.append(lat_cyc + extra + req.burst)
    total_cycles = max(cycle, 1)
    return SimResult(
        cycles=total_cycles,
        accepted=accepted,
        words_delivered=words,
        efficiency=words / total_cycles,
        mean_latency_cycles=(sum(latencies) / len(latencies)) if latencies else 0,
        max_latency_cycles=max(latencies) if latencies else 0,
        per_consumer_words=per_consumer,
    )


def interleaved_stream(n_consumers: int, bursts_per_consumer: int,
                       burst: int) -> List[ReadRequest]:
    """The paper's §III-B pattern: several tensor-chain groups round-robin
    their read addresses over one pseudo-channel (non-sequential)."""
    reqs = []
    for i in range(bursts_per_consumer):
        for c in range(n_consumers):
            reqs.append(ReadRequest(consumer=c, burst=burst, issue_cycle=0))
    return reqs
