# The paper's planning passes (placement, FIFO sizing, fifo_sim) and the
# schedule data model live here; the staged compile() API that fuses them
# and binds layer engines lives in ``repro.compiler``.
# ``build_pipeline_plan`` is a deprecation shim over that compiler.
from repro.core.schedule import (HBM, PINNED, LayerSchedule,  # noqa: F401
                                 PipelinePlan, build_pipeline_plan)
