# The paper's planning passes (placement, FIFO sizing, fifo_sim), the
# schedule data model, and the §V-A credit-admission law live here; the
# staged compile() API that fuses them and binds layer engines lives in
# ``repro.compiler``.
# ``build_pipeline_plan`` is a deprecation shim over that compiler.
from repro.core.admission import (AdmissionController,  # noqa: F401
                                  AdmissionError, AdmissionTrace,
                                  HeadOfQueue, WeightedFairScheduler,
                                  jain_fairness, replay_schedule)
from repro.core.schedule import (HBM, PINNED, LayerSchedule,  # noqa: F401
                                 PipelinePlan, build_pipeline_plan)
