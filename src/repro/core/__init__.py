# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
from repro.core.schedule import (HBM, PINNED, LayerSchedule,  # noqa: F401
                                 PipelinePlan, build_pipeline_plan)
