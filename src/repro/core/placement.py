"""Layer selection for HBM offload — Eq. 1 + Algorithm 1 (§V-B), plus the
HPIPE parallelism allocator that produces the (p_i, p_o) the score consumes,
and the clockwise pseudo-channel assignment.

Units follow the paper exactly:
  * memory in M20K blocks (20480 bits each); offloading a layer's weight
    buffer frees its M20Ks but pays 2 M20Ks for the 512x80b last-stage FIFO
    (the ``- 2`` in Eq. 1) — burst-matching cost is added separately;
  * bandwidth in 80-bit tensor-chain feeds: a layer consumes p_i*p_o chains,
    one pseudo-channel feeds 3 (240 of 256 bits).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.cnn import CNNConfig, ConvLayerSpec
from repro.core import hbm_model

M20K_BITS = 20480
CHAIN_BITS = 80
CHAINS_PER_PC = 3                 # 240 of 256 bits per PC (§III-B)

# Pooling topology nodes are placed and costed like every engine (their
# activation line buffers land in the BRAM budget), but they are
# weightless: no M20Ks to save (Eq. 1 score is negative, so Algorithm 1
# never offloads them), no AI-TBs to balance, comparator/accumulator
# cycles off the critical path.


# ---------------------------------------------------------------------------
# parallelism allocation (the HPIPE compiler's balancing pass, §II-B)
# ---------------------------------------------------------------------------


@dataclass
class LayerPlan:
    spec: ConvLayerSpec
    p_i: int = 1
    p_o: int = 1
    offload: bool = False          # True -> weights in HBM
    pc: Optional[int] = None       # pseudo-channel id when offloaded

    @property
    def cycles_per_image(self) -> int:
        """Compute cycles with full-width parallelism: each cycle one
        (p_i x 10-weight, p_o-channel) chain group advances all out_w
        positions; rows are processed line by line.  Pool nodes sweep one
        output line per cycle on fabric comparators/accumulators — never
        the pipeline bottleneck."""
        s = self.spec
        if s.is_pool:
            return s.out_h
        ci_eff = s.c_in if s.kind != "dwconv" else 1
        co_eff = s.c_out if s.kind != "dwconv" else s.c_in
        depth = -(-ci_eff * s.k_h * s.k_w // (10 * self.p_i))
        chans = -(-co_eff // self.p_o)
        return s.out_h * depth * chans

    @property
    def tensor_blocks(self) -> int:
        """AI-TBs consumed: one chain covers 3 adjacent output columns.
        Pool nodes do no MACs and consume none."""
        if self.spec.is_pool:
            return 0
        return self.p_i * self.p_o * -(-self.spec.out_w // 3)

    @property
    def weight_m20ks(self) -> int:
        """On-chip weight memory in M20Ks (the Eq. 1 numerator's first term,
        including the output_width/18 duplication factor for fanout)."""
        blocks = -(-self.spec.weight_bits(8) // M20K_BITS)
        dup = -(-self.spec.out_w // 18)
        return blocks * dup

    @property
    def chains(self) -> int:
        """HBM bandwidth demand in 80-bit chain feeds (Eq. 1 denominator)."""
        return self.p_i * self.p_o


def allocate_parallelism(cfg: CNNConfig, tb_budget: int,
                         fabric_mhz: float = hbm_model.FABRIC_MHZ
                         ) -> List[LayerPlan]:
    """Greedy pipeline balancing: repeatedly double (p_i or p_o) of the
    bottleneck layer while tensor blocks remain (HPIPE's compiler strategy:
    'increase the throughput of layers that would otherwise bottleneck')."""
    plans = [LayerPlan(spec=l) for l in cfg.layers]
    # pool nodes keep (1, 1): weightless comparator/accumulator engines
    # have no chain parallelism to balance and no AI-TBs to spend
    balance = [p for p in plans if not p.spec.is_pool]
    if not balance:
        return plans

    def used() -> int:
        return sum(p.tensor_blocks for p in plans)

    while True:
        bott = max(balance, key=lambda p: p.cycles_per_image)
        s = bott.spec
        ci_eff = (s.c_in if s.kind != "dwconv" else 1) * s.k_h * s.k_w
        co_eff = s.c_out if s.kind != "dwconv" else s.c_in
        # prefer the dimension with remaining headroom
        candidates = []
        if bott.p_i * 10 < ci_eff:
            candidates.append("p_i")
        if bott.p_o * 2 <= co_eff:
            candidates.append("p_o")
        if not candidates:
            break
        # try the preferred dimension first, but fall back to the other
        # one before giving up: the cheaper dimension may still fit the
        # remaining AI-TB budget when the preferred double does not
        candidates.sort(key=lambda d: ci_eff / bott.p_i if d == "p_i"
                        else co_eff / bott.p_o, reverse=True)
        doubled = False
        for dim in candidates:
            setattr(bott, dim, getattr(bott, dim) * 2)
            if used() > tb_budget:
                setattr(bott, dim, getattr(bott, dim) // 2)
                continue
            doubled = True
            break
        if not doubled:
            break
    return plans


# ---------------------------------------------------------------------------
# Eq. 1 score
# ---------------------------------------------------------------------------


def eq1_score(plan: LayerPlan) -> float:
    """Desirability of moving layer weights to HBM: M20Ks saved (minus the
    2-M20K last-stage FIFO cost) per unit of HBM bandwidth required."""
    s = plan.spec
    kernel_m20ks = -(-s.weight_bits(8) // M20K_BITS)
    dup = -(-s.out_w // 18)
    saved = (kernel_m20ks - 2) * dup
    bw = plan.p_i * plan.p_o * CHAIN_BITS
    return saved / bw


# ---------------------------------------------------------------------------
# Algorithm 1: greedy offload under the pseudo-channel bandwidth budget
# ---------------------------------------------------------------------------


def algorithm1(plans: Sequence[LayerPlan], n_pc: int = hbm_model.USABLE_PCS,
               ) -> List[LayerPlan]:
    """Offload the highest-scoring layers until chain bandwidth runs out.
    Mutates and returns ``plans`` (offload flags)."""
    order = sorted(range(len(plans)), key=lambda i: eq1_score(plans[i]),
                   reverse=True)
    free_bw = n_pc * CHAINS_PER_PC
    for i in order:
        if free_bw == 0:
            break
        if eq1_score(plans[i]) <= 0:
            continue                       # offloading would not save memory
        need = plans[i].chains
        if need <= free_bw:
            plans[i].offload = True
            free_bw -= need
    return list(plans)


def hybrid_selection(plans: Sequence[LayerPlan], bram_m20ks: int,
                     n_pc: int = hbm_model.USABLE_PCS,
                     burst: int = 8) -> List[LayerPlan]:
    """The full hybrid policy (§VI-A): keep as many weight buffers on chip
    as BRAM allows; layers chosen for HBM by Algorithm 1 order.  Activations
    always stay on chip (§III-B).  Offloads highest-score layers first until
    the on-chip remainder fits."""
    # work on copies: the caller's plans (and their offload flags) must
    # stay untouched — the autotuner calls this in a loop over candidate
    # plans and relies on the seed staying pristine
    plans = [dataclasses.replace(p) for p in plans]
    for p in plans:
        p.offload = False
        p.pc = None
    act_m20ks = sum(-(-l.spec.activation_window_bits(8) // M20K_BITS)
                    for l in plans)
    order = sorted(range(len(plans)), key=lambda i: eq1_score(plans[i]),
                   reverse=True)
    free_bw = n_pc * CHAINS_PER_PC

    def onchip_m20ks() -> int:
        total = act_m20ks
        for p in plans:
            if p.offload:
                total += hbm_model.fifo_m20k_cost(burst) * \
                    -(-p.spec.out_w // 18)
            else:
                total += p.weight_m20ks
        return total

    for i in order:
        if onchip_m20ks() <= bram_m20ks:
            break
        if free_bw >= plans[i].chains and eq1_score(plans[i]) > 0:
            plans[i].offload = True
            free_bw -= plans[i].chains
    return list(plans)


def assign_pseudo_channels(plans: Sequence[LayerPlan],
                           n_pc: int = hbm_model.N_PCS) -> None:
    """Clockwise assignment (§V-B): offloaded layers in pipeline order get
    PCs 0->15 then 31->16, wrapping round-robin when layers outnumber PCs.

    Only the first ``n_pc`` pseudo-channels in clockwise die order are
    usable (§VI-B: 31 of the NX2100's 32 close timing), so the walk must
    never hand out an id >= ``n_pc`` — a target with 8 usable PCs gets
    ids 0..7, never the far-stack 16..31 range."""
    clockwise = list(range(16)) + list(range(31, 15, -1))
    clockwise = [pc for pc in clockwise if pc < n_pc]
    k = 0
    for p in plans:
        if p.offload:
            p.pc = clockwise[k % len(clockwise)]
            k += 1


# ---------------------------------------------------------------------------
# throughput model (drives Table II / Fig. 6 benchmarks)
# ---------------------------------------------------------------------------


# Pipeline compute efficiency: fraction of peak tensor-chain issue rate the
# real HPIPE pipeline sustains (line-boundary bubbles, ragged tiling,
# control overheads).  Single global constant calibrated once against the
# paper's three measured hybrid throughputs (§VI-A); documented in
# EXPERIMENTS.md — not tuned per network.
PIPELINE_EFF = 0.62


def pipeline_throughput(plans: Sequence[LayerPlan], burst: int = 8,
                        fabric_mhz: float = hbm_model.FABRIC_MHZ,
                        n_pc: int = hbm_model.USABLE_PCS,
                        ) -> Dict[str, float]:
    """Images/s of the layer pipeline: every layer runs concurrently; the
    pipeline rate is set by the slowest layer.

    An HBM-fed layer consumes p_i*p_o 80-bit words per compute cycle, so
    its weight feed must sustain that rate x Fig. 3a efficiency.  The chain
    budget is global (Algorithm 1's ``n_pc x 3`` pool — a wide layer spans
    pseudo-channels); when offloaded demand exceeds the pool, every HBM
    layer is throttled by the same oversubscription factor."""
    eff = hbm_model.read_efficiency(burst)
    demand = sum(p.chains for p in plans if p.offload)
    pool = n_pc * CHAINS_PER_PC
    over = min(1.0, pool / demand) if demand else 1.0
    worst_s = 0.0
    bott = None
    for p in plans:
        t = p.cycles_per_image / (fabric_mhz * 1e6 * PIPELINE_EFF)
        if p.offload:
            # stream rate never exceeds eff x (its share of the pool)
            t_w = p.cycles_per_image / (fabric_mhz * 1e6 * eff * over)
            t = max(t, t_w)
        if t > worst_s:
            worst_s, bott = t, p
    return {
        "images_per_s": 1.0 / worst_s if worst_s else float("inf"),
        "bottleneck": bott.spec.name if bott else "",
        "bottleneck_on_hbm": bool(bott.offload) if bott else False,
        "oversubscription": over,
    }
