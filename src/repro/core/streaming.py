"""Per-tensor weight placement for the LM side — the paper's hybrid memory
system (Eq. 1 / Alg. 1) adapted to the TPU memory hierarchy (DESIGN.md §2).

Two tiers, two mechanisms:

1. **VMEM pinning** (per-chip): a pinned tensor's weights stay resident in
   VMEM across grid steps of the streamed-matmul kernel (fetched once per
   batch), while a streamed tensor's weights are re-read from HBM on every
   use.  The analogue of keeping a weight buffer in M20Ks vs HBM.  Budget:
   VMEM bytes per core.

2. **DP-shard streaming** (across chips): a *replicated* tensor costs HBM
   capacity on every chip but is instantly available; a *dp-streamed*
   tensor is sharded over the ``data`` axis (1/dp of the bytes per chip)
   and all-gathered over ICI right before use — the distribution-level
   analogue of HBM offload, with ICI playing the pseudo-channel.  Budget:
   per-chip HBM capacity (what must fit) and per-step gather bytes (what
   keeps the step time).

Both planners are the same greedy: score tensors by
(capacity saved) / (bandwidth required) — Eq. 1 — and move the best
scorers until the budget constraint is met, mirroring Algorithm 1.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.layers import axis_size

# TPU v5e-class constants (see repro/roofline/hw.py for the full set)
VMEM_BYTES = 128 * 2**20
HBM_BYTES = 16 * 2**30


@dataclass
class TensorPlacement:
    path: str
    bytes: int                     # total logical bytes (per model copy)
    uses_per_step: float           # fraction of steps this tensor is read
    decision: str = "replicated"   # replicated | dp_streamed
    vmem_pinned: bool = False

    @property
    def score(self) -> float:
        """Eq. 1 analogue: per-chip capacity saved per unit of gather
        bandwidth.  Rarely-used big tensors (routed experts) score highest;
        hot small tensors (norms, router) lowest."""
        if self.uses_per_step <= 0:
            return float("inf")
        return 1.0 / self.uses_per_step


@dataclass
class PlacementPlan:
    tensors: List[TensorPlacement]
    dp: int
    hbm_per_device: int
    notes: str = ""

    def bytes_per_device(self) -> int:
        total = 0
        for t in self.tensors:
            model_sharded = t.bytes            # already divided by model ax
            total += model_sharded // self.dp if t.decision == "dp_streamed" \
                else model_sharded
        return total

    def gather_bytes_per_step(self) -> float:
        return sum(t.bytes * t.uses_per_step * (self.dp - 1) / self.dp
                   for t in self.tensors if t.decision == "dp_streamed")

    def streamed(self) -> List[TensorPlacement]:
        return [t for t in self.tensors if t.decision == "dp_streamed"]


def _flatten_with_paths(params) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


def tensor_uses_per_step(path: str, cfg: ArchConfig) -> float:
    """How often (per decode step / per microbatch) a tensor is read.
    Routed expert weights are read with probability ~top_k/n_experts per
    token — the paper's ideal HBM candidates (big, low bandwidth)."""
    if cfg.moe is not None and "ffn" in path and (
            "w_gate" in path or "w_up" in path or "w_down" in path) \
            and "shared" not in path:
        return min(1.0, cfg.moe.top_k / cfg.moe.n_experts * 8)
        # x8: batches >1 token hit several experts; bounded by 1
    if "cross" in path:
        return 1.0
    return 1.0


def model_sharded_bytes(leaf, spec: Optional[P]) -> int:
    """Bytes of one leaf after model-axis sharding (what replication would
    cost per chip before any dp-streaming)."""
    n = leaf.size * leaf.dtype.itemsize if hasattr(leaf, "dtype") else 0
    if spec is not None:
        for ax in spec:
            if ax is not None:
                n //= axis_size(ax)
    return n


def plan_placement(params, specs, cfg: ArchConfig, *,
                   hbm_per_device: int = HBM_BYTES,
                   reserve_bytes: int = 6 * 2**30,
                   dp: Optional[int] = None) -> PlacementPlan:
    """Algorithm 1 on LM weights: dp-stream the best-scoring tensors until
    the replicated remainder fits per-chip HBM (minus a reserve for
    activations / KV cache / optimizer shards)."""
    dp = dp or max(axis_size(("pod", "data")), 1)
    leaves = _flatten_with_paths(params)
    spec_leaves = [s for _, s in _flatten_with_paths(specs)] \
        if specs is not None else [None] * len(leaves)
    tensors = []
    for (path, leaf), spec in zip(leaves, spec_leaves):
        tensors.append(TensorPlacement(
            path=path,
            bytes=model_sharded_bytes(leaf, spec),
            uses_per_step=tensor_uses_per_step(path, cfg),
        ))
    plan = PlacementPlan(tensors=tensors, dp=dp,
                         hbm_per_device=hbm_per_device)
    budget = hbm_per_device - reserve_bytes
    if dp <= 1:
        plan.notes = "dp=1: streaming impossible, all replicated"
        return plan
    order = sorted(range(len(tensors)),
                   key=lambda i: (tensors[i].score, tensors[i].bytes),
                   reverse=True)
    for i in order:
        if plan.bytes_per_device() <= budget:
            break
        # streaming a tiny tensor saves nothing — skip the long tail
        if tensors[i].bytes < 2**20:
            continue
        tensors[i].decision = "dp_streamed"
    plan.notes = (f"replicated={sum(t.decision=='replicated' for t in tensors)}"
                  f" dp_streamed={len(plan.streamed())}"
                  f" bytes/dev={plan.bytes_per_device()/2**30:.2f} GiB")
    return plan


def plan_vmem_residency(params, cfg: ArchConfig, *,
                        vmem_budget: int = VMEM_BYTES // 2) -> Dict[str, bool]:
    """Per-chip tier: choose which tensors the streamed-matmul kernel keeps
    VMEM-resident.  All weights are read once per step, so capacity saved /
    bandwidth is uniform — the knapsack then prefers packing the largest
    total, i.e. greedy by size descending (ties to Eq. 1: every pinned byte
    saves exactly one HBM byte per step)."""
    leaves = _flatten_with_paths(params)
    order = sorted(leaves, key=lambda kv: kv[1].size * kv[1].dtype.itemsize,
                   reverse=True)
    pinned: Dict[str, bool] = {}
    used = 0
    for path, leaf in order:
        nbytes = leaf.size * leaf.dtype.itemsize
        take = used + nbytes <= vmem_budget
        pinned[path] = take
        if take:
            used += nbytes
    return pinned


def apply_plan_to_specs(specs, plan: PlacementPlan, params):
    """Rewrite the PartitionSpec tree: dp-streamed tensors get their first
    shardable (currently-unsharded, divisible) dim sharded over ``data``.
    GSPMD then emits the all-gather at each use site — the 'prefetch' the
    XLA scheduler overlaps with compute, as the paper's FIFOs do.

    Divisibility is checked against the actual leaf shapes; a tensor with
    no evenly-divisible free dim keeps its replicated placement (recorded
    back into the plan)."""
    streamed_paths = {t.path for t in plan.streamed()}
    data_size = axis_size("data")
    is_p = lambda x: isinstance(x, P)
    flat = jax.tree_util.tree_flatten_with_path(specs, is_leaf=is_p)[0]
    treedef = jax.tree_util.tree_structure(specs, is_leaf=is_p)
    shapes = {jax.tree_util.keystr(kp): leaf.shape
              for kp, leaf in _flatten_with_paths_kp(params)}
    placed = {t.path: t for t in plan.tensors}
    new_leaves = []
    for kp, spec in flat:
        path = jax.tree_util.keystr(kp)
        if path in streamed_paths and isinstance(spec, P):
            shape = shapes.get(path, ())
            parts = list(spec) + [None] * (len(shape) - len(spec))
            used_axes = {a for p in parts if p is not None
                         for a in (p if isinstance(p, tuple) else (p,))}
            for d in range(len(parts)):
                if parts[d] is None and "data" not in used_axes and \
                        d < len(shape) and shape[d] % max(data_size, 1) == 0 \
                        and data_size > 1:
                    parts[d] = "data"
                    break
            else:
                placed[path].decision = "replicated"   # could not shard
            spec = P(*parts)
        new_leaves.append(spec)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def _flatten_with_paths_kp(params):
    return jax.tree_util.tree_flatten_with_path(params)[0]
