"""Throughput upper bounds — Eq. 2 and the Fig. 6 analysis.

Three bounds, exactly as the paper constructs them:
  1. all-HBM bound: effective HBM bandwidth (279 GB/s: 31 PCs x 240 bits @
     300 MHz, 100% efficiency) / weight traffic per image (Eq. 2 — kernels
     are re-read once per output row because HPIPE parallelizes across the
     full activation width);
  2. compute bound at a given tensor-block count (each AI-TB: 3 dot-10s =
     30 int8 MACs per cycle @ 300 MHz);
  3. unlimited-HBM bound: grow compute to the 85%-utilization limit of the
     device and take the compute bound there (the light-green bar).
"""
from __future__ import annotations

from typing import Dict

from repro.configs.cnn import CNNConfig
from repro.core import hbm_model

AI_TB_MACS_PER_CYCLE = 30
NX2100_TENSOR_BLOCKS = 3960
NX2100_M20KS = 6847               # ~140 Mb of M20K on Stratix 10 NX2100
UTIL_LIMIT = 0.85                 # §VI-B unlimited-bandwidth experiment


def eq2_weight_traffic_bytes(cfg: CNNConfig, bits: int = 8) -> int:
    """MT_required = sum_l k_h*k_w*c_i*c_o*output_height (bytes at 8-bit)."""
    return cfg.total_weight_traffic(bits)


def all_hbm_bound_ims(cfg: CNNConfig) -> float:
    """Throughput if weights stream perfectly from HBM (Fig. 6 light blue)."""
    return hbm_model.EFFECTIVE_BW_BYTES / eq2_weight_traffic_bytes(cfg)


def compute_bound_ims(cfg: CNNConfig,
                      tensor_blocks: int = NX2100_TENSOR_BLOCKS,
                      fabric_mhz: float = hbm_model.FABRIC_MHZ) -> float:
    """Peak images/s if every AI-TB ran every cycle."""
    macs = cfg.total_macs()
    return tensor_blocks * AI_TB_MACS_PER_CYCLE * fabric_mhz * 1e6 / macs


def unlimited_hbm_bound_ims(cfg: CNNConfig, hybrid_ims: float,
                            used_tbs: int,
                            device_tbs: int = NX2100_TENSOR_BLOCKS) -> float:
    """Fig. 6 light green: unlimited HBM stacks and the DSP count grown to
    the 85%-utilization limit (§VI-B).  Throughput scales with compute until
    that limit: hybrid x (0.85*device / used).  Paper: 2.27x on ResNet-50,
    2.08x on VGG-16, ~1x on ResNet-18."""
    scale = max(1.0, UTIL_LIMIT * device_tbs / max(used_tbs, 1))
    return hybrid_ims * scale


def gops(cfg: CNNConfig, images_per_s: float) -> float:
    """Table III GOPs convention: 2*MACs per image."""
    return 2 * cfg.total_macs() * images_per_s / 1e9


def fig6_summary(cfg: CNNConfig, hw_all_hbm: float, hw_hybrid: float,
                 used_tbs: int) -> Dict[str, float]:
    bound = all_hbm_bound_ims(cfg)
    return {
        "all_hbm_hw": hw_all_hbm,
        "hybrid_hw": hw_hybrid,
        "all_hbm_bound": bound,
        "unlimited_bound": unlimited_hbm_bound_ims(cfg, hw_hybrid, used_tbs),
        "fraction_of_bound": hw_all_hbm / bound,
    }
