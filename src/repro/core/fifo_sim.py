"""Discrete-event simulation of the H2PIPE weight-distribution network.

Reproduces the paper's §V-A result: when several layer engines share one
HBM-to-fabric DCFIFO, a ready/valid latency-insensitive protocol can
head-of-line block and deadlock (Fig. 5), while credit-based flow control
cannot.  The simulator models:

  HBM controller -> shared DCFIFO -> per-layer burst-matching FIFOs
      -> layer engines, with activation FIFOs between consecutive layers.

A layer engine consumes one activation from its upstream FIFO plus
``weights_per_act`` weight words to emit one activation downstream.  The
weight prefetcher round-robins burst reads over the layers sharing the
pseudo-channel; deliveries arrive in request order after ``hbm_latency``
cycles (the deterministic abstraction of Fig. 3b).

Modes
-----
``ready_valid``  the DCFIFO head transfers only if the destination
                 burst-matching FIFO has space; otherwise it blocks ALL
                 layers behind it (head-of-line blocking).
``credit``       the prefetcher holds per-layer credit counters sized to the
                 burst-matching FIFO and issues a read only when the whole
                 burst is guaranteed space — the DCFIFO can always drain.

Implementation note (exact full-net simulation)
-----------------------------------------------
Words within one layer are indistinguishable, so the hot credit-mode
path (:func:`simulate`) tracks word *counts* — burst-aggregated inflight
records and integer occupancy arrays — instead of one deque entry per
word, and fast-forwards exactly through periodic steady states (when the
residual state recurs with every engine mid-burst, the next ``m``
periods are an affine replay and are applied in O(1)).  The cycle cap
scales with the total word demand, so ``word_scale=1`` runs over full
Eq. 2 word streams (hundreds of thousands of words per activation) are
exact AND finish in CI time.  The original per-word event loop survives
as :func:`simulate_reference` — it still serves the ``ready_valid``
head-of-line mode, and the regression tests assert the fast path is
cycle-for-cycle identical to it.

The same credit semantics guard the multi-stage pipeline executor in
``core/dataflow.py``; the property tests in tests/test_core_paper.py and
tests/test_fifo_sim_fast.py check the deadlock repro, credit-mode
liveness, and fast-vs-reference equality over random topologies.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np


@dataclass
class SimConfig:
    n_layers: int = 3
    burst: int = 4                    # words per HBM read
    bm_fifo_depth: int = 8            # per-layer burst-matching FIFO (words)
    act_fifo_depth: int = 2           # inter-layer activation FIFO
    dcfifo_depth: int = 16            # shared HBM->fabric DCFIFO
    hbm_latency: int = 12             # cycles request -> first word
    weights_per_act: Tuple[int, ...] = (1, 1, 1)
    outputs_needed: int = 64          # activations layer N-1 must emit
    deadlock_window: int = 2000       # no-progress cycles -> deadlocked
    cycle_cap: Optional[int] = None   # None -> scaled to the word demand


@dataclass
class SimOutcome:
    completed: bool
    deadlocked: bool
    cycles: int
    outputs: int
    stall_cycles: int                 # cycles the tail engine was frozen
    per_layer_weight_words: List[int] = field(default_factory=list)


def _cycle_cap(cfg: SimConfig) -> int:
    """Hard stop for a wedged-but-progressing sim.  The historical fixed
    500k cap predates exact full-net streams (a single activation can
    demand >200k words at word_scale=1), so the cap now scales with the
    total demand — including the latency-bound delivery rate: a layer
    with ``bm_fifo_depth`` credits against ``hbm_latency`` cycles of
    read latency sustains only ``bm/(bm+latency)`` words per cycle
    (Little's law — the §IV-A motivation for latency-covering FIFOs),
    so budget that many delivery rounds over the whole word stream."""
    if cfg.cycle_cap is not None:
        return cfg.cycle_cap
    total_words = sum(w * cfg.outputs_needed for w in cfg.weights_per_act)
    rounds = 1 + cfg.hbm_latency // max(1, cfg.bm_fifo_depth)
    return max(500_000, 4 * total_words * rounds + cfg.hbm_latency + 10_000)


def simulate(cfg: SimConfig, mode: str = "credit",
             start_skew: Optional[List[int]] = None) -> SimOutcome:
    """Run the network until the last layer emits ``outputs_needed``
    activations, deadlock is detected, or the cycle cap is hit.

    ``start_skew``: cycle at which each layer engine powers on (the paper's
    start-up scenario: the first layer operating while consecutive layers
    still wait on activations).

    ``credit`` mode runs on the burst-aggregated fast path (bit-identical
    to :func:`simulate_reference` — regression-tested); ``ready_valid``
    keeps the per-word reference loop, whose head-of-line blocking is the
    very thing being modelled.
    """
    assert mode in ("ready_valid", "credit")
    if mode == "credit":
        return _simulate_credit_fast(cfg, start_skew)
    return simulate_reference(cfg, mode, start_skew)


def _simulate_credit_fast(cfg: SimConfig,
                          start_skew: Optional[List[int]]) -> SimOutcome:
    """Credit-mode sim over word counts instead of per-word deques.

    Two credit-mode invariants make this exact:
      * credits reserve burst-matching space at issue time, so the
        DCFIFO always drains fully within the cycle — its only residual
        role is capping deliveries at ``dcfifo_depth`` words/cycle;
      * deliveries happen in request order at one word/cycle per burst,
        so an inflight burst is fully described by (first-arrival cycle,
        layer, words remaining).

    On top of the counters, an exact periodic fast-forward: whenever the
    residual state (FIFO occupancies, credits, activation queues,
    round-robin pointer, inflight offsets) recurs while every layer is
    mid-activation (no ``weight_need`` reset in between), the dynamics
    are a fixed affine step per period — apply ``m`` periods at once,
    bounded so no layer crosses an activation boundary or an issuance
    truncation inside the jump.  This is what makes ``word_scale=1``
    full-net streams (~10^6 words) simulate exactly in well under a
    second instead of ~10^6 Python cycles.
    """
    L = cfg.n_layers
    wpa = list(cfg.weights_per_act)
    assert len(wpa) == L
    skew = list(start_skew) if start_skew else [0] * L
    max_skew = max(skew)
    cap = _cycle_cap(cfg)
    burst = cfg.burst
    lat = cfg.hbm_latency
    bm_depth = cfg.bm_fifo_depth
    act_depth = cfg.act_fifo_depth
    dc_depth = cfg.dcfifo_depth
    needed = cfg.outputs_needed
    window = cfg.deadlock_window

    # numpy int64 state keeps the totals overflow-safe for full Eq. 2
    # streams; the per-cycle loop reads/writes them through plain lists
    # (cheaper in the interpreter) and syncs at jump/exit points.
    total_need = np.asarray(wpa, np.int64) * needed

    bm = [0] * L                      # burst-matching FIFO occupancy
    credits = [bm_depth] * L
    weight_need = wpa[:]              # remaining words for current act
    got_words = [0] * L
    acts = [0] * (L + 1)              # inter-layer activation FIFOs
    issued = [0] * L
    inflight: Deque[List[int]] = deque()   # [next_arrival, layer, remaining]
    outputs = 0
    stall = 0
    rr = 0
    last_progress = 0
    cycle = 0

    # periodic fast-forward bookkeeping
    snapshots: Dict[Tuple, Tuple] = {}
    jump_floor = 2 * burst            # only worth probing mid-big-burst

    while outputs < needed and cycle < cap:
        cycle += 1
        progressed = False

        # 1+3. deliver arrived words straight into the burst-matching
        #      FIFOs (credits reserved the space; the DCFIFO's residual
        #      effect is the per-cycle delivery cap), in request order.
        space = dc_depth
        while inflight and space > 0:
            head = inflight[0]
            fd, lid, rem = head
            if fd > cycle:
                break                        # head word not arrived (FIFO)
            take = cycle - fd + 1            # words arrived, 1/cycle each
            if take > rem:
                take = rem
            if take > space:
                take = space
            bm[lid] += take
            space -= take
            progressed = True
            if take == rem:
                inflight.popleft()
            else:
                head[0] = fd + take          # next undelivered word
                head[2] = rem - take
                break

        # 2. prefetcher issues one burst per cycle at most
        for probe in range(L):
            lid = (rr + probe) % L
            rem_need = int(total_need[lid]) - issued[lid]
            if rem_need <= 0:
                continue
            n = burst if rem_need > burst else rem_need
            if credits[lid] < n:
                continue
            credits[lid] -= n
            inflight.append([cycle + lat, lid, n])
            issued[lid] += n
            rr = (lid + 1) % L
            break

        # 4. layer engines (last to first so same-cycle hand-off works)
        boundary = False
        for lid in range(L - 1, -1, -1):
            if cycle < skew[lid]:
                continue
            tail = lid == L - 1
            if not ((lid == 0 or acts[lid] > 0)
                    and (tail or acts[lid + 1] < act_depth)):
                if tail:
                    stall += 1
                continue
            wn = weight_need[lid]
            if wn > 0:
                if bm[lid] > 0:
                    bm[lid] -= 1
                    got_words[lid] += 1
                    wn = weight_need[lid] = wn - 1
                    credits[lid] += 1
                    progressed = True
                else:
                    if tail:
                        stall += 1
                    continue
            if wn == 0:
                weight_need[lid] = wpa[lid]
                boundary = True
                if lid > 0:
                    acts[lid] -= 1
                if tail:
                    outputs += 1
                else:
                    acts[lid + 1] += 1
                progressed = True

        if progressed:
            last_progress = cycle
        elif cycle - last_progress > window:
            return SimOutcome(False, True, cycle, outputs, stall, got_words)

        # 5. periodic steady-state fast-forward (exact, see docstring)
        if boundary:
            snapshots.clear()            # an act completed: regime changed
            continue
        if cycle <= max_skew or min(weight_need) <= jump_floor:
            continue
        key = (tuple(bm), tuple(credits), tuple(acts), rr,
               tuple((b[0] - cycle, b[1], b[2]) for b in inflight))
        prev = snapshots.get(key)
        if prev is None:
            snapshots[key] = (cycle, outputs, stall, tuple(weight_need),
                              tuple(got_words), tuple(issued))
            continue
        p_cycle, p_outputs, p_stall, p_need, p_got, p_issued = prev
        period = cycle - p_cycle
        if outputs != p_outputs:
            snapshots[key] = (cycle, outputs, stall, tuple(weight_need),
                              tuple(got_words), tuple(issued))
            continue
        dgot = [got_words[i] - p_got[i] for i in range(L)]
        dneed = [p_need[i] - weight_need[i] for i in range(L)]
        dissue = [issued[i] - p_issued[i] for i in range(L)]
        dstall = stall - p_stall
        # exactness guards: the period must be a pure mid-activation
        # chew (every consumed word decremented weight_need — no reset),
        # with real progress to replay.
        if dneed != dgot or not any(dgot):
            snapshots[key] = (cycle, outputs, stall, tuple(weight_need),
                              tuple(got_words), tuple(issued))
            continue
        m = (cap - cycle - 1) // period
        for i in range(L):
            if dgot[i] > 0:
                # never reach an activation boundary inside the jump
                m = min(m, (weight_need[i] - 1) // dgot[i])
            if dissue[i] > 0:
                # never truncate a burst (remaining stays >= period+burst)
                m = min(m, (int(total_need[i]) - issued[i] - dissue[i]
                            - burst) // dissue[i])
        if m <= 0:
            snapshots[key] = (cycle, outputs, stall, tuple(weight_need),
                              tuple(got_words), tuple(issued))
            continue
        shift = m * period
        cycle += shift
        stall += m * dstall
        for i in range(L):
            weight_need[i] -= m * dgot[i]
            got_words[i] += m * dgot[i]
            issued[i] += m * dissue[i]
        for b in inflight:
            b[0] += shift
        last_progress = cycle
        snapshots.clear()

    return SimOutcome(outputs >= needed, False, cycle, outputs, stall,
                      got_words)


def simulate_reference(cfg: SimConfig, mode: str = "credit",
                       start_skew: Optional[List[int]] = None) -> SimOutcome:
    """The original per-word event loop: one deque entry per weight word.

    Kept as the executable specification — ``ready_valid`` mode runs
    here (head-of-line blocking needs the word-tagged DCFIFO), and the
    fast credit path is regression-tested cycle-for-cycle against it.
    Too slow for word_scale=1 full-net streams; use :func:`simulate`.
    """
    assert mode in ("ready_valid", "credit")
    L = cfg.n_layers
    wpa = list(cfg.weights_per_act)
    assert len(wpa) == L
    start_skew = start_skew or [0] * L

    # state
    dcfifo: Deque[int] = deque()                  # words tagged by layer id
    inflight: Deque[Tuple[int, int]] = deque()    # (deliver_cycle, layer)
    bm: List[Deque[int]] = [deque() for _ in range(L)]
    acts: List[Deque[int]] = [deque() for _ in range(L + 1)]
    credits = [cfg.bm_fifo_depth for _ in range(L)]
    weight_need = [wpa[i] for i in range(L)]      # remaining for current act
    got_words = [0] * L
    outputs = 0
    stall = 0
    rr = 0                                        # round-robin pointer
    last_progress = 0
    cycle = 0
    cap = _cycle_cap(cfg)

    # total weight words each layer will ever need (stop prefetching after)
    total_need = [wpa[i] * cfg.outputs_needed for i in range(L)]
    issued = [0] * L

    while outputs < cfg.outputs_needed and cycle < cap:
        cycle += 1
        progressed = False

        # 1. deliver arrived HBM words into the DCFIFO (in request order)
        while inflight and inflight[0][0] <= cycle and \
                len(dcfifo) < cfg.dcfifo_depth:
            _, lid = inflight.popleft()
            dcfifo.append(lid)
            progressed = True

        # 2. prefetcher issues one burst per cycle at most
        for probe in range(L):
            lid = (rr + probe) % L
            if issued[lid] >= total_need[lid]:
                continue
            n = min(cfg.burst, total_need[lid] - issued[lid])
            if mode == "credit":
                if credits[lid] < n:
                    continue
                credits[lid] -= n
            else:
                if len(inflight) + len(dcfifo) + n > cfg.dcfifo_depth:
                    continue
            for w in range(n):
                inflight.append((cycle + cfg.hbm_latency + w, lid))
            issued[lid] += n
            rr = (lid + 1) % L
            break

        # 3. DCFIFO head -> burst-matching FIFO (head-of-line semantics)
        while dcfifo:
            head = dcfifo[0]
            if len(bm[head]) < cfg.bm_fifo_depth:
                bm[head].append(dcfifo.popleft())
                progressed = True
            else:
                break                              # HoL block (ready/valid)
                # (credit mode never hits this: space was reserved)

        # 4. layer engines (last to first so same-cycle hand-off works)
        for lid in reversed(range(L)):
            if cycle < start_skew[lid]:
                continue
            src_ok = (lid == 0) or bool(acts[lid])
            dst_ok = len(acts[lid + 1]) < cfg.act_fifo_depth or lid == L - 1
            if not (src_ok and dst_ok):
                if lid == L - 1:
                    stall += 1
                continue
            if weight_need[lid] > 0:
                if bm[lid]:
                    bm[lid].popleft()
                    got_words[lid] += 1
                    weight_need[lid] -= 1
                    if mode == "credit":
                        credits[lid] += 1
                    progressed = True
                else:
                    if lid == L - 1:
                        stall += 1
                    continue
            if weight_need[lid] == 0:
                weight_need[lid] = wpa[lid]
                if lid > 0:
                    acts[lid].popleft()
                if lid == L - 1:
                    outputs += 1
                else:
                    acts[lid + 1].append(1)
                progressed = True

        if progressed:
            last_progress = cycle
        elif cycle - last_progress > cfg.deadlock_window:
            return SimOutcome(False, True, cycle, outputs, stall, got_words)

    return SimOutcome(outputs >= cfg.outputs_needed, False, cycle, outputs,
                      stall, got_words)


def fig5_scenario() -> SimConfig:
    """The paper's deadlock setup: three consecutive layers share one
    DCFIFO; the downstream layer's burst-matching FIFO fills while it waits
    on activations that can only come from the upstream layer — whose
    weights are stuck behind the head of the DCFIFO."""
    return SimConfig(
        n_layers=3,
        burst=4,
        bm_fifo_depth=4,
        act_fifo_depth=1,
        dcfifo_depth=8,
        hbm_latency=6,
        weights_per_act=(8, 1, 1),     # layer 0 is weight-hungry
        outputs_needed=32,
    )


def demo() -> Dict[str, SimOutcome]:
    """Run the Fig. 5 scenario both ways (used by tests and benchmarks)."""
    cfg = fig5_scenario()
    skew = [0, 40, 80]                # §V-A start-up skew
    return {
        "ready_valid": simulate(cfg, "ready_valid", start_skew=skew),
        "credit": simulate(cfg, "credit", start_skew=skew),
    }
