"""Discrete-event simulation of the H2PIPE weight-distribution network.

Reproduces the paper's §V-A result: when several layer engines share one
HBM-to-fabric DCFIFO, a ready/valid latency-insensitive protocol can
head-of-line block and deadlock (Fig. 5), while credit-based flow control
cannot.  The simulator models:

  HBM controller -> shared DCFIFO -> per-layer burst-matching FIFOs
      -> layer engines, with activation FIFOs between consecutive layers.

A layer engine consumes one activation from its upstream FIFO plus
``weights_per_act`` weight words to emit one activation downstream.  The
weight prefetcher round-robins burst reads over the layers sharing the
pseudo-channel; deliveries arrive in request order after ``hbm_latency``
cycles (the deterministic abstraction of Fig. 3b).

Modes
-----
``ready_valid``  the DCFIFO head transfers only if the destination
                 burst-matching FIFO has space; otherwise it blocks ALL
                 layers behind it (head-of-line blocking).
``credit``       the prefetcher holds per-layer credit counters sized to the
                 burst-matching FIFO and issues a read only when the whole
                 burst is guaranteed space — the DCFIFO can always drain.

The same credit semantics guard the multi-stage pipeline executor in
``core/dataflow.py``; the property tests in tests/test_fifo_sim.py check
both the deadlock repro and credit-mode liveness over random topologies.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple


@dataclass
class SimConfig:
    n_layers: int = 3
    burst: int = 4                    # words per HBM read
    bm_fifo_depth: int = 8            # per-layer burst-matching FIFO (words)
    act_fifo_depth: int = 2           # inter-layer activation FIFO
    dcfifo_depth: int = 16            # shared HBM->fabric DCFIFO
    hbm_latency: int = 12             # cycles request -> first word
    weights_per_act: Tuple[int, ...] = (1, 1, 1)
    outputs_needed: int = 64          # activations layer N-1 must emit
    deadlock_window: int = 2000       # no-progress cycles -> deadlocked


@dataclass
class SimOutcome:
    completed: bool
    deadlocked: bool
    cycles: int
    outputs: int
    stall_cycles: int                 # cycles the tail engine was frozen
    per_layer_weight_words: List[int] = field(default_factory=list)


def simulate(cfg: SimConfig, mode: str = "credit",
             start_skew: Optional[List[int]] = None) -> SimOutcome:
    """Run the network until the last layer emits ``outputs_needed``
    activations, deadlock is detected, or a hard cycle cap is hit.

    ``start_skew``: cycle at which each layer engine powers on (the paper's
    start-up scenario: the first layer operating while consecutive layers
    still wait on activations)."""
    assert mode in ("ready_valid", "credit")
    L = cfg.n_layers
    wpa = list(cfg.weights_per_act)
    assert len(wpa) == L
    start_skew = start_skew or [0] * L

    # state
    dcfifo: Deque[int] = deque()                  # words tagged by layer id
    inflight: Deque[Tuple[int, int]] = deque()    # (deliver_cycle, layer)
    bm: List[Deque[int]] = [deque() for _ in range(L)]
    acts: List[Deque[int]] = [deque() for _ in range(L + 1)]
    credits = [cfg.bm_fifo_depth for _ in range(L)]
    weight_need = [wpa[i] for i in range(L)]      # remaining for current act
    got_words = [0] * L
    outputs = 0
    stall = 0
    rr = 0                                        # round-robin pointer
    last_progress = 0
    cycle = 0
    CAP = 500_000

    # total weight words each layer will ever need (stop prefetching after)
    total_need = [wpa[i] * cfg.outputs_needed for i in range(L)]
    issued = [0] * L

    while outputs < cfg.outputs_needed and cycle < CAP:
        cycle += 1
        progressed = False

        # 1. deliver arrived HBM words into the DCFIFO (in request order)
        while inflight and inflight[0][0] <= cycle and \
                len(dcfifo) < cfg.dcfifo_depth:
            _, lid = inflight.popleft()
            dcfifo.append(lid)
            progressed = True

        # 2. prefetcher issues one burst per cycle at most
        for probe in range(L):
            lid = (rr + probe) % L
            if issued[lid] >= total_need[lid]:
                continue
            n = min(cfg.burst, total_need[lid] - issued[lid])
            if mode == "credit":
                if credits[lid] < n:
                    continue
                credits[lid] -= n
            else:
                if len(inflight) + len(dcfifo) + n > cfg.dcfifo_depth:
                    continue
            for w in range(n):
                inflight.append((cycle + cfg.hbm_latency + w, lid))
            issued[lid] += n
            rr = (lid + 1) % L
            break

        # 3. DCFIFO head -> burst-matching FIFO (head-of-line semantics)
        while dcfifo:
            head = dcfifo[0]
            if len(bm[head]) < cfg.bm_fifo_depth:
                bm[head].append(dcfifo.popleft())
                progressed = True
            else:
                break                              # HoL block (ready/valid)
                # (credit mode never hits this: space was reserved)

        # 4. layer engines (last to first so same-cycle hand-off works)
        for lid in reversed(range(L)):
            if cycle < start_skew[lid]:
                continue
            src_ok = (lid == 0) or bool(acts[lid])
            dst_ok = len(acts[lid + 1]) < cfg.act_fifo_depth or lid == L - 1
            if not (src_ok and dst_ok):
                if lid == L - 1:
                    stall += 1
                continue
            if weight_need[lid] > 0:
                if bm[lid]:
                    bm[lid].popleft()
                    got_words[lid] += 1
                    weight_need[lid] -= 1
                    if mode == "credit":
                        credits[lid] += 1
                    progressed = True
                else:
                    if lid == L - 1:
                        stall += 1
                    continue
            if weight_need[lid] == 0:
                weight_need[lid] = wpa[lid]
                if lid > 0:
                    acts[lid].popleft()
                if lid == L - 1:
                    outputs += 1
                else:
                    acts[lid + 1].append(1)
                progressed = True

        if progressed:
            last_progress = cycle
        elif cycle - last_progress > cfg.deadlock_window:
            return SimOutcome(False, True, cycle, outputs, stall, got_words)

    return SimOutcome(outputs >= cfg.outputs_needed, False, cycle, outputs,
                      stall, got_words)


def fig5_scenario() -> SimConfig:
    """The paper's deadlock setup: three consecutive layers share one
    DCFIFO; the downstream layer's burst-matching FIFO fills while it waits
    on activations that can only come from the upstream layer — whose
    weights are stuck behind the head of the DCFIFO."""
    return SimConfig(
        n_layers=3,
        burst=4,
        bm_fifo_depth=4,
        act_fifo_depth=1,
        dcfifo_depth=8,
        hbm_latency=6,
        weights_per_act=(8, 1, 1),     # layer 0 is weight-hungry
        outputs_needed=32,
    )


def demo() -> Dict[str, SimOutcome]:
    """Run the Fig. 5 scenario both ways (used by tests and benchmarks)."""
    cfg = fig5_scenario()
    skew = [0, 40, 80]                # §V-A start-up skew
    return {
        "ready_valid": simulate(cfg, "ready_valid", start_skew=skew),
        "credit": simulate(cfg, "credit", start_skew=skew),
    }
