"""Span/event tracing with Chrome Trace Event export — the measured half.

H2PIPE's headline evaluation is bandwidth efficiency against theoretical
limits (§VI): the paper attributes every lost cycle to a stall source.
The modelled side of that attribution already exists (``fifo_sim``,
``predict_stalls``); this module is the *measured* side — a thread-safe,
bounded tracer the serving runtimes emit host-side timeline events into,
exportable as Chrome Trace Event JSON (open ``chrome://tracing`` or
https://ui.perfetto.dev and load the file).

Design constraints, in order:

  * **zero overhead when disabled** — the default sink is
    :data:`NULL_TRACER`, whose methods are constant no-ops (no event
    objects, no lock, no per-call allocation); call sites additionally
    guard arg construction behind ``tracer.enabled``;
  * **bounded** — a long-lived server must not grow without bound: the
    event buffer is a ring of ``capacity`` events, oldest evicted first,
    with the eviction count surfaced (``dropped``) so a truncated trace
    is never mistaken for a complete one;
  * **injectable clock** — every timestamp comes from ``clock()``
    (default ``time.perf_counter``), so the latency/percentile logic of
    the serving engines is testable with a :class:`ManualClock` instead
    of sleeps, and all timestamps within one engine share one timebase;
  * **async in-flight spans** — a dispatched microbatch begins on the
    dispatcher thread and ends on the completer thread; Chrome's async
    event pairs (``ph: b``/``e`` with an ``id``) model exactly that.

Tracks (Chrome ``tid`` rows, one per pipeline phase):
``admission`` (credit wait), ``pack`` (microbatch packing), ``dispatch``
(XLA enqueue), ``in_flight`` (device occupancy, async), ``delivery``
(result unpacking), ``request`` (per-request lifetime, async), ``round``
(sharded per-stage rounds).
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = ["Tracer", "NullTracer", "NULL_TRACER", "ManualClock",
           "TRACKS", "monotonic_clock", "chrome_trace_events",
           "validate_chrome_trace"]

#: the default monotonic timebase (injectable everywhere it is used)
monotonic_clock: Callable[[], float] = time.perf_counter

#: canonical track names, in display order.  Unknown tracks are allowed
#: (they get tids after these), but the serving engines stick to this set.
TRACKS: Tuple[str, ...] = ("request", "admission", "pack", "dispatch",
                           "in_flight", "delivery", "round")

_DEFAULT_CAPACITY = 65536


class ManualClock:
    """A settable monotonic clock for tests: starts at ``start``,
    advances ``step`` on every call (so concurrent threads still see
    strictly monotonic time), plus explicit :meth:`advance`.  Thread-safe
    — the serving engines call the clock from three threads."""

    def __init__(self, start: float = 0.0, step: float = 0.0):
        self._t = float(start)
        self.step = float(step)
        self._lock = threading.Lock()

    def __call__(self) -> float:
        with self._lock:
            t = self._t
            self._t += self.step
            return t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"clock must be monotonic; advance({dt})")
        with self._lock:
            self._t += dt

    @property
    def now(self) -> float:
        with self._lock:
            return self._t


class _NullSpan:
    """Reusable no-op context manager (one shared instance, so a
    disabled tracer's ``span()`` allocates nothing per call)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The disabled sink: every method is a constant no-op.  Call sites
    check ``tracer.enabled`` before building event arguments, so a
    disabled engine pays one attribute read per would-be event."""

    enabled = False
    dropped = 0
    clock: Callable[[], float] = staticmethod(monotonic_clock)

    def instant(self, name: str, track: str = "dispatch",
                **args: Any) -> None:
        pass

    def begin(self, name: str, track: str, event_id: int,
              **args: Any) -> None:
        pass

    def end(self, name: str, track: str, event_id: int,
            **args: Any) -> None:
        pass

    def counter(self, name: str, value: float,
                track: str = "dispatch") -> None:
        pass

    def span(self, name: str, track: str = "dispatch", **args: Any):
        return _NULL_SPAN

    def events(self) -> List[Tuple]:
        return []

    def to_chrome_trace(self) -> Dict[str, Any]:
        return {"traceEvents": [], "displayTimeUnit": "ms"}


#: the shared disabled sink — the default ``tracer=`` everywhere
NULL_TRACER = NullTracer()


class Tracer:
    """Thread-safe bounded event tracer (see module docstring).

    Events are stored as ``(ph, name, track, ts, dur, event_id, args)``
    tuples in a ring buffer of ``capacity`` entries; ``dropped`` counts
    ring evictions.  ``ts`` is in *seconds* on the injected clock;
    export rebases to microseconds relative to the first retained event
    (Chrome wants non-negative ``ts``).
    """

    enabled = True

    def __init__(self, *, capacity: int = _DEFAULT_CAPACITY,
                 clock: Callable[[], float] = monotonic_clock,
                 process_name: str = "repro-serving"):
        if capacity < 1:
            raise ValueError(f"tracer capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.clock = clock
        self.process_name = process_name
        self.dropped = 0
        self._events: deque = deque()
        self._lock = threading.Lock()

    # -- recording -----------------------------------------------------------

    def _push(self, ev: Tuple) -> None:
        with self._lock:
            if len(self._events) >= self.capacity:
                self._events.popleft()
                self.dropped += 1
            self._events.append(ev)

    def instant(self, name: str, track: str = "dispatch",
                **args: Any) -> None:
        """One point-in-time event ('i' phase)."""
        self._push(("i", name, track, self.clock(), None, None,
                    args or None))

    def begin(self, name: str, track: str, event_id: int,
              **args: Any) -> None:
        """Async begin ('b'): the matching :meth:`end` may come from a
        different thread — ``(name, track, event_id)`` pairs them."""
        self._push(("b", name, track, self.clock(), None, event_id,
                    args or None))

    def end(self, name: str, track: str, event_id: int,
            **args: Any) -> None:
        """Async end ('e') for the matching :meth:`begin`."""
        self._push(("e", name, track, self.clock(), None, event_id,
                    args or None))

    def counter(self, name: str, value: float,
                track: str = "dispatch") -> None:
        """A sampled counter series ('C' phase)."""
        self._push(("C", name, track, self.clock(), None, None,
                    {"value": value}))

    @contextmanager
    def span(self, name: str, track: str = "dispatch", **args: Any):
        """Complete-event bracket ('X' with duration): the body runs on
        one thread, begin-to-exit wall time on the injected clock."""
        t0 = self.clock()
        try:
            yield self
        finally:
            self._push(("X", name, track, t0, self.clock() - t0, None,
                        args or None))

    # -- reading -------------------------------------------------------------

    def events(self) -> List[Tuple]:
        """Snapshot of the retained ring, oldest first."""
        with self._lock:
            return list(self._events)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"events": len(self._events), "capacity": self.capacity,
                    "dropped": self.dropped}

    # -- export --------------------------------------------------------------

    def to_chrome_trace(self, *, pid: int = 1) -> Dict[str, Any]:
        """The Chrome Trace Event JSON object (``traceEvents`` array
        format) — loadable in Perfetto / ``chrome://tracing``.  Spans
        that began before the ring's oldest retained event are exported
        as-is (their async ends may be unmatched when ``dropped > 0``;
        :func:`validate_chrome_trace` treats a dropped trace as
        best-effort)."""
        evs = self.events()
        return chrome_trace_events(evs, pid=pid,
                                   process_name=self.process_name)

    def dump(self, path: str, *, pid: int = 1) -> None:
        """Write the Chrome trace JSON to ``path``."""
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(pid=pid), f)


def chrome_trace_events(events: List[Tuple], *, pid: int = 1,
                        process_name: str = "repro-serving"
                        ) -> Dict[str, Any]:
    """Convert recorded ``(ph, name, track, ts, dur, id, args)`` tuples
    into the Chrome Trace Event JSON object.  Timestamps rebase to
    microseconds relative to the earliest retained event, so ``ts`` is
    always non-negative; tracks become ``tid`` rows named by metadata
    events.

    Events are emitted sorted by timestamp: ring order is *push* order,
    and a cross-thread async pair (begin on the dispatcher, end on the
    completer) can be pushed out of timestamp order under thread
    scheduling.  The sort is stable, and a begin is always pushed before
    its matching end, so equal-timestamp pairs stay ordered."""
    events = sorted(events, key=lambda ev: ev[3])
    tids: Dict[str, int] = {t: i for i, t in enumerate(TRACKS)}
    for ev in events:
        tids.setdefault(ev[2], len(tids))
    t0 = min((ev[3] for ev in events), default=0.0)
    out: List[Dict[str, Any]] = [
        {"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
         "args": {"name": process_name}}]
    for track, tid in sorted(tids.items(), key=lambda kv: kv[1]):
        out.append({"ph": "M", "name": "thread_name", "pid": pid,
                    "tid": tid, "args": {"name": track}})
        out.append({"ph": "M", "name": "thread_sort_index", "pid": pid,
                    "tid": tid, "args": {"sort_index": tid}})
    for ph, name, track, ts, dur, event_id, args in events:
        rec: Dict[str, Any] = {
            "ph": ph, "name": name, "cat": track,
            "ts": (ts - t0) * 1e6, "pid": pid, "tid": tids[track],
        }
        if dur is not None:
            rec["dur"] = dur * 1e6
        if event_id is not None:
            rec["id"] = event_id
        if args:
            rec["args"] = args
        out.append(rec)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def validate_chrome_trace(trace: Dict[str, Any], *,
                          require_tracks: Tuple[str, ...] = ()
                          ) -> List[str]:
    """Schema-check a Chrome trace object; returns a list of problems
    (empty == valid).  Checked: the ``traceEvents`` envelope, known
    phases, non-negative finite ``ts`` monotone per track (complete
    events carry non-negative ``dur``), async begin/end pairs matched
    per ``(cat, name, id)``, and — when ``require_tracks`` names rows —
    that each is present with at least one event."""
    problems: List[str] = []
    evs = trace.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]
    track_names: Dict[int, str] = {}
    for ev in evs:
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            track_names[ev.get("tid")] = ev.get("args", {}).get("name")
    last_ts: Dict[Tuple[int, int], float] = {}
    open_async: Dict[Tuple[str, str, Any], int] = {}
    seen_tracks: Dict[str, int] = {}
    for i, ev in enumerate(evs):
        ph = ev.get("ph")
        if ph not in ("M", "X", "i", "b", "e", "C"):
            problems.append(f"event {i}: unknown phase {ph!r}")
            continue
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0 or ts != ts:
            problems.append(f"event {i} ({ev.get('name')}): bad ts {ts!r}")
            continue
        key = (ev.get("pid"), ev.get("tid"))
        if ts < last_ts.get(key, 0.0) - 1e-6:
            problems.append(
                f"event {i} ({ev.get('name')}): ts {ts} went backwards "
                f"on track {track_names.get(ev.get('tid'), ev.get('tid'))}")
        last_ts[key] = max(last_ts.get(key, 0.0), ts)
        track = ev.get("cat") or track_names.get(ev.get("tid"))
        if track:
            seen_tracks[track] = seen_tracks.get(track, 0) + 1
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(
                    f"event {i} ({ev.get('name')}): bad dur {dur!r}")
        elif ph == "b":
            k = (ev.get("cat"), ev.get("name"), ev.get("id"))
            open_async[k] = open_async.get(k, 0) + 1
        elif ph == "e":
            k = (ev.get("cat"), ev.get("name"), ev.get("id"))
            if open_async.get(k, 0) <= 0:
                problems.append(
                    f"event {i}: async end without begin for {k}")
            else:
                open_async[k] -= 1
    for k, n in open_async.items():
        if n:
            problems.append(f"async begin without end for {k} (x{n})")
    for t in require_tracks:
        if not seen_tracks.get(t):
            problems.append(f"required track {t!r} has no events")
    return problems
