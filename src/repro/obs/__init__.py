"""Cross-cutting observability: tracing, metrics, stall attribution.

Two pillars plus the report section that joins them to the paper:

  * :mod:`repro.obs.trace` — thread-safe bounded span/event tracer with
    an injectable monotonic clock and Chrome Trace Event JSON export
    (Perfetto / ``chrome://tracing``); the no-op :data:`NULL_TRACER` is
    the default sink everywhere, so tracing costs nothing unless asked
    for;
  * :mod:`repro.obs.metrics` — labelled counter/gauge/histogram registry
    with a JSON-safe ``snapshot()``;
  * :mod:`repro.obs.stall` — measured admission-wait / dispatch-gap
    fractions laid against ``fifo_sim``'s modelled stall cycles: the
    measured half of the §VI bandwidth-efficiency reproduction.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry, default_registry)
from repro.obs.stall import stall_attribution  # noqa: F401
from repro.obs.trace import (NULL_TRACER, TRACKS,  # noqa: F401
                             ManualClock, NullTracer, Tracer,
                             chrome_trace_events, monotonic_clock,
                             validate_chrome_trace)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "default_registry", "stall_attribution", "NULL_TRACER", "TRACKS",
    "ManualClock", "NullTracer", "Tracer", "chrome_trace_events",
    "monotonic_clock", "validate_chrome_trace",
]
