"""Labelled counter/gauge/histogram registry with a ``snapshot()`` dict.

The numeric companion to :mod:`repro.obs.trace`: where the tracer
answers *when* (a timeline of one serving interval), the registry
answers *how much* (monotone totals, point-in-time levels, bounded
distributions) — cheap enough to stay always-on, serializable as one
plain dict so reports and benchmark artifacts can embed it.

  * :class:`Counter` — monotone float total (``inc``);
  * :class:`Gauge` — last-write-wins level (``set``/``inc``);
  * :class:`Histogram` — exact ``count``/``sum``/``min``/``max`` over
    the full lifetime plus nearest-rank percentiles over a bounded
    window of the most recent ``window`` observations (a long-lived
    server must not grow without bound — same policy as the serving
    engines' METRIC_WINDOW deques).

Instruments are identified by ``(name, sorted labels)``; getting an
existing key returns the SAME instrument, so call sites never cache
handles.  All operations are thread-safe.  Registries are cheap — each
serving engine owns its own, so ``snapshot()`` is engine-local; the
module-level :func:`default_registry` collects cross-cutting compiler
timings (``compile()`` per-pass wall seconds, trace-cache state) where
no engine exists to own them.
"""
from __future__ import annotations

import math
import threading
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "default_registry"]


def _key(name: str, labels: Dict[str, Any]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotone total."""

    def __init__(self, key: str, lock: threading.Lock):
        self.key = key
        self._lock = lock
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"{self.key}: counters only go up (inc {n})")
        with self._lock:
            self.value += n


class Gauge:
    """Point-in-time level (last write wins)."""

    def __init__(self, key: str, lock: threading.Lock):
        self.key = key
        self._lock = lock
        self.value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self.value += n


class Histogram:
    """Exact lifetime aggregates + percentiles over a bounded window."""

    def __init__(self, key: str, lock: threading.Lock, window: int = 1024):
        if window < 1:
            raise ValueError(f"histogram window must be >= 1, got {window}")
        self.key = key
        self._lock = lock
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self._window: deque = deque(maxlen=window)

    def observe(self, v: float) -> None:
        v = float(v)
        if math.isnan(v):
            raise ValueError(f"{self.key}: observe(nan)")
        with self._lock:
            self.count += 1
            self.sum += v
            self.min = v if self.min is None else min(self.min, v)
            self.max = v if self.max is None else max(self.max, v)
            self._window.append(v)

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile over the retained window."""
        with self._lock:
            win = sorted(self._window)
        if not win:
            return 0.0
        return win[max(0, math.ceil(p * len(win)) - 1)]

    def summary(self) -> Dict[str, float]:
        with self._lock:
            win = sorted(self._window)
            out = {"count": self.count, "sum": self.sum,
                   "min": self.min if self.min is not None else 0.0,
                   "max": self.max if self.max is not None else 0.0,
                   "window": len(win)}
        for p, tag in ((0.5, "p50"), (0.95, "p95"), (0.99, "p99")):
            out[tag] = win[max(0, math.ceil(p * len(win)) - 1)] \
                if win else 0.0
        return out


class MetricsRegistry:
    """Get-or-create registry over the three instrument kinds.  One lock
    per instrument (shared creation lock for the maps); ``snapshot()``
    returns a JSON-safe dict suitable for report embedding."""

    def __init__(self, *, histogram_window: int = 1024):
        self.histogram_window = histogram_window
        self._create = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str, **labels: Any) -> Counter:
        key = _key(name, labels)
        with self._create:
            got = self._counters.get(key)
            if got is None:
                got = self._counters[key] = Counter(key, threading.Lock())
            return got

    def gauge(self, name: str, **labels: Any) -> Gauge:
        key = _key(name, labels)
        with self._create:
            got = self._gauges.get(key)
            if got is None:
                got = self._gauges[key] = Gauge(key, threading.Lock())
            return got

    def histogram(self, name: str, **labels: Any) -> Histogram:
        key = _key(name, labels)
        with self._create:
            got = self._histograms.get(key)
            if got is None:
                got = self._histograms[key] = Histogram(
                    key, threading.Lock(), self.histogram_window)
            return got

    def snapshot(self) -> Dict[str, Any]:
        """One JSON-safe dict of everything:
        ``{"counters": {key: total}, "gauges": {key: level},
        "histograms": {key: summary}}``."""
        with self._create:
            counters = list(self._counters.values())
            gauges = list(self._gauges.values())
            hists = list(self._histograms.values())
        return {
            "counters": {c.key: c.value for c in counters},
            "gauges": {g.key: g.value for g in gauges},
            "histograms": {h.key: h.summary() for h in hists},
        }


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry for cross-cutting producers with no
    engine to own a registry (``compile()`` pass timings, trace-cache
    instrumentation)."""
    return _DEFAULT
