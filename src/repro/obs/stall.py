"""Stall attribution: measured host fractions vs modelled stall cycles.

H2PIPE's §VI evaluation is a *bandwidth efficiency* claim: achieved
throughput laid against the theoretical HBM limit, with every lost cycle
attributed to a stall source (FIFO credit starvation, burst-matching
depth, pseudo-channel contention).  The reproduction models that side
exactly (``fifo_sim`` credit mode over the streamed set); this module
closes the loop by laying the *measured* serving-side fractions next to
it, in one JSON-safe dict that rides on
:class:`~repro.runtime.cnn_serving.ServingReport.bandwidth_efficiency`:

  * **measured** (host wall clock on the serving engine's injected
    clock):
      - ``admission_wait_fraction`` — time the dispatcher spent blocked
        on the §V-A credit bound, over the serving wall.  The runtime
        analogue of the paper's FIFO-credit stalls: credits exhausted
        means the device (HBM) side is the bottleneck;
      - ``dispatch_gap_fraction`` — time the dispatcher spent with NO
        work to pack (queue empty between dispatches), over the wall.
        Gaps mean the *supply* side starved the pipeline — the
        complement of admission waits;
  * **modelled** (deterministic ``fifo_sim`` credit-mode replay of the
    plan's streamed set): tail-engine ``stall_cycles`` over total
    ``cycles``, plus the per-engine word deliveries the simulation
    produced — the §VI per-engine view.

The two halves answer the paper's question for a real serving interval:
of the cycles we lost, how many does the model predict (FIFO/credit
structure) and how many are measured host effects (arrival gaps,
dispatch overhead) that no FIFO depth can fix.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence

__all__ = ["stall_attribution"]


def _fraction(num: float, den: float) -> float:
    return num / den if den > 0 else 0.0


def stall_attribution(*, wall_s: float, admission_wait_s: float,
                      dispatch_gap_s: float,
                      modelled: Optional[Any] = None,
                      engine_names: Sequence[str] = (),
                      word_scale: Optional[int] = None
                      ) -> Dict[str, Any]:
    """Build the ``bandwidth_efficiency`` report section.

    ``modelled`` is a ``fifo_sim.SimOutcome`` (duck-typed: ``cycles``,
    ``stall_cycles``, ``outputs``, ``completed``,
    ``per_layer_weight_words``) or ``None`` when the plan streams
    nothing; ``engine_names`` are the streamed engines in the sim's
    layer order, ``word_scale`` the demand divisor the sim ran under
    (so per-engine words can be rescaled by readers).

    ``engine_names`` must be exactly as long as the sim's per-layer word
    list — a mismatch means the caller's name order and the sim topology
    drifted apart, and silently zipping them would misattribute words, so
    it raises :class:`ValueError` instead.  The per-engine view is
    emitted as ``per_engine_weight_word_rows`` — a list of
    ``[name, words]`` pairs that preserves duplicates and sim order —
    with the ``per_engine_weight_words`` dict kept as a compatibility
    view (duplicate names collapse there, last row wins).

    Both measured fractions are host wall-clock on shared machines —
    they carry meaning as *attribution* (which side of the pipeline
    starved), not as absolute performance, and the benchmark gate treats
    them under ``METRIC_THRESHOLD_FLOOR`` accordingly.
    """
    out: Dict[str, Any] = {
        "wall_s": wall_s,
        "measured": {
            "admission_wait_s": admission_wait_s,
            "admission_wait_fraction": _fraction(admission_wait_s, wall_s),
            "dispatch_gap_s": dispatch_gap_s,
            "dispatch_gap_fraction": _fraction(dispatch_gap_s, wall_s),
        },
    }
    if modelled is not None:
        words = list(modelled.per_layer_weight_words)
        if len(engine_names) != len(words):
            raise ValueError(
                f"stall_attribution: {len(engine_names)} engine name(s) "
                f"for {len(words)} per-layer word count(s) — the streamed "
                f"set and the sim topology drifted apart")
        rows = [[name, w] for name, w in zip(engine_names, words)]
        out["modelled"] = {
            "stall_cycles": modelled.stall_cycles,
            "cycles": modelled.cycles,
            "stall_fraction": _fraction(modelled.stall_cycles,
                                        modelled.cycles),
            "outputs": modelled.outputs,
            "completed": modelled.completed,
            "word_scale": word_scale,
            "per_engine_weight_word_rows": rows,
            # compat view: duplicate engine names collapse (last wins);
            # readers that care about order/duplicates use the rows
            "per_engine_weight_words": dict(rows),
        }
    return out
