"""Core NN building blocks: norms, RoPE, attention (GQA / sliding / MLA /
blockwise-chunked), embeddings — pure-functional JAX with explicit sharding
specs.

Conventions
-----------
* params are nested dicts of jnp arrays; every init_* has a matching *_specs
  returning an identically-structured dict of ``PartitionSpec``.
* ``DP_AXES = ("pod", "data")`` shards batch; ``MODEL_AXIS = "model"`` shards
  heads / ffn hidden / experts / vocab.  Dim sizes not divisible by the mesh
  axis are replicated (``maybe_axis``) — this keeps every assigned arch
  lowerable on the 16x16 and 2x16x16 production meshes.
* KV caches are stacked over layers: [L, B, S, n_kv, head_dim].
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

DP_AXES = ("pod", "data")
MODEL_AXIS = "model"

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# kernel mode: route attention through the Pallas flash kernels (the
# beyond-paper perf lever — scores never round-trip HBM).  interpret=True
# on CPU; a real TPU run flips interpret off.  Enabled per-run by the
# launcher / dry-run (--kernels on).
# ---------------------------------------------------------------------------

_KERNEL_MODE = {"enabled": False, "interpret": True}


def set_kernel_mode(enabled: bool, interpret: bool = True) -> None:
    _KERNEL_MODE["enabled"] = enabled
    _KERNEL_MODE["interpret"] = interpret


def kernel_mode_enabled() -> bool:
    return _KERNEL_MODE["enabled"]


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------


_MESH_AXIS_SIZES: Dict[str, int] = {}


def set_mesh_axis_sizes(sizes: Dict[str, int]) -> None:
    """Record the active mesh axis sizes so spec builders can check
    divisibility.  Called by the launcher before building specs."""
    _MESH_AXIS_SIZES.clear()
    _MESH_AXIS_SIZES.update(sizes)


def axis_size(name) -> int:
    if isinstance(name, (tuple, list)):
        return math.prod(axis_size(n) for n in name)
    return _MESH_AXIS_SIZES.get(name, 1)


def maybe_axis(dim: int, name):
    """Return the mesh axis name if ``dim`` is divisible by its size (so the
    tensor dim can be sharded), else None (replicate)."""
    s = axis_size(name)
    return name if (s > 1 and dim % s == 0) else None


def dp_spec(batch: int):
    """Batch sharding over the data-parallel axes present in the active
    mesh (("pod","data"), ("data",) or none), with divisibility fallback.
    ``batch == 0`` means 'unknown, assume divisible' (spec builders)."""
    present = tuple(a for a in DP_AXES if a in _MESH_AXIS_SIZES)
    if not present:
        return None
    full = axis_size(present)
    if full > 1 and (batch == 0 or batch % full == 0):
        return present if len(present) > 1 else present[-1]
    if "data" in present and axis_size("data") > 1 and \
            (batch == 0 or batch % axis_size("data") == 0):
        return "data"
    return None


def constrain(x, spec: P):
    """with_sharding_constraint that is a no-op outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) <= 2 else math.prod(shape[:-1])
    if len(shape) >= 3:                    # [d, H, hd] style
        fan_in = shape[0]
    std = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape) * std).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm_specs() -> Params:
    return {"scale": P(None)}


def rmsnorm(params: Params, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + params["scale"].astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    angles = angles[..., None, :]                       # [..., S, 1, hd/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------


def pad_vocab(v: int, multiple: int = 128) -> int:
    return ((v + multiple - 1) // multiple) * multiple


def init_embedding(key, vocab: int, d: int, dtype) -> Params:
    vp = pad_vocab(vocab)
    return {"table": _dense_init(key, (vp, d), dtype, scale=d ** -0.5)}


def embedding_specs(vocab: int) -> Params:
    return {"table": P(maybe_axis(pad_vocab(vocab), MODEL_AXIS), None)}


def embed(params: Params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params: Params, x, softcap: float = 0.0):
    logits = jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                        params["table"].astype(jnp.float32))
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits


# ---------------------------------------------------------------------------
# Attention (GQA, sliding window, logit softcap) — blockwise-chunked compute
# ---------------------------------------------------------------------------


def init_attention(key, cfg) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, cfg.n_heads, hd), dtype),
        "wk": _dense_init(ks[1], (d, cfg.n_kv_heads, hd), dtype),
        "wv": _dense_init(ks[2], (d, cfg.n_kv_heads, hd), dtype),
        "wo": _dense_init(ks[3], (cfg.n_heads, hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads, hd), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, hd), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, hd), dtype)
    return p


def attention_specs(cfg) -> Params:
    h_ax = maybe_axis(cfg.n_heads, MODEL_AXIS)
    kv_ax = maybe_axis(cfg.n_kv_heads, MODEL_AXIS)
    p = {
        "wq": P(None, h_ax, None),
        "wk": P(None, kv_ax, None),
        "wv": P(None, kv_ax, None),
        "wo": P(h_ax, None, None),
    }
    if cfg.qkv_bias:
        p["bq"] = P(h_ax, None)
        p["bk"] = P(kv_ax, None)
        p["bv"] = P(kv_ax, None)
    return p


def _qkv(params, cfg, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa_block(q, k, v, mask, scale, softcap):
    """One (q-block, kv-block) attention tile with running softmax stats.

    q: [B,Sq,H,hd]  k/v: [B,Sk,kv,hd] (kv already repeated to H)
    Returns (unnormalized out, rowmax, rowsum)."""
    scores = jnp.einsum("bqhk,bshk->bhqs", q, k).astype(jnp.float32) * scale
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    scores = jnp.where(mask, scores, -1e30)
    m = jnp.max(scores, axis=-1)                               # [B,H,Sq]
    e = jnp.exp(scores - m[..., None])
    e = jnp.where(mask, e, 0.0)
    s = jnp.sum(e, axis=-1)
    out = jnp.einsum("bhqs,bshk->bqhk", e.astype(v.dtype), v)
    return out.astype(jnp.float32), m, s


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def blockwise_attention(q, k, v, *, causal: bool, window=None,
                        softcap: float = 0.0, q_block: int = 1024,
                        kv_block: int = 1024,
                        q_offset: int = 0):
    """Memory-efficient attention: double loop over (q-block, kv-block) with
    online softmax.  Pure-JAX oracle for the Pallas flash kernel; also the
    default XLA path so 32k prefill never materializes [S,S].

    q: [B,Sq,H,hd], k/v: [B,Sk,KV,hd].  ``window``: None = full causal;
    otherwise a (possibly traced) sliding-window size — traced values let a
    layer-scan mix local/global layers (gemma2) in one program.
    ``q_offset``: absolute position of q[0] (for decode/chunked prefill).
    """
    B, Sq, H, hd = q.shape
    hd_v = v.shape[-1]                  # may differ from hd (MLA)
    Sk = k.shape[1]
    n_rep = H // k.shape[2]
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    scale = 1.0 / math.sqrt(hd)
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Sk)
    nq, nk = Sq // q_block, Sk // kv_block
    assert Sq % q_block == 0 and Sk % kv_block == 0, (Sq, q_block, Sk, kv_block)

    q_pos_base = jnp.arange(q_block)
    k_pos_base = jnp.arange(kv_block)

    def q_step(qi):
        qs = jax.lax.dynamic_slice_in_dim(q, qi * q_block, q_block, axis=1)
        q_pos = q_offset + qi * q_block + q_pos_base

        def kv_step(carry, ki):
            acc, m_run, s_run = carry
            ks_ = jax.lax.dynamic_slice_in_dim(k, ki * kv_block, kv_block, 1)
            vs_ = jax.lax.dynamic_slice_in_dim(v, ki * kv_block, kv_block, 1)
            k_pos = ki * kv_block + k_pos_base
            mask = jnp.ones((q_block, kv_block), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= q_pos[:, None] - k_pos[None, :] < window
            mask = mask[None, None]
            out, m, s = _sdpa_block(qs, ks_, vs_, mask, scale, softcap)
            m_new = jnp.maximum(m_run, m)
            alpha = jnp.exp(m_run - m_new)
            acc = acc * alpha[..., None].transpose(0, 2, 1, 3) + \
                out * jnp.exp(m - m_new)[..., None].transpose(0, 2, 1, 3)
            s_run = s_run * alpha + s * jnp.exp(m - m_new)
            return (acc, m_new, s_run), None

        init = (jnp.zeros((B, q_block, H, hd_v), jnp.float32),
                jnp.full((B, H, q_block), -jnp.inf),
                jnp.zeros((B, H, q_block)))
        # checkpoint the kv step so AD recomputes block scores instead of
        # saving [B,H,q_block,kv_block] per block pair (flash-backward)
        (acc, _, s_run), _ = jax.lax.scan(
            jax.checkpoint(kv_step,
                           policy=jax.checkpoint_policies.nothing_saveable),
            init, jnp.arange(nk))
        denom = jnp.maximum(s_run, 1e-30)[..., None].transpose(0, 2, 1, 3)
        return (acc / denom).astype(q.dtype)

    outs = jax.lax.map(q_step, jnp.arange(nq))         # [nq,B,q_block,H,hd_v]
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd_v)


def attention_forward(params: Params, cfg, x, positions, *, window=None,
                      kv_cache: Optional[Tuple] = None,
                      cache_index: Optional[jnp.ndarray] = None,
                      ring: bool = False, causal: bool = True):
    """Full attention sublayer.  Returns (out, new_kv) where new_kv is the
    (k, v) to store for this layer when serving.

    prefill/train: kv_cache None -> self-attend over x.
    decode: kv_cache = (k_cache, v_cache) [B,S_c,kv,hd]; x is [B,1,d].
    ``window``: None = full causal, else sliding-window size (traced ok).
    ``ring``: the cache is a ring buffer of size window (sub-quadratic
    decode for sliding-window archs; cache slot = pos % S_c); keys are
    RoPE-rotated at their absolute position before storage so reads need
    no re-rotation.
    """
    q, k, v = _qkv(params, cfg, x, positions)
    if kv_cache is None:
        use_kernel = (
            _KERNEL_MODE["enabled"]
            and (window is None or isinstance(window, int))
            and q.shape[-1] == v.shape[-1]
            and q.shape[1] % min(128, q.shape[1]) == 0)
        out = None
        if use_kernel:
            out = _flash_call(q, k, v, causal=causal,
                              window=int(window or 0),
                              softcap=cfg.attn_logit_softcap)
        if out is None:
            out = blockwise_attention(q, k, v, causal=causal, window=window,
                                      softcap=cfg.attn_logit_softcap)
        new_kv = (k, v)
    else:
        # decode: write the new token's K/V at cache_index (mod size if ring)
        kc, vc = kv_cache
        S = kc.shape[1]
        slot = cache_index % S if ring else cache_index
        kc = jax.lax.dynamic_update_index_in_dim(
            kc, k[:, 0].astype(kc.dtype), slot, axis=1)
        vc = jax.lax.dynamic_update_index_in_dim(
            vc, v[:, 0].astype(vc.dtype), slot, axis=1)
        n_rep = cfg.n_heads // cfg.n_kv_heads
        KV = cfg.n_kv_heads
        B = q.shape[0]
        hd = q.shape[-1]
        scale = 1.0 / math.sqrt(cfg.resolved_head_dim)
        # grouped-query form: contract q's head groups directly against the
        # UNREPEATED cache.  jnp.repeat on a sequence-sharded cache forces
        # GSPMD into a full f32 all-gather of the 32k cache per layer (the
        # HC3-it1 finding, EXPERIMENTS.md §Perf) — this keeps the cache
        # sharded and only small [B,H] reductions cross the mesh.
        qg = q.reshape(B, 1, KV, n_rep, hd)
        scores = jnp.einsum("bqgrd,bsgd->bgrqs", qg,
                            kc).astype(jnp.float32) * scale
        if cfg.attn_logit_softcap:
            scores = jnp.tanh(scores / cfg.attn_logit_softcap) * \
                cfg.attn_logit_softcap
        kpos = jnp.arange(S)
        if ring:
            # entry j holds absolute position pos - ((slot - j) mod S)
            age = (slot - kpos) % S
            entry_pos = cache_index - age
            valid = (entry_pos >= 0)[None, None, None, None, :]
        else:
            valid = kpos[None, None, None, None, :] <= cache_index
            if window is not None:
                valid &= kpos[None, None, None, None, :] > \
                    cache_index - window
        scores = jnp.where(valid, scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bgrqs,bsgd->bqgrd", w.astype(vc.dtype), vc)
        out = out.reshape(B, 1, cfg.n_heads, hd)
        new_kv = (kc, vc)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), params["wo"])
    return y, new_kv


def _current_physical_mesh():
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        return m if (m is not None and not m.empty
                     and m.devices.size > 1) else None
    except Exception:
        return None


def _flash_call(q, k, v, *, causal: bool, window: int, softcap: float):
    """Route through the Pallas flash kernel.  Under an active mesh the
    call is wrapped in shard_map over the data axes (manual partitioning:
    each device runs the kernel on its local batch; no GSPMD collectives
    can appear inside the kernel region — the production pattern for
    custom kernels)."""
    from repro.kernels.flash_attention.ops import flash_attention_vjp
    bq = min(128, q.shape[1])
    bk = min(128, k.shape[1])
    interp = _KERNEL_MODE["interpret"]

    def call(q, k, v):
        return flash_attention_vjp(q, k, v, causal, window, softcap,
                                   bq, bk, interp)

    mesh = _current_physical_mesh()
    dp = dp_spec(q.shape[0])
    if mesh is not None and dp is not None:
        from jax.experimental.shard_map import shard_map
        # shard heads over the model axis (TP attention; keeps the kernel
        # region free of boundary gathers for MLA's 128 heads — §Perf
        # HC2-it3).  BOTH q and kv head counts must divide the axis;
        # otherwise the region would replicate the whole attention across
        # model columns (16x real compute, §Perf HC1-it4 refuted) — fall
        # back to the XLA blockwise path, which GSPMD partitions the same
        # way as the baseline.  Future iteration: head padding or a
        # flash-decoding lse-combine to seq-shard non-divisible archs.
        h_ax = maybe_axis(q.shape[2], MODEL_AXIS)
        kv_ax = maybe_axis(k.shape[2], MODEL_AXIS)
        if h_ax is not None and kv_ax is not None:
            q_spec = P(dp, None, h_ax, None)
            kv_spec = P(dp, None, kv_ax, None)
            return shard_map(call, mesh=mesh,
                             in_specs=(q_spec, kv_spec, kv_spec),
                             out_specs=q_spec, check_rep=False)(q, k, v)
        # Heads don't divide the model axis.  A KV-group-folded layout
        # ([B*KV, S, rep, hd] sharded over the full mesh) was tried and
        # REFUTED: the boundary reshard of q/k/v/o (replicated-over-model
        # upstream -> mesh-sharded region) costs 7.6 s of collective on
        # phi4 train_4k, dwarfing the 1 s memory win (§Perf HC1-it4).
        # Fall back to the XLA blockwise path (same partitioning as the
        # paper-faithful baseline); the durable fix is adopting the folded
        # layout for the WHOLE layer stack, noted as future work.
        return None
    return call(q, k, v)


# ---------------------------------------------------------------------------
# Cross-attention (encoder-decoder)
# ---------------------------------------------------------------------------


def cross_attention_kv(params: Params, cfg, memory):
    """Project encoder output once; the (k, v) pair is cached for the whole
    decode (the read-many 'pinned' tier of DESIGN.md §4).  memory: [B,Sm,d]."""
    k = jnp.einsum("bsd,dhk->bshk", memory, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, params["wv"])
    if cfg.qkv_bias:
        k = k + params["bk"]
        v = v + params["bv"]
    return k, v


def cross_attention_forward(params: Params, cfg, x, kv):
    """Non-causal attention of decoder states over cached encoder K/V."""
    k, v = kv
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if cfg.qkv_bias:
        q = q + params["bq"]
    n_rep = cfg.n_heads // cfg.n_kv_heads
    kr, vr = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    scale = 1.0 / math.sqrt(cfg.resolved_head_dim)
    scores = jnp.einsum("bqhk,bshk->bhqs", q, kr).astype(jnp.float32) * scale
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshk->bqhk", w.astype(vr.dtype), vr)
    return jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), params["wo"])
