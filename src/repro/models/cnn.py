"""JAX CNN models built from the H2PIPE per-layer descriptors.

The paper's accelerator is generated layer-by-layer from ``ConvLayerSpec``s;
we mirror that: ``init_cnn_params`` / ``cnn_forward`` consume the same specs
that drive the placement algorithm (Eq. 1), the memory table (Table I) and
the traffic bound (Eq. 2), so the numbers in the benchmarks refer to the
exact network that runs.

Numerics follow the paper: int8 weights with per-output-channel scales
(int8 fine-tune of an fp32 model); activations int8 with per-tensor scale.
Compute accumulates in int32 on the MXU (jnp path: int8 x int8 -> int32
via preferred_element_type), then requantizes — the Pallas ``conv2d_int8``
kernel implements the same contract.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.cnn import (POOL_KINDS, CNNConfig, ConvLayerSpec,
                               ResBlockSpec, residual_blocks, stem_unit)
from repro.kernels.pool_int8.ref import (global_avgpool_int8_ref,
                                         maxpool_int8_ref)
from repro.kernels.quant import requant_epilogue
from repro.models.layers import maybe_axis, MODEL_AXIS

Params = Dict[str, Any]


def _qscale(key, shape):
    return jnp.full(shape, 0.05, jnp.float32)


def init_conv_layer(key, spec: ConvLayerSpec) -> Params:
    kw, kh = spec.k_w, spec.k_h
    if spec.kind == "dwconv":
        w_shape = (kh, kw, 1, spec.c_in)                    # HWIO depthwise
        c_out = spec.c_in
    else:
        w_shape = (kh, kw, spec.c_in, spec.c_out)
        c_out = spec.c_out
    w = jax.random.randint(key, w_shape, -127, 128, jnp.int8)
    return {
        "w": w,
        "w_scale": _qscale(key, (c_out,)),
        "bias": jnp.zeros((c_out,), jnp.float32),
    }


def conv_layer_specs(spec: ConvLayerSpec) -> Params:
    if spec.kind == "dwconv":
        ax = maybe_axis(spec.c_in, MODEL_AXIS)
        return {"w": P(None, None, None, ax), "w_scale": P(ax), "bias": P(ax)}
    ax = maybe_axis(spec.c_out, MODEL_AXIS)
    return {"w": P(None, None, None, ax), "w_scale": P(ax), "bias": P(ax)}


@functools.partial(jax.jit, static_argnames=("spec", "act_scale", "relu"))
def conv_layer_forward(params: Params, spec: ConvLayerSpec, x,
                       act_scale: float = 0.05, relu: bool = True):
    """x: [B,H,W,C] int8.  Returns int8 activations (requantized).

    Jitted (spec is a hashable frozen dataclass) so the dequant/requant
    epilogue compiles to the same fused float ops as the Pallas engines —
    keeping the model path and the kernel path bit-identical around
    round-to-nearest ties."""
    feature_group_count = spec.c_in if spec.kind == "dwconv" else 1
    pad = "SAME" if spec.kind != "fc" else "VALID"
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.int8), params["w"].astype(jnp.int8),
        window_strides=(spec.stride, spec.stride),
        padding=pad,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=feature_group_count,
        preferred_element_type=jnp.int32)
    # requantize to int8 for the next layer engine (the shared epilogue)
    return requant_epilogue(y, params["w_scale"], params["bias"],
                            act_scale=act_scale, relu=relu)


def init_cnn_params(key, cfg: CNNConfig) -> Params:
    """Parameters for every weighted node; pool nodes (maxpool / GAP) are
    weightless topology engines and get no entry."""
    ks = jax.random.split(key, len(cfg.layers))
    return {l.name: init_conv_layer(k, l)
            for k, l in zip(ks, cfg.layers) if not l.is_pool}


def cnn_param_specs(cfg: CNNConfig) -> Params:
    return {l.name: conv_layer_specs(l) for l in cfg.layers if not l.is_pool}


def pool_forward(spec: ConvLayerSpec, x, act_scale: float = 0.05):
    """The jnp reference for one pooling topology node — the same
    numerics the Pallas pool engines are differential-tested against."""
    if spec.kind == "maxpool":
        return maxpool_int8_ref(x, k=spec.k_h, stride=spec.stride)
    assert spec.kind == "gap", spec.kind
    return global_avgpool_int8_ref(x, act_scale=act_scale)


# engine(spec, layer_params, x, relu) -> Optional[(y_q, y_float)].  The
# per-layer dispatch hook the pipeline executor plugs in: it routes each
# layer to its compile-time LayerEngine binding (repro.compiler.engines);
# returning None falls back to the jnp reference path here.
EngineHook = Callable[[ConvLayerSpec, Params, jnp.ndarray, bool],
                      Optional[Tuple[jnp.ndarray, Optional[jnp.ndarray]]]]

# block_engine(block, params, x) -> Optional[y_q].  The block-granular
# dispatch hook: a whole residual block (conv chain + downsample + add +
# relu) offered as ONE unit, for pipelines that bound it to a fused block
# engine (res_block_int8).  Returning None falls back to per-layer
# execution below.
BlockEngineHook = Callable[[ResBlockSpec, Params, jnp.ndarray],
                           Optional[jnp.ndarray]]

# scan_engine(lead_block, params, x, limit) -> Optional[(y_q, consumed)].
# The scan-group dispatch hook: offered at the LEAD block of each residual
# block, BEFORE the block hook.  Accepting means the hook executed a whole
# homogeneous run of blocks starting there (one lax.scan body over stacked
# per-block params) and consumed ``consumed`` member layers; ``limit`` is
# how many layers remain in the active layer_range, so the hook declines
# runs that would cross a stage boundary (per-block execution then covers
# them).  Returning None falls through to ``block_engine``.
ScanEngineHook = Callable[[ResBlockSpec, Params, jnp.ndarray, int],
                          Optional[Tuple[jnp.ndarray, int]]]


def cnn_forward(params: Params, cfg: CNNConfig, images,
                engine: Optional[EngineHook] = None,
                block_engine: Optional[BlockEngineHook] = None,
                scan_engine: Optional[ScanEngineHook] = None,
                layer_range: Optional[Tuple[int, int]] = None
                ) -> jnp.ndarray:
    """Plain feed-forward execution (the functional reference; the pipeline
    executor in runtime/pipeline.py runs the same layers through the Pallas
    engines by passing ``engine``/``block_engine``).

    images: [B,224,224,3] (or reduced) int8.  Returns logits [B,classes].
    Residual/downsample wiring for ResNets comes from
    ``configs.cnn.residual_blocks`` — the same grouping the compiler's
    block binding uses, so the topology and the bindings cannot drift.
    Pooling is NOT wired here: maxpool and global-average-pool are
    first-class graph nodes in ``cfg.layers``, offered to the engine hook
    like any conv (the compiler binds them to the pool engines) — nothing
    about the topology is implicit anymore.

    ``engine``: per-layer dispatch hook.  When provided, each node
    is offered to the hook first (the pipeline executor routes it to its
    compile-time engine binding — pinned or HBM-streamed Pallas kernels,
    including the grouped depthwise and the pooling engines); nodes the
    hook declines (returns None for — e.g. layers unknown to the plan)
    run the jnp path, so every node executes exactly once either way.

    ``block_engine``: block-granular hook, offered each residual block
    BEFORE its layers run individually; declining falls back to the
    per-layer wiring here (which itself offers each layer to ``engine``).
    The same hook is offered the config's :class:`StemUnitSpec` (stem
    conv + following maxpool as one fused unit) at the stem, when the
    config has one.

    ``scan_engine``: scan-group hook, offered at each residual block's
    lead conv BEFORE ``block_engine`` with the count of layers remaining
    in the active range; accepting executes a whole homogeneous block
    run as one scanned body and skips its member layers (see
    :data:`ScanEngineHook`).

    ``layer_range``: ``(start, stop)`` indices into ``cfg.layers`` — run
    only that contiguous slice (the sharded pipeline executor walks one
    stage's slice per device).  ``images`` is then the slice's input
    activation; when the slice stops before the final layer the return
    value is the int8 activation feeding layer ``stop`` (the stage
    boundary), not logits.  A range may not start or stop inside a
    residual block: the identity add spans the whole block, so a cut
    there would silently drop the skip connection.
    """

    def apply_layer(spec: ConvLayerSpec, x, relu: bool = True):
        if engine is not None:
            out = engine(spec, params.get(spec.name, {}), x, relu)
            if out is not None:
                return out
        if spec.is_pool:
            return pool_forward(spec, x), None
        return conv_layer_forward(params[spec.name], spec, x, relu=relu)

    x = images
    layers = list(cfg.layers)
    blocks = {b.convs[0].name: b for b in residual_blocks(cfg)}
    start, stop = (0, len(layers)) if layer_range is None else layer_range
    if not 0 <= start < stop <= len(layers):
        raise ValueError(
            f"layer_range {layer_range} outside [0, {len(layers)})")
    member_head = {m.name: b.convs[0].name
                   for b in residual_blocks(cfg) for m in b.members}
    for cut, where in ((start, "start"), (stop, "stop")):
        if cut < len(layers):
            name = layers[cut].name
            if name in member_head and member_head[name] != name:
                raise ValueError(
                    f"layer_range {where}={cut} cuts residual block "
                    f"{member_head[name]!r} open at member {name!r}; "
                    f"stage cuts must treat blocks as atomic units")
    stem = stem_unit(cfg)
    i = start
    while i < stop:
        spec = layers[i]
        name = spec.name
        if (stem is not None and name == stem.conv.name and i + 2 <= stop
                and block_engine is not None):
            # the stem conv + maxpool pair as one fused unit; declining
            # (or a range that cuts the pair) falls through to the
            # per-layer walk below, bit-identically
            out = block_engine(stem, params, x)
            if out is not None:
                x = out
                i += 2
                continue
        if spec.is_pool:
            x, _ = apply_layer(spec, x, relu=False)
            i += 1
            continue
        if name in blocks:
            blk = blocks[name]
            if scan_engine is not None:
                out = scan_engine(blk, params, x, stop - i)
                if out is not None:
                    x, consumed = out
                    i += consumed
                    continue
            if block_engine is not None:
                out = block_engine(blk, params, x)
                if out is not None:
                    x = out
                    i += len(blk.members)
                    continue
            identity = x
            h = x
            for ci, cspec in enumerate(blk.convs):
                last = ci == len(blk.convs) - 1
                h, _ = apply_layer(cspec, h, relu=not last)
            if blk.ds is not None:
                identity, _ = apply_layer(blk.ds, identity, relu=False)
            y = h.astype(jnp.int32) + identity.astype(jnp.int32)
            x = jnp.clip(y, -127, 127).astype(jnp.int8)
            x = jnp.where(x > 0, x, 0)                      # relu on int8
            i += len(blk.members)
            continue
        if name.startswith("fc") or name in ("head0", "head1", "head"):
            # the map reaching an fc head is whatever the graph's explicit
            # pool nodes produced — no implicit GAP here anymore
            last = i == len(layers) - 1
            x, y_f = apply_layer(spec, x, relu=not last)
            if last:
                return y_f.reshape(y_f.shape[0], -1)
            i += 1
            continue
        x, _ = apply_layer(spec, x)
        i += 1
    if stop < len(layers):
        return x                  # int8 stage-boundary activation
    # no explicit fc tail (shouldn't happen) — pool and return
    return jnp.mean(x.astype(jnp.float32), axis=(1, 2))


def cnn_input_shape(cfg: CNNConfig, batch: int) -> Tuple[int, int, int, int]:
    l0 = cfg.layers[0]
    return (batch, l0.in_h, l0.in_w, l0.c_in)
