"""Full LM assembly for every assigned architecture.

One homogeneous layer stack is *scanned* (params stacked with a leading [L]
axis, ``lax.scan`` over layers) so 60-80-layer archs trace and compile
quickly; heterogeneous stacks (xLSTM's mLSTM/sLSTM alternation) use a python
loop over 12 layers.  Per-layer attention windows are passed as a traced [L]
array so gemma2's local/global alternation stays a single scanned program.

Public API
----------
init_params / param_specs            (structure-matched PartitionSpec tree)
forward(params, cfg, batch)          -> (hidden [B,S,d], aux dict)
logits_from_hidden / lm_loss         (chunked over sequence: never [B,S,V])
init_cache / prefill / decode_step   serving path (ring-buffer KV for
                                     sliding-window archs -> long_500k)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import ssm as ssm_mod
from repro.models.ffn import (ffn, ffn_specs, init_ffn, init_moe, moe_ffn,
                              moe_specs)
from repro.models.layers import (DP_AXES, MODEL_AXIS, Params, apply_rope,
                                 attention_forward, attention_specs, constrain,
                                 cross_attention_forward, cross_attention_kv,
                                 dp_spec, embed, embedding_specs,
                                 init_attention, init_embedding, init_rmsnorm,
                                 pad_vocab, rmsnorm, rmsnorm_specs, unembed)
from repro.models.mla import init_mla, mla_forward, mla_specs

LOSS_CHUNK = 1024       # sequence chunk for the vocab-safe xent.  Perf
# note (EXPERIMENTS.md §Perf HC1-it2): the tied-embedding gradient is
# all-reduced once per chunk by GSPMD, so fewer/bigger chunks trade peak
# logits memory for collective volume; 1024 keeps the sharded chunk
# logits ~1.6 GiB/device while quartering the per-chunk AR traffic.


# ---------------------------------------------------------------------------
# per-layer init / specs
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {"ln1": init_rmsnorm(cfg.d_model), "ln2": init_rmsnorm(cfg.d_model)}
    if cfg.attn_kind == "mla":
        p["attn"] = init_mla(ks[0], cfg)
    elif cfg.attn_kind != "none":
        p["attn"] = init_attention(ks[0], cfg)
    if cfg.family == "hybrid":
        p["mamba"] = ssm_mod.init_mamba(ks[1], cfg)
        p["alpha"] = jnp.zeros((), jnp.float32)      # sigmoid(0)=.5 mix
    if cfg.moe is not None:
        p["ffn"] = init_moe(ks[2], cfg)
    elif cfg.d_ff:
        p["ffn"] = init_ffn(ks[2], cfg.d_model, cfg.d_ff, jnp.dtype(cfg.dtype))
    return p


def _layer_specs(cfg: ArchConfig) -> Params:
    p: Params = {"ln1": rmsnorm_specs(), "ln2": rmsnorm_specs()}
    if cfg.attn_kind == "mla":
        p["attn"] = mla_specs(cfg)
    elif cfg.attn_kind != "none":
        p["attn"] = attention_specs(cfg)
    if cfg.family == "hybrid":
        p["mamba"] = ssm_mod.mamba_specs(cfg)
        p["alpha"] = P()
    if cfg.moe is not None:
        p["ffn"] = moe_specs(cfg)
    elif cfg.d_ff:
        p["ffn"] = ffn_specs(cfg.d_ff)
    return p


def _init_dec_layer(key, cfg: ArchConfig) -> Params:
    """Decoder layer with cross-attention (enc-dec archs)."""
    ks = jax.random.split(key, 3)
    p = _init_layer(ks[0], cfg)
    p["lnx"] = init_rmsnorm(cfg.d_model)
    p["cross"] = init_attention(ks[1], cfg)
    return p


def _dec_layer_specs(cfg: ArchConfig) -> Params:
    p = _layer_specs(cfg)
    p["lnx"] = rmsnorm_specs()
    p["cross"] = attention_specs(cfg)
    return p


def _stack_specs(tree):
    """Prepend the stacked layer axis (unsharded) to every leaf spec."""
    return jax.tree.map(lambda s: P(None, *s), tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# model init / specs
# ---------------------------------------------------------------------------


def init_params(key, cfg: ArchConfig) -> Params:
    keys = jax.random.split(key, 8)
    dtype = jnp.dtype(cfg.dtype)
    params: Params = {
        "embed": init_embedding(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "ln_f": init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = init_embedding(keys[1], cfg.vocab_size,
                                           cfg.d_model, dtype)
    if cfg.family == "ssm":                         # xLSTM: alternating blocks
        bkeys = jax.random.split(keys[2], cfg.n_layers)
        blocks = []
        for i, bk in enumerate(bkeys):
            core = (ssm_mod.init_mlstm(bk, cfg) if i % 2 == 0
                    else ssm_mod.init_slstm(bk, cfg))
            blocks.append({"ln": init_rmsnorm(cfg.d_model), "core": core})
        params["blocks"] = blocks
        return params
    if cfg.enc_dec:
        ekeys = jax.random.split(keys[2], cfg.n_enc_layers)
        dkeys = jax.random.split(keys[3], cfg.n_layers)
        params["enc_layers"] = jax.vmap(lambda k: _init_layer(k, cfg))(ekeys)
        params["dec_layers"] = jax.vmap(lambda k: _init_dec_layer(k, cfg))(dkeys)
        params["ln_enc"] = init_rmsnorm(cfg.d_model)
        return params
    lkeys = jax.random.split(keys[2], cfg.n_layers)
    params["layers"] = jax.vmap(lambda k: _init_layer(k, cfg))(lkeys)
    return params


def param_specs(cfg: ArchConfig) -> Params:
    specs: Params = {
        "embed": embedding_specs(cfg.vocab_size),
        "ln_f": rmsnorm_specs(),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = embedding_specs(cfg.vocab_size)
    if cfg.family == "ssm":
        blocks = []
        for i in range(cfg.n_layers):
            core = (ssm_mod.mlstm_specs(cfg) if i % 2 == 0
                    else ssm_mod.slstm_specs(cfg))
            blocks.append({"ln": rmsnorm_specs(), "core": core})
        specs["blocks"] = blocks
        return specs
    if cfg.enc_dec:
        specs["enc_layers"] = _stack_specs(_layer_specs(cfg))
        specs["dec_layers"] = _stack_specs(_dec_layer_specs(cfg))
        specs["ln_enc"] = rmsnorm_specs()
        return specs
    specs["layers"] = _stack_specs(_layer_specs(cfg))
    return specs


# ---------------------------------------------------------------------------
# windows
# ---------------------------------------------------------------------------


def _has_window(cfg: ArchConfig) -> bool:
    return cfg.attn_kind in ("local_global", "sliding")


def layer_windows(cfg: ArchConfig, full: int) -> Optional[jnp.ndarray]:
    """Per-layer sliding-window sizes as a traced [L] array, or None.
    ``full`` stands in for 'no window' on global layers (>= any distance)."""
    if not _has_window(cfg):
        return None
    if cfg.attn_kind == "sliding":
        return jnp.full((cfg.n_layers,), cfg.window, jnp.int32)
    # local_global: even layers local, odd layers global
    idx = jnp.arange(cfg.n_layers)
    return jnp.where(idx % 2 == 0, cfg.window, full).astype(jnp.int32)


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _x_spec():
    return P(dp_spec(0) or DP_AXES, None, None)


def _embed_inputs(params, cfg: ArchConfig, batch: Dict[str, Any]):
    """Token embeddings, with VLM patch / decoder-input handling."""
    x = embed(params["embed"], batch["tokens"]).astype(jnp.dtype(cfg.dtype))
    x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    if cfg.family == "vlm" and "patches" in batch:
        # patch embeddings replace the first n_patches token slots
        x = jax.lax.dynamic_update_slice(
            x, batch["patches"].astype(x.dtype), (0, 0, 0))
    return x


def _dense_layer_body(cfg: ArchConfig, x, layer_params, window, positions,
                      *, causal=True, cross_mem=None):
    """One transformer layer (attn/mla [+mamba] + ffn).
    Returns (x, aux, kv, mamba_state)."""
    h = rmsnorm(layer_params["ln1"], x, cfg.norm_eps)
    if cfg.attn_kind == "mla":
        a, kv = mla_forward(layer_params["attn"], cfg, h, positions)
    elif cfg.attn_kind == "none":
        a, kv = 0.0, None
    else:
        a, kv = attention_forward(layer_params["attn"], cfg, h, positions,
                                  window=window, causal=causal)
    mstate = None
    if cfg.family == "hybrid":
        m, mstate = ssm_mod.mamba_forward(layer_params["mamba"], cfg, h)
        mix = jax.nn.sigmoid(layer_params["alpha"]).astype(x.dtype)
        a = mix * a + (1.0 - mix) * m
    x = x + a
    if cross_mem is not None:
        hx = rmsnorm(layer_params["lnx"], x, cfg.norm_eps)
        x = x + cross_attention_forward(layer_params["cross"], cfg, hx,
                                        cross_mem)
    aux = jnp.zeros((), jnp.float32)
    if "ffn" in layer_params:
        h2 = rmsnorm(layer_params["ln2"], x, cfg.norm_eps)
        if cfg.moe is not None:
            f, aux = moe_ffn(layer_params["ffn"], cfg, h2, cfg.act)
        else:
            f = ffn(layer_params["ffn"], h2, cfg.act)
        x = x + f
    return x, aux, kv, mstate


def _scan_layers(params_stack, cfg: ArchConfig, x, positions, windows, *,
                 causal=True, cross_mem=None, remat=False, collect_kv=False):
    """lax.scan over the stacked layer params.  Returns (x, aux_sum, kvs)."""
    S = x.shape[1]

    def body(carry, xs):
        x, aux_sum = carry
        if windows is not None:
            lp, w = xs
        else:
            lp, w = xs, None
        x = constrain(x, _x_spec())
        x, aux, kv, mstate = _dense_layer_body(cfg, x, lp, w, positions,
                                               causal=causal,
                                               cross_mem=cross_mem)
        ys = (kv, mstate) if collect_kv else None
        return (x, aux_sum + aux), ys

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    xs = (params_stack, windows) if windows is not None else params_stack
    (x, aux_sum), kvs = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, aux_sum, kvs


def forward(params: Params, cfg: ArchConfig, batch: Dict[str, Any], *,
            remat: bool = False, collect_kv: bool = False):
    """Returns (hidden [B,S,d], aux) — aux carries the MoE load-balance loss
    and (when collect_kv) the per-layer stacked K/V for prefill."""
    aux: Dict[str, Any] = {}
    if cfg.family == "ssm":
        x = _embed_inputs(params, cfg, batch)
        states = []
        for blk_i, blk in enumerate(params["blocks"]):
            h = rmsnorm(blk["ln"], x, cfg.norm_eps)
            fwd = (ssm_mod.mlstm_forward if blk_i % 2 == 0
                   else ssm_mod.slstm_forward)
            y, st = fwd(blk["core"], cfg, h)
            states.append(st)
            x = x + y
        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        aux["moe_loss"] = jnp.zeros(())
        if collect_kv:
            aux["states"] = states
        return x, aux

    if cfg.enc_dec:
        frames = batch["frames"]                       # [B,F,d] stub frontend
        Bf, F, _ = frames.shape
        enc_pos = jnp.broadcast_to(jnp.arange(F), (Bf, F))
        enc_x = frames.astype(jnp.dtype(cfg.dtype))
        enc_x, _, _ = _scan_layers(params["enc_layers"], cfg, enc_x, enc_pos,
                                   None, causal=False, remat=remat)
        memory = rmsnorm(params["ln_enc"], enc_x, cfg.norm_eps)
        aux["enc_memory"] = memory

        x = _embed_inputs(params, cfg, batch)
        B, S = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

        def dec_body(carry, lp):
            x, aux_sum = carry
            x = constrain(x, _x_spec())
            mem_kv = cross_attention_kv(lp["cross"], cfg, memory)
            x, aux_l, kv, _ = _dense_layer_body(cfg, x, lp, None, positions,
                                                cross_mem=mem_kv)
            return (x, aux_sum + aux_l), kv if collect_kv else None

        if remat:
            dec_body = jax.checkpoint(
                dec_body, policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux_sum), kvs = jax.lax.scan(
            dec_body, (x, jnp.zeros(())), params["dec_layers"])
        aux["moe_loss"] = aux_sum
        if collect_kv:
            aux["kv"] = kvs
        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        return x, aux

    x = _embed_inputs(params, cfg, batch)
    B, S = batch["tokens"].shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    windows = layer_windows(cfg, S)
    x, aux_sum, kvs = _scan_layers(params["layers"], cfg, x, positions,
                                   windows, remat=remat,
                                   collect_kv=collect_kv)
    aux["moe_loss"] = aux_sum
    if collect_kv:
        aux["kv"] = kvs[0] if kvs is not None else None
        aux["mstate"] = kvs[1] if kvs is not None else None
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return x, aux


# ---------------------------------------------------------------------------
# logits / loss (vocab-chunked: the full [B,S,V] tensor never exists)
# ---------------------------------------------------------------------------


def _unembed_table(params, cfg: ArchConfig):
    return params["embed" if cfg.tie_embeddings else "unembed"]


def logits_from_hidden(params, cfg: ArchConfig, hidden):
    """Logits for a small number of positions (decode/last-token only)."""
    return unembed(_unembed_table(params, cfg), hidden,
                   cfg.final_logit_softcap)


def lm_loss(params, cfg: ArchConfig, hidden, labels, mask):
    """Causal-LM cross-entropy, scanned over sequence chunks.

    hidden: [B,S,d]; labels/mask: [B,S].  Padded vocab columns are excluded
    from the logsumexp.  Returns (mean_loss, denom)."""
    B, S, d = hidden.shape
    table = _unembed_table(params, cfg)["table"].astype(jnp.float32)
    vp = table.shape[0]
    col_ok = (jnp.arange(vp) < cfg.vocab_size)

    chunk = min(LOSS_CHUNK, S)
    assert S % chunk == 0
    nc = S // chunk

    def step(carry, idx):
        tot, den = carry
        h = jax.lax.dynamic_slice_in_dim(hidden, idx * chunk, chunk, axis=1)
        y = jax.lax.dynamic_slice_in_dim(labels, idx * chunk, chunk, axis=1)
        m = jax.lax.dynamic_slice_in_dim(mask, idx * chunk, chunk, axis=1)
        logits = jnp.einsum("bsd,vd->bsv", h.astype(jnp.float32), table)
        if cfg.final_logit_softcap:
            logits = jnp.tanh(logits / cfg.final_logit_softcap) * \
                cfg.final_logit_softcap
        logits = jnp.where(col_ok, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        tot = tot + jnp.sum((lse - gold) * m)
        den = den + jnp.sum(m)
        return (tot, den), None

    (tot, den), _ = jax.lax.scan(step, (jnp.zeros(()), jnp.zeros(())),
                                 jnp.arange(nc))
    return tot / jnp.maximum(den, 1.0), den


def loss_fn(params, cfg: ArchConfig, batch, *, remat: bool = True,
            moe_loss_weight: float = 0.01):
    hidden, aux = forward(params, cfg, batch, remat=remat)
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(batch["labels"], jnp.float32)
    loss, _ = lm_loss(params, cfg, hidden, batch["labels"], mask)
    if cfg.moe is not None:
        loss = loss + moe_loss_weight * aux["moe_loss"] / max(cfg.n_layers, 1)
    return loss


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode_step
# ---------------------------------------------------------------------------


def kv_cache_len(cfg: ArchConfig, max_seq: int) -> int:
    """Ring buffer of size window for pure sliding-window archs."""
    if cfg.attn_kind == "sliding":
        return min(max_seq, cfg.window)
    return max_seq


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               enc_len: int = 0) -> Params:
    """Cache pytree for decode.  All leaves have a leading [L] layer axis."""
    dtype = jnp.dtype(cfg.dtype)
    L = cfg.n_layers
    hd = cfg.resolved_head_dim
    cache: Params = {}
    if cfg.family == "ssm":
        # per-block recurrent states (python list — heterogeneous)
        states = []
        for i in range(L):
            states.append(ssm_mod.init_mlstm_state(cfg, batch) if i % 2 == 0
                          else ssm_mod.init_slstm_state(cfg, batch))
        cache["states"] = states
        return cache
    S_c = kv_cache_len(cfg, max_seq)
    if cfg.attn_kind == "mla":
        m = cfg.mla
        cache["c"] = jnp.zeros((L, batch, S_c, m.kv_lora_rank), dtype)
        cache["pe"] = jnp.zeros((L, batch, S_c, m.qk_rope_head_dim), dtype)
    elif cfg.attn_kind != "none":
        cache["k"] = jnp.zeros((L, batch, S_c, cfg.n_kv_heads, hd), dtype)
        cache["v"] = jnp.zeros((L, batch, S_c, cfg.n_kv_heads, hd), dtype)
    if cfg.family == "hybrid":
        conv0, h0 = ssm_mod.init_mamba_state(cfg, batch)
        cache["conv"] = jnp.tile(conv0[None], (L,) + (1,) * conv0.ndim)
        cache["h"] = jnp.tile(h0[None], (L,) + (1,) * h0.ndim)
    if cfg.enc_dec:
        cache["cross_k"] = jnp.zeros((L, batch, enc_len, cfg.n_kv_heads, hd),
                                     dtype)
        cache["cross_v"] = jnp.zeros((L, batch, enc_len, cfg.n_kv_heads, hd),
                                     dtype)
    return cache


def cache_specs(cfg: ArchConfig, batch: int = 0) -> Params:
    """PartitionSpecs for the cache: batch over DP (when divisible);
    kv-heads over model where divisible, else the sequence axis over model
    (context-parallel decode — DESIGN.md §5: keeps the 32k x 128 caches
    inside per-chip HBM)."""
    from repro.models.layers import axis_size, maybe_axis
    dp = dp_spec(batch)
    kv_ax = maybe_axis(cfg.n_kv_heads, MODEL_AXIS)
    seq_ax = None if kv_ax is not None else MODEL_AXIS
    cache: Params = {}
    if cfg.family == "ssm":
        cache["states"] = [
            tuple(P(dp) for _ in range(3)) if i % 2 == 0
            else tuple(P(dp) for _ in range(4))
            for i in range(cfg.n_layers)]
        return cache
    if cfg.attn_kind == "mla":
        cache["c"] = P(None, dp, MODEL_AXIS, None)
        cache["pe"] = P(None, dp, MODEL_AXIS, None)
    elif cfg.attn_kind != "none":
        cache["k"] = P(None, dp, seq_ax, kv_ax, None)
        cache["v"] = P(None, dp, seq_ax, kv_ax, None)
    if cfg.family == "hybrid":
        cache["conv"] = P(None, dp, None, None)
        cache["h"] = P(None, dp, None, None)
    if cfg.enc_dec:
        cache["cross_k"] = P(None, dp, None, kv_ax, None)
        cache["cross_v"] = P(None, dp, None, kv_ax, None)
    return cache


def decode_step(params: Params, cfg: ArchConfig, cache: Params,
                tokens, pos):
    """One decode step.  tokens: [B,1] int32; pos: scalar int32 (absolute
    position of the new token; every sequence in the batch is at the same
    position — continuous-batching offsets live in the serving runtime).

    Returns (logits [B,vocab_pad], new_cache)."""
    B = tokens.shape[0]
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    positions = jnp.full((B, 1), pos, jnp.int32)

    if cfg.family == "ssm":
        new_states = []
        for i, blk in enumerate(params["blocks"]):
            h = rmsnorm(blk["ln"], x, cfg.norm_eps)
            fwd = (ssm_mod.mlstm_forward if i % 2 == 0
                   else ssm_mod.slstm_forward)
            y, st = fwd(blk["core"], cfg, h, state=cache["states"][i])
            new_states.append(st)
            x = x + y
        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = logits_from_hidden(params, cfg, x[:, 0])
        return logits, {"states": new_states}

    ring = cfg.attn_kind == "sliding"
    windows = layer_windows(cfg, cache_len := _cache_seq_len(cfg, cache))

    def body(carry, xs):
        x = carry
        lp, cl, w = xs["params"], xs["cache"], xs.get("window")
        x = constrain(x, _x_spec())
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        new_cl = dict(cl)
        if cfg.attn_kind == "mla":
            a, (cc, pc) = mla_forward(lp["attn"], cfg, h, positions,
                                      kv_cache=(cl["c"], cl["pe"]),
                                      cache_index=pos)
            new_cl["c"], new_cl["pe"] = cc, pc
        elif cfg.attn_kind == "none":
            a = 0.0
        else:
            a, (kc, vc) = attention_forward(
                lp["attn"], cfg, h, positions, window=w,
                kv_cache=(cl["k"], cl["v"]), cache_index=pos, ring=ring)
            new_cl["k"], new_cl["v"] = kc, vc
        if cfg.family == "hybrid":
            m, (conv, hs) = ssm_mod.mamba_forward(
                lp["mamba"], cfg, h, state=(cl["conv"], cl["h"]))
            new_cl["conv"], new_cl["h"] = conv, hs
            mix = jax.nn.sigmoid(lp["alpha"]).astype(x.dtype)
            a = mix * a + (1.0 - mix) * m
        x = x + a
        if cfg.enc_dec:
            hx = rmsnorm(lp["lnx"], x, cfg.norm_eps)
            x = x + cross_attention_forward(lp["cross"], cfg, hx,
                                            (cl["cross_k"], cl["cross_v"]))
        if "ffn" in lp:
            h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
            if cfg.moe is not None:
                f, _ = moe_ffn(lp["ffn"], cfg, h2, cfg.act)
            else:
                f = ffn(lp["ffn"], h2, cfg.act)
            x = x + f
        return x, new_cl

    layer_stack = params["dec_layers"] if cfg.enc_dec else params["layers"]
    xs = {"params": layer_stack, "cache": cache}
    if windows is not None:
        xs["window"] = windows
    x, new_cache = jax.lax.scan(body, x, xs)
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = logits_from_hidden(params, cfg, x[:, 0])
    return logits, new_cache


def _cache_seq_len(cfg: ArchConfig, cache: Params) -> int:
    if cfg.attn_kind == "mla":
        return cache["c"].shape[2]
    if "k" in cache:
        return cache["k"].shape[2]
    return 0


def prefill(params: Params, cfg: ArchConfig, batch: Dict[str, Any],
            max_seq: int):
    """Run the full prompt, build the decode cache, return last-token logits.

    For ring-buffer (sliding) archs the prefill writes only the last
    ``window`` positions into the cache."""
    hidden, aux = forward(params, cfg, batch, collect_kv=True)
    B, S = batch["tokens"].shape
    cache = init_cache(cfg, B, max_seq,
                       enc_len=batch["frames"].shape[1] if cfg.enc_dec else 0)
    if cfg.family == "ssm":
        cache["states"] = aux["states"]
        logits = logits_from_hidden(params, cfg, hidden[:, -1])
        return logits, cache
    if "kv" in aux and aux["kv"] is not None and cfg.attn_kind != "none":
        k, v = aux["kv"]                              # [L,B,S,kv,hd] each
        if cfg.attn_kind == "mla":
            S_c = cache["c"].shape[2]
            cache["c"] = jax.lax.dynamic_update_slice(
                cache["c"], k.astype(cache["c"].dtype), (0, 0, 0, 0))
            cache["pe"] = jax.lax.dynamic_update_slice(
                cache["pe"], v.astype(cache["pe"].dtype), (0, 0, 0, 0))
        else:
            S_c = cache["k"].shape[2]
            if S_c < S:                               # ring: keep the tail
                k = k[:, :, S - S_c:]
                v = v[:, :, S - S_c:]
                # ring layout: slot = pos % S_c; roll so slots line up
                shift = (S - S_c) % S_c
                k = jnp.roll(k, shift, axis=2)
                v = jnp.roll(v, shift, axis=2)
            cache["k"] = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
            cache["v"] = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    if cfg.family == "hybrid" and aux.get("mstate") is not None:
        conv, h_state = aux["mstate"]                 # [L,B,K-1,inner], [L,...]
        cache["conv"] = conv.astype(cache["conv"].dtype)
        cache["h"] = h_state
    if cfg.enc_dec:
        memory = aux["enc_memory"]

        def xkv(lp):
            return cross_attention_kv(lp["cross"], cfg, memory)
        ck, cv = jax.vmap(xkv)(params["dec_layers"])
        cache["cross_k"], cache["cross_v"] = (
            ck.astype(cache["cross_k"].dtype),
            cv.astype(cache["cross_v"].dtype))
    logits = logits_from_hidden(params, cfg, hidden[:, -1])
    return logits, cache
