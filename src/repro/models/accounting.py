"""Parameter / FLOP / traffic accounting for LM architectures.

These closed-form counts drive the H2PIPE placement algorithm (Eq. 1 analogue),
the weight-traffic roofline (Eq. 2 analogue: decode throughput <= HBM_bw /
weight bytes touched per token) and the MODEL_FLOPS figures of the roofline
report (6·N·D dense, 6·N_active·D MoE).
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ArchConfig


def _attn_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    if cfg.attn_kind == "mla":
        m = cfg.mla
        q = d * m.q_lora_rank + m.q_lora_rank * cfg.n_heads * (
            m.qk_nope_head_dim + m.qk_rope_head_dim)
        kv = d * (m.kv_lora_rank + m.qk_rope_head_dim)
        kv += m.kv_lora_rank * cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
        o = cfg.n_heads * m.v_head_dim * d
        return q + kv + o
    if cfg.attn_kind == "none":
        return 0
    q = d * cfg.n_heads * hd
    kv = 2 * d * cfg.n_kv_heads * hd
    o = cfg.n_heads * hd * d
    bias = (cfg.n_heads + 2 * cfg.n_kv_heads) * hd if cfg.qkv_bias else 0
    return q + kv + o + bias


def _ffn_params(cfg: ArchConfig) -> Dict[str, int]:
    """Returns {'total': ..., 'active': ...} for one layer's FFN."""
    d = cfg.d_model
    if cfg.moe is not None:
        m = cfg.moe
        per_expert = 3 * d * m.d_ff_expert            # gate/up/down
        router = d * m.n_experts
        total = (m.n_experts + m.n_shared) * per_expert + router
        active = (m.top_k + m.n_shared) * per_expert + router
        return {"total": total, "active": active}
    if cfg.d_ff == 0:
        return {"total": 0, "active": 0}
    n = 3 * d * cfg.d_ff
    return {"total": n, "active": n}


def _ssm_params(cfg: ArchConfig) -> int:
    if cfg.ssm is None:
        return 0
    s = cfg.ssm
    d = cfg.d_model
    if cfg.family == "ssm":                            # xlstm blocks
        dm = int(d * s.mlstm_proj_factor)
        mlstm = d * 2 * dm + 3 * dm * dm // cfg.n_heads + dm * d  # in/qkv/out
        ds = int(d * s.slstm_proj_factor)
        slstm = 4 * d * d + d * ds + ds * d            # gates + ffn up/down
        return (mlstm + slstm) // 2                    # alternating -> average
    inner = int(d * s.expand)
    # mamba: in_proj (x & z), conv, x->(dt,B,C), dt_proj, out_proj, A, D
    p = d * 2 * inner
    p += inner * s.conv_width
    p += inner * (s.state_dim * 2 + inner // 16)
    p += inner * d
    p += inner * s.state_dim + inner
    return p


def layer_param_counts(cfg: ArchConfig) -> Dict[str, int]:
    """Per-layer breakdown: attn / ffn_total / ffn_active / ssm / norms."""
    return {
        "attn": _attn_params(cfg),
        "ffn_total": _ffn_params(cfg)["total"],
        "ffn_active": _ffn_params(cfg)["active"],
        "ssm": _ssm_params(cfg),
        "norms": 4 * cfg.d_model,
    }


def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    lc = layer_param_counts(cfg)
    per_layer = (lc["attn"] + (lc["ffn_active"] if active_only else lc["ffn_total"])
                 + lc["ssm"] + lc["norms"])
    n_layers = cfg.n_layers + cfg.n_enc_layers
    embed = cfg.vocab_size * cfg.d_model
    if not cfg.tie_embeddings:
        embed *= 2
    cross = 0
    if cfg.enc_dec:
        # decoder cross-attention per decoder layer
        cross = cfg.n_layers * _attn_params(cfg)
    return n_layers * per_layer + cross + embed + cfg.d_model


def model_flops_per_token(cfg: ArchConfig) -> int:
    """6·N_active·(1 token) — the 'useful FLOPs' convention."""
    return 6 * count_params(cfg, active_only=True)


def weight_bytes(cfg: ArchConfig, bytes_per_param: int = 2) -> int:
    return count_params(cfg) * bytes_per_param


def active_weight_bytes_per_token(cfg: ArchConfig, bytes_per_param: int = 2) -> int:
    """Eq. 2 analogue for decode: weight bytes that must be read from HBM to
    produce one token (batch=1).  This is the H2PIPE 'weight traffic' term."""
    return count_params(cfg, active_only=True) * bytes_per_param
