"""Multi-head Latent Attention (DeepSeek-V2).

MLA compresses K/V into a low-rank latent ``c_kv`` (rank ``kv_lora_rank``)
plus a single shared RoPE key ``k_pe``.  This is the activation-side analogue
of H2PIPE's insight: the latent cache is the small, latency-critical state
kept in the fast tier, while the big decompression weights stream from HBM.

Decode uses the *absorbed* formulation (W_UK folded into the query, W_UV into
the output) so the per-step cache read is only [S, kv_rank + rope_dim].
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import (MODEL_AXIS, _dense_init, _flash_call,
                                 apply_rope, blockwise_attention,
                                 kernel_mode_enabled, maybe_axis, rmsnorm,
                                 init_rmsnorm, rmsnorm_specs)

Params = Dict[str, Any]


def init_mla(key, cfg) -> Params:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    return {
        "wq_a": _dense_init(ks[0], (d, m.q_lora_rank), dtype),
        "q_norm": init_rmsnorm(m.q_lora_rank),
        "wq_b": _dense_init(ks[1], (m.q_lora_rank, H,
                                    m.qk_nope_head_dim + m.qk_rope_head_dim),
                            dtype),
        "wkv_a": _dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim),
                             dtype),
        "kv_norm": init_rmsnorm(m.kv_lora_rank),
        "wk_b": _dense_init(ks[3], (m.kv_lora_rank, H, m.qk_nope_head_dim),
                            dtype),
        "wv_b": _dense_init(ks[4], (m.kv_lora_rank, H, m.v_head_dim), dtype),
        "wo": _dense_init(ks[5], (H, m.v_head_dim, d), dtype),
    }


def mla_specs(cfg) -> Params:
    h_ax = maybe_axis(cfg.n_heads, MODEL_AXIS)
    return {
        "wq_a": P(None, None),
        "q_norm": rmsnorm_specs(),
        "wq_b": P(None, h_ax, None),
        "wkv_a": P(None, None),
        "kv_norm": rmsnorm_specs(),
        "wk_b": P(None, h_ax, None),
        "wv_b": P(None, h_ax, None),
        "wo": P(h_ax, None, None),
    }


def _project_q(params, cfg, x, positions):
    m = cfg.mla
    q_lat = jnp.einsum("bsd,dr->bsr", x, params["wq_a"])
    q_lat = rmsnorm(params["q_norm"], q_lat, cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q_lat, params["wq_b"])
    q_nope = q[..., :m.qk_nope_head_dim]
    q_pe = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_pe


def _project_kv_latent(params, cfg, x, positions):
    m = cfg.mla
    kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    c_kv = rmsnorm(params["kv_norm"], kv[..., :m.kv_lora_rank], cfg.norm_eps)
    k_pe = apply_rope(kv[..., None, m.kv_lora_rank:], positions, cfg.rope_theta)
    return c_kv, k_pe[:, :, 0]


def mla_forward(params: Params, cfg, x, positions, *,
                kv_cache: Optional[Tuple] = None,
                cache_index: Optional[jnp.ndarray] = None):
    """kv_cache = (c_kv [B,S,r], k_pe [B,S,rope]) — the compressed cache."""
    m = cfg.mla
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_pe = _project_q(params, cfg, x, positions)
    c_new, kpe_new = _project_kv_latent(params, cfg, x, positions)

    if kv_cache is None:
        # train / prefill: decompress K,V and run blockwise attention
        k_nope = jnp.einsum("bsr,rhk->bshk", c_new, params["wk_b"])
        v = jnp.einsum("bsr,rhk->bshk", c_new, params["wv_b"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kpe_new[:, :, None],
                                      k_nope.shape[:3] + (m.qk_rope_head_dim,))],
            axis=-1)
        q = jnp.concatenate([q_nope, q_pe], axis=-1)
        out = None
        if kernel_mode_enabled() and \
                q.shape[1] % min(128, q.shape[1]) == 0:
            # flash kernel with split head dims (qk 192 / v 128) — the MLA
            # score tensor never round-trips HBM (§Perf HC2-it2)
            out = _flash_call(q, k, v, causal=True, window=0, softcap=0.0)
        if out is None:
            out = blockwise_attention(q, k, v, causal=True)
        new_cache = (c_new, kpe_new)
    else:
        cc, pc = kv_cache
        cc = jax.lax.dynamic_update_index_in_dim(
            cc, c_new[:, 0].astype(cc.dtype), cache_index, axis=1)
        pc = jax.lax.dynamic_update_index_in_dim(
            pc, kpe_new[:, 0].astype(pc.dtype), cache_index, axis=1)
        # absorbed decode: q_abs[b,1,h,r] = q_nope · W_UK
        q_abs = jnp.einsum("bqhk,rhk->bqhr", q_nope, params["wk_b"])
        scores = (jnp.einsum("bqhr,bsr->bhqs", q_abs, cc)
                  + jnp.einsum("bqhk,bsk->bhqs", q_pe, pc)).astype(jnp.float32)
        scores = scores * scale
        S = cc.shape[1]
        valid = jnp.arange(S)[None, None, None, :] <= cache_index
        scores = jnp.where(valid, scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bhqs,bsr->bqhr", w.astype(cc.dtype), cc)
        out = jnp.einsum("bqhr,rhk->bqhk", o_lat, params["wv_b"])
        new_cache = (cc, pc)

    y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), params["wo"])
    return y, new_cache
