"""State-space and recurrent blocks: Mamba (hymba's SSM heads) and the
xLSTM pair (mLSTM / sLSTM).

TPU adaptation (DESIGN.md §2): the recurrences are evaluated in *chunked*
form — ``lax.scan`` over chunks of the sequence with a parallel
(associative-scan / cumulative) evaluation inside each chunk.  This bounds
the activation working set to one chunk (the VMEM-tier analogue of HPIPE's
line buffers) while keeping the sequential HLO loop short (S/chunk steps).

Decode carries an explicit recurrent state so serving cost per token is
O(d_inner * d_state) — these archs are the ones that run ``long_500k``.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import MODEL_AXIS, _dense_init, maybe_axis

Params = Dict[str, Any]

CHUNK = 128     # within-chunk parallel width (MXU/VPU-friendly multiple of 8)


def _inner_dim(cfg) -> int:
    return int(cfg.d_model * cfg.ssm.expand)


def _dt_rank(cfg) -> int:
    return max(1, _inner_dim(cfg) // 16)


# ---------------------------------------------------------------------------
# Mamba (selective SSM)
# ---------------------------------------------------------------------------


def init_mamba(key, cfg) -> Params:
    s = cfg.ssm
    d, inner, dtr = cfg.d_model, _inner_dim(cfg), _dt_rank(cfg)
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * inner), dtype),
        "conv_w": _dense_init(ks[1], (s.conv_width, inner), dtype,
                              scale=1.0 / math.sqrt(s.conv_width)),
        "conv_b": jnp.zeros((inner,), dtype),
        # x -> (dt_rank, B, C)
        "x_proj": _dense_init(ks[2], (inner, dtr + 2 * s.state_dim), dtype),
        "dt_proj": _dense_init(ks[3], (dtr, inner), dtype),
        "dt_bias": jnp.log(jnp.expm1(                       # softplus^-1 init
            jnp.exp(jax.random.uniform(ks[4], (inner,),
                                       minval=math.log(1e-3),
                                       maxval=math.log(1e-1))))).astype(jnp.float32),
        "A_log": jnp.log(jnp.arange(1, s.state_dim + 1, dtype=jnp.float32)
                         )[None, :].repeat(inner, 0),        # [inner, state]
        "D": jnp.ones((inner,), jnp.float32),
        "out_proj": _dense_init(ks[5], (inner, d), dtype),
    }


def mamba_specs(cfg) -> Params:
    inner = _inner_dim(cfg)
    ax = maybe_axis(inner, MODEL_AXIS)
    return {
        "in_proj": P(None, ax),     # 2*inner divisible iff inner is
        "conv_w": P(None, ax),
        "conv_b": P(ax),
        "x_proj": P(ax, None),
        "dt_proj": P(None, ax),
        "dt_bias": P(ax),
        "A_log": P(ax, None),
        "D": P(ax),
        "out_proj": P(ax, None),
    }


def _causal_conv(x, w, b, state: Optional[jnp.ndarray]):
    """Depthwise causal conv over time.  x: [B,S,inner]; w: [K,inner].
    state: [B,K-1,inner] trailing context (decode) or None (train/prefill).
    Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros(x.shape[:1] + (K - 1,) + x.shape[2:], x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                  # [B,S+K-1,inner]
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K)) + b
    new_state = xp[:, -(K - 1):] if K > 1 else pad[:, :0]
    return y, new_state


def _ssm_scan_chunked(u, dt, Bc, Cc, A, h0):
    """Chunked selective-scan.

    u,dt: [B,S,inner]; Bc,Cc: [B,S,state]; A: [inner,state]; h0: [B,inner,state]
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t u_t ;  y_t = C_t . h_t
    Within a chunk the linear recurrence is evaluated with cumulative products
    in log space (parallel); chunks are threaded with lax.scan.
    """
    Bsz, S, inner = u.shape
    state = A.shape[1]
    chunk = min(CHUNK, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    def chunk_step(h, args):
        uc, dtc, bc, cc = args                              # [B,chunk,...]
        # decay factors a_t = exp(dt_t * A)   [B,chunk,inner,state]
        log_a = dtc[..., None] * A[None, None]              # A<0
        # suffix products P_t = prod_{s<=t} a_s  via cumsum of logs
        cum = jnp.cumsum(log_a, axis=1)
        x_t = dtc[..., None] * bc[:, :, None, :] * uc[..., None]
        # h_t = exp(cum_t) * (h0 + sum_{s<=t} exp(-cum_s) x_s)
        # guard exp(-cum) overflow: cum <= 0 so -cum >= 0 can overflow for
        # long chunks; instead use the scan-free two-pass stable form:
        #   z_s = x_s * exp(cum_t - cum_s)  computed as segment sums.
        # We use the standard stable trick: h_t = exp(cum_t)*h0 +
        #   sum_s exp(cum_t - cum_s) x_s, with exp(cum_t-cum_s) formed by
        #   cumulative logsumexp-style matrix; cheap version: associative scan.
        def op(l, r):
            al, bl = l
            ar, br = r
            return al + ar, bl * jnp.exp(ar) + br
        _, hs = jax.lax.associative_scan(op, (log_a, x_t), axis=1)
        hs = hs + jnp.exp(cum) * h[:, None]                 # carry-in
        y = jnp.einsum("bcis,bcs->bci", hs, cc)
        return hs[:, -1], y

    u32, dt32 = u.astype(jnp.float32), dt.astype(jnp.float32)
    B32, C32 = Bc.astype(jnp.float32), Cc.astype(jnp.float32)
    args = tuple(a.reshape((Bsz, nc, chunk) + a.shape[2:]).swapaxes(0, 1)
                 for a in (u32, dt32, B32, C32))
    hN, ys = jax.lax.scan(chunk_step, h0.astype(jnp.float32), args)
    y = ys.swapaxes(0, 1).reshape(Bsz, S, inner)
    return y, hN


def mamba_forward(params: Params, cfg, x, *,
                  state: Optional[Tuple] = None):
    """x: [B,S,d].  state = (conv_state [B,K-1,inner], h [B,inner,state]) for
    decode (S==1) or None.  Returns (y, new_state)."""
    s = cfg.ssm
    inner = _inner_dim(cfg)
    dtr = _dt_rank(cfg)
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    xin, z = xz[..., :inner], xz[..., inner:]

    conv_state = state[0] if state is not None else None
    xc, new_conv = _causal_conv(xin, params["conv_w"], params["conv_b"],
                                conv_state)
    xc = jax.nn.silu(xc)

    proj = jnp.einsum("bsi,ie->bse", xc, params["x_proj"])
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", proj[..., :dtr], params["dt_proj"])
        .astype(jnp.float32) + params["dt_bias"])
    Bc = proj[..., dtr:dtr + s.state_dim]
    Cc = proj[..., dtr + s.state_dim:]
    A = -jnp.exp(params["A_log"])                            # [inner,state]

    Bsz = x.shape[0]
    h0 = (state[1] if state is not None
          else jnp.zeros((Bsz, inner, s.state_dim), jnp.float32))
    y, hN = _ssm_scan_chunked(xc, dt, Bc, Cc, A, h0)
    y = y + xc.astype(jnp.float32) * params["D"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, params["out_proj"])
    return out, (new_conv, hN)


def init_mamba_state(cfg, batch: int):
    s = cfg.ssm
    inner = _inner_dim(cfg)
    return (jnp.zeros((batch, s.conv_width - 1, inner), jnp.dtype(cfg.dtype)),
            jnp.zeros((batch, inner, s.state_dim), jnp.float32))


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory, parallel) and sLSTM (scalar memory, sequential)
# ---------------------------------------------------------------------------


def _mlstm_dims(cfg):
    dm = int(cfg.d_model * cfg.ssm.mlstm_proj_factor)
    hd = dm // cfg.n_heads
    return dm, hd


def init_mlstm(key, cfg) -> Params:
    d = cfg.d_model
    dm, hd = _mlstm_dims(cfg)
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 7)
    return {
        "up": _dense_init(ks[0], (d, 2 * dm), dtype),        # x and gate path
        "wq": _dense_init(ks[1], (dm, dm), dtype),
        "wk": _dense_init(ks[2], (dm, dm), dtype),
        "wv": _dense_init(ks[3], (dm, dm), dtype),
        "w_if": _dense_init(ks[4], (dm, 2 * cfg.n_heads), dtype),  # i,f gates
        "b_if": jnp.zeros((2 * cfg.n_heads,), jnp.float32),
        "down": _dense_init(ks[5], (dm, d), dtype),
    }


def mlstm_specs(cfg) -> Params:
    dm, _ = _mlstm_dims(cfg)
    ax = maybe_axis(dm, MODEL_AXIS)
    h_ax = maybe_axis(cfg.n_heads, MODEL_AXIS)
    return {
        "up": P(None, ax), "wq": P(None, ax), "wk": P(None, ax),
        "wv": P(None, ax), "w_if": P(None, h_ax), "b_if": P(h_ax),
        "down": P(ax, None),
    }


def mlstm_forward(params: Params, cfg, x, *, state=None):
    """mLSTM = gated linear attention with matrix memory C [B,H,hd,hd].

    Chunked-recurrent evaluation: within a chunk, masked quadratic attention
    against in-chunk keys plus a read of the carried matrix memory; the memory
    is updated once per chunk (the standard chunkwise linear-attention form).
    state = (C [B,H,hd,hd], n [B,H,hd], m [B,H]) for decode.
    """
    H = cfg.n_heads
    dm, hd = _mlstm_dims(cfg)
    Bsz, S, _ = x.shape
    ug = jnp.einsum("bsd,de->bse", x, params["up"])
    u, g = ug[..., :dm], ug[..., dm:]
    q = jnp.einsum("bse,ef->bsf", u, params["wq"]).reshape(Bsz, S, H, hd)
    k = jnp.einsum("bse,ef->bsf", u, params["wk"]).reshape(Bsz, S, H, hd)
    v = jnp.einsum("bse,ef->bsf", u, params["wv"]).reshape(Bsz, S, H, hd)
    gates = jnp.einsum("bse,eg->bsg", u, params["w_if"]).astype(jnp.float32) \
        + params["b_if"]
    i_g = gates[..., :H]                                     # log-space input
    f_g = jax.nn.log_sigmoid(gates[..., H:])                 # log forget

    q = q.astype(jnp.float32) / math.sqrt(hd)
    k = k.astype(jnp.float32) / math.sqrt(hd)
    v32 = v.astype(jnp.float32)

    chunk = min(CHUNK, S)
    assert S % chunk == 0
    nc = S // chunk

    if state is None:
        C0 = jnp.zeros((Bsz, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((Bsz, H, hd), jnp.float32)
        m0 = jnp.full((Bsz, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def chunk_step(carry, args):
        C, n, m = carry
        qc, kc, vc, ic, fc = args                            # [B,chunk,...]
        # cumulative log forget within chunk (inclusive)
        F = jnp.cumsum(fc, axis=1)                           # [B,c,H]
        # stabilizer per step: m_t = max(F_t + m_in, max_s<=t (F_t - F_s + i_s))
        lse_in = F + m[:, None]                              # memory path
        a = ic - F                                           # [B,c,H]
        run_max = jax.lax.associative_scan(jnp.maximum, a, axis=1)
        m_t = jnp.maximum(lse_in, F + run_max)
        # intra-chunk attention: D[t,s] = F_t - F_s + i_s  (s<=t)
        D = F[:, :, None] - F[:, None, :] + ic[:, None, :, :]    # [B,t,s,H]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))[None, :, :, None]
        W = jnp.where(mask, jnp.exp(D - m_t[:, :, None]), 0.0)
        scores = jnp.einsum("bthd,bshd->btsh", qc, kc) * W
        y_intra = jnp.einsum("btsh,bshd->bthd", scores, vc)
        n_intra = jnp.einsum("btsh,bshd->bthd", scores, kc)
        # inter-chunk: read carried memory
        decay = jnp.exp(lse_in - m_t)                        # [B,c,H]
        y_inter = jnp.einsum("bthd,bhde->bthe", qc, C) * decay[..., None]
        n_inter = jnp.einsum("bthd,bhd->bth", qc, n) * decay
        num = y_intra + y_inter
        den = jnp.abs(jnp.einsum("bthd,bthd->bth", qc, n_intra)
                      + n_inter)
        y = num / jnp.maximum(den, jnp.exp(-m_t))[..., None]
        # update memory to end of chunk
        m_new = m_t[:, -1]                                   # [B,H]
        Ftot = F[:, -1]                                      # [B,H]
        w_upd = jnp.exp(ic + (Ftot[:, None] - F) - m_new[:, None])  # [B,c,H]
        C_new = C * jnp.exp(Ftot + m - m_new)[..., None, None] + \
            jnp.einsum("bsh,bshd,bshe->bhde", w_upd, kc, vc)
        n_new = n * jnp.exp(Ftot + m - m_new)[..., None] + \
            jnp.einsum("bsh,bshd->bhd", w_upd, kc)
        return (C_new, n_new, m_new), y

    args = tuple(a.reshape((Bsz, nc, chunk) + a.shape[2:]).swapaxes(0, 1)
                 for a in (q, k, v32, i_g, f_g))
    (CN, nN, mN), ys = jax.lax.scan(chunk_step, (C0, n0, m0), args)
    y = ys.swapaxes(0, 1).reshape(Bsz, S, dm)
    y = y.astype(x.dtype) * jax.nn.silu(g)
    out = jnp.einsum("bse,ed->bsd", y, params["down"])
    return out, (CN, nN, mN)


def init_mlstm_state(cfg, batch: int):
    _, hd = _mlstm_dims(cfg)
    H = cfg.n_heads
    return (jnp.zeros((batch, H, hd, hd), jnp.float32),
            jnp.zeros((batch, H, hd), jnp.float32),
            jnp.full((batch, H), -1e30, jnp.float32))


def init_slstm(key, cfg) -> Params:
    d = cfg.d_model
    ds = int(cfg.d_model * cfg.ssm.slstm_proj_factor)
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        # 4 gates (i,f,z,o) from input and recurrent paths
        "w_x": _dense_init(ks[0], (d, 4 * d), dtype),
        "w_h": _dense_init(ks[1], (d, 4 * d), dtype),
        "b": jnp.zeros((4 * d,), jnp.float32),
        "up": _dense_init(ks[2], (d, ds), dtype),
        "down": _dense_init(ks[3], (ds, d), dtype),
    }


def slstm_specs(cfg) -> Params:
    d = cfg.d_model
    ds = int(d * cfg.ssm.slstm_proj_factor)
    ax4 = maybe_axis(4 * d, MODEL_AXIS)
    axs = maybe_axis(ds, MODEL_AXIS)
    return {"w_x": P(None, ax4), "w_h": P(None, ax4), "b": P(ax4),
            "up": P(None, axs), "down": P(axs, None)}


def slstm_forward(params: Params, cfg, x, *, state=None):
    """Scalar-memory LSTM with exponential gating and stabilizer state.
    Sequential over time (true recurrence through h): lax.scan.
    state = (c,n,m,h) each [B,d]."""
    d = cfg.d_model
    Bsz, S, _ = x.shape
    xg = jnp.einsum("bsd,de->bse", x, params["w_x"]).astype(jnp.float32)

    if state is None:
        z0 = jnp.zeros((Bsz, d), jnp.float32)
        state = (z0, z0, jnp.full((Bsz, d), -1e30, jnp.float32), z0)

    w_h = params["w_h"].astype(jnp.float32)
    b = params["b"]

    def step(carry, xg_t):
        c, n, m, h = carry
        g = xg_t + h @ w_h + b
        i_t, f_t, z_t, o_t = jnp.split(g, 4, axis=-1)
        f_log = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(f_log + m, i_t)
        i_e = jnp.exp(i_t - m_new)
        f_e = jnp.exp(f_log + m - m_new)
        c_new = f_e * c + i_e * jnp.tanh(z_t)
        n_new = f_e * n + i_e
        h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, m_new, h_new), h_new

    (cN, nN, mN, hN), hs = jax.lax.scan(step, state, xg.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(x.dtype)                    # [B,S,d]
    y = jnp.einsum("bsd,de->bse", y, params["up"])
    y = jax.nn.gelu(y)
    out = jnp.einsum("bse,ed->bsd", y, params["down"])
    return out, (cN, nN, mN, hN)


def init_slstm_state(cfg, batch: int):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return (z, z, jnp.full((batch, d), -1e30, jnp.float32), z)
