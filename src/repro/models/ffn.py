"""Gated FFN (SwiGLU / GeGLU) and Mixture-of-Experts with dense dispatch.

The MoE uses the GShard-style einsum dispatch/combine so expert weights shard
cleanly over the ``model`` mesh axis (expert parallelism) and the whole layer
stays a single SPMD program — collectives (all-to-all under EP) are emitted by
GSPMD and show up in the roofline's collective term.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import MODEL_AXIS, _dense_init, maybe_axis

Params = Dict[str, Any]


def _act(name: str):
    return jax.nn.silu if name == "silu" else jax.nn.gelu


# ---------------------------------------------------------------------------
# dense FFN
# ---------------------------------------------------------------------------


def init_ffn(key, d: int, d_ff: int, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(ks[0], (d, d_ff), dtype),
        "w_up": _dense_init(ks[1], (d, d_ff), dtype),
        "w_down": _dense_init(ks[2], (d_ff, d), dtype),
    }


def ffn_specs(d_ff: int) -> Params:
    ax = maybe_axis(d_ff, MODEL_AXIS)
    return {"w_gate": P(None, ax), "w_up": P(None, ax), "w_down": P(ax, None)}


def ffn(params: Params, x, act: str = "silu"):
    g = _act(act)(jnp.einsum("bsd,df->bsf", x, params["w_gate"]))
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    return jnp.einsum("bsf,fd->bsd", g * u, params["w_down"])


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def init_moe(key, cfg) -> Params:
    m = cfg.moe
    d, f = cfg.d_model, m.d_ff_expert
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, m.n_experts), jnp.float32),
        "w_gate": _dense_init(ks[1], (m.n_experts, d, f), dtype),
        "w_up": _dense_init(ks[2], (m.n_experts, d, f), dtype),
        "w_down": _dense_init(ks[3], (m.n_experts, f, d), dtype),
    }
    if m.n_shared:
        p["shared"] = init_ffn(ks[4], d, f * m.n_shared, dtype)
    return p


def moe_specs(cfg) -> Params:
    m = cfg.moe
    e_ax = maybe_axis(m.n_experts, MODEL_AXIS)
    f_ax = maybe_axis(m.d_ff_expert, MODEL_AXIS) if e_ax is None else None
    p = {
        "router": P(None, None),
        "w_gate": P(e_ax, None, f_ax),
        "w_up": P(e_ax, None, f_ax),
        "w_down": P(e_ax, f_ax, None),
    }
    if m.n_shared:
        p["shared"] = ffn_specs(m.d_ff_expert * m.n_shared)
    return p


MOE_GROUP = 1024          # tokens per dispatch group (GShard-style grouping)
MOE_DENSE_T = 256         # below this token count, run the dropless path


def _moe_dense_small(params: Params, cfg, xt, act: str):
    """Dropless path for small token counts (decode steps, tiny batches):
    every expert processes every token, gates zero the non-selected ones.

    Rationale (H2PIPE economics): at decode, a batch of B tokens with
    top-k routing touches ~all experts anyway, so the step is bound by
    expert WEIGHT reads, not FLOPs — computing all experts costs no extra
    HBM traffic and removes the gather/capacity machinery (and its drops)
    entirely.  Exactness also makes serving bit-compatible with training
    for small batches."""
    m = cfg.moe
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    gates = jnp.zeros_like(probs).at[
        jnp.arange(xt.shape[0])[:, None], top_e].set(top_p)    # [T,E]
    g = _act(act)(jnp.einsum("td,edf->etf", xt, params["w_gate"]))
    u = jnp.einsum("td,edf->etf", xt, params["w_up"])
    ye = jnp.einsum("etf,efd->etd", g * u, params["w_down"])   # [E,T,d]
    y = jnp.einsum("etd,te->td", ye, gates.astype(ye.dtype))
    return y, _aux_loss(probs, top_e, m.n_experts)


def moe_ffn(params: Params, cfg, x, act: str = "silu"):
    """Grouped, gather-based top-k dispatch (scales to 1M-token steps).

    Tokens are split into groups of ~MOE_GROUP (groups shard over the data
    axis); within a group each expert has capacity ceil(cf*Tg*k/E).  Routing
    uses gathers/scatters instead of the GShard one-hot einsum, avoiding the
    O(T*E*C) dispatch tensor and its matmul FLOPs — only O(E*C*d) data
    movement per group.  Overflowing tokens are dropped (capacity factor
    1.25, the paper-standard policy).
    """
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    if T <= MOE_DENSE_T:
        y, aux = _moe_dense_small(params, cfg, xt, act)
        if m.n_shared:
            y = y + ffn(params["shared"], xt[None], act)[0]
        return y.reshape(B, S, d), aux
    tg = min(MOE_GROUP, T)
    assert T % tg == 0, (T, tg)
    G = T // tg
    cap = max(1, int(m.capacity_factor * tg * m.top_k / m.n_experts))
    xg = xt.reshape(G, tg, d)

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                    # [G,tg,E]
    top_p, top_e = jax.lax.top_k(probs, m.top_k)               # [G,tg,k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    if _ep_available(m):
        y = _moe_ep_shardmap(params, cfg, xg, top_p, top_e, cap, act)
        if m.n_shared:
            y = y + ffn(params["shared"], xt[None], act)[0].reshape(
                G, tg, d)
        probs2 = probs.reshape(T, m.n_experts)
        return (y.reshape(B, S, d),
                _aux_loss(probs2, top_e.reshape(T, m.top_k), m.n_experts))

    def route_group(top_e_g, top_p_g, x_g):
        # position of each (token,k) choice within its expert's buffer
        flat_e = top_e_g.reshape(-1)                           # [tg*k]
        onehot = jax.nn.one_hot(flat_e, m.n_experts, dtype=jnp.int32)
        pos = (jnp.cumsum(onehot, 0) - onehot)[
            jnp.arange(flat_e.shape[0]), flat_e]               # [tg*k]
        keep = pos < cap
        tok = jnp.arange(flat_e.shape[0]) // m.top_k
        # slot -> token map via scatter (dropped slots point at token 0
        # but are masked by `valid`)
        slot_tok = jnp.zeros((m.n_experts, cap), jnp.int32)
        valid = jnp.zeros((m.n_experts, cap), jnp.bool_)
        e_idx = jnp.where(keep, flat_e, 0)
        c_idx = jnp.where(keep, pos, 0)
        slot_tok = slot_tok.at[e_idx, c_idx].max(
            jnp.where(keep, tok, 0), mode="drop")
        valid = valid.at[e_idx, c_idx].max(keep, mode="drop")
        xe = x_g[slot_tok] * valid[..., None].astype(x_g.dtype)  # [E,C,d]
        gate = jnp.where(keep.reshape(tg, m.top_k), top_p_g, 0.0)
        return xe, gate, pos.reshape(tg, m.top_k)

    xe, gate, pos = jax.vmap(route_group)(top_e, top_p, xg)    # [G,E,C,d]

    g = _act(act)(jnp.einsum("gecd,edf->gecf", xe, params["w_gate"]))
    u = jnp.einsum("gecd,edf->gecf", xe, params["w_up"])
    ye = jnp.einsum("gecf,efd->gecd", g * u, params["w_down"])  # [G,E,C,d]

    def combine_group(ye_g, top_e_g, pos_g, gate_g):
        back = ye_g[top_e_g, jnp.clip(pos_g, 0, cap - 1)]      # [tg,k,d]
        return jnp.einsum("tkd,tk->td", back,
                          gate_g.astype(ye_g.dtype))

    y = jax.vmap(combine_group)(ye, top_e, pos, gate)          # [G,tg,d]
    y = y.reshape(T, d)

    if m.n_shared:
        y = y + ffn(params["shared"], xt[None], act)[0]
    probs2 = probs.reshape(T, m.n_experts)
    return y.reshape(B, S, d), _aux_loss(probs2,
                                         top_e.reshape(T, m.top_k),
                                         m.n_experts)


def _ep_available(m) -> bool:
    """Expert-parallel shard_map path: needs an active multi-device mesh
    whose model axis divides n_experts (EXPERIMENTS.md §Perf HC2)."""
    from repro.models.layers import (_current_physical_mesh, axis_size)
    mesh = _current_physical_mesh()
    return (mesh is not None and "model" in mesh.axis_names
            and axis_size("model") > 1
            and m.n_experts % axis_size("model") == 0)


def _moe_ep_shardmap(params: Params, cfg, xg, top_p, top_e, cap, act):
    """Expert parallelism as a manual shard_map region (HC2-it1).

    The GSPMD gather-combine all-gathers the full [G,E,C,d] expert output
    across the model axis (~63 GB/device/layer on deepseek-v2).  Here each
    model shard computes ONLY its E/model experts locally and the combine
    is a single psum of the [tokens, d] partial output — the collective
    shrinks from E*C*d to d per token.  Routing metadata (top-k, positions,
    keep) is computed outside, replicated over the model axis.

    This is the H2PIPE pseudo-channel assignment at datacenter scale:
    experts (weight-heavy, low duty cycle) live sharded like HBM-offloaded
    kernels, and only the small activation stream crosses the interconnect.
    """
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.models.layers import (_current_physical_mesh, axis_size,
                                     dp_spec)
    m = cfg.moe
    mesh = _current_physical_mesh()
    n_model = axis_size("model")
    E_local = m.n_experts // n_model
    G, tg, d = xg.shape
    k = m.top_k

    # routing positions within each expert's capacity buffer (global,
    # deterministic, replicated across model shards)
    def positions(top_e_g):
        flat_e = top_e_g.reshape(-1)
        onehot = jax.nn.one_hot(flat_e, m.n_experts, dtype=jnp.int32)
        pos = (jnp.cumsum(onehot, 0) - onehot)[
            jnp.arange(flat_e.shape[0]), flat_e]
        return pos.reshape(tg, k)

    pos = jax.vmap(positions)(top_e)                       # [G,tg,k]
    keep = pos < cap
    gate = jnp.where(keep, top_p, 0.0)

    dp = dp_spec(G) or None

    def region(w_gate, w_up, w_down, xg_l, te_l, pos_l, gate_l):
        col = jax.lax.axis_index("model")

        def one_group(x_g, te_g, pos_g, gate_g):
            rel = te_g - col * E_local                     # [tg,k]
            mine = (rel >= 0) & (rel < E_local) & (pos_g < cap)
            flat_rel = jnp.where(mine, rel, 0).reshape(-1)
            flat_pos = jnp.where(mine, pos_g, 0).reshape(-1)
            tok = jnp.arange(tg * k) // k
            slot_tok = jnp.zeros((E_local, cap), jnp.int32).at[
                flat_rel, flat_pos].max(
                jnp.where(mine.reshape(-1), tok, 0), mode="drop")
            valid = jnp.zeros((E_local, cap), jnp.bool_).at[
                flat_rel, flat_pos].max(mine.reshape(-1), mode="drop")
            xe = x_g[slot_tok] * valid[..., None].astype(x_g.dtype)
            g = _act(act)(jnp.einsum("ecd,edf->ecf", xe, w_gate))
            u = jnp.einsum("ecd,edf->ecf", xe, w_up)
            ye = jnp.einsum("ecf,efd->ecd", g * u, w_down)  # [E_l,C,d]
            back = ye[jnp.clip(rel, 0, E_local - 1),
                      jnp.clip(pos_g, 0, cap - 1)]          # [tg,k,d]
            w = (gate_g * mine.astype(gate_g.dtype)).astype(back.dtype)
            return jnp.einsum("tkd,tk->td", back, w)

        y_partial = jax.vmap(one_group)(xg_l, te_l, pos_l, gate_l)
        return jax.lax.psum(y_partial, "model")

    g_spec = P(dp, None, None)
    meta_spec = P(dp, None, None)
    fn = shard_map(
        region, mesh=mesh,
        in_specs=(P("model", None, None), P("model", None, None),
                  P("model", None, None), g_spec, meta_spec, meta_spec,
                  meta_spec),
        out_specs=g_spec, check_rep=False)
    return fn(params["w_gate"], params["w_up"], params["w_down"],
              xg, top_e, pos, gate)


def _aux_loss(probs, top_e, n_experts: int):
    """Switch-style load-balancing auxiliary loss."""
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(top_e[:, 0], n_experts, dtype=jnp.float32), axis=0)
    return n_experts * jnp.sum(me * ce)
