"""Fault-tolerant distributed training runtime.

Composes the pieces: model (transformer.loss_fn), optimizer (ZeRO AdamW),
data (deterministic pipeline), checkpointing (atomic/async), and the mesh.

Scale features (DESIGN.md §5):
  * one jitted train_step: loss -> grads -> clip -> AdamW, with microbatch
    gradient accumulation as an inner ``lax.scan`` (keeps the DP all-reduce
    once per step and lets XLA overlap it with the tail of the backward);
  * ZeRO-1 moment sharding over the full mesh;
  * optional int8 gradient compression (error feedback) for the DP
    all-reduce;
  * crash recovery: any exception in the step loop triggers restore of the
    newest verified checkpoint and the loop resumes at that step — because
    the data pipeline is counter-based the retraining is bitwise identical;
  * elastic rescale: ``Trainer.restore`` accepts a different mesh than the
    one that wrote the checkpoint (host-side arrays are re-scattered);
  * straggler mitigation is structural: steps are globally synchronous
    SPMD, so the mitigation is (a) deterministic re-assignment of a dead
    host's data shard (pipeline.host_batch is a pure function) and (b) the
    hot-spare pod documented in DESIGN.md — there is no per-host state
    outside the checkpoint.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.ckpt import checkpoint as ckpt_lib
from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig, TokenDataset
from repro.models import transformer as tmod
from repro.models.layers import dp_spec, set_mesh_axis_sizes
from repro.optim import adamw


@dataclass
class TrainConfig:
    steps: int = 100
    microbatches: int = 1            # gradient accumulation factor
    ckpt_every: int = 50
    ckpt_path: str = "/tmp/repro_ckpt"
    keep_n: int = 3
    log_every: int = 10
    remat: bool = True
    adamw: adamw.AdamWConfig = field(default_factory=adamw.AdamWConfig)


def make_train_step(arch: ArchConfig, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  ``batch`` has leading [microbatches, ...] when accumulating.
    """
    acfg = tcfg.adamw

    def step_fn(params, opt_state, batch):
        if tcfg.microbatches > 1:
            def micro(carry, mb):
                gsum, lsum = carry
                loss, g = jax.value_and_grad(tmod.loss_fn)(
                    params, arch, mb, remat=tcfg.remat)
                return (jax.tree.map(jnp.add, gsum, g), lsum + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (gsum, lsum), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros(())), batch)
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, gsum)
            loss = lsum / tcfg.microbatches
        else:
            loss, grads = jax.value_and_grad(tmod.loss_fn)(
                params, arch, batch, remat=tcfg.remat)
        params, opt_state, metrics = adamw.apply(grads, opt_state, params,
                                                 acfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return step_fn


class Trainer:
    """Step loop with checkpoint/restore and crash recovery."""

    def __init__(self, arch: ArchConfig, tcfg: TrainConfig,
                 data: TokenDataset, mesh: Optional[Mesh] = None,
                 seed: int = 0):
        self.arch = arch
        self.tcfg = tcfg
        self.data = data
        self.mesh = mesh
        if mesh is not None:
            set_mesh_axis_sizes(dict(zip(mesh.axis_names,
                                         mesh.devices.shape)))
        key = jax.random.PRNGKey(seed)
        self.params = tmod.init_params(key, arch)
        self.opt_state = adamw.init(self.params, tcfg.adamw)
        self.step = 0
        self.ckpt = ckpt_lib.AsyncCheckpointer(tcfg.ckpt_path,
                                               keep_n=tcfg.keep_n)
        self._jit_step = jax.jit(make_train_step(arch, tcfg),
                                 donate_argnums=(0, 1))
        self.history: list = []

    # -- checkpoint plumbing ------------------------------------------------
    def _state_tree(self):
        return {"params": self.params, "opt": self.opt_state}

    def save(self, sync: bool = False):
        if sync:
            ckpt_lib.save(self.tcfg.ckpt_path, self.step, self._state_tree(),
                          keep_n=self.tcfg.keep_n)
        else:
            self.ckpt.save(self.step, self._state_tree())

    def restore(self) -> bool:
        got = ckpt_lib.restore_latest(self.tcfg.ckpt_path, self._state_tree())
        if got is None:
            return False
        self.step, tree = got
        self.params, self.opt_state = tree["params"], tree["opt"]
        return True

    # -- batches ------------------------------------------------------------
    def _batch(self, step: int) -> Dict[str, jnp.ndarray]:
        gb = self.data.global_batch(step)
        b = {k: jnp.asarray(v) for k, v in gb.items()}
        if self.tcfg.microbatches > 1:
            m = self.tcfg.microbatches
            b = {k: v.reshape((m, v.shape[0] // m) + v.shape[1:])
                 for k, v in b.items()}
        return b

    # -- loop ---------------------------------------------------------------
    def run(self, n_steps: Optional[int] = None,
            fail_at: Optional[int] = None) -> list:
        """Run the loop.  ``fail_at``: inject a crash at that step (tests /
        chaos drills) — recovery restores the newest checkpoint and
        continues."""
        target = self.step + (n_steps or self.tcfg.steps)
        while self.step < target:
            try:
                if fail_at is not None and self.step == fail_at:
                    fail_at = None
                    raise RuntimeError("injected node failure")
                batch = self._batch(self.step)
                self.params, self.opt_state, m = self._jit_step(
                    self.params, self.opt_state, batch)
                self.step += 1
                if self.step % self.tcfg.log_every == 0 or \
                        self.step == target:
                    self.history.append(
                        {"step": self.step,
                         "loss": float(m["loss"]),
                         "grad_norm": float(m["grad_norm"])})
                if self.step % self.tcfg.ckpt_every == 0:
                    self.save()
            except (RuntimeError, OSError) as e:
                # node failure path: restore + resume (deterministic data
                # makes the replay exact)
                self.ckpt.wait()
                if not self.restore():
                    # no checkpoint yet: re-init deterministically
                    key = jax.random.PRNGKey(0)
                    self.params = tmod.init_params(key, self.arch)
                    self.opt_state = adamw.init(self.params,
                                                self.tcfg.adamw)
                    self.step = 0
        self.ckpt.wait()
        return self.history
