"""Layer-pipelined CNN inference executor — the running H2PIPE system.

``build_pipeline_plan`` (core/schedule.py) decides, per layer, whether the
weight buffer lives on chip or streams from HBM; this module *executes* a
CNN under that plan.  Each conv layer dispatches to the ``conv2d_int8``
Pallas engine — weights pinned in VMEM (the M20K tier) or double-buffered
from HBM through the kernel's DMA ring (the pseudo-channel tier) — and
1x1 fc heads reuse the ``stream_matmul`` machinery (``pinned`` vs the
explicit-FIFO ``fifo`` mode).  Topology wiring (residual adds, maxpool,
global-average-pool) stays in ``models.cnn.cnn_forward``; the executor
plugs in as its ``engine`` hook, so the pipelined execution is the SAME
network the functional reference runs — outputs are bit-identical.

The report cross-checks three views of the weight path that the paper
keeps consistent by construction:
  * executed:   streamed words counted at kernel dispatch (Eq. 2 traffic);
  * analytic:   the plan's ``weight_words_per_image`` (Eq. 2 formula);
  * simulated:  ``fifo_sim`` credit-mode delivery + tail-stall prediction
                over the same per-row word demands (§V-A).
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import fifo_sim
from repro.core.schedule import HBM, PINNED, LayerSchedule, PipelinePlan
from repro.configs.cnn import ConvLayerSpec
from repro.kernels.conv2d_int8.ops import conv2d_int8
from repro.kernels.pallas_compat import resolve_interpret
from repro.kernels.quant import requant_epilogue
from repro.kernels.stream_matmul.ops import stream_matmul
from repro.models.cnn import cnn_forward, init_cnn_params

Params = Dict[str, Any]


def _block(n: int, cap: int) -> int:
    """Largest divisor of n not exceeding cap (Pallas block sizing)."""
    for b in range(min(n, cap), 0, -1):
        if n % b == 0:
            return b
    return 1


# the ONE dequant+bias+relu+requant epilogue (kernels/quant.py), jitted so
# its float ops compile exactly like the reference path's
_requant = functools.partial(jax.jit, static_argnames=("act_scale", "relu"))(
    requant_epilogue)


@dataclass
class LayerExecStats:
    name: str
    mode: str                     # "pinned" | "hbm"
    kernel: str                   # "conv2d_int8" | "stream_matmul" | "jnp"
    hbm_words: int = 0            # Eq. 2 words streamed for this dispatch


@dataclass
class ExecutionReport:
    plan: PipelinePlan
    images: int = 0
    layers: List[LayerExecStats] = field(default_factory=list)

    @property
    def hbm_weight_words(self) -> Dict[str, int]:
        """Total streamed weight words per layer for the whole batch."""
        out: Dict[str, int] = {}
        for st in self.layers:
            if st.mode == HBM:
                out[st.name] = out.get(st.name, 0) + st.hbm_words
        return out

    @property
    def total_hbm_words(self) -> int:
        return sum(self.hbm_weight_words.values())

    @property
    def streamed_layer_count(self) -> int:
        return len({st.name for st in self.layers if st.mode == HBM})

    def fifo_prediction(self, outputs_needed: int = 32,
                        word_scale: Optional[int] = None
                        ) -> fifo_sim.SimOutcome:
        """§V-A credit-mode stall/delivery prediction for the streamed set."""
        return self.plan.predict_stalls(outputs_needed, word_scale)

    def modelled_throughput(self) -> Dict[str, float]:
        return self.plan.throughput()


class PipelineExecutor:
    """Executes a CNN end-to-end under a ``PipelinePlan``.

    ``interpret=None`` auto-selects Pallas interpret mode off-TPU
    (pallas_compat), so the same executor runs on CPU CI and real TPUs.
    """

    def __init__(self, plan: PipelinePlan, *, interpret: Optional[bool] = None,
                 act_scale: float = 0.05):
        self.plan = plan
        self.interpret = resolve_interpret(interpret)
        self.act_scale = act_scale
        self._report: Optional[ExecutionReport] = None

    # -- params -------------------------------------------------------------

    def init_params(self, key) -> Params:
        return init_cnn_params(key, self.plan.cfg)

    # -- execution ----------------------------------------------------------

    def run(self, params: Params, images
            ) -> Tuple[jnp.ndarray, ExecutionReport]:
        """images: [B,H,W,C] int8 -> (logits [B,classes], report)."""
        report = ExecutionReport(plan=self.plan, images=int(images.shape[0]))
        self._report = report
        logits = cnn_forward(params, self.plan.cfg, images,
                             engine=self._engine)
        self._report = None
        return logits, report

    def __call__(self, params: Params, images) -> jnp.ndarray:
        return self.run(params, images)[0]

    # -- per-layer dispatch (models.cnn engine hook) ------------------------

    def _engine(self, spec: ConvLayerSpec, p: Params, x, relu: bool):
        try:
            sched = self.plan.schedule_for(spec.name)
        except KeyError:
            return None                       # layer unknown to the plan
        if spec.kind == "dwconv":
            # the Pallas engine has no feature-group path yet — reference
            # path executes, so record the mode that actually ran (pinned),
            # not the plan's wish: accounting reflects execution.
            self._record(sched, kernel="jnp", batch=0, mode=PINNED)
            return None

        if spec.kind == "fc" and spec.k_h == 1 and x.ndim == 4 \
                and x.shape[1] == 1 and x.shape[2] == 1:
            return self._fc_matmul(sched, p, x, relu)
        return self._conv(sched, p, x, relu)

    def _conv(self, sched: LayerSchedule, p: Params, x, relu: bool):
        spec = sched.spec
        y = conv2d_int8(x, p["w"], stride=spec.stride,
                        stream=sched.streamed, n_buffers=sched.n_buffers,
                        interpret=self.interpret)
        y_q, y_f = _requant(y, p["w_scale"], p["bias"],
                            act_scale=self.act_scale, relu=relu)
        out_h = y.shape[1]
        self._record(sched, kernel="conv2d_int8", batch=int(x.shape[0]),
                     rows=out_h)
        return y_q, y_f

    def _fc_matmul(self, sched: LayerSchedule, p: Params, x, relu: bool):
        spec = sched.spec
        B = int(x.shape[0])
        c_in, c_out = spec.c_in, spec.c_out
        x2 = x.reshape(B, c_in)
        w2 = p["w"].reshape(c_in, c_out)
        mode = "fifo" if sched.streamed else "pinned"
        y = stream_matmul(x2, w2, mode=mode,
                          bm=_block(B, 128), bk=_block(c_in, 512),
                          bn=_block(c_out, 128),
                          n_buffers=max(2, sched.n_buffers),
                          interpret=self.interpret)
        y_q, y_f = _requant(y.reshape(B, 1, 1, c_out), p["w_scale"],
                            p["bias"], act_scale=self.act_scale, relu=relu)
        self._record(sched, kernel="stream_matmul", batch=B, rows=1)
        return y_q, y_f

    def _record(self, sched: LayerSchedule, *, kernel: str, batch: int,
                rows: int = 0, mode: Optional[str] = None) -> None:
        if self._report is None:
            return
        mode = sched.mode if mode is None else mode
        words = 0
        if mode == HBM and batch:
            # Eq. 2 accounting: kernels re-read once per output row, per
            # image.  (On TPU the matmul amortizes the batch dim; the
            # paper's accelerator is batch-1, so we report paper units.)
            words = sched.weight_words_per_row * rows * batch
        self._report.layers.append(LayerExecStats(
            name=sched.spec.name, mode=mode, kernel=kernel,
            hbm_words=words))


def execute_cnn(plan: PipelinePlan, params: Params, images, *,
                interpret: Optional[bool] = None
                ) -> Tuple[jnp.ndarray, ExecutionReport]:
    """One-shot convenience: run ``images`` through ``plan``."""
    return PipelineExecutor(plan, interpret=interpret).run(params, images)
