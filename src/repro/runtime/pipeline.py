"""Layer-pipelined CNN inference executor — the running H2PIPE system.

``repro.compiler.compile(cfg, target)`` decides, per layer (or per fused
residual block), which registered
:class:`~repro.compiler.engines.LayerEngine` runs it and whether its
weight buffer lives on chip or streams from HBM; this module *executes*
a CNN under that :class:`CompiledPipeline`, through one of two backends:

``backend="fused"`` (default)
    The stage-6 path: the whole engine table is closed over
    ``models.cnn.cnn_forward`` and compiled into ONE ``jax.jit`` program
    per (input shape, dtype) — a warm ``run()`` is a single XLA
    dispatch, the software analogue of the paper's point that the whole
    network IS one pipelined circuit.  Traces are cached on the
    ``CompiledPipeline`` (shared across executors and threads); the
    input buffer is donated on real backends.  Stats come from the
    trace: engines return shape-static :class:`LayerExecStats` instead
    of mutating a context, so the single trace yields the exact
    template every warm run's :class:`ExecutionReport` replays.

``backend="eager"``
    The per-layer walk: each engine dispatched from Python, one jit
    boundary per engine call.  Bit-identical to the fused path (golden
    test) and handy for debugging a single engine; this is what every
    ``run()`` was before the fused path existed.

The topology is owned end to end by the compiler: maxpool and
global-average-pool are first-class graph nodes bound to their own pool
engines, and residual blocks (basic and bottleneck) fuse to
``res_block_int8`` units — ``models.cnn.cnn_forward`` only walks
``cfg.layers`` and offers every node to the ``engine``/``block_engine``
hooks both backends plug in, so the pipelined execution is the SAME
network the functional reference runs — outputs are bit-identical, and
100% of the graph appears in the engine table and the reports.

The report cross-checks three views of the weight path that the paper
keeps consistent by construction:
  * executed:   streamed words from the traced dispatch counters
                (Eq. 2 traffic);
  * analytic:   the plan's ``weight_words_per_image`` (Eq. 2 formula);
  * simulated:  ``fifo_sim`` credit-mode delivery + tail-stall prediction
                over the same per-row word demands (§V-A).

Re-entrancy: per-run state is confined to the run's own
:class:`ExecutionReport`; the engine context is frozen and engines are
stateless, so concurrent ``run()``\\ s on one executor (or one compiled
pipeline) cannot corrupt each other's accounting.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple, Union

import jax.numpy as jnp

from repro.compiler.engines import EngineContext, LayerExecStats
from repro.compiler.pipeline import (CompiledPipeline, ExecutionReport,
                                     finalize, make_dispatchers)
from repro.core.schedule import PipelinePlan
from repro.kernels.pallas_compat import resolve_interpret
from repro.models.cnn import cnn_forward, init_cnn_params

__all__ = ["PipelineExecutor", "ExecutionReport", "LayerExecStats",
           "execute_cnn"]

Params = Dict[str, Any]

BACKENDS = ("fused", "eager")


class PipelineExecutor:
    """Executes a CNN end-to-end under a :class:`CompiledPipeline`.

    ``interpret=None`` defers to the compiled target's backend (and from
    there to pallas_compat auto-detection), so the same executor runs on
    CPU CI and real TPUs.  A bare :class:`PipelinePlan` (the deprecated
    ``build_pipeline_plan`` output) is accepted and gets engines bound on
    the fly, without target budget enforcement.

    ``backend`` picks the execution strategy: ``"fused"`` (one jitted
    XLA program per input shape, cached on the compiled pipeline) or
    ``"eager"`` (the per-layer dispatch walk) — bit-identical by
    contract.
    """

    def __init__(self, compiled: Union[CompiledPipeline, PipelinePlan], *,
                 interpret: Optional[bool] = None, act_scale: float = 0.05,
                 backend: str = "fused"):
        if isinstance(compiled, PipelinePlan):
            compiled = finalize(compiled, target=None)
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; "
                             f"expected one of {BACKENDS}")
        self.compiled = compiled
        if interpret is None and compiled.target is not None:
            interpret = compiled.target.interpret
        self.interpret = resolve_interpret(interpret)
        self.act_scale = act_scale
        self.backend = backend

    @property
    def plan(self) -> PipelinePlan:
        return self.compiled.plan

    # -- params -------------------------------------------------------------

    def init_params(self, key) -> Params:
        return init_cnn_params(key, self.plan.cfg)

    # -- execution ----------------------------------------------------------

    def run(self, params: Params, images
            ) -> Tuple[jnp.ndarray, ExecutionReport]:
        """images: [B,H,W,C] int8 -> (logits [B,classes], report)."""
        report = ExecutionReport(plan=self.plan, images=int(images.shape[0]),
                                 block_assignments=self.compiled
                                 .block_assignments,
                                 scan_assignments=self.compiled
                                 .scan_assignments)
        if self.backend == "fused":
            trace = self.compiled.fused_trace(
                params, images, interpret=self.interpret,
                act_scale=self.act_scale)
            logits = trace.fn(params, images)
            report.layers.extend(trace.stats)      # post-hoc aggregation
            return logits, report

        ctx = EngineContext(interpret=self.interpret,
                            act_scale=self.act_scale)
        dispatch, block_dispatch, scan_dispatch = make_dispatchers(
            self.compiled, ctx, report.layers)
        logits = cnn_forward(params, self.plan.cfg, images, engine=dispatch,
                             block_engine=block_dispatch,
                             scan_engine=scan_dispatch)
        return logits, report

    def __call__(self, params: Params, images) -> jnp.ndarray:
        return self.run(params, images)[0]


def execute_cnn(plan: Union[CompiledPipeline, PipelinePlan], params: Params,
                images, *, interpret: Optional[bool] = None,
                backend: str = "fused"
                ) -> Tuple[jnp.ndarray, ExecutionReport]:
    """One-shot convenience: run ``images`` through ``plan``."""
    return PipelineExecutor(plan, interpret=interpret,
                            backend=backend).run(params, images)
