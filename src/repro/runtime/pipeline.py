"""Layer-pipelined CNN inference executor — the running H2PIPE system.

``repro.compiler.compile(cfg, target)`` decides, per layer, which
registered :class:`~repro.compiler.engines.LayerEngine` runs it and
whether its weight buffer lives on chip or streams from HBM; this module
*executes* a CNN under that :class:`CompiledPipeline`.  Dispatch is
table-driven: the executor looks up each layer's compile-time engine
binding and calls it with a per-run :class:`EngineContext` — there is no
if/elif kernel selection here and no shared mutable state, so one
executor (or one compiled pipeline) can serve concurrent requests.

Topology wiring (residual adds, maxpool, global-average-pool) stays in
``models.cnn.cnn_forward``; the executor plugs in as its ``engine`` hook,
so the pipelined execution is the SAME network the functional reference
runs — outputs are bit-identical.

The report cross-checks three views of the weight path that the paper
keeps consistent by construction:
  * executed:   streamed words counted at engine dispatch (Eq. 2 traffic);
  * analytic:   the plan's ``weight_words_per_image`` (Eq. 2 formula);
  * simulated:  ``fifo_sim`` credit-mode delivery + tail-stall prediction
                over the same per-row word demands (§V-A).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple, Union

import jax.numpy as jnp

from repro.compiler.engines import EngineContext, LayerExecStats, get_engine
from repro.compiler.pipeline import (CompiledPipeline, ExecutionReport,
                                     finalize)
from repro.configs.cnn import ConvLayerSpec
from repro.core.schedule import PipelinePlan
from repro.kernels.pallas_compat import resolve_interpret
from repro.models.cnn import cnn_forward, init_cnn_params

__all__ = ["PipelineExecutor", "ExecutionReport", "LayerExecStats",
           "execute_cnn"]

Params = Dict[str, Any]


class PipelineExecutor:
    """Executes a CNN end-to-end under a :class:`CompiledPipeline`.

    ``interpret=None`` defers to the compiled target's backend (and from
    there to pallas_compat auto-detection), so the same executor runs on
    CPU CI and real TPUs.  A bare :class:`PipelinePlan` (the deprecated
    ``build_pipeline_plan`` output) is accepted and gets engines bound on
    the fly, without target budget enforcement.

    Re-entrancy: ``run`` threads all per-execution state (the report,
    the interpret flag, the activation scale) through an
    :class:`EngineContext` created per call — concurrent ``run``\\ s on
    one executor cannot corrupt each other's accounting.
    """

    def __init__(self, compiled: Union[CompiledPipeline, PipelinePlan], *,
                 interpret: Optional[bool] = None, act_scale: float = 0.05):
        if isinstance(compiled, PipelinePlan):
            compiled = finalize(compiled, target=None)
        self.compiled = compiled
        if interpret is None and compiled.target is not None:
            interpret = compiled.target.interpret
        self.interpret = resolve_interpret(interpret)
        self.act_scale = act_scale

    @property
    def plan(self) -> PipelinePlan:
        return self.compiled.plan

    # -- params -------------------------------------------------------------

    def init_params(self, key) -> Params:
        return init_cnn_params(key, self.plan.cfg)

    # -- execution ----------------------------------------------------------

    def run(self, params: Params, images
            ) -> Tuple[jnp.ndarray, ExecutionReport]:
        """images: [B,H,W,C] int8 -> (logits [B,classes], report)."""
        report = ExecutionReport(plan=self.plan, images=int(images.shape[0]))
        ctx = EngineContext(interpret=self.interpret,
                            act_scale=self.act_scale, stats=report.layers)

        def dispatch(spec: ConvLayerSpec, p: Params, x, relu: bool):
            asn = self.compiled.assignment_for(spec.name)
            if asn is None:
                return None               # layer unknown to the plan
            sched = self.plan.schedule_for(spec.name)
            return get_engine(asn.engine).run(ctx, sched, p, x, relu)

        logits = cnn_forward(params, self.plan.cfg, images, engine=dispatch)
        return logits, report

    def __call__(self, params: Params, images) -> jnp.ndarray:
        return self.run(params, images)[0]


def execute_cnn(plan: Union[CompiledPipeline, PipelinePlan], params: Params,
                images, *, interpret: Optional[bool] = None
                ) -> Tuple[jnp.ndarray, ExecutionReport]:
    """One-shot convenience: run ``images`` through ``plan``."""
    return PipelineExecutor(plan, interpret=interpret).run(params, images)
