"""Continuous-streaming CNN serving — H2PIPE's §V runtime, not one-shot.

The paper's accelerator never runs one image at a time: all layers
process concurrently on a continuous image stream, a new image admitted
every initiation interval, the number in flight bounded by FIFO credits
(§V-A).  ``PipelineExecutor.run()`` is the one-shot analogue; this
module is the *serving* analogue, built on the two PR-3 prerequisites
(executor re-entrancy, the per-shape fused-trace cache):

:class:`CnnServingEngine`
    Owns a :class:`~repro.compiler.pipeline.CompiledPipeline`, a bounded
    request queue, and two worker threads.  Requests of mixed image
    counts are *packed* into one fixed microbatch shape (pad + mask) so
    the per-shape fused-trace cache stays at a single warm entry, and
    dispatch is asynchronously double-buffered: the dispatcher enqueues
    microbatch ``t+1`` while ``t``'s device computation is in flight,
    calling ``block_until_ready`` only at result delivery — warm serving
    throughput is back-to-back single-dispatch XLA programs, the §V-A
    credit bound (:class:`~repro.core.admission.AdmissionController`,
    at most ``credits`` microbatches in flight) standing between the
    dispatcher and the device queue exactly where the paper's
    burst-matching FIFO credits stand between prefetcher and HBM.

:class:`ServingReport`
    What a serving interval did: throughput (images/s), p50/p95/p99
    request latency, queue depth over time, microbatch occupancy, and
    per-request Eq. 2 HBM words (useful words per request, plus the
    executed total including padding — the padding overhead is visible,
    never silently folded in).

Results are bit-identical to sequential ``run()`` per request: packing
only concatenates images along the batch dimension, every engine is
per-image, and padded rows are sliced away before delivery (contract
tested in tests/test_cnn_serving.py, including under concurrent
producers).
"""
from __future__ import annotations

import dataclasses
import json
import math
import queue
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple,
                    Union)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fifo_sim
from repro.core.admission import AdmissionController, AdmissionError
from repro.kernels.pallas_compat import resolve_interpret
from repro.models.cnn import cnn_input_shape
from repro.obs.metrics import MetricsRegistry
from repro.obs.stall import stall_attribution
from repro.obs.trace import NULL_TRACER, monotonic_clock

__all__ = ["CnnRequest", "CnnServingEngine", "MicrobatchPacker",
           "ServingReport", "restore_tuple_fields"]

_STOP = object()                      # request-queue shutdown sentinel

# a long-lived server must not grow without bound: per-request metrics
# keep the most recent window (percentiles/rows are over this window;
# the throughput counters are exact lifetime totals)
METRIC_WINDOW = 16384
REQUEST_ROW_WINDOW = 1024


class CnnRequest:
    """One submitted inference request: ``n`` images in, ``n`` logits
    rows out.  Rows may span microbatches; the result is visible only
    once every row has been delivered."""

    def __init__(self, rid: int, images: np.ndarray,
                 now: Optional[float] = None):
        self.rid = rid
        self.images = images
        self.n = int(images.shape[0])
        # the submitting engine passes its injected clock's reading; the
        # bare-constructor default keeps direct (test) construction easy
        self.t_submit = time.perf_counter() if now is None else now
        self.t_done: Optional[float] = None
        self.hbm_words = 0            # useful Eq. 2 words (n * words/image)
        self._logits: Optional[np.ndarray] = None
        self._remaining = self.n
        self._error: Optional[BaseException] = None
        self._event = threading.Event()

    @property
    def done(self) -> bool:
        return self._event.is_set()

    @property
    def latency_s(self) -> float:
        if self.t_done is None:
            raise RuntimeError(f"request {self.rid} not complete")
        return self.t_done - self.t_submit

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        """Block until delivered; returns logits [n, classes]."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid} not done in {timeout}s")
        if self._error is not None:
            raise RuntimeError(
                f"request {self.rid} failed in the serving engine"
            ) from self._error
        return self._logits

    # called only from the completer thread (single consumer)
    def _deliver(self, row_offset: int, rows: np.ndarray, now: float) -> bool:
        if self._logits is None:
            self._logits = np.empty((self.n,) + rows.shape[1:], rows.dtype)
        self._logits[row_offset:row_offset + len(rows)] = rows
        self._remaining -= len(rows)
        if self._remaining == 0:
            self.t_done = now
            self._event.set()
            return True
        return False

    def _fail(self, exc: BaseException) -> None:
        self._error = exc
        self._event.set()


class MicrobatchPacker:
    """Greedy pad+mask packing over one bounded request queue: fill a
    fixed ``microbatch`` shape from whatever rows are available, rows
    spanning microbatch boundaries via the (request, offset) cursor,
    never waiting for more once at least one row is held (latency over
    occupancy — the padding keeps partial batches exact, just less
    dense).  Owned by ONE consumer thread; shared by the host-queue
    engine here and the shard-local producers of
    :class:`~repro.runtime.sharded_serving.ShardedCnnServingEngine`
    (one packer per shard queue there).
    """

    def __init__(self, request_queue: "queue.Queue", microbatch: int):
        self.queue = request_queue
        self.microbatch = microbatch
        self.cursor: Optional[List[Any]] = None      # [request, row_offset]
        self.saw_stop = False

    def collect(self, *, block: bool = True):
        """One packed microbatch: ``(rows, filled)`` with ``rows`` a
        list of ``(request, req_offset, mb_offset, take)`` spans, or
        ``None`` when nothing is available (queue empty and
        ``block=False``, or the stop sentinel was drained)."""
        rows: List[Tuple[CnnRequest, int, int, int]] = []
        filled = 0
        while filled < self.microbatch:
            if self.cursor is None:
                if self.saw_stop:
                    break
                try:
                    item = self.queue.get(block=block and filled == 0)
                except queue.Empty:
                    break
                if item is _STOP:
                    self.saw_stop = True
                    break
                self.cursor = [item, 0]
            req, off = self.cursor
            take = min(req.n - off, self.microbatch - filled)
            rows.append((req, off, filled, take))
            filled += take
            self.cursor = [req, off + take] if off + take < req.n else None
        if filled == 0:
            return None                              # stopped and empty
        return rows, filled

    @property
    def depth_hint(self) -> int:
        """Approximate queued depth (requests + the partially consumed
        cursor) for the report's queue-depth samples."""
        return self.queue.qsize() + (1 if self.cursor else 0)

    def fail_cursor(self, exc: BaseException) -> None:
        """Fail the partially consumed request, if any."""
        if self.cursor is not None:
            self.cursor[0]._fail(exc)
            self.cursor = None


def _deep_tuple(value: Any) -> Any:
    """Recursively convert lists to tuples (JSON has no tuples, report
    fields may nest them — per-stage rows of per-shard pairs)."""
    if isinstance(value, list):
        return tuple(_deep_tuple(v) for v in value)
    return value


def restore_tuple_fields(cls, payload: Dict[str, Any]) -> Dict[str, Any]:
    """The report deserialization law shared by every report dataclass
    (:class:`ServingReport` and its sharded subclass here, the front-end
    report in :mod:`repro.runtime.frontend`): drop unknown keys (derived
    values ride in the dict but are never constructor args) and restore
    tuple-typed fields from JSON's lists — *recursively*, so nested rows
    round-trip to equality rather than silently decaying to lists one
    level down."""
    names = {f.name for f in dataclasses.fields(cls)}
    data = {k: v for k, v in payload.items() if k in names}
    for f in dataclasses.fields(cls):
        # annotations may be strings (``from __future__ import
        # annotations``) or live typing objects — match both spellings
        if f.name in data and str(f.type).startswith(
                ("Tuple", "typing.Tuple", "tuple")):
            data[f.name] = _deep_tuple(data[f.name])
    return data


@dataclass
class ServingReport:
    """Aggregate view of one serving interval (see module docstring)."""

    requests: int
    images: int
    microbatches: int
    microbatch_size: int
    padded_rows: int
    credits: int
    max_in_flight: int
    wall_s: float
    images_per_s: float
    p50_ms: float
    p95_ms: float
    p99_ms: float
    hbm_words_per_image: int
    hbm_words_useful: int             # sum over requests of n * words/image
    hbm_words_executed: int           # traced words incl. padded rows
    queue_depth: List[Tuple[float, int]] = field(default_factory=list)
    request_rows: List[Dict[str, Any]] = field(default_factory=list)
    #: total rows dispatched including padding — equals
    #: ``microbatches * microbatch_size`` under the fixed packed shape,
    #: less under adaptive sizing (small packs dispatch small shapes).
    #: 0 on reports from engines predating the field (fixed-shape
    #: fallback applies).
    dispatched_rows: int = 0
    #: adaptive-sizing evidence: packed-shape row count -> dispatches
    #: (one ``{str(rows): count}`` entry per ladder rung used).  Empty
    #: for fixed-shape engines.
    microbatch_shapes: Dict[str, int] = field(default_factory=dict)
    #: stage-6 LRU trace cache counters (entries/max_entries/hits/misses/
    #: evictions) from ``CompiledPipeline.trace_cache_stats()`` — whether
    #: the serving interval's shape population thrashes the trace bound.
    trace_cache: Dict[str, int] = field(default_factory=dict)
    #: the engine-local :class:`~repro.obs.metrics.MetricsRegistry`
    #: snapshot at report time (counters/gauges/histograms).
    metrics: Dict[str, Any] = field(default_factory=dict)
    #: measured admission-wait / dispatch-gap fractions laid against the
    #: ``fifo_sim`` modelled stall cycles
    #: (:func:`repro.obs.stall.stall_attribution`) — the measured half
    #: of the §VI bandwidth-efficiency reproduction.
    bandwidth_efficiency: Dict[str, Any] = field(default_factory=dict)

    @property
    def pad_fraction(self) -> float:
        total = self.dispatched_rows \
            or self.microbatches * self.microbatch_size
        return self.padded_rows / total if total else 0.0

    @property
    def effective_images_per_s(self) -> float:
        """Dispatch-side throughput discounted by padding: the rate
        microbatch rows left the dispatcher, weighted by the fraction
        that carried real images — what the pipeline would sustain on
        perfectly packed input, collapsed to what it delivered."""
        if self.wall_s <= 0:
            return 0.0
        total = self.dispatched_rows \
            or self.microbatches * self.microbatch_size
        return (total / self.wall_s) * (1.0 - self.pad_fraction)

    def table(self) -> str:
        """Human-readable summary + per-request rows."""
        head = [
            f"requests={self.requests}  images={self.images}  "
            f"microbatches={self.microbatches}x{self.microbatch_size} "
            f"(pad {self.pad_fraction:.0%})  "
            f"in-flight<= {self.max_in_flight}/{self.credits}",
            f"throughput={self.images_per_s:.1f} images/s  "
            f"effective={self.effective_images_per_s:.1f} images/s "
            f"(pad-fraction-weighted)  "
            f"latency p50={self.p50_ms:.1f}ms p95={self.p95_ms:.1f}ms "
            f"p99={self.p99_ms:.1f}ms",
            f"Eq.2 words/image={self.hbm_words_per_image}  "
            f"useful={self.hbm_words_useful}  "
            f"executed={self.hbm_words_executed} (incl. padding)",
        ]
        if len(self.microbatch_shapes) > 1:
            shapes = "  ".join(f"{k}x{v}" for k, v in
                               self.microbatch_shapes.items())
            head.append(f"adaptive shapes (rows x dispatches): {shapes}")
        if self.trace_cache:
            tc = self.trace_cache
            head.append(
                f"trace cache: {tc.get('entries', 0)}/"
                f"{tc.get('max_entries', 0)} entries  "
                f"hits={tc.get('hits', 0)} misses={tc.get('misses', 0)} "
                f"evictions={tc.get('evictions', 0)}")
        be = self.bandwidth_efficiency
        if be:
            m = be.get("measured", {})
            line = (f"stalls: admission-wait "
                    f"{m.get('admission_wait_fraction', 0.0):.1%}  "
                    f"dispatch-gap "
                    f"{m.get('dispatch_gap_fraction', 0.0):.1%}")
            mo = be.get("modelled")
            if mo:
                line += (f"  modelled {mo.get('stall_fraction', 0.0):.1%} "
                         f"({mo.get('stall_cycles', 0)}/"
                         f"{mo.get('cycles', 0)} cycles)")
            head.append(line)
        hdr = f"{'rid':>5s} {'images':>6s} {'latency_ms':>10s} " \
              f"{'hbm_words':>10s}"
        rows = [hdr, "-" * len(hdr)]
        for r in self.request_rows:
            rows.append(f"{r['rid']:>5d} {r['images']:>6d} "
                        f"{r['latency_ms']:>10.2f} {r['hbm_words']:>10d}")
        return "\n".join(head + rows)

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        """JSON-safe dict of every field plus the derived rates the
        benchmark artifacts want (``pad_fraction``,
        ``effective_images_per_s``) — the artifact shape
        ``benchmarks/serving_throughput.py`` embeds directly instead of
        hand-rolling its own."""
        out = dataclasses.asdict(self)
        out["queue_depth"] = [list(q) for q in self.queue_depth]
        out["pad_fraction"] = self.pad_fraction
        out["effective_images_per_s"] = self.effective_images_per_s
        return out

    def to_json(self, **kw: Any) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, **kw)

    @classmethod
    def from_json(cls, payload: Union[str, Dict[str, Any]]
                  ) -> "ServingReport":
        """Round-trip inverse of :meth:`to_json`/:meth:`to_dict`:
        ``cls.from_json(rep.to_json()) == rep`` (derived keys are
        recomputed, JSON's lists restored to the tuple-shaped fields —
        recursively, so nested per-stage/per-shard row tuples survive).
        Works for subclasses (``ShardedServingReport.from_json``)."""
        data = json.loads(payload) if isinstance(payload, str) \
            else dict(payload)
        data = restore_tuple_fields(cls, data)
        data["queue_depth"] = [tuple(q) for q in
                               data.get("queue_depth", [])]
        return cls(**data)


class ServingObsMixin:
    """The observability surface both serving engines share: lazy
    ``fifo_sim`` modelled stalls, the measured-vs-modelled
    ``bandwidth_efficiency`` section, and the metrics snapshot with
    trace-cache gauges.  Expects ``self.compiled`` / ``self.admission`` /
    ``self.metrics`` / ``self._gap_s``."""

    def _modelled_stalls(self):
        """The deterministic ``fifo_sim`` side of stall attribution,
        computed once per engine (plans that stream nothing model as
        ``None``): ``(outcome, streamed engine names, word_scale)``."""
        if self._modelled is False:
            plan = self.compiled.plan
            try:
                sim_cfg, scale = plan.sim_config()
                outcome = fifo_sim.simulate(sim_cfg, "credit")
                names = tuple(s.spec.name for s in plan.streamed
                              if s.weight_words_per_row > 0)
                self._modelled = (outcome, names, scale)
            except ValueError:
                self._modelled = None
        return self._modelled

    def _stall_report(self, wall: float) -> Dict[str, Any]:
        modelled = self._modelled_stalls()
        outcome, names, scale = modelled if modelled else (None, (), None)
        return stall_attribution(
            wall_s=wall,
            admission_wait_s=self.admission.wait_seconds_total,
            dispatch_gap_s=self._gap_s,
            modelled=outcome, engine_names=names, word_scale=scale)

    def _metrics_snapshot(self) -> Dict[str, Any]:
        """Engine registry snapshot with the trace-cache counters set as
        gauges at read time (the cache lives on the pipeline; the
        gauges make it part of THIS engine's metrics view)."""
        for k, v in self.compiled.trace_cache_stats().items():
            self.metrics.gauge("trace_cache", counter=k).set(v)
        self.metrics.gauge("admission_wait_seconds_total").set(
            self.admission.wait_seconds_total)
        self.metrics.gauge("dispatch_gap_seconds_total").set(self._gap_s)
        return self.metrics.snapshot()


class CnnServingEngine(ServingObsMixin):
    """Credit-bounded, double-buffered serving over one compiled pipeline.

    ``credits`` is the §V-A in-flight bound: at most that many
    microbatches between dispatch and delivery (the runtime mirror of
    ``core/dataflow.py``'s at-most-``n_stages``-in-flight static
    schedule — ``pipeline_stats(S, M)["in_flight_credits"] == S``).
    ``microbatch`` is the one packed shape every dispatch uses, so the
    fused-trace cache holds exactly one warm entry however mixed the
    request sizes are.

    ``adaptive=True`` trades that single warm entry for latency under
    light load: each dispatch packs into the smallest rung of
    ``microbatch_ladder`` (default: powers of two up to ``microbatch``)
    that holds the rows actually collected, so a shallow queue dispatches
    small low-padding shapes and a deep queue grows back to the full
    ``microbatch``.  The ladder must fit the pipeline's bounded
    trace-cache LRU (``trace_cache_size``) so every rung stays warm —
    validated at construction, and the shapes actually used are surfaced
    as ``ServingReport.microbatch_shapes``.

    Use as a context manager (``with cp.serve(params) as eng``) or call
    :meth:`start`/:meth:`stop` explicitly; :meth:`submit` is thread-safe
    (N producers may submit concurrently — the admission invariants are
    asserted under exactly that in the stress test).
    """

    def __init__(self, compiled, params, *, microbatch: int = 8,
                 credits: int = 4, queue_depth: int = 64,
                 interpret: Optional[bool] = None, act_scale: float = 0.05,
                 adaptive: bool = False,
                 microbatch_ladder: Optional[Sequence[int]] = None,
                 tracer=None, metrics: Optional[MetricsRegistry] = None,
                 clock: Optional[Callable[[], float]] = None,
                 metric_window: int = METRIC_WINDOW,
                 request_row_window: int = REQUEST_ROW_WINDOW):
        if microbatch <= 0:
            raise ValueError("microbatch must be positive")
        self.compiled = compiled
        self.params = params
        self.microbatch = microbatch
        self.act_scale = act_scale
        if microbatch_ladder is not None:
            adaptive = True
        if adaptive:
            if microbatch_ladder is None:
                # powers of two up to the full shape (always included)
                microbatch_ladder = sorted(
                    {min(1 << i, microbatch)
                     for i in range(microbatch.bit_length())}
                    | {microbatch})
            ladder = sorted(set(int(r) for r in microbatch_ladder))
            if not ladder or ladder[0] < 1 or ladder[-1] != microbatch:
                raise ValueError(
                    f"microbatch_ladder must be positive sizes topping "
                    f"out at microbatch={microbatch}, got {ladder}")
            if len(ladder) > compiled.trace_cache_size:
                raise ValueError(
                    f"microbatch_ladder has {len(ladder)} rungs but the "
                    f"trace cache holds {compiled.trace_cache_size} — "
                    f"the ladder would thrash its own traces")
            self.microbatch_ladder: Tuple[int, ...] = tuple(ladder)
        else:
            self.microbatch_ladder = (microbatch,)
        self.adaptive = adaptive
        if interpret is None and compiled.target is not None:
            interpret = compiled.target.interpret
        self.interpret = resolve_interpret(interpret)
        # observability: no-op tracer unless one is injected; an
        # engine-local metrics registry; ONE clock shared by requests,
        # the tracer, and the admission controller (so a fake clock
        # makes every latency/percentile path deterministic)
        self.tracer = NULL_TRACER if tracer is None else tracer
        self.metrics = MetricsRegistry() if metrics is None else metrics
        if clock is None:
            clock = self.tracer.clock if self.tracer.enabled \
                else monotonic_clock
        self._clock = clock
        self.admission = AdmissionController(credits, name="cnn-serving",
                                             clock=clock)
        self._queue: "queue.Queue" = queue.Queue(maxsize=queue_depth)
        self._inflight: "queue.Queue" = queue.Queue()
        self._in_shape = cnn_input_shape(compiled.plan.cfg, microbatch)
        #: analytic Eq. 2 words per image (plan-side; start() cross-checks
        #: the fused trace's executed counters against it)
        self.words_per_image = sum(
            compiled.plan.hbm_words_per_image().values())
        self._trace = None
        self._packer = MicrobatchPacker(self._queue, microbatch)
        self._threads: List[threading.Thread] = []
        self._started = False
        self._stopped = False
        self._error: Optional[BaseException] = None

        self._lock = threading.Condition()
        # serializes submissions against shutdown: stop() flips
        # _accepting and enqueues the sentinel under this lock, so no
        # submit() can land a request behind the sentinel unseen
        self._submit_lock = threading.Lock()
        self._accepting = False
        self._rid = 0
        self._outstanding = 0
        self._latencies: deque = deque(maxlen=metric_window)
        self._request_rows: deque = deque(maxlen=request_row_window)
        self._images_done = 0
        self._requests_done = 0
        self._mb_count = 0
        self._padded_rows = 0
        self._dispatched_rows = 0
        self._shape_counts: Dict[int, int] = {}
        self._rung_traces: Dict[int, Any] = {}
        self._depth_samples: deque = deque(maxlen=metric_window)
        self._t0: Optional[float] = None
        self._t_last: Optional[float] = None
        # stall attribution: dispatcher time spent with nothing to pack
        # (between dispatches) — admission waits live on the controller
        self._gap_s = 0.0
        self._modelled = False        # False = not yet computed (lazy)

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "CnnServingEngine":
        if self._started:
            return self
        if self._stopped:
            raise RuntimeError(
                "serving engine is single-use; create a new one "
                "(CompiledPipeline.serve) instead of restarting")
        # warm the ONE fused trace every microbatch reuses (and read the
        # per-image Eq. 2 words off its stats template)
        zeros = jnp.zeros(self._in_shape, jnp.int8)
        self._trace = self.compiled.fused_trace(
            self.params, zeros, interpret=self.interpret,
            act_scale=self.act_scale)
        traced = sum(st.hbm_words for st in self._trace.stats)
        if traced != self.words_per_image * self.microbatch:
            raise RuntimeError(
                f"traced Eq. 2 words ({traced}) disagree with the plan "
                f"({self.words_per_image} words/image x {self.microbatch})")
        self._rung_traces[self.microbatch] = self._trace
        self._threads = [
            threading.Thread(target=self._dispatch_loop, daemon=True,
                             name="cnn-serving-dispatch"),
            threading.Thread(target=self._complete_loop, daemon=True,
                             name="cnn-serving-complete"),
        ]
        for t in self._threads:
            t.start()
        self._started = True
        self._accepting = True
        return self

    def stop(self) -> None:
        """Drain everything already submitted, then shut the workers
        down and verify the admission accounting is quiescent.  The
        engine is single-use: a stopped engine cannot be restarted."""
        if not self._started:
            return
        # under the submit lock: once _accepting flips, no submit() can
        # enqueue, and everything enqueued earlier sits BEFORE the
        # sentinel — the dispatcher drains it all, nothing is orphaned
        with self._submit_lock:
            self._accepting = False
            self._queue.put(_STOP)
        for t in self._threads:
            t.join()
        self._started = False
        self._stopped = True
        if self._error is None:
            self.admission.assert_quiescent()

    def __enter__(self) -> "CnnServingEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- submission ----------------------------------------------------------

    def submit(self, images) -> CnnRequest:
        """Enqueue ``images`` ([n,H,W,C] int8, any n >= 1); returns the
        request handle.  Blocks when the bounded queue is full (the
        outer backpressure tier above the microbatch credits)."""
        if not self._started:
            raise RuntimeError("serving engine not started")
        if self._error is not None:
            raise RuntimeError("serving engine failed") from self._error
        arr = np.asarray(images)
        if arr.ndim == 3:
            arr = arr[None]
        want = self._in_shape[1:]
        if arr.ndim != 4 or arr.shape[1:] != want or arr.shape[0] < 1:
            raise ValueError(
                f"expected images [n,{want[0]},{want[1]},{want[2]}], "
                f"got {arr.shape}")
        arr = arr.astype(np.int8, copy=False)
        with self._lock:
            self._rid += 1
            req = CnnRequest(self._rid, arr, now=self._clock())
            req.hbm_words = req.n * self.words_per_image
            self._outstanding += 1
        if self.tracer.enabled:
            self.tracer.begin("request", "request", req.rid, images=req.n)
        # check-and-enqueue is atomic against stop()'s sentinel, so a
        # racing shutdown either rejects this request or dispatches it —
        # it can never strand it behind the sentinel.  The put is
        # bounded (never parked forever on a full queue whose workers
        # died), and an engine failure racing past the check is caught
        # by the post-put sweep — the request fails, it does not hang.
        with self._submit_lock:
            while True:
                if not self._accepting:
                    self._reject(req)
                    raise RuntimeError("serving engine is stopping")
                try:
                    self._queue.put(req, timeout=0.5)
                    break
                except queue.Full:
                    continue
        # the serving interval starts at the first request that actually
        # ENTERED the queue, and only enqueued requests count as
        # submitted — a submit() that lost the race against stop() is
        # rejected above and must skew neither wall_s nor the counter
        self._count_submitted(req)
        if self._error is not None:
            self._sweep_queues(self._error)
        return req

    def _count_submitted(self, req: CnnRequest) -> None:
        with self._lock:
            if self._t0 is None or req.t_submit < self._t0:
                self._t0 = req.t_submit
        self.metrics.counter("serving_requests_submitted").inc()

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted request has been delivered."""
        with self._lock:
            if not self._lock.wait_for(
                    lambda: self._outstanding == 0 or self._error is not None,
                    timeout):
                raise TimeoutError(
                    f"{self._outstanding} request(s) still outstanding")
        if self._error is not None:
            raise RuntimeError("serving engine failed") from self._error

    def serve(self, batches: Sequence[Any]
              ) -> Tuple[List[np.ndarray], ServingReport]:
        """Closed-loop convenience: submit all ``batches``, drain, and
        return ([logits per batch], report)."""
        reqs = [self.submit(b) for b in batches]
        self.drain()
        return [r.result() for r in reqs], self.report()

    # -- reporting -----------------------------------------------------------

    def report(self) -> ServingReport:
        metrics = self._metrics_snapshot()
        with self._lock:
            lat = sorted(self._latencies)       # most recent metric window
            n_req = self._requests_done         # exact lifetime counter
            wall = (self._t_last - self._t0) \
                if (self._t0 is not None and self._t_last is not None) else 0.0
            mb = self._mb_count
            images = self._images_done

            def pct(p: float) -> float:
                if not lat:
                    return 0.0
                # nearest-rank: ceil(p*n)-th smallest (1-indexed)
                return 1e3 * lat[max(0, math.ceil(p * len(lat)) - 1)]

            return ServingReport(
                requests=n_req,
                images=images,
                microbatches=mb,
                microbatch_size=self.microbatch,
                padded_rows=self._padded_rows,
                credits=self.admission.capacity,
                max_in_flight=self.admission.max_in_flight_seen,
                wall_s=wall,
                images_per_s=images / wall if wall > 0 else 0.0,
                p50_ms=pct(0.50), p95_ms=pct(0.95), p99_ms=pct(0.99),
                hbm_words_per_image=self.words_per_image,
                hbm_words_useful=images * self.words_per_image,
                hbm_words_executed=self._dispatched_rows
                * self.words_per_image,
                queue_depth=list(self._depth_samples),
                request_rows=list(self._request_rows),
                dispatched_rows=self._dispatched_rows,
                microbatch_shapes={str(k): v for k, v in
                                   sorted(self._shape_counts.items())},
                trace_cache=self.compiled.trace_cache_stats(),
                metrics=metrics,
                bandwidth_efficiency=self._stall_report(wall),
            )

    # -- worker threads ------------------------------------------------------

    def _dispatch_loop(self) -> None:
        try:
            while True:
                # dispatch-gap attribution: time between finishing one
                # dispatch and holding the next pack is supply starvation
                # (queue empty), counted only once serving has begun —
                # the wait for the FIRST request is not a pipeline stall
                t_idle = self._clock()
                pack = self._collect_pack()
                if self._mb_count > 0:
                    self._gap_s += self._clock() - t_idle
                if pack is None:
                    break
                self._dispatch(*pack)
        except BaseException as exc:                 # pragma: no cover
            self._fail(exc)
        finally:
            self._inflight.put(None)                 # completer sentinel

    def _collect_pack(self):
        """One packed microbatch off the host queue (the shared
        :class:`MicrobatchPacker` greedy pad+mask policy)."""
        if self.tracer.enabled:
            with self.tracer.span("pack", "pack"):
                return self._packer.collect()
        return self._packer.collect()

    def _rung_for(self, filled: int) -> int:
        """Smallest ladder rung holding ``filled`` rows (the adaptive
        grow/shrink policy: shape follows what the queue supplied)."""
        for rung in self.microbatch_ladder:
            if rung >= filled:
                return rung
        return self.microbatch

    def _trace_for(self, rung: int):
        """The fused trace for a ladder rung, Eq. 2-checked on first use
        (the pipeline's bounded LRU holds the compilation; this dict just
        skips the cache probe and re-verification on the hot path)."""
        got = self._rung_traces.get(rung)
        if got is None:
            zeros = jnp.zeros((rung,) + self._in_shape[1:], jnp.int8)
            got = self.compiled.fused_trace(
                self.params, zeros, interpret=self.interpret,
                act_scale=self.act_scale)
            traced = sum(st.hbm_words for st in got.stats)
            if traced != self.words_per_image * rung:
                raise RuntimeError(
                    f"traced Eq. 2 words ({traced}) disagree with the "
                    f"plan ({self.words_per_image} words/image x {rung})")
            self._rung_traces[rung] = got
        return got

    def _dispatch(self, rows, filled: int) -> None:
        tracer = self.tracer
        # padded packed shape: the one fixed microbatch, or (adaptive)
        # the smallest warm ladder rung the collected rows fit in
        shape_rows = self._rung_for(filled) if self.adaptive \
            else self.microbatch
        trace = self._trace if shape_rows == self.microbatch \
            else self._trace_for(shape_rows)
        buf = np.zeros((shape_rows,) + self._in_shape[1:], np.int8)
        for req, roff, moff, take in rows:
            buf[moff:moff + take] = req.images[roff:roff + take]
        # the §V-A credit: at most ``credits`` microbatches between here
        # and delivery — blocks the dispatcher, never the device
        # (admission.wait_seconds_total accrues the blocked time)
        if tracer.enabled:
            with tracer.span("credit_wait", "admission"):
                ok = self.admission.acquire()
        else:
            ok = self.admission.acquire()
        if not ok:
            raise AdmissionError("admission controller closed mid-serve")
        if tracer.enabled:
            with tracer.span("dispatch", "dispatch", filled=filled,
                             shape_rows=shape_rows):
                logits = trace.fn(self.params, jnp.asarray(buf))
        else:
            logits = trace.fn(self.params, jnp.asarray(buf))
        t = self._clock()
        with self._lock:
            self._mb_count += 1
            seq = self._mb_count
            self._padded_rows += shape_rows - filled
            self._dispatched_rows += shape_rows
            self._shape_counts[shape_rows] = \
                self._shape_counts.get(shape_rows, 0) + 1
            depth = self._packer.depth_hint
            # rebase on `is not None`: an injected clock legitimately
            # starts at 0.0, and 0.0 is falsy — truthiness here silently
            # broke the first engine's sample timestamps
            self._depth_samples.append(
                (t - self._t0 if self._t0 is not None else 0.0, depth))
        if tracer.enabled:
            tracer.begin("microbatch", "in_flight", seq, filled=filled)
            tracer.counter("queue_depth", depth)
        self.metrics.counter("serving_microbatches").inc()
        self.metrics.counter("serving_padded_rows").inc(
            shape_rows - filled)
        self.metrics.gauge("serving_queue_depth").set(depth)
        self._inflight.put((logits, rows, seq))

    def _complete_loop(self) -> None:
        try:
            while True:
                item = self._inflight.get()
                if item is None:
                    break
                logits, rows, seq = item
                arr = np.asarray(jax.block_until_ready(logits))
                self.admission.release()             # credit back on arrival
                now = self._clock()
                if self.tracer.enabled:
                    self.tracer.end("microbatch", "in_flight", seq)
                finished: List[CnnRequest] = []
                if self.tracer.enabled:
                    with self.tracer.span("deliver", "delivery", seq=seq):
                        for req, roff, moff, take in rows:
                            if req._deliver(roff, arr[moff:moff + take],
                                            now):
                                finished.append(req)
                else:
                    for req, roff, moff, take in rows:
                        if req._deliver(roff, arr[moff:moff + take], now):
                            finished.append(req)
                if finished:
                    lat_hist = self.metrics.histogram("serving_latency_ms")
                    with self._lock:
                        for req in finished:
                            self._latencies.append(req.latency_s)
                            self._images_done += req.n
                            self._requests_done += 1
                            self._request_rows.append({
                                "rid": req.rid, "images": req.n,
                                "latency_ms": 1e3 * req.latency_s,
                                "hbm_words": req.hbm_words,
                            })
                        self._t_last = now
                        self._outstanding -= len(finished)
                        self._lock.notify_all()
                    for req in finished:
                        lat_hist.observe(1e3 * req.latency_s)
                        self.metrics.counter("serving_requests_done").inc()
                        self.metrics.counter(
                            "serving_images_done").inc(req.n)
                        if self.tracer.enabled:
                            self.tracer.end("request", "request", req.rid)
        except BaseException as exc:                 # pragma: no cover
            self._fail(exc)

    def _reject(self, req: CnnRequest) -> None:
        """Back out a request that never entered the queue: the
        outstanding count reverts, and because ``_t0`` / the submitted
        counter are only advanced post-enqueue (:meth:`_count_submitted`)
        there is nothing else to unwind — a rejected request leaves
        ``wall_s`` and ``serving_requests_submitted`` untouched.  The
        request's trace span is closed so the export stays matched."""
        with self._lock:
            self._outstanding -= 1
            self._lock.notify_all()
        if self.tracer.enabled:
            self.tracer.end("request", "request", req.rid, rejected=True)

    def _fail(self, exc: BaseException) -> None:
        """Fail every queued and in-flight request, wake all waiters."""
        self._accepting = False        # flag only: no _submit_lock here (a
        # producer may hold it blocked in put() with no dispatcher left)
        with self._lock:
            if self._error is None:
                self._error = exc
            self._lock.notify_all()
        self.admission.close()
        self._sweep_queues(exc)
        self._packer.fail_cursor(exc)

    def _sweep_queues(self, exc: BaseException) -> None:
        """Fail everything sitting in the queues.  Safe to call from any
        thread, repeatedly: each item is retrieved exactly once (also run
        from submit() after a failure races its enqueue, so no request
        can land post-sweep and hang)."""
        for q in (self._queue, self._inflight):
            while True:
                try:
                    item = q.get_nowait()
                except queue.Empty:
                    break
                if isinstance(item, CnnRequest):
                    item._fail(exc)
                elif isinstance(item, tuple):
                    for req, *_ in item[1]:
                        req._fail(exc)
                else:
                    # a shutdown sentinel (_STOP / completer None): a
                    # parked worker still needs it to exit — put it back
                    # and stop sweeping (nothing can land behind a
                    # sentinel: submissions are lock-serialized)
                    q.put(item)
                    break
