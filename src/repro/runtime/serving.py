"""Batched serving runtime: prefill + decode with credit-bounded admission.

The paper's accelerator is an inference pipeline; this is the LM-side
equivalent of its runtime: requests are admitted into a fixed-size batch of
decode slots, each slot carrying its own position counter.  Admission is
credit-based (§V-A): a request enters only when a slot (credit) is free, so
the KV cache — the on-chip activation tier — can never be overrun, and no
head-of-line blocking is possible between the prefill and decode queues.

The decode step itself is one jitted SPMD program over the whole batch
(slot divergence handled by per-slot masks), which is what the dry-run's
``decode_*`` cells lower.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.admission import AdmissionController
from repro.models import transformer as tmod


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # [S] int32
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Single-sequence-position batch engine (all slots share a position
    clock; finished slots are masked and refilled between steps).  Per-slot
    position offsets are handled by left-padding prompts to a common
    length, the standard static-batch serving scheme."""

    def __init__(self, params, arch: ArchConfig, *, batch_slots: int = 4,
                 max_seq: int = 128):
        self.params = params
        self.arch = arch
        self.slots = batch_slots
        self.max_seq = max_seq
        # free decode slots ARE §V-A credits; the bookkeeping is the
        # shared controller both serving runtimes use (core/admission.py)
        self.admission = AdmissionController(batch_slots, name="lm-serving")
        self.active: Dict[int, Request] = {}
        self._decode = jax.jit(
            lambda p, c, t, pos: tmod.decode_step(p, arch, c, t, pos))

    @property
    def credits(self) -> int:
        """Free slots (read-only view of the admission controller)."""
        return self.admission.free_credits

    def admit(self, reqs: List[Request]) -> List[Request]:
        """Admit up to ``credits`` requests; returns those admitted."""
        taken = []
        for r in reqs:
            if not self.admission.try_acquire():
                break
            taken.append(r)
        return taken

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve all requests to completion, batch at a time."""
        pending = list(requests)
        finished: List[Request] = []
        while pending or self.active:
            batch = self.admit(pending)
            pending = pending[len(batch):]
            if batch:
                finished.extend(self._serve_batch(batch))
                self.admission.release(len(batch))
        self.admission.assert_quiescent()
        return finished

    def _serve_batch(self, batch: List[Request]) -> List[Request]:
        arch = self.arch
        S = max(len(r.prompt) for r in batch)
        B = len(batch)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(batch):
            toks[i, S - len(r.prompt):] = r.prompt      # left pad
        feed = {"tokens": jnp.asarray(toks)}
        if arch.family == "vlm":
            feed["patches"] = jnp.zeros((B, arch.n_patches, arch.d_model),
                                        jnp.float32)
        if arch.enc_dec:
            feed["frames"] = jnp.zeros((B, arch.n_frames, arch.d_model),
                                       jnp.float32)
        logits, cache = tmod.prefill(self.params, arch, feed, self.max_seq)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        for i, r in enumerate(batch):
            r.out.append(int(nxt[i]))
        max_new = max(r.max_new for r in batch)
        pos = S
        cur = nxt[:, None]
        for t in range(max_new - 1):
            logits, cache = self._decode(self.params, cache, cur,
                                         jnp.int32(pos))
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            for i, r in enumerate(batch):
                if len(r.out) < r.max_new:
                    r.out.append(int(nxt[i]))
            cur = nxt[:, None]
            pos += 1
        for r in batch:
            r.done = True
        return batch
