"""Multi-device sharded dataflow serving — the mesh-pipelined runtime.

H2PIPE's die pipelines every layer engine concurrently, each fed by its
own HBM pseudo-channel; the distribution-level analogue runs the SAME
compiled schedule as a pipeline over mesh devices.  The compiler cuts
the placed layer order into balanced device-local stage programs
(:meth:`CompiledPipeline.partition`), and this engine executes them:

  * **mesh pipeline**: one stage per device over the ``axis`` ring —
    each tick every stage runs ITS slice of the compiled engine table
    (heterogeneous ``lax.switch`` programs inside one ``shard_map``)
    and hands its boundary activation to the next stage via
    ``lax.ppermute`` (``core/dataflow.py::staged_pipeline_apply``); a
    round of M microbatches drains in M + S - 1 stage times (the §V-A
    static schedule: one admission per tick, at most S resident);
  * **shard-local producers**: ``submit(images, shard=...)`` feeds one
    of S bounded shard queues (round-robin by default) — each shard
    packs its own microbatches with the SAME
    :class:`~repro.runtime.cnn_serving.MicrobatchPacker` the host-queue
    engine uses, and the dispatcher drains shards fairly into rounds
    instead of funneling every producer through one host queue;
  * **cross-device credits**: the §V-A in-flight bound is the shared
    :class:`~repro.core.admission.AdmissionController` — UNCHANGED —
    counting dispatched-not-delivered microbatches across the whole
    mesh (``credits >= round_microbatches`` so a full round fits;
    ``2x`` double-buffers rounds).  Its invariant hooks prove the bound
    held, exactly as for the single-device engine;
  * **per-stage Eq. 2**: start() hard-fails unless every stage's
    ``ExecutionReport.verify()`` passes on the partitioned plan AND the
    staged trace's executed per-stage word counters equal the stage
    plans — splitting the graph never loosens the plan-vs-dispatch
    cross-check.

Results are bit-identical to sequential ``run()`` per request: stages
compute the same engine programs on the same activations (the ring only
moves int8 boundary buffers), padded rows/microbatches are sliced away
before delivery.
"""
from __future__ import annotations

import queue
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.admission import AdmissionController, AdmissionError
from repro.core.dataflow import staged_pipeline_apply
from repro.kernels.pallas_compat import resolve_interpret
from repro.models.cnn import cnn_input_shape
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_TRACER, monotonic_clock
from repro.runtime.cnn_serving import (_STOP, METRIC_WINDOW,
                                       REQUEST_ROW_WINDOW, CnnRequest,
                                       MicrobatchPacker, ServingObsMixin,
                                       ServingReport)

__all__ = ["ShardedCnnServingEngine", "ShardedServingReport"]


@dataclass
class ShardedServingReport(ServingReport):
    """The :class:`ServingReport` fields plus the staged-topology view:
    how the rounds filled, what each stage streamed, and the mesh
    shape the numbers were produced on."""

    n_stages: int = 1
    rounds: int = 0
    round_microbatches: int = 0
    empty_microbatches: int = 0       # whole-padding slots in short rounds
    stage_hbm_words_per_image: Tuple[int, ...] = ()
    shard_requests: Tuple[int, ...] = ()

    @property
    def round_fill_fraction(self) -> float:
        total = self.rounds * self.round_microbatches
        return self.microbatches / total if total else 0.0


class ShardedCnnServingEngine(ServingObsMixin):
    """Credit-bounded serving over a compiled pipeline partitioned
    across a device mesh (see module docstring).

    ``microbatch`` is the per-stage activation batch (one ring slot);
    ``round_microbatches`` (default ``8 * n_stages``) is how many
    microbatches one staged dispatch carries — larger rounds amortize
    the S - 1 fill bubble (``pipeline_stats``).  ``credits`` bounds
    dispatched-not-delivered microbatches across the mesh (default
    ``2 * round_microbatches``: one round in flight, one filling).

    Use as a context manager (``with cp.serve_sharded(params, mesh=m)
    as eng``) or call :meth:`start`/:meth:`stop`; :meth:`submit` is
    thread-safe, with an optional explicit target shard.
    """

    def __init__(self, compiled, params, *, mesh, axis: str = "model",
                 microbatch: int = 4,
                 round_microbatches: Optional[int] = None,
                 credits: Optional[int] = None, queue_depth: int = 64,
                 interpret: Optional[bool] = None, act_scale: float = 0.05,
                 tracer=None, metrics: Optional[MetricsRegistry] = None,
                 clock: Optional[Callable[[], float]] = None,
                 metric_window: int = METRIC_WINDOW,
                 request_row_window: int = REQUEST_ROW_WINDOW):
        if microbatch <= 0:
            raise ValueError("microbatch must be positive")
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        if axis not in sizes:
            raise ValueError(
                f"mesh has no axis {axis!r}; available axes: {sizes}")
        self.compiled = compiled
        self.params = params
        self.mesh = mesh
        self.axis = axis
        self.n_stages = sizes[axis]
        self.microbatch = microbatch
        self.act_scale = act_scale
        if interpret is None and compiled.target is not None:
            interpret = compiled.target.interpret
        self.interpret = resolve_interpret(interpret)
        self.partition = compiled.partition(self.n_stages)
        M = (8 * self.n_stages if round_microbatches is None
             else round_microbatches)
        if M < 1:
            raise ValueError("round_microbatches must be >= 1")
        self.round_microbatches = M
        credits = 2 * M if credits is None else credits
        if credits < M:
            raise ValueError(
                f"credits ({credits}) must cover one full round of "
                f"{M} microbatches — a smaller bound would deadlock the "
                f"round dispatcher")
        self.tracer = NULL_TRACER if tracer is None else tracer
        self.metrics = MetricsRegistry() if metrics is None else metrics
        if clock is None:
            clock = self.tracer.clock if self.tracer.enabled \
                else monotonic_clock
        self._clock = clock
        self.admission = AdmissionController(credits,
                                             name="sharded-serving",
                                             clock=clock)
        self._in_shape = cnn_input_shape(compiled.plan.cfg, microbatch)
        self._round_shape = (M,) + self._in_shape
        self.words_per_image = sum(
            compiled.plan.hbm_words_per_image().values())

        # shard-local producers: one bounded queue + packer per stage
        self._queues = [queue.Queue(maxsize=queue_depth)
                        for _ in range(self.n_stages)]
        self._packers = [MicrobatchPacker(q, microbatch)
                         for q in self._queues]
        self._shard_requests = [0] * self.n_stages
        self._rr_submit = 0           # round-robin producer assignment
        self._rr_drain = 0            # round-robin dispatcher fairness
        self._work = threading.Condition()   # "a shard queue has work"

        self._fn = None
        self._inflight: "queue.Queue" = queue.Queue()
        self._threads: List[threading.Thread] = []
        self._started = False
        self._stopped = False
        self._error: Optional[BaseException] = None

        self._lock = threading.Condition()
        self._submit_lock = threading.Lock()
        self._accepting = False
        self._rid = 0
        self._outstanding = 0
        self._latencies: deque = deque(maxlen=metric_window)
        self._request_rows: deque = deque(maxlen=request_row_window)
        self._images_done = 0
        self._requests_done = 0
        self._mb_count = 0
        self._round_count = 0
        self._padded_rows = 0
        self._empty_microbatches = 0
        self._depth_samples: deque = deque(maxlen=metric_window)
        self._t0: Optional[float] = None
        self._t_last: Optional[float] = None
        # stall attribution (see ServingObsMixin): round-dispatcher idle
        # time between rounds; admission waits live on the controller
        self._gap_s = 0.0
        self._modelled = False        # False = not yet computed (lazy)

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "ShardedCnnServingEngine":
        if self._started:
            return self
        if self._stopped:
            raise RuntimeError(
                "sharded serving engine is single-use; create a new one "
                "(CompiledPipeline.serve_sharded) instead of restarting")
        from repro.compiler.partition import stage_forward_fns
        part = self.partition
        S = self.n_stages
        mb = self.microbatch
        # trace-time stats sinks: one per stage, filled while lowering
        collect: List[list] = [[] for _ in range(S)]
        fns = stage_forward_fns(part, interpret=self.interpret,
                                act_scale=self.act_scale, collect=collect)
        bshapes = [None] + [part.boundary_shape(s, mb)
                            for s in range(1, S)]

        def round_forward(p, x_round):
            return staged_pipeline_apply(
                fns, p, x_round, mesh=self.mesh, axis=self.axis,
                boundary_shapes=bshapes, out_shape=part.out_shape(mb),
                out_dtype=jnp.float32)

        zeros = jnp.zeros(self._round_shape, jnp.int8)
        self._fn = jax.jit(round_forward).lower(self.params,
                                                zeros).compile()

        # the split-graph Eq. 2 guarantee, both directions: the sliced
        # plan verifies against the sliced stats template per stage...
        part.verify_eq2(batch=mb)
        # ...and the staged trace's EXECUTED per-stage counters agree
        # with each stage program's plan-side words
        n_nodes = sum(len(c) for c in collect)
        L = len(self.compiled.plan.schedules)
        if n_nodes != L:
            raise RuntimeError(
                f"staged trace dispatched {n_nodes} node(s), plan has {L}")
        for s, sp in enumerate(part.stages):
            traced = sum(st.hbm_words for st in collect[s])
            want = sp.hbm_words_per_image * mb
            if traced != want:
                raise RuntimeError(
                    f"stage {s} traced Eq. 2 words ({traced}) disagree "
                    f"with its stage plan ({sp.hbm_words_per_image} "
                    f"words/image x {mb})")

        self._threads = [
            threading.Thread(target=self._dispatch_loop, daemon=True,
                             name="sharded-serving-dispatch"),
            threading.Thread(target=self._complete_loop, daemon=True,
                             name="sharded-serving-complete"),
        ]
        for t in self._threads:
            t.start()
        self._started = True
        self._accepting = True
        return self

    def stop(self) -> None:
        """Drain everything already submitted, then shut down and verify
        the admission accounting is quiescent.  Single-use."""
        if not self._started:
            return
        with self._submit_lock:
            self._accepting = False
            for q in self._queues:
                q.put(_STOP)
        with self._work:
            self._work.notify_all()
        for t in self._threads:
            t.join()
        self._started = False
        self._stopped = True
        if self._error is None:
            self.admission.assert_quiescent()

    def __enter__(self) -> "ShardedCnnServingEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- submission ----------------------------------------------------------

    def submit(self, images, shard: Optional[int] = None) -> CnnRequest:
        """Enqueue ``images`` ([n,H,W,C] int8) on a shard-local producer
        queue — ``shard`` picks the queue explicitly (a producer local
        to that device's host slice), default round-robins.  Blocks when
        the target shard's bounded queue is full."""
        if not self._started:
            raise RuntimeError("sharded serving engine not started")
        if self._error is not None:
            raise RuntimeError("sharded serving engine failed") \
                from self._error
        arr = np.asarray(images)
        if arr.ndim == 3:
            arr = arr[None]
        want = self._in_shape[1:]
        if arr.ndim != 4 or arr.shape[1:] != want or arr.shape[0] < 1:
            raise ValueError(
                f"expected images [n,{want[0]},{want[1]},{want[2]}], "
                f"got {arr.shape}")
        if shard is not None and not 0 <= shard < self.n_stages:
            raise ValueError(
                f"shard {shard} outside [0, {self.n_stages})")
        arr = arr.astype(np.int8, copy=False)
        with self._lock:
            self._rid += 1
            req = CnnRequest(self._rid, arr, now=self._clock())
            req.hbm_words = req.n * self.words_per_image
            self._outstanding += 1
            if shard is None:
                shard = self._rr_submit % self.n_stages
                self._rr_submit += 1
        if self.tracer.enabled:
            self.tracer.begin("request", "request", req.rid,
                              images=req.n, shard=shard)
        with self._submit_lock:
            while True:
                if not self._accepting:
                    self._reject(req)
                    raise RuntimeError(
                        "sharded serving engine is stopping")
                try:
                    self._queues[shard].put(req, timeout=0.5)
                    break
                except queue.Full:
                    continue
        # only requests that actually entered a shard queue advance the
        # serving clock and the submitted counters (mirrors
        # CnnServingEngine: a submit() that lost the race against stop()
        # must skew neither wall_s nor the per-shard accounting)
        with self._lock:
            self._shard_requests[shard] += 1
            if self._t0 is None or req.t_submit < self._t0:
                self._t0 = req.t_submit
        self.metrics.counter("serving_requests_submitted",
                             shard=shard).inc()
        with self._work:
            self._work.notify_all()
        if self._error is not None:
            self._sweep_queues(self._error)
        return req

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted request has been delivered."""
        with self._lock:
            if not self._lock.wait_for(
                    lambda: self._outstanding == 0
                    or self._error is not None, timeout):
                raise TimeoutError(
                    f"{self._outstanding} request(s) still outstanding")
        if self._error is not None:
            raise RuntimeError("sharded serving engine failed") \
                from self._error

    def serve(self, batches: Sequence[Any]
              ) -> Tuple[List[np.ndarray], ShardedServingReport]:
        """Closed-loop convenience: submit all ``batches`` (round-robin
        over shards), drain, return ([logits per batch], report)."""
        reqs = [self.submit(b) for b in batches]
        self.drain()
        return [r.result() for r in reqs], self.report()

    # -- reporting -----------------------------------------------------------

    def report(self) -> ShardedServingReport:
        import math
        metrics = self._metrics_snapshot()
        with self._lock:
            lat = sorted(self._latencies)
            wall = (self._t_last - self._t0) \
                if (self._t0 is not None and self._t_last is not None) \
                else 0.0
            images = self._images_done

            def pct(p: float) -> float:
                if not lat:
                    return 0.0
                return 1e3 * lat[max(0, math.ceil(p * len(lat)) - 1)]

            return ShardedServingReport(
                requests=self._requests_done,
                images=images,
                microbatches=self._mb_count,
                microbatch_size=self.microbatch,
                padded_rows=self._padded_rows,
                credits=self.admission.capacity,
                max_in_flight=self.admission.max_in_flight_seen,
                wall_s=wall,
                images_per_s=images / wall if wall > 0 else 0.0,
                p50_ms=pct(0.50), p95_ms=pct(0.95), p99_ms=pct(0.99),
                hbm_words_per_image=self.words_per_image,
                hbm_words_useful=images * self.words_per_image,
                hbm_words_executed=(self._mb_count
                                    + self._empty_microbatches)
                * self.microbatch * self.words_per_image,
                queue_depth=list(self._depth_samples),
                request_rows=list(self._request_rows),
                dispatched_rows=(self._mb_count + self._empty_microbatches)
                * self.microbatch,
                microbatch_shapes={str(self.microbatch): self._mb_count}
                if self._mb_count else {},
                trace_cache=self.compiled.trace_cache_stats(),
                metrics=metrics,
                bandwidth_efficiency=self._stall_report(wall),
                n_stages=self.n_stages,
                rounds=self._round_count,
                round_microbatches=self.round_microbatches,
                empty_microbatches=self._empty_microbatches,
                stage_hbm_words_per_image=tuple(
                    s.hbm_words_per_image for s in self.partition.stages),
                shard_requests=tuple(self._shard_requests),
            )

    # -- worker threads ------------------------------------------------------

    def _dispatch_loop(self) -> None:
        try:
            while True:
                # dispatch-gap attribution: time between rounds with
                # nothing to pack (counted once serving has begun)
                t_idle = self._clock()
                packs = self._collect_round()
                if self._round_count > 0:
                    self._gap_s += self._clock() - t_idle
                if packs is None:
                    break
                self._dispatch_round(packs)
        except BaseException as exc:                 # pragma: no cover
            self._fail(exc)
        finally:
            self._inflight.put(None)                 # completer sentinel

    def _next_pack(self, *, block: bool):
        """One packed microbatch from the first shard (round-robin from
        the fairness cursor) with work available; ``block=True`` waits
        for any shard to produce, returning None only when every shard's
        stop sentinel has been drained."""
        while True:
            for k in range(self.n_stages):
                p = self._packers[(self._rr_drain + k) % self.n_stages]
                got = p.collect(block=False)
                if got is not None:
                    self._rr_drain = (self._rr_drain + k + 1) \
                        % self.n_stages
                    return got
            if all(p.saw_stop for p in self._packers):
                return None
            if not block:
                return None
            with self._work:
                self._work.wait(0.02)

    def _collect_round(self):
        """Fill a round: block for the first microbatch, then greedily
        take whatever the shards have, never waiting once at least one
        microbatch is held (the packer's latency-over-occupancy policy,
        lifted to rounds).  Short rounds are padded with empty slots."""
        if self.tracer.enabled:
            with self.tracer.span("pack", "pack"):
                return self._collect_round_inner()
        return self._collect_round_inner()

    def _collect_round_inner(self):
        packs: List[Tuple[list, int]] = []
        while len(packs) < self.round_microbatches:
            got = self._next_pack(block=not packs)
            if got is None:
                break
            packs.append(got)
        return packs or None

    def _dispatch_round(self, packs) -> None:
        tracer = self.tracer
        k = len(packs)
        buf = np.zeros(self._round_shape, np.int8)
        for m, (rows, _filled) in enumerate(packs):
            for req, roff, moff, take in rows:
                buf[m, moff:moff + take] = req.images[roff:roff + take]
        # the §V-A cross-device credit: one per microbatch between
        # dispatch and delivery, across the whole mesh
        # (admission.wait_seconds_total accrues the blocked time)
        if tracer.enabled:
            with tracer.span("credit_wait", "admission", microbatches=k):
                for _ in range(k):
                    if not self.admission.acquire():
                        raise AdmissionError(
                            "admission controller closed mid-serve")
        else:
            for _ in range(k):
                if not self.admission.acquire():
                    raise AdmissionError(
                        "admission controller closed mid-serve")
        if tracer.enabled:
            with tracer.span("dispatch", "dispatch", microbatches=k):
                logits = self._fn(self.params, jnp.asarray(buf))
        else:
            logits = self._fn(self.params, jnp.asarray(buf))
        t = self._clock()
        with self._lock:
            self._round_count += 1
            seq = self._round_count
            self._mb_count += k
            self._padded_rows += sum(
                self.microbatch - filled for _rows, filled in packs)
            self._empty_microbatches += self.round_microbatches - k
            depth = sum(p.depth_hint for p in self._packers)
            # rebase on `is not None` (an injected clock can start at
            # 0.0) — mirrors the CnnServingEngine depth-sampling fix
            self._depth_samples.append(
                (t - self._t0 if self._t0 is not None else 0.0, depth))
        if tracer.enabled:
            # the sharded in-flight/round view: one async round span plus
            # a per-stage round annotation (stage programs run inside ONE
            # staged dispatch, so per-stage host timing does not exist —
            # the args carry the per-stage plan words instead)
            tracer.begin("round", "in_flight", seq, microbatches=k)
            tracer.instant(
                "stage_round", "round", round=seq, microbatches=k,
                stage_hbm_words_per_image=[
                    s.hbm_words_per_image for s in self.partition.stages])
            tracer.counter("queue_depth", depth)
        self.metrics.counter("serving_rounds").inc()
        self.metrics.counter("serving_microbatches").inc(k)
        self.metrics.counter("serving_empty_microbatches").inc(
            self.round_microbatches - k)
        self.metrics.gauge("serving_queue_depth").set(depth)
        self._inflight.put((logits, packs, k, seq))

    def _complete_loop(self) -> None:
        try:
            while True:
                item = self._inflight.get()
                if item is None:
                    break
                logits, packs, k, seq = item
                arr = np.asarray(jax.block_until_ready(logits))
                self.admission.release(k)
                now = self._clock()
                if self.tracer.enabled:
                    self.tracer.end("round", "in_flight", seq)
                finished: List[CnnRequest] = []
                if self.tracer.enabled:
                    with self.tracer.span("deliver", "delivery", seq=seq):
                        for m, (rows, _filled) in enumerate(packs):
                            for req, roff, moff, take in rows:
                                if req._deliver(
                                        roff, arr[m, moff:moff + take],
                                        now):
                                    finished.append(req)
                else:
                    for m, (rows, _filled) in enumerate(packs):
                        for req, roff, moff, take in rows:
                            if req._deliver(roff, arr[m, moff:moff + take],
                                            now):
                                finished.append(req)
                if finished:
                    lat_hist = self.metrics.histogram("serving_latency_ms")
                    with self._lock:
                        for req in finished:
                            self._latencies.append(req.latency_s)
                            self._images_done += req.n
                            self._requests_done += 1
                            self._request_rows.append({
                                "rid": req.rid, "images": req.n,
                                "latency_ms": 1e3 * req.latency_s,
                                "hbm_words": req.hbm_words,
                            })
                        self._t_last = now
                        self._outstanding -= len(finished)
                        self._lock.notify_all()
                    for req in finished:
                        lat_hist.observe(1e3 * req.latency_s)
                        self.metrics.counter("serving_requests_done").inc()
                        self.metrics.counter(
                            "serving_images_done").inc(req.n)
                        if self.tracer.enabled:
                            self.tracer.end("request", "request", req.rid)
        except BaseException as exc:                 # pragma: no cover
            self._fail(exc)

    # -- failure plumbing (mirrors CnnServingEngine) -------------------------

    def _reject(self, req: CnnRequest) -> None:
        """Back out a request that never entered a shard queue (wall_s,
        shard counts and the submitted counter were not yet advanced —
        they move post-enqueue); close its trace span."""
        with self._lock:
            self._outstanding -= 1
            self._lock.notify_all()
        if self.tracer.enabled:
            self.tracer.end("request", "request", req.rid, rejected=True)

    def _fail(self, exc: BaseException) -> None:
        self._accepting = False
        with self._lock:
            if self._error is None:
                self._error = exc
            self._lock.notify_all()
        self.admission.close()
        with self._work:
            self._work.notify_all()
        self._sweep_queues(exc)
        for p in self._packers:
            p.fail_cursor(exc)

    def _sweep_queues(self, exc: BaseException) -> None:
        for q in list(self._queues) + [self._inflight]:
            while True:
                try:
                    item = q.get_nowait()
                except queue.Empty:
                    break
                if isinstance(item, CnnRequest):
                    item._fail(exc)
                elif isinstance(item, tuple):
                    for rows, _filled in item[1]:
                        for req, *_ in rows:
                            req._fail(exc)
                else:
                    q.put(item)
                    break
