"""Multi-tenant, multi-network serving front-end.

One H2PIPE deployment rarely serves one model: the paper's premise is a
*library* of CNNs (ResNet-18/50, MobileNet) each compiled to its own
deeply pipelined accelerator, and a datacenter box hosts several at
once.  This module is the admission tier ABOVE the per-network serving
engines: tenants register against a network with a weight and an
optional latency deadline, submit requests through one front door, and
a weighted-fair scheduler decides whose request each engine sees next.

Layering (nothing below this tier changes):

  * per-network :class:`~repro.runtime.cnn_serving.CnnServingEngine` /
    :class:`~repro.runtime.sharded_serving.ShardedCnnServingEngine`
    keep their own §V-A credit bounds, packers, and fused-trace reuse;
  * :class:`~repro.core.admission.WeightedFairScheduler` (deficit
    round-robin + deadline promotion) orders the per-tenant queues of
    each network — long-run delivered images/s tracks tenant weights
    while a request whose deadline slack goes negative jumps the line;
  * an optional front-end-wide
    :class:`~repro.core.admission.AdmissionController`
    (``max_outstanding``) bounds total in-flight requests across ALL
    networks — the global tier whose invariant hooks the stress tests
    assert under concurrent multi-tenant producers;
  * each engine's small ``queue_depth`` is the backpressure that makes
    the scheduler meaningful: the engine queue fills, ``submit`` blocks
    the forwarding thread, and the backlog pools HERE where DRR (not
    FIFO arrival order) picks what goes next.

Observability rides the shared obs subsystem: tenant-labelled counters
on the front-end :class:`~repro.obs.metrics.MetricsRegistry`, one trace
track per tenant (``tenant:<name>`` — the Tracer admits new tracks on
first use), and :class:`FrontEndReport` with per-tenant latency
percentiles, deadline-miss rates, and Jain's fairness index over
weight-normalized delivered throughput.
"""
from __future__ import annotations

import dataclasses
import json
import math
import queue
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple, Union)

import numpy as np

from repro.core.admission import (AdmissionController, HeadOfQueue,
                                  WeightedFairScheduler, jain_fairness)
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_TRACER, monotonic_clock
from repro.runtime.cnn_serving import METRIC_WINDOW, restore_tuple_fields

__all__ = ["FrontEndReport", "FrontEndRequest", "MultiTenantFrontEnd",
           "TenantSpec"]

_STOP = object()


@dataclass(frozen=True)
class TenantSpec:
    """One registered tenant: which network it runs on, its weighted
    share, and (optionally) its per-request latency deadline."""

    name: str
    network: str
    weight: float = 1.0
    deadline_ms: Optional[float] = None


class FrontEndRequest:
    """One tenant-submitted request as the front door sees it: holds the
    images until the scheduler forwards them to the network's engine,
    then proxies the engine-side handle.  ``deadline`` is absolute on
    the front-end clock (``t_submit + deadline_ms``); :attr:`missed`
    is judged at delivery time."""

    def __init__(self, rid: int, tenant: str, network: str,
                 images: np.ndarray, now: float,
                 deadline_ms: Optional[float] = None):
        self.rid = rid
        self.tenant = tenant
        self.network = network
        self.images = images
        self.n = int(images.shape[0])
        self.t_submit = now
        self.deadline = now + deadline_ms / 1e3 \
            if deadline_ms is not None else None
        self.t_forward: Optional[float] = None
        self.t_done: Optional[float] = None
        self.missed = False
        self._logits: Optional[np.ndarray] = None
        self._error: Optional[BaseException] = None
        self._event = threading.Event()

    @property
    def done(self) -> bool:
        return self._event.is_set()

    @property
    def latency_s(self) -> float:
        if self.t_done is None:
            raise RuntimeError(f"request {self.rid} not complete")
        return self.t_done - self.t_submit

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        """Block until delivered; returns logits ``[n, classes]``."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid} not done in {timeout}s")
        if self._error is not None:
            raise RuntimeError(
                f"request {self.rid} ({self.tenant}) failed"
            ) from self._error
        return self._logits

    def _deliver(self, logits: np.ndarray, now: float) -> None:
        self._logits = logits
        self.t_done = now
        self.missed = self.deadline is not None and now > self.deadline
        self._event.set()

    def _fail(self, exc: BaseException) -> None:
        self._error = exc
        self._event.set()


@dataclass
class FrontEndReport:
    """Aggregate view of one multi-tenant serving interval: totals, the
    fairness index, and one row per tenant (scalars only, so the JSON
    round-trip is exact)."""

    requests: int
    images: int
    wall_s: float
    images_per_s: float
    #: Jain's index over per-tenant delivered images/s divided by tenant
    #: weight — 1.0 means delivery tracked the weights exactly.
    fairness: float
    #: deadline promotions the schedulers performed (requests served
    #: out of DRR order because their slack went negative).
    promotions: int
    networks: Tuple[str, ...] = ()
    #: per-tenant rows: tenant/network/weight/deadline_ms/requests/
    #: images/images_per_s/p50_ms/p95_ms/p99_ms/deadline_misses/
    #: deadline_miss_rate/picks/served_cost
    tenant_rows: Tuple[Dict[str, Any], ...] = ()
    metrics: Dict[str, Any] = field(default_factory=dict)

    def table(self) -> str:
        head = [
            f"requests={self.requests}  images={self.images}  "
            f"wall={self.wall_s:.3f}s  "
            f"throughput={self.images_per_s:.1f} images/s",
            f"networks={','.join(self.networks)}  "
            f"fairness(Jain)={self.fairness:.3f}  "
            f"deadline promotions={self.promotions}",
        ]
        hdr = (f"{'tenant':>12s} {'network':>14s} {'w':>5s} {'reqs':>5s} "
               f"{'imgs':>6s} {'img/s':>8s} {'p50ms':>8s} {'p99ms':>8s} "
               f"{'miss':>6s}")
        rows = [hdr, "-" * len(hdr)]
        for r in self.tenant_rows:
            rows.append(
                f"{r['tenant']:>12s} {r['network']:>14s} "
                f"{r['weight']:>5.1f} {r['requests']:>5d} "
                f"{r['images']:>6d} {r['images_per_s']:>8.1f} "
                f"{r['p50_ms']:>8.2f} {r['p99_ms']:>8.2f} "
                f"{r['deadline_miss_rate']:>6.0%}")
        return "\n".join(head + rows)

    # -- serialization (same law as ServingReport) ---------------------------

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def to_json(self, **kw: Any) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, **kw)

    @classmethod
    def from_json(cls, payload: Union[str, Dict[str, Any]]
                  ) -> "FrontEndReport":
        data = json.loads(payload) if isinstance(payload, str) \
            else dict(payload)
        return cls(**restore_tuple_fields(cls, data))


class _Lane:
    """Per-network scheduling lane: the tenant queues, the DRR
    scheduler over them, and the forward queue its collector drains."""

    def __init__(self, engine: Any, quantum: float):
        self.engine = engine
        self.sched = WeightedFairScheduler(quantum=quantum)
        self.queues: Dict[str, deque] = {}
        self.cond = threading.Condition()
        self.stopping = False
        self.forward_q: "queue.Queue" = queue.Queue()
        self.threads: List[threading.Thread] = []


class MultiTenantFrontEnd:
    """One admission front door over several running serving engines.

    ``engines`` maps network name to an (unstarted) serving engine —
    anything with the ``start/stop/submit(images) -> request`` surface
    of :class:`~repro.runtime.cnn_serving.CnnServingEngine` (the
    sharded engine qualifies).  The front-end owns engine lifecycle:
    :meth:`start` starts them, :meth:`stop` drains and stops them.

    Per network, one *scheduler* thread runs the weighted-fair pick
    loop over that network's tenant queues and forwards the chosen
    request to the engine (blocking on the engine's bounded queue —
    that block IS the backpressure that pools the backlog up here),
    and one *collector* thread awaits engine results in forward order,
    delivers them to the front-end handles, and keeps the per-tenant
    stats.  ``max_outstanding`` adds a front-end-wide
    :class:`AdmissionController` credit bound across all networks
    (acquired before forwarding, released at delivery).

    Use as a context manager, mirror of the engines themselves::

        with MultiTenantFrontEnd({"r18": cp18.serve_engine(...)}) as fe:
            fe.register_tenant("alice", network="r18", weight=4.0)
            req = fe.submit("alice", images)
            logits = req.result()
    """

    def __init__(self, engines: Mapping[str, Any], *,
                 quantum: float = 1.0,
                 max_outstanding: Optional[int] = None,
                 tracer=None, metrics: Optional[MetricsRegistry] = None,
                 clock: Optional[Callable[[], float]] = None,
                 metric_window: int = METRIC_WINDOW):
        if not engines:
            raise ValueError("front-end needs at least one engine")
        self.tracer = NULL_TRACER if tracer is None else tracer
        self.metrics = MetricsRegistry() if metrics is None else metrics
        if clock is None:
            clock = self.tracer.clock if self.tracer.enabled \
                else monotonic_clock
        self._clock = clock
        self.admission = AdmissionController(
            max_outstanding, name="frontend", clock=clock) \
            if max_outstanding is not None else None
        self._lanes: Dict[str, _Lane] = {
            net: _Lane(eng, quantum) for net, eng in engines.items()}
        self.tenants: Dict[str, TenantSpec] = {}
        self._lock = threading.Condition()
        self._rid = 0
        self._outstanding = 0
        self._t0: Optional[float] = None
        self._t_last: Optional[float] = None
        self._started = False
        self._stopped = False
        self._accepting = False
        self._error: Optional[BaseException] = None
        # per-tenant delivery stats (under self._lock)
        self._lat: Dict[str, deque] = {}
        self._images: Dict[str, int] = {}
        self._requests: Dict[str, int] = {}
        self._done: Dict[str, int] = {}
        self._misses: Dict[str, int] = {}
        self._metric_window = metric_window

    # -- registration --------------------------------------------------------

    def register_tenant(self, name: str, *, network: str,
                        weight: float = 1.0,
                        deadline_ms: Optional[float] = None) -> TenantSpec:
        """Register ``name`` against ``network`` with a fair-share
        ``weight`` and an optional per-request ``deadline_ms``.  Must
        name a known network; tenant names are front-end-global."""
        if network not in self._lanes:
            raise ValueError(
                f"unknown network {network!r}; have "
                f"{sorted(self._lanes)}")
        if name in self.tenants:
            raise ValueError(f"tenant {name!r} already registered")
        spec = TenantSpec(name, network, float(weight), deadline_ms)
        lane = self._lanes[network]
        with lane.cond:
            lane.sched.register(name, spec.weight)
            lane.queues[name] = deque()
        with self._lock:
            self.tenants[name] = spec
            self._lat[name] = deque(maxlen=self._metric_window)
            self._images[name] = 0
            self._requests[name] = 0
            self._done[name] = 0
            self._misses[name] = 0
        return spec

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "MultiTenantFrontEnd":
        if self._started:
            return self
        if self._stopped:
            raise RuntimeError("front-end is single-use; build a new one")
        started: List[Any] = []
        try:
            for lane in self._lanes.values():
                lane.engine.start()
                started.append(lane.engine)
        except BaseException:
            for eng in started:
                eng.stop()
            raise
        for net, lane in self._lanes.items():
            lane.threads = [
                threading.Thread(target=self._schedule_loop,
                                 args=(net, lane), daemon=True,
                                 name=f"frontend-sched-{net}"),
                threading.Thread(target=self._collect_loop,
                                 args=(net, lane), daemon=True,
                                 name=f"frontend-collect-{net}"),
            ]
            for t in lane.threads:
                t.start()
        self._started = True
        self._accepting = True
        return self

    def stop(self) -> None:
        """Drain every queued and in-flight request, stop the engines,
        and (when configured) verify the global admission tier is
        quiescent."""
        if not self._started:
            return
        self._accepting = False
        for lane in self._lanes.values():
            with lane.cond:
                lane.stopping = True
                lane.cond.notify_all()
        for lane in self._lanes.values():
            lane.threads[0].join()            # scheduler drained its queues
            lane.forward_q.put(_STOP)
            lane.threads[1].join()            # collector delivered the rest
            lane.engine.stop()
        self._started = False
        self._stopped = True
        if self._error is None and self.admission is not None:
            self.admission.assert_quiescent()

    def __enter__(self) -> "MultiTenantFrontEnd":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- submission ----------------------------------------------------------

    def submit(self, tenant: str, images) -> FrontEndRequest:
        """Enqueue ``images`` for ``tenant``; returns the front-end
        handle.  Thread-safe (one producer per tenant or many — the
        global admission invariants are asserted under exactly that)."""
        if not self._started:
            raise RuntimeError("front-end not started")
        if self._error is not None:
            raise RuntimeError("front-end failed") from self._error
        spec = self.tenants.get(tenant)
        if spec is None:
            raise ValueError(f"unknown tenant {tenant!r}")
        arr = np.asarray(images)
        if arr.ndim == 3:
            arr = arr[None]
        lane = self._lanes[spec.network]
        with self._lock:
            self._rid += 1
            req = FrontEndRequest(self._rid, tenant, spec.network, arr,
                                  self._clock(), spec.deadline_ms)
            self._outstanding += 1
            if self._t0 is None or req.t_submit < self._t0:
                self._t0 = req.t_submit
            self._requests[tenant] += 1
        if self.tracer.enabled:
            self.tracer.begin("request", f"tenant:{tenant}", req.rid,
                              images=req.n, network=spec.network)
        self.metrics.counter("frontend_requests_submitted",
                             tenant=tenant).inc()
        with lane.cond:
            if not self._accepting:
                with self._lock:
                    self._outstanding -= 1
                    self._requests[tenant] -= 1
                if self.tracer.enabled:
                    self.tracer.end("request", f"tenant:{tenant}", req.rid,
                                    rejected=True)
                raise RuntimeError("front-end is stopping")
            lane.queues[tenant].append(req)
            lane.cond.notify_all()
        return req

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted request has been delivered."""
        with self._lock:
            if not self._lock.wait_for(
                    lambda: self._outstanding == 0
                    or self._error is not None, timeout):
                raise TimeoutError(
                    f"{self._outstanding} request(s) still outstanding")
        if self._error is not None:
            raise RuntimeError("front-end failed") from self._error

    def serve(self, batches: Sequence[Tuple[str, Any]]
              ) -> Tuple[List[np.ndarray], FrontEndReport]:
        """Closed-loop convenience: submit every ``(tenant, images)``
        pair, drain, return ([logits per batch], report)."""
        reqs = [self.submit(t, b) for t, b in batches]
        self.drain()
        return [r.result() for r in reqs], self.report()

    # -- worker threads ------------------------------------------------------

    def _schedule_loop(self, net: str, lane: _Lane) -> None:
        try:
            while True:
                with lane.cond:
                    while True:
                        backlog = {
                            t: HeadOfQueue(cost=float(q[0].n),
                                           deadline=q[0].deadline)
                            for t, q in lane.queues.items() if q}
                        if backlog or lane.stopping:
                            break
                        lane.cond.wait()
                    if not backlog:
                        return                 # stopping and fully drained
                    tenant = lane.sched.pick(backlog, now=self._clock())
                    req = lane.queues[tenant].popleft()
                # forward OUTSIDE the lane lock: both the global credit
                # acquire and the engine's bounded queue may block, and
                # submit() must stay free to append meanwhile
                if self.admission is not None:
                    self.admission.acquire()
                req.t_forward = self._clock()
                try:
                    eng_req = lane.engine.submit(req.images)
                except BaseException as exc:
                    if self.admission is not None:
                        self.admission.release()
                    raise exc
                lane.forward_q.put((req, eng_req))
        except BaseException as exc:          # pragma: no cover - fatal path
            self._fail(exc, lane)

    def _collect_loop(self, net: str, lane: _Lane) -> None:
        try:
            while True:
                item = lane.forward_q.get()
                if item is _STOP:
                    return
                req, eng_req = item
                try:
                    logits = eng_req.result()
                except BaseException as exc:
                    # the engine-side request failed: fail THIS handle
                    # (its waiter must not hang), return the credit, then
                    # fall into the lane-wide failure path
                    req._fail(exc)
                    if self.admission is not None:
                        self.admission.release()
                    with self._lock:
                        self._outstanding -= 1
                        self._lock.notify_all()
                    raise exc
                if self.admission is not None:
                    self.admission.release()
                now = self._clock()
                req._deliver(logits, now)
                if self.tracer.enabled:
                    self.tracer.end("request", f"tenant:{req.tenant}",
                                    req.rid, images=req.n,
                                    missed=req.missed)
                self.metrics.counter("frontend_images_delivered",
                                     tenant=req.tenant).inc(req.n)
                if req.missed:
                    self.metrics.counter("frontend_deadline_missed",
                                         tenant=req.tenant).inc()
                with self._lock:
                    self._lat[req.tenant].append(req.latency_s)
                    self._images[req.tenant] += req.n
                    self._done[req.tenant] += 1
                    if req.missed:
                        self._misses[req.tenant] += 1
                    if self._t_last is None or now > self._t_last:
                        self._t_last = now
                    self._outstanding -= 1
                    self._lock.notify_all()
        except BaseException as exc:          # pragma: no cover - fatal path
            self._fail(exc, lane)

    def _fail(self, exc: BaseException, lane: _Lane) -> None:
        with self._lock:
            if self._error is None:
                self._error = exc
            self._lock.notify_all()
        with lane.cond:
            for q in lane.queues.values():
                while q:
                    q.popleft()._fail(exc)
            lane.stopping = True
            lane.cond.notify_all()
        # forwarded-but-undelivered handles must not strand their waiters
        while True:
            try:
                item = lane.forward_q.get_nowait()
            except queue.Empty:
                break
            if item is not _STOP:
                item[0]._fail(exc)

    # -- reporting -----------------------------------------------------------

    def report(self) -> FrontEndReport:
        """Snapshot across every tenant.  Safe to call mid-run (the
        benchmark samples two snapshots to measure steady-state
        weighted shares)."""
        with self._lock:
            wall = (self._t_last - self._t0) \
                if (self._t0 is not None and self._t_last is not None) \
                else 0.0
            rows: List[Dict[str, Any]] = []
            shares: Dict[str, float] = {}
            total_req = 0
            total_img = 0
            for name, spec in sorted(self.tenants.items()):
                lane = self._lanes[spec.network]
                lat = sorted(self._lat[name])

                def pct(p: float) -> float:
                    if not lat:
                        return 0.0
                    return 1e3 * lat[max(0, math.ceil(p * len(lat)) - 1)]

                n_req = self._requests[name]
                n_img = self._images[name]
                rate = n_img / wall if wall > 0 else 0.0
                misses = self._misses[name]
                delivered = self._done[name]
                rows.append({
                    "tenant": name,
                    "network": spec.network,
                    "weight": spec.weight,
                    "deadline_ms": spec.deadline_ms,
                    "requests": n_req,
                    "images": n_img,
                    "images_per_s": rate,
                    "p50_ms": pct(0.50),
                    "p95_ms": pct(0.95),
                    "p99_ms": pct(0.99),
                    "deadline_misses": misses,
                    "deadline_miss_rate":
                        misses / delivered if delivered else 0.0,
                    "picks": lane.sched.picks.get(name, 0),
                    "served_cost": lane.sched.served_cost.get(name, 0.0),
                })
                total_req += n_req
                total_img += n_img
                if n_req:
                    shares[name] = rate / spec.weight
            return FrontEndReport(
                requests=total_req,
                images=total_img,
                wall_s=wall,
                images_per_s=total_img / wall if wall > 0 else 0.0,
                fairness=jain_fairness(shares),
                promotions=sum(l.sched.promotions
                               for l in self._lanes.values()),
                networks=tuple(sorted(self._lanes)),
                tenant_rows=tuple(rows),
                metrics=self.metrics.snapshot(),
            )
