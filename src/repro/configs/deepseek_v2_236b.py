"""DeepSeek-V2 (236B) — MoE decoder LM with Multi-head Latent Attention.
[arXiv:2405.04434; hf]

60L d_model=5120 128H (MLA kv_lora=512) d_ff=1536 (per expert)
vocab=102400, MoE 2 shared + 160 routed, top-6.

Deviation from the HF checkpoint: the real model's first layer is a dense FFN;
we keep all 60 layers MoE so the layer stack is uniform and scannable
(DESIGN.md §4).  Parameter count changes by <0.1%.
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,                 # MLA: latent KV shared by all heads
    head_dim=128,
    d_ff=1536,                      # per-expert hidden
    vocab_size=102400,
    attn_kind="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, n_shared=2, d_ff_expert=1536),
    act="silu",
    tie_embeddings=False,
    subquadratic=False,
)
