"""xLSTM-125M — recurrent LM with alternating sLSTM and mLSTM blocks.
[arXiv:2405.04517; unverified]

12L d_model=768 4H vocab=50304, d_ff=0 (no separate FFN: the blocks contain
their own up/down projections — mLSTM proj factor 2, sLSTM proj factor 4/3).
Pure recurrent (no attention) -> sub-quadratic, runs long_500k.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    attn_kind="none",
    ssm=SSMConfig(state_dim=16, conv_width=4,
                  mlstm_proj_factor=2.0, slstm_proj_factor=4.0 / 3.0),
    act="gelu",
    tie_embeddings=True,
    subquadratic=True,
)
