"""Cohere Command R+ (104B) — dense decoder LM.

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000 — GQA, no-bias.
[hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    attn_kind="global",
    qkv_bias=False,
    rope_theta=75_000.0,
    act="silu",
    tie_embeddings=True,
    subquadratic=False,
)
