"""SeamlessM4T-medium — encoder-decoder multimodal (speech/text) transformer.
[arXiv:2308.11596; hf]

12L d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.  We implement the
transformer BACKBONE only (12 encoder + 12 decoder layers); the speech
frontend is a stub supplying precomputed frame embeddings [B, n_frames, d].
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,                    # decoder depth
    n_enc_layers=12,
    enc_dec=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    attn_kind="global",
    n_frames=1024,                  # encoder frames fed by the stub frontend
    act="silu",
    tie_embeddings=True,
    subquadratic=False,
)
