"""Gemma 2 9B — dense decoder LM with alternating local/global attention and
logit soft-capping.  [arXiv:2408.00118; hf]

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    attn_kind="local_global",   # even layers sliding-window, odd layers global
    window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
    subquadratic=False,         # half the layers are global -> still quadratic
)
