"""Config dataclasses for the H2PIPE-JAX framework.

One ``ArchConfig`` describes any of the supported architectures (dense / MoE /
hybrid / VLM / audio enc-dec / SSM LMs, plus the paper's CNNs via
``configs/cnn.py``).  Configs are frozen dataclasses so they can be hashed and
used as static jit arguments.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN."""

    n_experts: int
    top_k: int
    n_shared: int = 0               # shared (always-on) experts
    d_ff_expert: int = 0            # per-expert hidden size
    router_dtype: str = "float32"
    # capacity factor used for the dense-dispatch (dropless einsum) path
    capacity_factor: float = 1.25
    jitter: float = 0.0


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """State-space / recurrent block parameters (mamba-style and xLSTM)."""

    state_dim: int = 16
    conv_width: int = 4
    expand: float = 2.0             # inner dim = expand * d_model
    # xLSTM specifics
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0


@dataclass(frozen=True)
class ArchConfig:
    """A complete architecture description.

    Attention kinds:
      ``global``        full causal attention in every layer
      ``local_global``  alternating sliding-window / global (gemma2)
      ``sliding``       sliding-window attention in every layer (hymba attn part)
      ``mla``           multi-head latent attention (deepseek-v2)
      ``none``          no attention (pure recurrent, xlstm)
    Families: dense | moe | hybrid | vlm | audio | ssm
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0               # 0 -> d_model // n_heads
    attn_kind: str = "global"
    window: int = 4096              # sliding-window size where applicable
    attn_logit_softcap: float = 0.0   # 0 disables (gemma2: 50.0)
    final_logit_softcap: float = 0.0  # (gemma2: 30.0)
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    act: str = "silu"               # silu (SwiGLU) | gelu (GeGLU)
    tie_embeddings: bool = True

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None

    # encoder-decoder (seamless): n_layers is the decoder depth
    enc_dec: bool = False
    n_enc_layers: int = 0

    # multimodal stubs: the frontend supplies precomputed embeddings
    n_patches: int = 0              # vlm: image patch embeddings per sample
    n_frames: int = 0               # audio: frames fed to the encoder

    # numerics
    dtype: str = "bfloat16"
    # sub-quadratic? (drives long_500k applicability)
    subquadratic: bool = False

    # ----- derived -----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def param_count(self) -> int:
        """Total parameter count (embedding included once if tied)."""
        from repro.models.accounting import count_params

        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.accounting import count_params

        return count_params(self, active_only=True)

    def reduced(self) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        changes = dict(
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=2 if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=128,
            window=16,
            n_enc_layers=min(self.n_enc_layers, 2),
            n_patches=min(self.n_patches, 8) if self.n_patches else 0,
            n_frames=min(self.n_frames, 16) if self.n_frames else 0,
        )
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=2, n_shared=min(self.moe.n_shared, 1),
                d_ff_expert=32,
            )
        if self.mla is not None:
            changes["mla"] = MLAConfig(
                kv_lora_rank=16, q_lora_rank=32, qk_nope_head_dim=16,
                qk_rope_head_dim=8, v_head_dim=16,
            )
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(self.ssm, state_dim=4, conv_width=2)
        return dataclasses.replace(self, **changes)


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    """One (seq_len, global_batch) evaluation cell.

    ``kind``: train | prefill | decode.  Decode shapes lower ``serve_step``
    (one new token against a KV cache of ``seq_len``), not ``train_step``.
    """

    name: str
    seq_len: int
    global_batch: int
    kind: str


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic attention; full-attention archs skip it."""
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, (
            "long_500k skipped: full (quadratic) attention arch; run only for "
            "SSM/hybrid/sliding-window archs (DESIGN.md §4)"
        )
    return True, ""


def reduced_shape(shape: ShapeConfig) -> ShapeConfig:
    return ShapeConfig(shape.name + "_reduced", min(shape.seq_len, 32),
                       min(shape.global_batch, 2), shape.kind)
