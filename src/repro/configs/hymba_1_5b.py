"""Hymba-1.5B — hybrid LM: parallel attention + mamba heads in every block.
[arXiv:2411.13676; hf]

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Attention heads use a sliding window (the HF model keeps 3 global layers; we
use sliding-window everywhere so the stack is uniform and the arch is
sub-quadratic, per the long_500k requirement for hybrids).
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    attn_kind="sliding",
    window=1024,
    ssm=SSMConfig(state_dim=16, conv_width=4, expand=2.0),
    act="silu",
    tie_embeddings=True,
    subquadratic=True,
)
