"""Qwen1.5/2-MoE-A2.7B — MoE decoder LM: 4 shared + 60 routed experts, top-4.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

24L d_model=2048 16H (GQA kv=16) d_ff=1408 (per expert) vocab=151936.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,                      # per-expert hidden
    vocab_size=151936,
    attn_kind="global",
    qkv_bias=True,
    moe=MoEConfig(n_experts=60, top_k=4, n_shared=4, d_ff_expert=1408),
    act="silu",
    tie_embeddings=True,
    subquadratic=False,
)
