"""The paper's own CNNs as per-layer descriptors.

H2PIPE's compiler reasons about a CNN layer-by-layer: kernel shape, channel
counts and output spatial size determine weight memory (Table I), weight
traffic per image (Eq. 2) and the HBM-offload score (Eq. 1).  We reproduce
that representation exactly; the same descriptors drive the JAX model
builders in ``repro.models.cnn``.

All networks use 224x224x3 ImageNet inputs and int8 weights (the paper's
precision), with HPIPE conventions:
  * activations buffered on chip as a sliding window of ``k_h`` lines
    (+1 line being written) per layer input,
  * weights re-read once per output row when streamed from HBM (Eq. 2).

Topology ops are first-class nodes: maxpool (``kind="maxpool"``) and
global-average-pool (``kind="gap"``) layers appear in ``CNNConfig.layers``
like every conv, so the compiler places, costs and binds 100% of the graph
— the paper emits a hardware engine for every node, pooling included; no
wiring hides inside the model's forward function.  Pool nodes carry zero
weights (they never stream, Eq. 2 words are 0) but real activation
buffers.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional, Tuple

#: Weightless topology kinds: placed and costed like any engine, but with
#: no weight memory, no Eq. 2 traffic, and no AI-TB parallelism to balance.
POOL_KINDS = ("maxpool", "gap")


@dataclass(frozen=True)
class ConvLayerSpec:
    """One CNN graph node (conv, fc-as-conv, or pooling) as H2PIPE sees it."""

    name: str
    kind: str                 # conv | dwconv | pwconv | fc | maxpool | gap
    k_h: int
    k_w: int
    c_in: int
    c_out: int
    stride: int
    in_h: int
    in_w: int

    @property
    def is_pool(self) -> bool:
        return self.kind in POOL_KINDS

    @property
    def out_h(self) -> int:
        """SAME-padded output rows: ceil(in_h / stride) — the row count
        the kernels actually emit, so Eq. 2 analytics (words per image =
        words per row x out_h) and executed dispatch counters agree for
        every geometry, odd maps included."""
        return -(-self.in_h // self.stride)

    @property
    def out_w(self) -> int:
        return -(-self.in_w // self.stride)

    @property
    def weight_count(self) -> int:
        if self.is_pool:
            return 0                  # comparators/accumulators, no weights
        if self.kind == "dwconv":
            return self.k_h * self.k_w * self.c_in
        return self.k_h * self.k_w * self.c_in * self.c_out

    def weight_bits(self, bits: int = 8) -> int:
        return self.weight_count * bits

    @property
    def macs(self) -> int:
        """Multiply-accumulates for one image (pool nodes do comparator /
        accumulator work on the fabric, not MACs on the tensor blocks)."""
        if self.is_pool:
            return 0
        if self.kind == "dwconv":
            return self.k_h * self.k_w * self.c_in * self.out_h * self.out_w
        return (self.k_h * self.k_w * self.c_in * self.c_out
                * self.out_h * self.out_w)

    def weight_traffic_bytes(self, bits: int = 8) -> int:
        """Eq. 2 term: kernels are re-read once per output line."""
        return self.weight_bits(bits) // 8 * self.out_h

    def activation_window_bits(self, bits: int = 8) -> int:
        """On-chip activation line buffer: k_h input lines + 1 in flight,
        double-buffered (HPIPE duplicates activation buffers for Fmax).
        A GAP node needs no line window — one input row in flight plus a
        32-bit per-channel accumulator."""
        if self.kind == "gap":
            return (self.in_w * self.c_in * bits + self.c_in * 32) * 2
        lines = self.k_h + 1
        return self.in_w * self.c_in * lines * bits * 2


@dataclass(frozen=True)
class CNNConfig:
    name: str
    layers: Tuple[ConvLayerSpec, ...]
    num_classes: int = 1000

    def total_weight_bits(self, bits: int = 8) -> int:
        return sum(l.weight_bits(bits) for l in self.layers)

    def total_activation_bits(self, bits: int = 8) -> int:
        return sum(l.activation_window_bits(bits) for l in self.layers)

    def total_weight_traffic(self, bits: int = 8) -> int:
        return sum(l.weight_traffic_bytes(bits) for l in self.layers)

    def total_macs(self) -> int:
        return sum(l.macs for l in self.layers)

    def reduced(self) -> "CNNConfig":
        """Tiny CIFAR-scale variant for smoke tests: keep the topology family,
        shrink depth/channels.  Pool nodes inside the kept prefix survive
        (shapes recomputed); a GAP node is re-synthesized before the first
        fc head when the map is still spatial, so the reduced graph — like
        the full one — contains every topology op as an explicit node."""
        keep = [l for i, l in enumerate(self.layers) if i < 4 or l.kind == "fc"]
        small: List[ConvLayerSpec] = []
        h, w = 32, 32
        c_prev = 3
        for l in keep:
            if l.kind == "gap":
                continue              # re-synthesized before the fc head
            if l.kind == "maxpool":
                small.append(dataclasses.replace(
                    l, c_in=c_prev, c_out=c_prev, in_h=h, in_w=w))
                h, w = max(1, h // l.stride), max(1, w // l.stride)
                continue
            c_in = c_prev
            c_out = min(l.c_out, 16)
            if l.kind == "dwconv":
                c_out = c_in
            stride = l.stride
            k_h, k_w = l.k_h, l.k_w
            if l.kind == "fc":          # fc-as-conv runs on the pooled 1x1 map
                if h > 1 or w > 1:      # explicit GAP node feeds the head
                    small.append(_gap(c_in, h, w))
                k_h = k_w = stride = 1
                h = w = 1
            small.append(dataclasses.replace(
                l, c_in=c_in, c_out=c_out, in_h=h, in_w=w,
                k_h=k_h, k_w=k_w, stride=stride))
            c_prev = c_out
            h, w = max(1, h // stride), max(1, w // stride)
        return CNNConfig(self.name + "-reduced", tuple(small), num_classes=10)


@dataclass(frozen=True)
class ResBlockSpec:
    """One residual block as a schedulable unit: the conv chain, the
    optional pointwise downsample on the identity path, and the add+relu
    join.  H2PIPE places whole engines, not abstract layers — grouping
    the block lets the compiler bind it to a single fused engine
    (``res_block_int8``) with its own VMEM cost and Eq. 2 accounting."""

    name: str                           # "s{i}b{j}" block prefix
    convs: Tuple[ConvLayerSpec, ...]    # main-path convs, pipeline order
    ds: Optional[ConvLayerSpec]         # identity-path downsample (or None)

    @property
    def members(self) -> Tuple[ConvLayerSpec, ...]:
        """All member layers in config order (convs then downsample —
        the order the config builders emit them)."""
        return self.convs + ((self.ds,) if self.ds is not None else ())


def residual_blocks(cfg: "CNNConfig") -> Tuple[ResBlockSpec, ...]:
    """Group a ResNet-family config's layers into residual blocks, by the
    same ``s{i}b{j}c{k}`` / ``...ds`` naming walk ``cnn_forward`` wires
    the adds with — the single source of truth for block membership that
    both the model topology and the compiler's block binding share.
    Non-ResNet configs (no block structure) return ()."""
    if not cfg.name.startswith("resnet"):
        return ()
    blocks: List[ResBlockSpec] = []
    layers = list(cfg.layers)
    i = 0
    while i < len(layers):
        name = layers[i].name
        if not (name[0] == "s" and "b" in name and "c" in name):
            i += 1
            continue
        prefix = name[:name.index("c")]
        members = [layers[i]]
        j = i + 1
        while j < len(layers) and layers[j].name.startswith(prefix):
            members.append(layers[j])
            j += 1
        ds = [m for m in members if m.name.endswith("ds")]
        convs = tuple(m for m in members if not m.name.endswith("ds"))
        blocks.append(ResBlockSpec(name=prefix, convs=convs,
                                   ds=ds[0] if ds else None))
        i = j
    return tuple(blocks)


def block_shape_signature(block: ResBlockSpec) -> Tuple:
    """Name-independent shape signature of a residual block: member
    kinds, kernel/channel/stride/input geometry, conv count and
    downsample presence.  Two blocks with equal signatures run the SAME
    computation on same-shaped tensors — the compile-time condition for
    folding them into one scanned body (their weights stack along a
    leading axis; only the values differ)."""
    def sig(m: ConvLayerSpec) -> Tuple:
        return (m.kind, m.k_h, m.k_w, m.c_in, m.c_out, m.stride,
                m.in_h, m.in_w)
    return ((len(block.convs), block.ds is not None)
            + tuple(sig(m) for m in block.members))


def homogeneous_block_runs(cfg: "CNNConfig", min_run: int = 2
                           ) -> Tuple[Tuple[ResBlockSpec, ...], ...]:
    """Maximal runs of >= ``min_run`` CONSECUTIVE residual blocks (adjacent
    in ``cfg.layers``, no interleaving nodes) with identical
    :func:`block_shape_signature` — e.g. each ResNet-50 stage minus its
    stride-2 / expanding lead block.  These are the scan candidates the
    compiler turns into :class:`~repro.core.schedule.ScanGroup`\\ s; the
    dw/pw alternation of the MobileNets has no residual blocks at all, so
    they (correctly) yield zero runs."""
    blocks = residual_blocks(cfg)
    if not blocks:
        return ()
    idx = {l.name: i for i, l in enumerate(cfg.layers)}
    span = {b.name: (idx[b.members[0].name], idx[b.members[-1].name] + 1)
            for b in blocks}
    runs: List[Tuple[ResBlockSpec, ...]] = []
    cur: List[ResBlockSpec] = [blocks[0]]
    for prev, b in zip(blocks, blocks[1:]):
        if (span[prev.name][1] == span[b.name][0]
                and block_shape_signature(b) == block_shape_signature(prev)):
            cur.append(b)
        else:
            if len(cur) >= min_run:
                runs.append(tuple(cur))
            cur = [b]
    if len(cur) >= min_run:
        runs.append(tuple(cur))
    return tuple(runs)


@dataclass(frozen=True)
class StemUnitSpec:
    """The stem conv + its following maxpool as ONE schedulable unit —
    the same block-unit machinery residual blocks use, so the stem no
    longer dispatches as two separate nodes.  ``name`` is the stem
    conv's layer name (the unit dispatches at its head, like a residual
    block does at its first conv)."""

    name: str
    conv: ConvLayerSpec
    pool: ConvLayerSpec

    @property
    def members(self) -> Tuple[ConvLayerSpec, ...]:
        return (self.conv, self.pool)


def stem_unit(cfg: "CNNConfig") -> Optional[StemUnitSpec]:
    """The fusable stem unit of ``cfg``: its first two layers, when they
    are exactly a conv followed by a maxpool (the ResNet-family stem).
    Configs whose stem feeds something else (VGG's conv-conv, the
    MobileNets' conv-dwconv) have no stem unit — None."""
    if (len(cfg.layers) >= 2 and cfg.layers[0].kind == "conv"
            and cfg.layers[1].kind == "maxpool"):
        return StemUnitSpec(name=cfg.layers[0].name,
                            conv=cfg.layers[0], pool=cfg.layers[1])
    return None


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------


def _maxpool(name: str, c: int, h: int, w: int, *, k: int = 2,
             stride: int = 2) -> ConvLayerSpec:
    """Explicit maxpool node (c_out == c_in, zero weights)."""
    return ConvLayerSpec(name, "maxpool", k, k, c, c, stride, h, w)


def _gap(c: int, h: int, w: int, name: str = "gap") -> ConvLayerSpec:
    """Global-average-pool node: the whole map is the window, out is 1x1."""
    return ConvLayerSpec(name, "gap", h, w, c, c, max(h, w), h, w)


def _vgg16() -> CNNConfig:
    cfg = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
           512, 512, 512, "M", 512, 512, 512, "M"]
    layers: List[ConvLayerSpec] = []
    h = w = 224
    c_in = 3
    i = 0
    pi = 0
    for v in cfg:
        if v == "M":
            layers.append(_maxpool(f"pool{pi}", c_in, h, w))
            pi += 1
            h //= 2
            w //= 2
            continue
        layers.append(ConvLayerSpec(f"conv{i}", "conv", 3, 3, c_in, v, 1, h, w))
        c_in = v
        i += 1
    # fc layers as 1x1 convs on the pooled feature map (HPIPE style);
    # fc0 consumes the 7x7 map directly (VALID 7x7 kernel), so VGG has no
    # GAP node — the five maxpools are its whole pooling topology
    layers.append(ConvLayerSpec("fc0", "fc", 7, 7, 512, 4096, 7, 7, 7))
    layers.append(ConvLayerSpec("fc1", "fc", 1, 1, 4096, 4096, 1, 1, 1))
    layers.append(ConvLayerSpec("fc2", "fc", 1, 1, 4096, 1000, 1, 1, 1))
    return CNNConfig("vgg16", tuple(layers))


def _resnet(depth: int) -> CNNConfig:
    """ResNet-18 (basic blocks) or ResNet-50 (bottleneck blocks)."""
    layers: List[ConvLayerSpec] = []
    layers.append(ConvLayerSpec("stem", "conv", 7, 7, 3, 64, 2, 224, 224))
    layers.append(_maxpool("maxpool", 64, 112, 112, k=3))
    h = w = 56   # after stem stride-2 and 3x3 maxpool stride-2

    if depth == 18:
        stages = [(64, 2), (128, 2), (256, 2), (512, 2)]
        c_in = 64
        for si, (c, blocks) in enumerate(stages):
            for b in range(blocks):
                stride = 2 if (si > 0 and b == 0) else 1
                if stride == 2:
                    h //= 2
                    w //= 2
                layers.append(ConvLayerSpec(
                    f"s{si}b{b}c0", "conv", 3, 3, c_in, c, stride,
                    h * stride, w * stride))
                layers.append(ConvLayerSpec(
                    f"s{si}b{b}c1", "conv", 3, 3, c, c, 1, h, w))
                if stride == 2 or c_in != c:
                    layers.append(ConvLayerSpec(
                        f"s{si}b{b}ds", "pwconv", 1, 1, c_in, c, stride,
                        h * stride, w * stride))
                c_in = c
        layers.append(_gap(512, 7, 7))
        layers.append(ConvLayerSpec("fc", "fc", 1, 1, 512, 1000, 1, 1, 1))
        return CNNConfig("resnet18", tuple(layers))

    if depth == 50:
        stages = [(64, 256, 3), (128, 512, 4), (256, 1024, 6), (512, 2048, 3)]
        c_in = 64
        for si, (mid, out, blocks) in enumerate(stages):
            for b in range(blocks):
                stride = 2 if (si > 0 and b == 0) else 1
                if stride == 2:
                    h //= 2
                    w //= 2
                layers.append(ConvLayerSpec(
                    f"s{si}b{b}c0", "pwconv", 1, 1, c_in, mid, 1,
                    h * stride, w * stride))
                layers.append(ConvLayerSpec(
                    f"s{si}b{b}c1", "conv", 3, 3, mid, mid, stride,
                    h * stride, w * stride))
                layers.append(ConvLayerSpec(
                    f"s{si}b{b}c2", "pwconv", 1, 1, mid, out, 1, h, w))
                if b == 0:
                    layers.append(ConvLayerSpec(
                        f"s{si}b{b}ds", "pwconv", 1, 1, c_in, out, stride,
                        h * stride, w * stride))
                c_in = out
        layers.append(_gap(2048, 7, 7))
        layers.append(ConvLayerSpec("fc", "fc", 1, 1, 2048, 1000, 1, 1, 1))
        return CNNConfig("resnet50", tuple(layers))

    raise ValueError(f"unsupported resnet depth {depth}")


def _mobilenet_v1() -> CNNConfig:
    layers: List[ConvLayerSpec] = []
    layers.append(ConvLayerSpec("stem", "conv", 3, 3, 3, 32, 2, 224, 224))
    h = w = 112
    c_in = 32
    plan = [(64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
            (512, 1), (512, 1), (512, 1), (512, 1), (512, 1), (1024, 2),
            (1024, 1)]
    for i, (c, s) in enumerate(plan):
        layers.append(ConvLayerSpec(f"dw{i}", "dwconv", 3, 3, c_in, c_in, s, h, w))
        h, w = h // s, w // s
        layers.append(ConvLayerSpec(f"pw{i}", "pwconv", 1, 1, c_in, c, 1, h, w))
        c_in = c
    layers.append(_gap(1024, 7, 7))
    layers.append(ConvLayerSpec("fc", "fc", 1, 1, 1024, 1000, 1, 1, 1))
    return CNNConfig("mobilenetv1", tuple(layers))


def _mobilenet_v2() -> CNNConfig:
    layers: List[ConvLayerSpec] = []
    layers.append(ConvLayerSpec("stem", "conv", 3, 3, 3, 32, 2, 224, 224))
    h = w = 112
    c_in = 32
    # (expansion, c_out, n, stride)
    plan = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
            (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    i = 0
    for t, c, n, s in plan:
        for b in range(n):
            stride = s if b == 0 else 1
            mid = c_in * t
            if t != 1:
                layers.append(ConvLayerSpec(
                    f"ir{i}ex", "pwconv", 1, 1, c_in, mid, 1, h, w))
            layers.append(ConvLayerSpec(
                f"ir{i}dw", "dwconv", 3, 3, mid, mid, stride, h, w))
            h, w = h // stride, w // stride
            layers.append(ConvLayerSpec(
                f"ir{i}pj", "pwconv", 1, 1, mid, c, 1, h, w))
            c_in = c
            i += 1
    layers.append(ConvLayerSpec("head", "pwconv", 1, 1, 320, 1280, 1, 7, 7))
    layers.append(_gap(1280, 7, 7))
    layers.append(ConvLayerSpec("fc", "fc", 1, 1, 1280, 1000, 1, 1, 1))
    return CNNConfig("mobilenetv2", tuple(layers))


def _mobilenet_v3() -> CNNConfig:
    """MobileNetV3-Large (SE layers counted as pointwise convs)."""
    layers: List[ConvLayerSpec] = []
    layers.append(ConvLayerSpec("stem", "conv", 3, 3, 3, 16, 2, 224, 224))
    h = w = 112
    c_in = 16
    # (k, exp, c_out, stride)
    plan = [(3, 16, 16, 1), (3, 64, 24, 2), (3, 72, 24, 1), (5, 72, 40, 2),
            (5, 120, 40, 1), (5, 120, 40, 1), (3, 240, 80, 2), (3, 200, 80, 1),
            (3, 184, 80, 1), (3, 184, 80, 1), (3, 480, 112, 1),
            (3, 672, 112, 1), (5, 672, 160, 2), (5, 960, 160, 1),
            (5, 960, 160, 1)]
    for i, (k, exp, c, s) in enumerate(plan):
        if exp != c_in:
            layers.append(ConvLayerSpec(
                f"b{i}ex", "pwconv", 1, 1, c_in, exp, 1, h, w))
        layers.append(ConvLayerSpec(f"b{i}dw", "dwconv", k, k, exp, exp, s, h, w))
        h, w = h // s, w // s
        layers.append(ConvLayerSpec(f"b{i}pj", "pwconv", 1, 1, exp, c, 1, h, w))
        c_in = c
    layers.append(ConvLayerSpec("head0", "pwconv", 1, 1, 160, 960, 1, 7, 7))
    layers.append(_gap(960, 7, 7))
    layers.append(ConvLayerSpec("head1", "fc", 1, 1, 960, 1280, 1, 1, 1))
    layers.append(ConvLayerSpec("fc", "fc", 1, 1, 1280, 1000, 1, 1, 1))
    return CNNConfig("mobilenetv3", tuple(layers))


def mini_resnet18(hw: int = 32, width: int = 32,
                  stages: int = 2) -> CNNConfig:
    """ResNet-18-topology network sized for *executable* pipeline demos:
    small enough that the Pallas engines run in interpret mode on CPU, yet
    with multi-M20K weight buffers so Eq. 1 scores go positive and
    Algorithm 1 genuinely offloads layers to HBM (the full-size nets would
    take minutes per image under the interpreter).

    Structure mirrors ``_resnet(18)``: stride-1 3x3 stem + an explicit
    3x3/stride-2 maxpool node, ``stages`` stages (up to ResNet-18's four)
    of two basic blocks each, with stride-2 transitions and pwconv
    downsamples, then an explicit GAP node (when the final map is still
    spatial) and an fc head.  ``stages=4`` gives the full four-stage
    pipeline depth at executable scale — the shape the dispatch-overhead
    benchmark uses.
    """
    if not 1 <= stages <= 4:
        raise ValueError("mini_resnet18 supports 1..4 stages")
    if hw % 2:
        # the maxpool node emits ceil(hw/2) rows while this builder
        # floor-halves the next layer's declared in_h — reject odd hw
        # rather than desynchronize the declared graph from execution
        raise ValueError("mini_resnet18: hw must be even (the stem "
                         "maxpool halves the map)")
    layers: List[ConvLayerSpec] = []
    layers.append(ConvLayerSpec("stem", "conv", 3, 3, 3, width, 1, hw, hw))
    layers.append(_maxpool("maxpool", width, hw, hw, k=3))
    h = w = hw // 2
    c_in = width
    for si, (c, blocks) in enumerate(
            [(width * 2 ** min(s, 3), 2) for s in range(stages)]):
        for b in range(blocks):
            stride = 2 if (si > 0 and b == 0) else 1
            in_h, in_w = h, w
            if stride == 2:
                if (h > 1 and h % 2) or (w > 1 and w % 2):
                    # an odd map would make this builder's floor-halved
                    # next-layer in_h diverge from the kernels' SAME
                    # output (ceil, == ConvLayerSpec.out_h) — reject
                    # rather than desynchronize the declared graph
                    raise ValueError(
                        f"mini_resnet18: stride-2 transition on an odd "
                        f"{h}x{w} map; pick hw so maps stay even (or 1) "
                        f"through all {stages} stages")
                h, w = max(1, h // 2), max(1, w // 2)   # even or 1x1: exact
            layers.append(ConvLayerSpec(
                f"s{si}b{b}c0", "conv", 3, 3, c_in, c, stride, in_h, in_w))
            layers.append(ConvLayerSpec(
                f"s{si}b{b}c1", "conv", 3, 3, c, c, 1, h, w))
            if stride == 2 or c_in != c:
                layers.append(ConvLayerSpec(
                    f"s{si}b{b}ds", "pwconv", 1, 1, c_in, c, stride,
                    in_h, in_w))
            c_in = c
    if h > 1 or w > 1:
        layers.append(_gap(c_in, h, w))
    layers.append(ConvLayerSpec("fc", "fc", 1, 1, c_in, 10, 1, 1, 1))
    return CNNConfig("resnet18-mini", tuple(layers), num_classes=10)


def mini_resnet50(hw: int = 32, width: int = 16,
                  stages: int = 2,
                  blocks_per_stage: int = 1) -> CNNConfig:
    """ResNet-50-topology network (BOTTLENECK blocks: 1x1 -> 3x3 -> 1x1
    with 4x expansion + pwconv downsample) at executable scale — the
    config the bottleneck-fusion differential tests run end to end in
    interpret mode.  One block per stage keeps the pipeline small; the
    block structure (three convs + ds, names ``s{i}b{j}c{0,1,2}`` /
    ``s{i}b{j}ds``) is exactly ``_resnet(50)``'s, so ``residual_blocks``
    groups it identically and ``res_block_int8`` fuses it the same way.

    ``blocks_per_stage > 1`` appends identity bottleneck blocks (no
    downsample, all same-shaped) behind each stage's lead block — the
    full-size net's repeat structure at mini scale, which is what the
    scan-over-blocks compile-scaling benchmark exercises: each stage's
    ``b1..bN`` run compiles as ONE scanned body.
    """
    if not 1 <= stages <= 4:
        raise ValueError("mini_resnet50 supports 1..4 stages")
    if blocks_per_stage < 1:
        raise ValueError("mini_resnet50 needs at least one block per stage")
    if hw % 2:
        raise ValueError("mini_resnet50: hw must be even (the stem "
                         "maxpool halves the map)")
    layers: List[ConvLayerSpec] = []
    layers.append(ConvLayerSpec("stem", "conv", 3, 3, 3, width, 1, hw, hw))
    layers.append(_maxpool("maxpool", width, hw, hw, k=3))
    h = w = hw // 2
    c_in = width
    for si in range(stages):
        mid = width * 2 ** min(si, 3)
        out = 4 * mid
        for b in range(blocks_per_stage):
            stride = 2 if (si > 0 and b == 0) else 1
            in_h, in_w = h, w
            if stride == 2:
                if (h > 1 and h % 2) or (w > 1 and w % 2):
                    raise ValueError(
                        f"mini_resnet50: stride-2 transition on an odd "
                        f"{h}x{w} map; pick hw so maps stay even (or 1) "
                        f"through all {stages} stages")
                h, w = max(1, h // 2), max(1, w // 2)
            layers.append(ConvLayerSpec(
                f"s{si}b{b}c0", "pwconv", 1, 1, c_in, mid, 1, in_h, in_w))
            layers.append(ConvLayerSpec(
                f"s{si}b{b}c1", "conv", 3, 3, mid, mid, stride, in_h, in_w))
            layers.append(ConvLayerSpec(
                f"s{si}b{b}c2", "pwconv", 1, 1, mid, out, 1, h, w))
            if b == 0:
                layers.append(ConvLayerSpec(
                    f"s{si}b{b}ds", "pwconv", 1, 1, c_in, out, stride,
                    in_h, in_w))
            c_in = out
    if h > 1 or w > 1:
        layers.append(_gap(c_in, h, w))
    layers.append(ConvLayerSpec("fc", "fc", 1, 1, c_in, 10, 1, 1, 1))
    return CNNConfig("resnet50-mini", tuple(layers), num_classes=10)


def mini_mobilenet(hw: int = 8, width: int = 16,
                   blocks: int = 4) -> CNNConfig:
    """MobileNetV1-topology network at executable scale — the config
    that runs ``dwconv_int8`` end to end (compile / run / golden
    placement) in interpret mode.  Structure mirrors
    ``_mobilenet_v1()``: a 3x3 stem (stride 1 at mini scale), then
    ``blocks`` depthwise-separable pairs (``dw{i}`` 3x3 dwconv +
    ``pw{i}`` 1x1 pwconv), stride-2 on every odd-indexed pair with the
    channel count doubling there, then GAP (when the final map is still
    spatial) and an fc head.  No residual adds, so
    ``residual_blocks()`` returns () and every stage cut is legal — the
    partition balancer's no-atomic-units case.
    """
    if blocks < 1:
        raise ValueError("mini_mobilenet needs at least one dw/pw pair")
    layers: List[ConvLayerSpec] = []
    layers.append(ConvLayerSpec("stem", "conv", 3, 3, 3, width, 1, hw, hw))
    h = w = hw
    c_in = width
    for i in range(blocks):
        stride = 2 if i % 2 == 1 else 1
        c_out = c_in * 2 if stride == 2 else c_in
        if stride == 2:
            if (h > 1 and h % 2) or (w > 1 and w % 2):
                # same even-map rule as the mini resnets: a floor-halved
                # odd map would diverge from the kernels' SAME output
                raise ValueError(
                    f"mini_mobilenet: stride-2 pair dw{i} on an odd "
                    f"{h}x{w} map; pick hw so maps stay even (or 1) "
                    f"through all {blocks} pairs")
        layers.append(ConvLayerSpec(
            f"dw{i}", "dwconv", 3, 3, c_in, c_in, stride, h, w))
        if stride == 2:
            h, w = max(1, h // 2), max(1, w // 2)
        layers.append(ConvLayerSpec(
            f"pw{i}", "pwconv", 1, 1, c_in, c_out, 1, h, w))
        c_in = c_out
    if h > 1 or w > 1:
        layers.append(_gap(c_in, h, w))
    layers.append(ConvLayerSpec("fc", "fc", 1, 1, c_in, 10, 1, 1, 1))
    return CNNConfig("mobilenet-mini", tuple(layers), num_classes=10)


CNN_CONFIGS = {
    "resnet18": _resnet(18),
    "resnet50": _resnet(50),
    "vgg16": _vgg16(),
    "mobilenetv1": _mobilenet_v1(),
    "mobilenetv2": _mobilenet_v2(),
    "mobilenetv3": _mobilenet_v3(),
}


def get_cnn(name: str) -> CNNConfig:
    return CNN_CONFIGS[name]
