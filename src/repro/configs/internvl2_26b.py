"""InternVL2-26B — VLM: InternViT frontend (STUB) + InternLM2-20B backbone.
[arXiv:2404.16821; hf]

Backbone: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
Per the assignment spec the modality frontend is a stub: ``input_specs()``
provides precomputed patch embeddings [B, n_patches, d_model] which the
backbone consumes as a prefix.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    attn_kind="global",
    n_patches=256,                  # 448x448 / 28px patches after pixel-shuffle
    act="silu",
    tie_embeddings=False,
    subquadratic=False,
)
