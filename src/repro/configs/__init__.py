"""Architecture / shape registry.

``get_arch("<id>")`` accepts the public ids with dashes/dots
(e.g. ``--arch qwen2-moe-a2.7b``).
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (ArchConfig, MLAConfig, MoEConfig, ShapeConfig,
                                SSMConfig, SHAPES, reduced_shape,
                                shape_applicable)
from repro.configs.cnn import CNN_CONFIGS, CNNConfig, ConvLayerSpec, get_cnn

_ARCH_MODULES = {
    "command-r-plus-104b": "command_r_plus_104b",
    "gemma2-9b": "gemma2_9b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "qwen2-72b": "qwen2_72b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "hymba-1.5b": "hymba_1_5b",
    "internvl2-26b": "internvl2_26b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "xlstm-125m": "xlstm_125m",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_arch(name: str) -> ArchConfig:
    key = name.replace("_", "-")
    if key not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[key]}")
    return mod.CONFIG


def all_archs() -> Dict[str, ArchConfig]:
    return {k: get_arch(k) for k in ARCH_IDS}


__all__ = [
    "ArchConfig", "MoEConfig", "MLAConfig", "SSMConfig", "ShapeConfig",
    "SHAPES", "shape_applicable", "reduced_shape", "ARCH_IDS", "get_arch",
    "all_archs", "CNNConfig", "ConvLayerSpec", "CNN_CONFIGS", "get_cnn",
]
