"""AdamW with ZeRO-1 moment sharding and optional int8 gradient compression.

The optimizer is pure-functional (init/apply) so the whole train step jits
as one program and GSPMD schedules the gradient all-reduce / moment updates
together (compute-comm overlap falls out of XLA's async collectives; the
bucketing knob is the remat/scan structure of the backward pass).

ZeRO-1: each moment tensor gets the *parameter's* sharding plus an extra
``data``-axis sharding on its first evenly-divisible free dim, so optimizer
state is partitioned across the full (pod, data, model) mesh.  The update
math is unchanged — GSPMD inserts the reduce-scatter / all-gather pair.

int8 compression: symmetric per-tensor quantization with error feedback
(residual carried in the optimizer state) for the DP all-reduce — the
"gradient compression" lever of the scale checklist.  Off by default;
enabled per-config.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import axis_size

Params = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    compress_int8: bool = False
    # wire format of the gradient crossing the DP collective: bf16 halves
    # the all-reduce/reduce-scatter bytes (moments still accumulate f32).
    # Off by default (paper-faithful baseline); the optimized dry-run
    # enables it (EXPERIMENTS.md §Perf HC1-it3).
    grad_wire_bf16: bool = False


def lr_schedule(cfg: AdamWConfig, step):
    warm = cfg.lr_peak * (step + 1) / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * \
        (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params: Params, cfg: AdamWConfig) -> Dict[str, Any]:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(zeros32, params),
        "nu": jax.tree.map(zeros32, params),
    }
    if cfg.compress_int8:
        state["residual"] = jax.tree.map(zeros32, params)
    return state


def _shard_extra_dim(spec: P, shape) -> P:
    """Extend a param spec with a ``data``-axis sharding on the first free,
    evenly-divisible dim (ZeRO-1 partitioning)."""
    d_sz = axis_size("data")
    if d_sz <= 1:
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    used = {a for p in parts if p is not None
            for a in (p if isinstance(p, tuple) else (p,))}
    if "data" in used:
        return spec
    for i, (p, dim) in enumerate(zip(parts, shape)):
        if p is None and dim % d_sz == 0:
            parts[i] = "data"
            return P(*parts)
    return spec


def state_specs(params: Params, param_specs: Params,
                cfg: AdamWConfig) -> Dict[str, Any]:
    is_p = lambda x: isinstance(x, P)
    mom_specs = jax.tree.map(
        lambda spec, p: _shard_extra_dim(spec, p.shape),
        param_specs, params, is_leaf=is_p)
    specs = {"step": P(), "mu": mom_specs, "nu": mom_specs}
    if cfg.compress_int8:
        specs["residual"] = mom_specs
    return specs


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def compress_int8(g, residual):
    """Symmetric per-tensor int8 quantization with error feedback.
    Returns (quantized-float value to all-reduce, new residual)."""
    g = g.astype(jnp.float32) + residual
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127)
    deq = q * scale
    return deq, g - deq


def apply(grads: Params, state: Dict[str, Any], params: Params,
          cfg: AdamWConfig) -> Tuple[Params, Dict[str, Any], Dict[str, Any]]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    new_state = {"step": step}

    if cfg.grad_wire_bf16:
        grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)

    if cfg.compress_int8:
        pairs = jax.tree.map(compress_int8, grads, state["residual"])
        grads = jax.tree.map(lambda pr: pr[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_state["residual"] = jax.tree.map(
            lambda pr: pr[1], pairs, is_leaf=lambda x: isinstance(x, tuple))

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = lr_schedule(cfg, state["step"])
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / bc1
        nhat = nu / bc2
        step_v = mhat / (jnp.sqrt(nhat) + cfg.eps)
        newp = p.astype(jnp.float32) - lr * (
            step_v + cfg.weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    leaves = lambda i: jax.tree.map(lambda t: t[i], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    new_params = leaves(0)
    new_state["mu"] = leaves(1)
    new_state["nu"] = leaves(2)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
