"""Trip-count-aware FLOP / memory-traffic accounting from the jaxpr.

Why this exists: XLA:CPU's ``compiled.cost_analysis()`` counts a ``while``
body ONCE, ignoring trip count (verified in EXPERIMENTS.md §Dry-run), so
any model whose layers live in a ``lax.scan`` — all of ours — is
undercounted by ~n_layers.  This interpreter walks the (already
differentiated, pre-SPMD) jaxpr instead and multiplies scan bodies by
their length, giving exact dot/conv FLOPs and a standard traffic proxy
(bytes of every operand + result touched per equation).

Remat shows up naturally: the lowered jaxpr of a grad-of-checkpoint
function contains the recompute equations explicitly, so the
``useful_fraction`` metric (MODEL_FLOPS / counted FLOPs) correctly charges
recomputation.

Counts are GLOBAL (pre-partitioning); the roofline divides by chip count —
i.e. it assumes perfect partitioning, which is exactly the roofline's job.
Collective traffic is measured separately from the post-SPMD HLO (see
``analysis.collective_bytes`` + ``hlo_loops.scaled_collective_bytes``).
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import numpy as np
from jax.extend import core as jcore


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _aval_size(aval) -> int:
    try:
        return int(np.prod(aval.shape))
    except Exception:
        return 0


def _dot_flops(eqn) -> int:
    ((lc, rc), (lb, rb)) = eqn.params["dimension_numbers"]
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    batch = 1
    for d in lb:
        batch *= a.shape[d]
    contract = 1
    for d in lc:
        contract *= a.shape[d]
    m = 1
    for d in range(len(a.shape)):
        if d not in lc and d not in lb:
            m *= a.shape[d]
    n = 1
    for d in range(len(b.shape)):
        if d not in rc and d not in rb:
            n *= b.shape[d]
    return 2 * batch * m * n * contract


def _conv_flops(eqn) -> int:
    # flops = 2 * out_elems * (kh * kw * c_in_per_group); HWIO weights have
    # shape [spatial..., c_in/groups, c_out] so prod(w.shape[:-1]) is the
    # per-output-element MAC count.
    out = eqn.outvars[0].aval
    w = eqn.invars[1].aval
    return 2 * _aval_size(out) * int(np.prod(w.shape[:-1]))


class Cost:
    """``bytes`` is the FUSED traffic model: only ops that must round-trip
    HBM on a TPU (dots, convs, reductions, gathers/scatters, sorts,
    transposes, loop-carried state) count their operand/result bytes;
    elementwise/broadcast/reshape/convert ops are assumed fused into their
    producers (XLA:TPU does this).  ``bytes_unfused`` keeps the pessimistic
    every-op sum for comparison."""
    __slots__ = ("flops", "bytes", "bytes_unfused")

    def __init__(self, flops=0.0, nbytes=0.0, nbytes_unfused=0.0):
        self.flops = flops
        self.bytes = nbytes
        self.bytes_unfused = nbytes_unfused

    def __iadd__(self, o):
        self.flops += o.flops
        self.bytes += o.bytes
        self.bytes_unfused += o.bytes_unfused
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k, self.bytes_unfused * k)


def _jaxpr_cost(jaxpr) -> Cost:
    total = Cost()
    for eqn in jaxpr.eqns:
        total += _eqn_cost(eqn)
    return total


def _sub_jaxprs(params):
    for v in params.values():
        if isinstance(v, jcore.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jcore.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for u in v:
                if isinstance(u, jcore.ClosedJaxpr):
                    yield u.jaxpr
                elif isinstance(u, jcore.Jaxpr):
                    yield u


# ops whose operands/results must transit HBM even under fusion
_TRAFFIC_PRIMS = {
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "reduce_and",
    "reduce_or", "argmax", "argmin", "reduce_precision",
    "cumsum", "cumlogsumexp", "cummax", "cumprod",
    "sort", "top_k",
}
_GATHERISH = {"gather", "dynamic_slice", "take", "take_along_axis"}
_SCATTERISH = {"scatter", "scatter-add", "scatter_add", "scatter_max",
               "dynamic_update_slice"}


def _eqn_cost(eqn) -> Cost:
    prim = eqn.primitive.name
    in_bytes = sum(_aval_bytes(v.aval) for v in eqn.invars
                   if hasattr(v, "aval"))
    out_bytes = sum(_aval_bytes(v.aval) for v in eqn.outvars)
    io_bytes = in_bytes + out_bytes

    if prim == "dot_general":
        return Cost(_dot_flops(eqn), io_bytes, io_bytes)
    if prim == "conv_general_dilated":
        return Cost(_conv_flops(eqn), io_bytes, io_bytes)
    if prim == "scan":
        body = eqn.params["jaxpr"]
        inner = _jaxpr_cost(body.jaxpr if hasattr(body, "jaxpr") else body)
        return inner.scaled(eqn.params["length"])
    if prim == "while":
        body = eqn.params["body_jaxpr"]
        inner = _jaxpr_cost(body.jaxpr if hasattr(body, "jaxpr") else body)
        return inner.scaled(1)                 # unknown trip count: floor
    if prim == "cond":
        branches = eqn.params["branches"]
        costs = [_jaxpr_cost(b.jaxpr if hasattr(b, "jaxpr") else b)
                 for b in branches]
        worst = max(costs, key=lambda c: c.flops) if costs else Cost()
        return worst
    if prim == "pallas_call":
        # a Pallas kernel is ONE fused op: its HBM traffic is its operands
        # + results (VMEM scratch never round-trips), and its FLOPs are the
        # kernel body's, times the grid size
        import numpy as _np
        body = eqn.params.get("jaxpr")
        gm = eqn.params.get("grid_mapping")
        grid = getattr(gm, "grid", ()) if gm is not None else ()
        trips = int(_np.prod([g for g in grid if isinstance(g, int)])) \
            if grid else 1
        inner = _jaxpr_cost(body.jaxpr if hasattr(body, "jaxpr") else body) \
            if body is not None else Cost()
        return Cost(inner.flops * trips, float(io_bytes), float(io_bytes))
    if "shard_map" in prim:
        # the body jaxpr carries PER-SHARD shapes and every device runs
        # it: total cost = body x mesh size.  Replicated work inside a
        # region is thus charged for real (exposing replication waste).
        mesh = eqn.params.get("mesh")
        n = 1
        try:
            n = int(np.prod(list(dict(getattr(mesh, "shape", {})).values()))) \
                or 1
        except Exception:
            n = getattr(getattr(mesh, "devices", None), "size", 1) or 1
        total = Cost()
        for s in _sub_jaxprs(eqn.params):
            total += _jaxpr_cost(s)
        return total.scaled(n)
    # structural wrappers: recurse
    subs = list(_sub_jaxprs(eqn.params))
    if subs:
        total = Cost()
        for s in subs:
            total += _jaxpr_cost(s)
        return total

    out_elems = sum(_aval_size(v.aval) for v in eqn.outvars)
    if prim in _TRAFFIC_PRIMS or prim.startswith("reduce_"):
        return Cost(float(out_elems), float(io_bytes), float(io_bytes))
    if prim in _GATHERISH:
        t = 2.0 * out_bytes                    # read gathered + write
        return Cost(float(out_elems), t, float(io_bytes))
    if prim in _SCATTERISH:
        upd = (_aval_bytes(eqn.invars[1].aval)
               if len(eqn.invars) > 1 and hasattr(eqn.invars[1], "aval")
               else out_bytes)
        t = 2.0 * upd                          # in-place update traffic
        return Cost(float(out_elems), t, float(io_bytes))
    if prim == "transpose":
        return Cost(0.0, 2.0 * out_bytes, float(io_bytes))
    # elementwise / broadcast / reshape / convert: fused (no HBM traffic)
    return Cost(float(out_elems), 0.0, float(io_bytes))


def cost_of(fn, *args, **kwargs) -> Dict[str, float]:
    """Global FLOPs and traffic-bytes of ``fn(*args)`` (abstract args OK)."""
    closed = jax.make_jaxpr(fn, **kwargs)(*args)
    c = _jaxpr_cost(closed.jaxpr)
    return {"flops": c.flops, "bytes": c.bytes}
