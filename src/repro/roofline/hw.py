"""TPU v5e-class hardware constants for the roofline analysis."""
from __future__ import annotations

PEAK_FLOPS_BF16 = 197e12        # per chip, bf16
PEAK_FLOPS_INT8 = 394e12        # per chip, int8
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW_PER_LINK = 50e9          # bytes/s per link (~)
ICI_LINKS = 4                   # torus links used concurrently (2D)
VMEM_BYTES = 128 * 2**20
HBM_BYTES = 16 * 2**30

MXU_DIM = 128                   # systolic array edge; align matmul dims
