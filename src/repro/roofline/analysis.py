"""Three-term roofline from a compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

``cost_analysis()`` supplies FLOPs and bytes accessed.  Collective bytes
are NOT in cost_analysis: we parse the (post-SPMD) HLO text and sum the
result-shape sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute.  The parser also returns per-op counts so
the perf loop can see WHICH collective grew or vanished between iterations.
"""
from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.roofline import hw

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g.  bf16[64,4096,512]{2,1,0}   or  f32[] (scalar)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind over an HLO module.

    HLO lines look like:
      %all-gather.3 = bf16[8,4096,1024]{...} all-gather(%param.5), ...
    Tuple-shaped results ((bf16[..], bf16[..])) are summed element-wise.
    ``-start`` variants (async collectives) are counted; their ``-done``
    twins are skipped to avoid double counting.
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        eq = s.find(" = ")
        if eq < 0:
            continue
        rhs = s[eq + 3:]
        m = re.match(r"(?:\([^=]*?\)|\S+)\s+([\w-]+)", rhs)
        if not m:
            continue
        op = m.group(1)
        base = op.replace("-start", "")
        if base not in _COLLECTIVES or op.endswith("-done"):
            continue
        shape_part = rhs[:rhs.find(base)]
        nbytes = _shape_bytes(shape_part)
        if op.endswith("-start") and base == "collective-permute":
            # cp-start result tuple repeats in/out buffers; halve
            nbytes //= 2
        out[base] += nbytes
        out["count"] += 1
    return out


@dataclass
class Roofline:
    """Cost provenance (calibrated for this container, see EXPERIMENTS.md
    §Dry-run): XLA:CPU's ``compiled.cost_analysis()`` is per-device AND
    counts while-loop bodies once (ignoring scan trip counts), so it badly
    undercounts scanned-layer models.  We therefore take

      hlo_flops / hlo_bytes  from the trip-count-aware jaxpr interpreter
                             (``jaxpr_cost`` — GLOBAL, pre-partitioning);
      coll_bytes             from the post-SPMD HLO text with while-body
                             trip-count scaling (``hlo_loops`` —
                             PER-DEVICE shapes).

    Terms: compute = flops/(chips*peak); memory = bytes/(chips*HBM_bw);
    collective = coll_bytes/(links*link_bw).  model_flops is the global
    6·N·D (train) / 2·N·D (inference) figure."""
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float               # global (jaxpr)
    hlo_bytes: float               # global (jaxpr)
    coll_bytes: float              # per device (HLO, loop-scaled)
    model_flops: float             # global
    coll_detail: Dict[str, int] = field(default_factory=dict)
    bytes_per_device: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * hw.PEAK_FLOPS_BF16)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * hw.HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (hw.ICI_BW_PER_LINK * hw.ICI_LINKS)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Step time lower bound if the dominant term fully overlaps the
        others (the roofline)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / counted FLOPs — how much compiled compute is
        useful (catches remat recompute and dispatch waste)."""
        if not self.hlo_flops:
            return 0.0
        return self.model_flops / self.hlo_flops

    @property
    def mfu_at_bound(self) -> float:
        """Model FLOPs utilization IF the program ran exactly at the
        dominant-term bound — the roofline fraction §Perf reports."""
        if not self.t_bound:
            return 0.0
        return (self.model_flops / self.chips) / (
            self.t_bound * hw.PEAK_FLOPS_BF16)

    def row(self) -> Dict[str, object]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "model_flops": self.model_flops, "hlo_flops": self.hlo_flops,
            "useful_frac": self.useful_fraction,
            "mfu_at_bound": self.mfu_at_bound,
            "bytes_per_device": self.bytes_per_device,
        }


def analyze(compiled, *, arch: str, shape: str, mesh_name: str, chips: int,
            model_flops: float, global_flops: Optional[float] = None,
            global_bytes: Optional[float] = None,
            hlo_text: Optional[str] = None) -> Roofline:
    """Build a Roofline from a compiled executable.

    global_flops/global_bytes: trip-count-aware jaxpr costs (preferred).
    Falls back to cost_analysis() x chips when absent (undercounts scans —
    only for quick probes)."""
    from repro.roofline.hlo_loops import scaled_collective_bytes
    if global_flops is None or global_bytes is None:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        global_flops = global_flops or float(ca.get("flops", 0.0)) * chips
        global_bytes = global_bytes or \
            float(ca.get("bytes accessed", 0.0)) * chips
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    scaled = scaled_collective_bytes(text)
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = float(getattr(ma, "temp_size_in_bytes", 0) +
                    getattr(ma, "argument_size_in_bytes", 0) +
                    getattr(ma, "output_size_in_bytes", 0))
    except Exception:
        pass
    coll["naive_module_sum"] = int(scaled["naive"])
    return Roofline(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                    hlo_flops=float(global_flops),
                    hlo_bytes=float(global_bytes),
                    coll_bytes=float(scaled["scaled"]),
                    model_flops=model_flops,
                    coll_detail=coll, bytes_per_device=mem)
