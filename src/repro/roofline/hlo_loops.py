"""Trip-count-aware collective accounting from post-SPMD HLO text.

``analysis.collective_bytes`` sums collective result bytes over the whole
module — but a collective inside a ``while`` body (every per-layer
all-gather of a dp-streamed weight, every MoE all-to-all: our layers live
in a scanned loop) executes trip-count times.  This parser:

  1. splits the HLO module into named computations;
  2. finds every ``while`` op, its body/condition computations;
  3. extracts the trip count from the condition's compare-with-constant
     (scan lowers to a counted loop — the constant is the length);
  4. multiplies collective bytes found in a body by its trip count,
     handling nesting by propagating multipliers through the call graph
     (while bodies, fusion calls and plain calls).

Falls back to multiplier 1 when a trip count cannot be recovered, so the
result is always >= the naive module-wide sum.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

from repro.roofline.analysis import _COLLECTIVES, _shape_bytes

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\)[^\n]*?condition=%?([\w\.\-]+)[^\n]*?body=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"=\s*s(?:32|64)\[\]\s*constant\((\d+)\)")
_CMP_RE = re.compile(r"compare\([^)]*\)")


def split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        s = line.rstrip()
        m = _COMP_HDR.match(s.strip()) if s and not s.startswith(" ") else None
        if m and s.strip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            if s.strip() == "}":
                cur = None
            else:
                comps[cur].append(s)
    return comps


TRIP_CAP = 8192     # legitimate program loops (layer scans, microbatches,
# loss chunks, attention blocks, MoE groups) are all <= ~1k; anything
# larger is an interpreted-Pallas grid loop whose inner collectives are
# GSPMD partitioning artifacts, so the multiplier is clamped.


def _trip_count(cond_lines: List[str]) -> int:
    """Counted loops compare the induction var against a constant; take the
    largest integer constant in the condition as the trip count (clamped
    to TRIP_CAP, see above)."""
    consts = []
    for line in cond_lines:
        m = _CONST_RE.search(line)
        if m:
            consts.append(int(m.group(1)))
    return min(max(consts), TRIP_CAP) if consts else 1


def _direct_collective_bytes(lines: List[str]) -> int:
    total = 0
    for line in lines:
        s = line.strip()
        eq = s.find(" = ")
        if eq < 0:
            continue
        rhs = s[eq + 3:]
        m = re.match(r"(?:\([^=]*?\)|\S+)\s+([\w-]+)", rhs)
        if not m:
            continue
        op = m.group(1)
        base = op.replace("-start", "")
        if base not in _COLLECTIVES or op.endswith("-done"):
            continue
        nbytes = _shape_bytes(rhs[:rhs.find(base)])
        if op.endswith("-start") and base == "collective-permute":
            nbytes //= 2
        total += nbytes
    return total


def scaled_collective_bytes(hlo: str) -> Dict[str, float]:
    """Collective bytes with while-body trip-count multipliers applied."""
    comps = split_computations(hlo)
    # map: computation -> list of (callee, multiplier)
    while_edges: Dict[str, List[Tuple[str, int]]] = {c: [] for c in comps}
    call_edges: Dict[str, List[str]] = {c: [] for c in comps}
    for name, lines in comps.items():
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                tc = _trip_count(comps.get(cond, []))
                while_edges[name].append((body, tc))
            else:
                cm = _CALL_RE.search(line)
                if cm and cm.group(1) in comps:
                    call_edges[name].append(cm.group(1))

    memo: Dict[str, float] = {}

    def total_bytes(comp: str, depth=0) -> float:
        if comp not in comps:
            return 0.0
        if comp in memo or depth > 50:
            return memo.get(comp, 0.0)
        memo[comp] = 0.0                       # cycle guard
        t = float(_direct_collective_bytes(comps[comp]))
        for body, tc in while_edges.get(comp, []):
            t += tc * total_bytes(body, depth + 1)
        for callee in call_edges.get(comp, []):
            t += total_bytes(callee, depth + 1)
        memo[comp] = t
        return t

    entry = None
    for name in comps:
        if "main" in name or entry is None:
            entry = name if ("main" in name or entry is None) else entry
    # prefer the ENTRY computation: HLO text marks it; approximate by the
    # computation that is not called by anyone
    called = {b for es in while_edges.values() for b, _ in es}
    called |= {c for es in call_edges.values() for c in es}
    roots = [c for c in comps if c not in called]
    best = max((total_bytes(r) for r in roots), default=0.0)
    naive = float(sum(_direct_collective_bytes(l) for l in comps.values()))
    return {"scaled": max(best, naive), "naive": naive}
