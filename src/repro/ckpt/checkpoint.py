"""Fault-tolerant checkpointing: atomic, async, keep-N, elastic reshard.

Durability contract:
  * a checkpoint directory becomes visible only via atomic rename, so a
    crash mid-save can never corrupt the latest restorable state;
  * ``restore_latest`` walks checkpoints newest-first, skipping any that
    fail integrity verification (truncated files, missing leaves);
  * saves run on a background thread (training never blocks on IO);
  * leaves are stored host-side as .npy with a manifest of the pytree
    structure, so a checkpoint written under one mesh can be re-sharded
    onto ANY new mesh/topology at load (elastic scaling) — ``device_put``
    against the new NamedSharding does the scatter.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

MANIFEST = "manifest.json"
COMMIT = "COMMITTED"


def _flat(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


def save(path: str, step: int, tree, *, keep_n: int = 3) -> str:
    """Synchronous atomic save.  Returns the committed directory."""
    os.makedirs(path, exist_ok=True)
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    names = []
    for i, (keypath, leaf) in enumerate(_flat(tree)):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
        names.append({"key": keypath, "file": f"leaf_{i:05d}.npy",
                      "dtype": str(arr.dtype), "shape": list(arr.shape)})
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump({"step": step, "leaves": names,
                   "time": time.time()}, f)
    with open(os.path.join(tmp, COMMIT), "w") as f:
        f.write(str(step))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                     # atomic commit
    _gc(path, keep_n)
    return final


class AsyncCheckpointer:
    """Background-thread saver: snapshot to host memory synchronously
    (cheap), write to disk off-thread.  ``wait()`` joins pending saves."""

    def __init__(self, path: str, keep_n: int = 3):
        self.path = path
        self.keep_n = keep_n
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, tree) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self.wait()

        def work():
            try:
                save(self.path, step, host_tree, keep_n=self.keep_n)
            except BaseException as e:       # surfaced via last_error
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err


def _gc(path: str, keep_n: int) -> None:
    steps = sorted(d for d in os.listdir(path)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep_n]:
        shutil.rmtree(os.path.join(path, d), ignore_errors=True)


def _verify(d: str) -> bool:
    if not os.path.exists(os.path.join(d, COMMIT)):
        return False
    try:
        with open(os.path.join(d, MANIFEST)) as f:
            man = json.load(f)
        for leaf in man["leaves"]:
            p = os.path.join(d, leaf["file"])
            if not os.path.exists(p):
                return False
            a = np.load(p, mmap_mode="r")
            if list(a.shape) != leaf["shape"]:
                return False
        return True
    except Exception:
        return False


def available_steps(path: str) -> List[int]:
    if not os.path.isdir(path):
        return []
    out = []
    for d in sorted(os.listdir(path)):
        if d.startswith("step_") and not d.endswith(".tmp") and \
                _verify(os.path.join(path, d)):
            out.append(int(d.split("_")[1]))
    return out


def restore_latest(path: str, like_tree, *,
                   shardings=None) -> Optional[Tuple[int, Any]]:
    """Restore the newest verifiable checkpoint into the structure of
    ``like_tree``.  ``shardings``: matching pytree of NamedShardings (or
    None) — this is the elastic-rescale hook: pass the NEW mesh's
    shardings and the host arrays are scattered accordingly."""
    for step in sorted(available_steps(path), reverse=True):
        d = os.path.join(path, f"step_{step:08d}")
        try:
            with open(os.path.join(d, MANIFEST)) as f:
                man = json.load(f)
            arrays = [np.load(os.path.join(d, leaf["file"]))
                      for leaf in man["leaves"]]
            treedef = jax.tree_util.tree_structure(like_tree)
            if treedef.num_leaves != len(arrays):
                continue
            tree = jax.tree_util.tree_unflatten(treedef, arrays)
            if shardings is not None:
                tree = jax.tree.map(
                    lambda a, s, ref: jax.device_put(
                        np.asarray(a).astype(ref.dtype), s),
                    tree, shardings, like_tree)
            else:
                tree = jax.tree.map(
                    lambda a, ref: jnp.asarray(
                        np.asarray(a).astype(ref.dtype)),
                    tree, like_tree)
            return step, tree
        except Exception:
            continue                          # corrupt -> try older
    return None
