"""The single requantization epilogue every int8 layer engine shares.

HPIPE's layer contract (models/cnn.py): int32 conv/matmul accumulator ->
per-output-channel dequant + bias -> optional relu -> requantize to int8
for the next engine.  Bit-identity between the functional reference, the
Pallas conv engines, and the fc matmul path depends on all of them running
THIS function (inside their own jit) — round-to-nearest ties flip if the
float ops are duplicated and drift apart.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def requant_epilogue(y, w_scale, bias, act_scale: float = 0.05,
                     relu: bool = True):
    """y: int32 accumulator [..., C_out].  Returns (int8 requantized,
    float32 pre-quant activations)."""
    y = y.astype(jnp.float32) * (w_scale * act_scale) + bias
    if relu:
        y = jax.nn.relu(y)
    y_q = jnp.clip(jnp.round(y / act_scale), -127, 127).astype(jnp.int8)
    return y_q, y
