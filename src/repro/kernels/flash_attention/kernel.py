"""Blockwise (flash) attention Pallas TPU kernel.

Online-softmax over KV blocks with f32 running stats in VMEM scratch.
Supports causal masking, sliding windows (gemma2 local layers, hymba),
logit soft-capping (gemma2) and GQA (kv-head sharing) — the feature set the
assigned archs need.  Block shapes are MXU-aligned (multiples of 128 in the
S dims whenever the sequence allows).

The paper connection: attention is the *activation-side* consumer in the
H2PIPE analogy — K/V blocks stream through VMEM exactly like the line
buffer holds the k_h rows in flight, while the weight path (stream_matmul)
handles the big deterministic tier.

Layout: q [B,H,Sq,hd]; k/v [B,KV,Sk,hd].  Grid (B, H, nq, nk), k innermost
(sequential); scratch (acc, m, l) persists across the k sweep.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref,
                  l_ref, *, bq: int, bk: int, nk: int, causal: bool,
                  window: int, softcap: float, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _reset():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0]                                    # [bq, hd]
    k = k_ref[0, 0]                                    # [bk, hd]
    v = v_ref[0, 0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.max(s, axis=-1)[:, None]               # [bq,1]
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)[:, None]
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _store():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_ref[...] + jnp.log(denom))[:, 0]


def flash_attention_kernel(q, k, v, *, causal: bool = True, window: int = 0,
                           softcap: float = 0.0, bq: int = 128,
                           bk: int = 128, interpret: bool = False,
                           return_lse: bool = False):
    """q: [B,H,Sq,hd]; k,v: [B,KV,Sk,hd] -> [B,H,Sq,hd] (and lse if
    requested — needed by the backward kernels)."""
    B, H, Sq, hd = q.shape
    _, KV, Sk, _ = k.shape
    hd_v = v.shape[-1]                   # may differ from hd (MLA: 192/128)
    assert H % KV == 0
    rep = H // KV
    bq, bk = min(bq, Sq), min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    nq, nk = Sq // bq, Sk // bk
    scale = 1.0 / math.sqrt(hd)
    grid = (B, H, nq, nk)
    o, lse = pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, nk=nk, causal=causal,
                          window=window, softcap=softcap, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki: (b, h // rep, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd_v),
                         lambda b, h, qi, ki: (b, h // rep, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, hd_v),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, qi, ki: (b, h, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sq, hd_v), q.dtype),
            jax.ShapeDtypeStruct((B, H, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, hd_v), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(q, k, v)
    return (o, lse) if return_lse else o


# ---------------------------------------------------------------------------
# backward kernels (flash-backward: recompute block scores, accumulate)
# ---------------------------------------------------------------------------


def _mask_and_scores(q, kb, q_pos, k_pos, *, causal, window, softcap, scale):
    s_raw = jnp.dot(q, kb.T, preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s_raw / softcap) * softcap
        dcap = 1.0 - (s / softcap) ** 2          # d s / d s_raw
    else:
        s, dcap = s_raw, None
    mask = jnp.ones(s.shape, jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= q_pos - k_pos < window
    return jnp.where(mask, s, NEG_INF), mask, dcap


def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                         dq_ref, acc_ref, *, bq, bk, nk, causal, window,
                         softcap, scale):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)
    kb = k_ref[0, 0].astype(jnp.float32)
    vb = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    s, mask, dcap = _mask_and_scores(q, kb, q_pos, k_pos, causal=causal,
                                     window=window, softcap=softcap,
                                     scale=scale)
    p = jnp.where(mask, jnp.exp(s - lse_ref[0, 0][:, None]), 0.0)
    dp = jnp.dot(do, vb.T, preferred_element_type=jnp.float32)
    ds = p * (dp - delta_ref[0, 0][:, None])
    if softcap:
        ds = ds * dcap
    acc_ref[...] += jnp.dot(ds, kb, preferred_element_type=jnp.float32) \
        * scale

    @pl.when(ki == nk - 1)
    def _store():
        dq_ref[0, 0] = acc_ref[...].astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                          dk_ref, dv_ref, dk_acc, dv_acc, *, bq, bk, nq,
                          causal, window, softcap, scale):
    ki = pl.program_id(2)
    qi = pl.program_id(3)

    @pl.when(qi == 0)
    def _():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0, 0].astype(jnp.float32)
    kb = k_ref[0, 0].astype(jnp.float32)
    vb = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    s, mask, dcap = _mask_and_scores(q, kb, q_pos, k_pos, causal=causal,
                                     window=window, softcap=softcap,
                                     scale=scale)
    p = jnp.where(mask, jnp.exp(s - lse_ref[0, 0][:, None]), 0.0)
    dp = jnp.dot(do, vb.T, preferred_element_type=jnp.float32)
    ds = p * (dp - delta_ref[0, 0][:, None])
    if softcap:
        ds = ds * dcap
    dk_acc[...] += jnp.dot(ds.T, q, preferred_element_type=jnp.float32) \
        * scale
    dv_acc[...] += jnp.dot(p.T, do, preferred_element_type=jnp.float32)

    @pl.when(qi == nq - 1)
    def _store():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, o, lse, do, *, causal, window, softcap,
                        bq: int = 128, bk: int = 128,
                        interpret: bool = False):
    """dq, dk, dv for the flash kernel.  k/v enter repeated to H heads
    (GQA folding happens in the custom_vjp wrapper)."""
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    hd_v = v.shape[-1]
    bq, bk = min(bq, Sq), min(bk, Sk)
    nq, nk = Sq // bq, Sk // bk
    scale = 1.0 / math.sqrt(hd)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    common = dict(causal=causal, window=window, softcap=softcap, scale=scale,
                  bq=bq, bk=bk)
    q_spec = pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0))
    o_spec = pl.BlockSpec((1, 1, bq, hd_v),
                          lambda b, h, qi, ki: (b, h, qi, 0))
    kv_spec = pl.BlockSpec((1, 1, bk, hd), lambda b, h, qi, ki: (b, h, ki, 0))
    v_spec = pl.BlockSpec((1, 1, bk, hd_v),
                          lambda b, h, qi, ki: (b, h, ki, 0))
    lse_spec = pl.BlockSpec((1, 1, bq), lambda b, h, qi, ki: (b, h, qi))
    dq = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, nk=nk, **common),
        grid=(B, H, nq, nk),
        in_specs=[q_spec, kv_spec, v_spec, o_spec, lse_spec, lse_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, hd), jnp.float32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(q, k, v, do, lse, delta)

    q_spec2 = pl.BlockSpec((1, 1, bq, hd), lambda b, h, ki, qi: (b, h, qi, 0))
    o_spec2 = pl.BlockSpec((1, 1, bq, hd_v),
                           lambda b, h, ki, qi: (b, h, qi, 0))
    kv_spec2 = pl.BlockSpec((1, 1, bk, hd),
                            lambda b, h, ki, qi: (b, h, ki, 0))
    v_spec2 = pl.BlockSpec((1, 1, bk, hd_v),
                           lambda b, h, ki, qi: (b, h, ki, 0))
    lse_spec2 = pl.BlockSpec((1, 1, bq), lambda b, h, ki, qi: (b, h, qi))
    dk, dv = pl.pallas_call(
        functools.partial(_flash_bwd_dkv_kernel, nq=nq, **common),
        grid=(B, H, nk, nq),
        in_specs=[q_spec2, kv_spec2, v_spec2, o_spec2, lse_spec2,
                  lse_spec2],
        out_specs=[kv_spec2, v_spec2],
        out_shape=[jax.ShapeDtypeStruct(k.shape, k.dtype),
                   jax.ShapeDtypeStruct(v.shape, v.dtype)],
        scratch_shapes=[pltpu.VMEM((bk, hd), jnp.float32),
                        pltpu.VMEM((bk, hd_v), jnp.float32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
