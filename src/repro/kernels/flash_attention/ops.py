"""Jit'd wrappers accepting the model's [B,S,H,hd] layout.

``flash_attention``       forward only (serving / tests)
``flash_attention_vjp``   differentiable (custom_vjp with the flash
                          backward kernels) — what the training path uses
                          when kernels are enabled
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (flash_attention_bwd,
                                                  flash_attention_kernel)
from repro.kernels.flash_attention.ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, bq: int = 128, bk: int = 128,
                    interpret: bool = False):
    """q: [B,Sq,H,hd]; k,v: [B,Sk,KV,hd] -> [B,Sq,H,hd] (model layout)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_kernel(qt, kt, vt, causal=causal, window=window,
                                 softcap=softcap, bq=bq, bk=bk,
                                 interpret=interpret)
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention_vjp(q, k, v, causal, window, softcap, bq, bk,
                        interpret):
    """Differentiable flash attention, model layout [B,S,H,hd] /
    [B,S,KV,hd].  GQA: k/v repeat to H in fwd; dk/dv sum back per group."""
    o, _ = _fwd_impl(q, k, v, causal, window, softcap, bq, bk, interpret)
    return o


def _fwd_impl(q, k, v, causal, window, softcap, bq, bk, interpret):
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o, lse = flash_attention_kernel(qt, kt, vt, causal=causal,
                                    window=window, softcap=softcap, bq=bq,
                                    bk=bk, interpret=interpret,
                                    return_lse=True)
    return o.transpose(0, 2, 1, 3), (q, k, v, o, lse)


def _fwd_rule(q, k, v, causal, window, softcap, bq, bk, interpret):
    out, res = _fwd_impl(q, k, v, causal, window, softcap, bq, bk,
                         interpret)
    return out, res


def _bwd_rule(causal, window, softcap, bq, bk, interpret, res, g):
    q, k, v, o, lse = res
    B, S, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    qt = q.transpose(0, 2, 1, 3)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1)
    dot = g.transpose(0, 2, 1, 3)
    dq, dk, dv = flash_attention_bwd(qt, kt, vt, o, lse, dot,
                                     causal=causal, window=window,
                                     softcap=softcap, bq=bq, bk=bk,
                                     interpret=interpret)
    dq = dq.transpose(0, 2, 1, 3).astype(q.dtype)
    # GQA: sum grouped-head grads back to the KV heads
    hd_v = v.shape[-1]
    dk = dk.reshape(B, KV, rep, S, hd).sum(2).transpose(0, 2, 1, 3)
    dv = dv.reshape(B, KV, rep, S, hd_v).sum(2).transpose(0, 2, 1, 3)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_vjp.defvjp(_fwd_rule, _bwd_rule)
