"""Pure-jnp oracle: direct (materialized) softmax attention."""
from __future__ import annotations

import math

import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0):
    """q: [B,H,Sq,hd]; k,v: [B,KV,Sk,hd] -> [B,H,Sq,hd].  O(S^2) memory —
    oracle only."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    rep = H // KV
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - jnp.max(s, -1, keepdims=True))
    p = jnp.where(mask, p, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", p / jnp.maximum(
        jnp.sum(p, -1, keepdims=True), 1e-30), v.astype(jnp.float32))
    return out.astype(q.dtype)
